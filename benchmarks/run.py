"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Prints ``name,us_per_call,derived`` CSV rows covering:
  * the paper's Figures 5-10 (HTAP throughput/abort benchmarks),
  * the measured multinode RSS-construction overhead (paper: ~10%),
  * kernel micro-benchmarks (CPU ref timing + TPU roofline),
  * the scan-vs-fused-agg executor sweep (host decode eliminated),
  * RSS freshness-lag characterization (beyond-paper),
  * materialized-aggregate serve cost, O(delta) vs O(table)
    (benchmarks.bench_materialized),
  * serve-path p50/p95/p99 latency per plan kind + stage breakdown and
    the observability-overhead bound (benchmarks.bench_serve_latency),
  * session serving at scale: token routing + resolve cache + dedup
    batching matrix (benchmarks.bench_sessions),
  * the roofline summary when dry-run artifacts exist.

``--smoke`` exercises every bench entry point at tiny scale (CI: the
entry points must not rot) WITHOUT touching BENCH_kernels.json — the
persisted perf trajectory only records full-scale runs.
"""

from __future__ import annotations

import argparse
import time


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    fig_rounds = 300 if smoke else 3000
    ov_rounds = 250 if smoke else 2500

    # ---------------------------------------------------- paper figures
    from . import paper_figures as pf
    t0 = time.perf_counter()
    rows = pf.fig_5_6_7(rounds=fig_rounds)
    dt = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for fig, mode, x, tps, qps, oab, aab, waits in rows:
        print(f"{fig}:{mode}:x={x},{dt:.0f},"
              f"oltp_tps={tps:.4f};olap_qps={qps:.5f};"
              f"oltp_abort={oab:.3f};olap_abort={aab:.3f};waits={waits}")
    t0 = time.perf_counter()
    rows = pf.fig_8_9_10(rounds=fig_rounds)
    dt = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for fig, mode, x, tps, qps, oab, aab, extra in rows:
        print(f"{fig}:{mode}:x={x},{dt:.0f},"
              f"oltp_tps={tps:.4f};olap_qps={qps:.5f};"
              f"oltp_abort={oab:.3f};extra={extra}")

    ov = pf.rss_construction_overhead(rounds=ov_rounds)
    print(f"multinode_rss_oltp_overhead,0,"
          f"{ov['oltp_overhead_pct']:.1f}%_vs_ssi+si")
    print(f"multinode_rss_olap_overhead,0,"
          f"{ov['olap_overhead_pct']:.1f}%_vs_ssi+si")
    for msg in pf.headline_checks(pf.fig_5_6_7(rounds=ov_rounds)):
        print(f"headline,0,{msg.replace(',', ';')}")

    # -------------------------------------------------------- freshness
    from .bench_freshness import (construct_cost_sweep, freshness_sweep,
                                  print_replica_lag_rows, replica_lag_sweep,
                                  scan_path_report)
    for name, us, derived in freshness_sweep():
        print(f"{name},{us:.1f},{derived}")

    # -------------------------------------------- replica-cluster routing
    lag_report = replica_lag_sweep(rounds=150 if smoke else 1000)
    print_replica_lag_rows(lag_report)

    # ------------------------------------------- RSS construction cost
    construct_report = construct_cost_sweep(
        history_lengths=(500, 1000) if smoke else (1000, 2000, 4000, 8000))
    for n, us in construct_report["incremental_us"].items():
        print(f"rss_construct:incremental:n={n},{us},per_round")
    for n, us in construct_report["batch_us"].items():
        print(f"rss_construct:batch:n={n},{us},per_round")
    print(f"rss_construct:growth,0,"
          f"batch=x{construct_report['batch_growth']};"
          f"incremental=x{construct_report['incremental_growth']}")

    # ------------------------------------------------ OLAP scan path
    scan_report = scan_path_report(rounds=300 if smoke else 2000)
    for mode in ("per_key", "scan"):
        r = scan_report[mode]
        print(f"olap_path:{mode},{r['wall_s'] * 1e6:.0f},"
              f"olap_commits={r['olap_commits']}")
    print(f"olap_path:speedup,0,"
          f"x{scan_report['olap_throughput_speedup']}_olap_commits")

    # ---------------------------------------------------------- kernels
    from .bench_kernels import (all_benches, gather_kernels_report,
                                group_agg_report, plan_batch_report,
                                scan_agg_report)
    for name, us, derived in all_benches():
        print(f"{name},{us:.1f},{derived}")

    # ------------------------------------- fused executor (scan vs agg)
    agg_report = scan_agg_report(
        page_counts=(256, 1024) if smoke else (1024, 4096, 16384),
        iters=2 if smoke else 5)
    for P, r in agg_report["sweep"].items():
        print(f"scan_agg:P={P},{r['fused_agg_us']},"
              f"host_decode={r['scan_host_decode_us']}us;"
              f"speedup=x{r['speedup']}")
    print(f"scan_agg:headline,0,fused=x{agg_report['headline_speedup']}"
          f"_vs_host_decode_at_P={agg_report['headline_pages']}")

    # --------------- grouped executor (strategy × groups × pages sweep)
    # smoke shapes dispatch to all three modes: (32,G) -> host,
    # (256,4) -> flat, (256,64) -> chunked
    group_report = group_agg_report(
        page_counts=(32, 256) if smoke else (1024, 4096),
        groups=(4, 64) if smoke else (4, 16, 64, 256),
        iters=2 if smoke else 5)
    for shape, r in group_report["sweep"].items():
        print(f"group_agg:{shape},{r['chunked_us']},"
              f"host_groupby={r['scan_host_groupby_us']}us;"
              f"flat={r['flat_us']}us;mode={r['mode']};"
              f"speedup_flat=x{r['speedup_flat']};"
              f"speedup_chunked=x{r['speedup_chunked']}")
    print(f"group_agg:headline,0,"
          f"chunked=x{group_report['headline_speedup']}"
          f"_vs_host_groupby_at_{group_report['headline_shape']};"
          f"decay={group_report['chunked_decay_pct_across_groups']}%")

    # ----------------------- whole-batch plan fusion (batch-size sweep)
    batch_report = plan_batch_report(
        batch_sizes=(1, 2, 4) if smoke else (1, 2, 4, 8),
        P=256 if smoke else 4096,
        iters=2 if smoke else 3)
    for n, r in batch_report["sweep"].items():
        print(f"plan_batch:N={n},{r['batched_us']},"
              f"unbatched={r['unbatched_us']}us;"
              f"dispatches={r['batched_dispatches']}_vs_"
              f"{r['unbatched_dispatches']};speedup=x{r['speedup']}")
    print(f"plan_batch:headline,0,"
          f"batched=x{batch_report['headline_speedup']}"
          f"_vs_unbatched_at_N={batch_report['headline_batch']}")

    # --------------- materialized aggregates (O(delta) vs O(table) serve)
    from .bench_materialized import bench_rows as mat_rows
    from .bench_materialized import full_report as mat_report_fn
    mat_report = mat_report_fn(smoke=smoke)
    for name, us, derived in mat_rows(mat_report):
        print(f"{name},{us:.1f},{derived}")

    # ------------- serve-path latency (p50/p99) + observability overhead
    from .bench_serve_latency import bench_rows as serve_rows
    from .bench_serve_latency import full_report as serve_report_fn
    serve_report = serve_report_fn(smoke=smoke)
    for name, us, derived in serve_rows(serve_report):
        print(f"{name},{us:.1f},{derived}")

    # ------------- session serving (token routing + cache + batching)
    from .bench_sessions import bench_rows as sess_rows
    from .bench_sessions import full_report as sess_report_fn
    sess_report = sess_report_fn(smoke=smoke)
    for name, us, derived in sess_rows(sess_report):
        print(f"{name},{us:.1f},{derived}")

    # ----------------- commit certification (certifier x contention)
    from .bench_certifier import bench_rows, certifier_sweep
    cert_report = certifier_sweep(
        contentions=(0.5,) if smoke else (0.25, 0.5, 0.9),
        rounds=300 if smoke else 2000)
    for name, us, derived in bench_rows(cert_report):
        print(f"{name},{us:.1f},{derived}")

    if smoke:
        print("bench_kernels_json,0,skipped_(smoke_mode)")
    else:
        # persist the perf trajectory for future PRs (merge: standalone
        # entry points own their sections)
        from .persist import persist_bench_sections
        out_path = persist_bench_sections(kernels=gather_kernels_report(),
                                          olap_scan_path=scan_report,
                                          rss_construct=construct_report,
                                          replica_lag=lag_report,
                                          scan_agg=agg_report,
                                          group_agg=group_report,
                                          plan_batch=batch_report,
                                          certifier_aborts=cert_report,
                                          serve_latency=serve_report,
                                          materialized=mat_report,
                                          session_serve=sess_report)
        print(f"bench_kernels_json,0,{out_path}")

    # --------------------------------------------------------- roofline
    try:
        from .roofline import build_table
        rows = build_table()
        for r in rows:
            print(f"roofline:{r['arch']}:{r['shape']},0,"
                  f"dom={r['dominant']};frac={r['roofline_fraction']:.2f};"
                  f"useful={r['useful_ratio']:.2f}")
    except FileNotFoundError:
        print("roofline,0,skipped_(run_launch.dryrun_first)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-scale pass over every bench entry point "
                         "(CI); does not write BENCH_kernels.json")
    main(smoke=ap.parse_args().smoke)
