"""Million-session serving bench: token routing + resolve cache + batching.

One report, ``session_serve``:

  * ``sweep`` — N Zipf-skewed sticky sessions (each holding a cluster
    `Session` token and re-issuing one plan family per round) served
    under the four corners of the {resolve cache, dedup batching}
    matrix.  Per config: wall-clock us/serve, serves/s, batch-dispatch
    count, mirror cache hit rates, and the token-guarantee counters
    (ships forced by tokens; violations — asserted zero by the driver).
  * ``speedup`` — baseline (both off) over cache+batch (both on),
    asserted ``>= SPEEDUP_FLOOR`` (3x) at full scale: the PR's
    headline claim that same-horizon session traffic amortizes into
    one resolve + one fused dispatch per horizon group.
  * ``policies`` — serves/s + replica serve distribution for the
    token-aware routing policies (incl. ``latency_slo``), cache+batch
    on, so policy overhead is visible next to the serve-path win.

Every timed config is preceded by a small warmup run of the same
config so JIT compilation never lands inside a measured window.

Standalone: ``PYTHONPATH=src python -m benchmarks.bench_sessions``
(persists the ``session_serve`` section of BENCH_kernels.json; --smoke
skips persistence and the speedup assertion).
"""

from __future__ import annotations

import argparse
import time

from repro.mvcc import run_sessions
from repro.mvcc.workload import Scale

# asserted floor on baseline/cache+batch us-per-serve at full scale
SPEEDUP_FLOOR = 3.0

# (tag, resolve_cache, batch_plans)
_CONFIGS = (("baseline", False, False),
            ("cache", True, False),
            ("batch", False, True),
            ("cache+batch", True, True))


def _run(tag: str, *, n_sessions: int, rounds: int, scale: Scale,
         cache: bool, batch: bool, policy="predicted_staleness",
         zipf_s: float = 1.2, seed: int = 42) -> dict:
    # same-config warmup: JIT compile + page build stay out of the window
    run_sessions(n_sessions=32, rounds=2, seed=seed + 1, scale=scale,
                 resolve_cache=cache, batch_plans=batch,
                 route_policy=policy, zipf_s=zipf_s)
    t0 = time.perf_counter()
    m, _ = run_sessions(n_sessions=n_sessions, rounds=rounds, seed=seed,
                        scale=scale, n_replicas=2, route_policy=policy,
                        ship_every=2, ship_skew=1, zipf_s=zipf_s,
                        resolve_cache=cache, batch_plans=batch,
                        write_fraction=0.05)
    wall = time.perf_counter() - t0
    return {
        "wall_s": round(wall, 4),
        "us_per_serve": round(wall * 1e6 / m.session_serves, 1),
        "serves": m.session_serves,
        "serves_per_s": round(m.session_serves / wall, 1),
        "batch_dispatches": m.olap_batch_dispatches,
        "batched_plans": m.olap_batched_plans,
        "served_by": m.olap_served_by,
        "token_acquires": m.session_token_acquires,
        "token_ships": m.session_token_ships,
        "token_violations": m.session_token_violations,
        "cache_hit_rates": {k: round(v, 3)
                            for k, v in m.cache_hit_rates().items()},
    }


def session_sweep(*, n_sessions: int, rounds: int, scale: Scale,
                  zipf_s: float = 1.2) -> dict:
    """{resolve cache} x {dedup batching} -> serve cost at N sessions."""
    sweep = {tag: _run(tag, n_sessions=n_sessions, rounds=rounds,
                       scale=scale, cache=cache, batch=batch, zipf_s=zipf_s)
             for tag, cache, batch in _CONFIGS}
    speedup = round(sweep["baseline"]["us_per_serve"]
                    / sweep["cache+batch"]["us_per_serve"], 2)
    return {"sweep": sweep, "speedup": speedup, "n_sessions": n_sessions,
            "rounds": rounds, "zipf_s": zipf_s}


def policy_sweep(*, n_sessions: int, rounds: int, scale: Scale,
                 policies=("freshest", "predicted_staleness",
                           "latency_slo")) -> dict:
    """Token-aware routing policies under the fast (cache+batch) path."""
    return {pol: _run(pol, n_sessions=n_sessions, rounds=rounds,
                      scale=scale, cache=True, batch=True, policy=pol)
            for pol in policies}


def full_report(*, smoke: bool = False) -> dict:
    scale = Scale(warehouses=2, districts=2, customers=5, items=10) \
        if smoke else Scale()
    n = 60 if smoke else 1000
    rounds = 2 if smoke else 3
    report = session_sweep(n_sessions=n, rounds=rounds, scale=scale)
    report["policies"] = policy_sweep(
        n_sessions=40 if smoke else 300, rounds=rounds, scale=scale,
        policies=("predicted_staleness",) if smoke
        else ("freshest", "predicted_staleness", "latency_slo"))
    report["speedup_floor"] = SPEEDUP_FLOOR
    if not smoke:
        assert report["speedup"] >= SPEEDUP_FLOOR, \
            f"session serve speedup x{report['speedup']} below " \
            f"x{SPEEDUP_FLOOR} floor: {report['sweep']}"
    return report


def bench_rows(report: dict) -> list[tuple[str, float, str]]:
    """CSV rows (name, us_per_serve, derived) for benchmarks.run."""
    rows: list[tuple[str, float, str]] = []
    for tag, r in report["sweep"].items():
        hits = ";".join(f"{k}={v}" for k, v in r["cache_hit_rates"].items())
        rows.append((f"session_serve:{tag}", r["us_per_serve"],
                     f"serves_per_s={r['serves_per_s']};"
                     f"dispatches={r['batch_dispatches']};"
                     f"token_ships={r['token_ships']};"
                     f"violations={r['token_violations']};{hits}"))
    rows.append((f"session_serve:headline", 0.0,
                 f"cache+batch=x{report['speedup']}_vs_baseline"
                 f"_at_N={report['n_sessions']}"
                 f"_(floor=x{report['speedup_floor']})"))
    for pol, r in report.get("policies", {}).items():
        rows.append((f"session_policy:{pol}", r["us_per_serve"],
                     f"serves_per_s={r['serves_per_s']};"
                     f"served_by={'/'.join(map(str, r['served_by']))};"
                     f"token_ships={r['token_ships']}"))
    return rows


def main(smoke: bool = False) -> None:
    report = full_report(smoke=smoke)
    print("name,us_per_call,derived")
    for name, us, derived in bench_rows(report):
        print(f"{name},{us:.1f},{derived}")
    if smoke:
        print("bench_kernels_json,0,skipped_(smoke_mode)")
        return
    from .persist import persist_bench_sections
    print(f"bench_kernels_json,0,"
          f"{persist_bench_sections(session_serve=report)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-scale pass; does not write BENCH_kernels.json")
    main(smoke=ap.parse_args().smoke)
