"""Beyond-paper: RSS freshness (staleness) + construction-cost scaling.

RSS trades freshness for wait-freedom: the watermark can only include
versions whose writers are Clear (ended before every active txn began).
We sweep writer concurrency and refresh interval and report the visible-
version lag (commits) of the exported snapshot.

`construct_cost_sweep` is the tentpole's cost claim, measured: per-round
RSS construction cost versus replayed-history length for

  * the incremental path (`RSSManager.construct`: begin-LSN heap +
    delta-only Algorithm 1 + compressed floor/above-floor snapshot) — flat,
  * the batch path (`RSSManager.construct_batch`: full Clear recompute +
    full edge flatten + full member sort each round) — grows linearly.

`scan_path_report` measures the batched-scan OLAP path (one
VersionStore.scan per ('scan', keys) step) against the per-key generator
walk: olap commits per round and wall time, same seed/workload — the
speedup record for BENCH_kernels.json.

Run standalone to refresh the freshness/construct sections of
BENCH_kernels.json without the full benchmark suite:

    PYTHONPATH=src python -m benchmarks.bench_freshness
"""

from __future__ import annotations

import random
import time

from repro.core import RSSManager, Wal
from repro.mvcc import SingleNodeHTAP, run_multi_node, run_single_node


def freshness_sweep():
    rows = []
    for n_writers in (1, 2, 4, 8):
        for refresh_every in (5, 20):
            htap = SingleNodeHTAP("ssi+rss")
            rng = random.Random(0)
            open_txns = []
            lags = []
            t0 = time.perf_counter()
            for i in range(600):
                # keep ~n_writers concurrently active
                while len(open_txns) < n_writers:
                    t = htap.oltp_begin()
                    htap.engine.write(t, f"k{rng.randrange(20)}",
                                      rng.randrange(100))
                    open_txns.append(t)
                t = open_txns.pop(rng.randrange(len(open_txns)))
                try:
                    htap.engine.commit(t)
                except Exception:
                    pass
                if i % refresh_every == 0:
                    htap.refresh_rss()
                    # committed-but-not-yet-member commits (the WAL itself
                    # is truncated as consumers catch up, so count through
                    # engine stats and the manager's monotone member count)
                    lag = htap.engine.stats["commits"] - \
                        htap.rss_manager.members_total
                    lags.append(lag)
            us = (time.perf_counter() - t0) * 1e6 / 600
            avg = sum(lags) / max(len(lags), 1)
            rows.append((f"rss_freshness:w{n_writers}:r{refresh_every}",
                         us, f"avg_lag={avg:.1f}_commits"))
    return rows


def _synthetic_wal(n_records: int, seed: int = 0, concurrency: int = 8) \
        -> Wal:
    """Engine-shaped WAL stream with a steady concurrent window."""
    rng = random.Random(seed)
    wal = Wal()
    active: list[int] = []
    tid = 0
    while wal.head_lsn < n_records:
        if len(active) < concurrency and (rng.random() < 0.5 or not active):
            tid += 1
            wal.log_begin(tid)
            active.append(tid)
        else:
            t = active.pop(rng.randrange(len(active)))
            wal.log_commit(t, seq=wal.head_lsn + 1)
            if active and rng.random() < 0.4:
                wal.log_deps(t, sorted(rng.sample(
                    active, rng.randint(1, min(2, len(active))))))
    return wal


def construct_cost_sweep(history_lengths=(1000, 2000, 4000, 8000),
                        round_records: int = 50) -> dict:
    """Per-round construction cost vs replayed-history length.

    Both paths replay the SAME stream in rounds of `round_records` records;
    we time only the construction call of the LAST rounds (state at full
    history length).  Incremental additionally GCs its bookkeeping each
    round — the sustained-load configuration."""
    out = {"round_records": round_records, "incremental_us": {},
           "batch_us": {}, "tracked_txns_incremental": {},
           "tracked_txns_batch": {}}
    for n in history_lengths:
        wal = _synthetic_wal(n)
        timings = {}
        for mode in ("incremental", "batch"):
            m = RSSManager()
            cost_us = []
            while m.applied_lsn < wal.head_lsn:
                applied = 0
                for rec in wal.tail(m.applied_lsn):
                    m.apply(rec)
                    applied += 1
                    if applied >= round_records:
                        break
                t0 = time.perf_counter()
                if mode == "incremental":
                    m.construct()
                else:
                    m.construct_batch()
                cost_us.append((time.perf_counter() - t0) * 1e6)
                if mode == "incremental":
                    m.gc()
            # last-quarter mean: construction cost at ~full history length
            tail = cost_us[-max(len(cost_us) // 4, 1):]
            timings[mode] = sum(tail) / len(tail)
            out[f"tracked_txns_{mode}"][str(n)] = m.tracked_txns()
        out["incremental_us"][str(n)] = round(timings["incremental"], 2)
        out["batch_us"][str(n)] = round(timings["batch"], 2)
    ns = [str(n) for n in history_lengths]
    out["batch_growth"] = round(
        out["batch_us"][ns[-1]] / max(out["batch_us"][ns[0]], 1e-9), 2)
    out["incremental_growth"] = round(
        out["incremental_us"][ns[-1]] /
        max(out["incremental_us"][ns[0]], 1e-9), 2)
    return out


def replica_lag_sweep(rounds: int = 1000, seed: int = 9) -> dict:
    """Replica-cluster freshness/throughput: N replicas × ship interval ×
    routing policy, on the skewed-lag multinode driver (replica i ships
    every `ship_every * (1 + i)` rounds).

    Per configuration: OLAP commits + qps (logical throughput), wall time
    (real throughput — ship-then-serve rounds are paid here), the mean
    replication lag of served snapshots (freshness), ship-then-serve count,
    and the per-replica serve distribution.  Two headlines: the
    bounded-staleness trade (vs round_robin at the laggiest configuration
    it serves far fresher snapshots at a sync-ship cost), and the
    predicted-lag dividend (cadence-aware routing replaces emergency
    ship-then-serve rounds with scheduled ships the cadence owed)."""
    policies = (("freshest", False), ("round_robin", False),
                ("bounded_staleness", True),   # bounded/predicted route with
                ("predicted_staleness", True))  # workload freshness hints
    sweep = []
    for policy, hints in policies:
        for n_replicas in (1, 2, 4):
            for ship_every in (20, 100):
                t0 = time.perf_counter()
                m = run_multi_node(
                    olap_mode="ssi+rss", oltp_clients=4, olap_clients=2,
                    rounds=rounds, seed=seed, olap_scan=True,
                    ship_every=ship_every, n_replicas=n_replicas,
                    route_policy=policy, max_staleness=40, ship_skew=1,
                    freshness_hints=hints)
                sweep.append({
                    "policy": policy,
                    "n_replicas": n_replicas,
                    "ship_every": ship_every,
                    "wall_s": round(time.perf_counter() - t0, 3),
                    "olap_commits": m.olap_commits,
                    "olap_qps_per_round": round(m.olap_qps(), 6),
                    "avg_lag_records": m.olap_avg_lag_records,
                    "avg_predicted_lag": m.olap_avg_predicted_lag,
                    "ship_then_serve": m.olap_ship_then_serve,
                    "scheduled_ships": m.olap_scheduled_ships,
                    "served_by": m.olap_served_by,
                    "max_wal_records": m.max_wal_records,
                })
    def pick(policy, n, ship):
        return next(r for r in sweep if r["policy"] == policy
                    and r["n_replicas"] == n and r["ship_every"] == ship)
    laggy_rr = pick("round_robin", 4, 100)
    laggy_bs = pick("bounded_staleness", 4, 100)
    laggy_ps = pick("predicted_staleness", 4, 100)
    acquires = sum(laggy_bs["served_by"])
    return {
        "rounds": rounds,
        "sweep": sweep,
        "headline": {
            # bounded staleness buys freshness (lag ratio vs round_robin)...
            "bounded_vs_round_robin_lag_ratio": round(
                laggy_bs["avg_lag_records"] /
                max(laggy_rr["avg_lag_records"], 1e-9), 3),
            # ... and pays in throughput: read-path acquisitions stall on a
            # synchronous replication round when no replica meets the bound
            "bounded_sync_ship_rounds": laggy_bs["ship_then_serve"],
            "bounded_sync_ship_per_acquire": round(
                laggy_bs["ship_then_serve"] / max(acquires, 1), 3),
            "bounded_wall_ratio_vs_round_robin": round(
                laggy_bs["wall_s"] / max(laggy_rr["wall_s"], 1e-9), 3),
            # predicted-lag routing: same bound, fewer emergency rounds
            "predicted_sync_ship_rounds": laggy_ps["ship_then_serve"],
            "predicted_scheduled_ships": laggy_ps["scheduled_ships"],
            "predicted_avg_lag_records": laggy_ps["avg_lag_records"],
            "predicted_avg_predicted_lag": laggy_ps["avg_predicted_lag"],
        },
    }


def scan_path_report(rounds: int = 2000, seed: int = 7) -> dict:
    """Batched-scan vs per-key OLAP path on the single-node RSS system:
    same seed, same workload, same round budget."""
    out = {}
    for mode, scan in (("per_key", False), ("scan", True)):
        t0 = time.perf_counter()
        m = run_single_node(olap_mode="ssi+rss", oltp_clients=4,
                            olap_clients=2, rounds=rounds, seed=seed,
                            olap_scan=scan)
        out[mode] = {
            "wall_s": round(time.perf_counter() - t0, 3),
            "olap_commits": m.olap_commits,
            "olap_qps_per_round": round(m.olap_qps(), 6),
            "olap_scan_steps": m.olap_scan_steps,
        }
    per_key, scan = out["per_key"], out["scan"]
    out["olap_throughput_speedup"] = round(
        scan["olap_commits"] / max(per_key["olap_commits"], 1), 2)
    return out


def print_replica_lag_rows(lag: dict) -> None:
    for r in lag["sweep"]:
        print(f"replica_lag:{r['policy']}:n{r['n_replicas']}:"
              f"s{r['ship_every']},{r['wall_s'] * 1e6:.0f},"
              f"avg_lag={r['avg_lag_records']};"
              f"olap_commits={r['olap_commits']};"
              f"ship_then_serve={r['ship_then_serve']};"
              f"scheduled={r['scheduled_ships']}")
    h = lag["headline"]
    print(f"replica_lag:headline,0,"
          f"bounded_lag=x{h['bounded_vs_round_robin_lag_ratio']}_vs_rr;"
          f"sync_ships={h['bounded_sync_ship_rounds']}"
          f"({h['bounded_sync_ship_per_acquire']}/acquire);"
          f"wall=x{h['bounded_wall_ratio_vs_round_robin']}_vs_rr")
    print(f"replica_lag:predicted,0,"
          f"sync_ships={h['predicted_sync_ship_rounds']}"
          f"_vs_{h['bounded_sync_ship_rounds']}_bounded;"
          f"scheduled={h['predicted_scheduled_ships']};"
          f"lag={h['predicted_avg_lag_records']}"
          f"(pred={h['predicted_avg_predicted_lag']})")


def main() -> None:
    """Refresh the rss_construct + replica_lag sections of
    BENCH_kernels.json in place."""
    from .persist import persist_bench_sections

    sweep = construct_cost_sweep()
    for n, us in sweep["incremental_us"].items():
        print(f"rss_construct:incremental:n={n},{us},"
              f"tracked={sweep['tracked_txns_incremental'][n]}")
    for n, us in sweep["batch_us"].items():
        print(f"rss_construct:batch:n={n},{us},"
              f"tracked={sweep['tracked_txns_batch'][n]}")
    print(f"rss_construct:growth,0,batch=x{sweep['batch_growth']};"
          f"incremental=x{sweep['incremental_growth']}")
    lag = replica_lag_sweep()
    print_replica_lag_rows(lag)
    path = persist_bench_sections(rss_construct=sweep, replica_lag=lag)
    print(f"bench_kernels_json,0,{path}")


if __name__ == "__main__":
    main()
