"""Beyond-paper: RSS freshness (staleness) characterization + scan path.

RSS trades freshness for wait-freedom: the watermark can only include
versions whose writers are Clear (ended before every active txn began).
We sweep writer concurrency and refresh interval and report the visible-
version lag (LSNs) of the exported snapshot.

`scan_path_report` measures the batched-scan OLAP path (one
VersionStore.scan per ('scan', keys) step) against the per-key generator
walk: olap commits per round and wall time, same seed/workload — the
speedup record for BENCH_kernels.json.
"""

from __future__ import annotations

import random
import time

from repro.mvcc import SingleNodeHTAP, run_single_node


def freshness_sweep():
    rows = []
    for n_writers in (1, 2, 4, 8):
        for refresh_every in (5, 20):
            htap = SingleNodeHTAP("ssi+rss")
            rng = random.Random(0)
            open_txns = []
            lags = []
            t0 = time.perf_counter()
            for i in range(600):
                # keep ~n_writers concurrently active
                while len(open_txns) < n_writers:
                    t = htap.oltp_begin()
                    htap.engine.write(t, f"k{rng.randrange(20)}",
                                      rng.randrange(100))
                    open_txns.append(t)
                t = open_txns.pop(rng.randrange(len(open_txns)))
                try:
                    htap.engine.commit(t)
                except Exception:
                    pass
                if i % refresh_every == 0:
                    snap = htap.refresh_rss()
                    n_committed = sum(1 for x in htap.engine.wal.records
                                      if x.type == "commit")
                    lag = n_committed - len(snap.txns)
                    lags.append(lag)
            us = (time.perf_counter() - t0) * 1e6 / 600
            avg = sum(lags) / max(len(lags), 1)
            rows.append((f"rss_freshness:w{n_writers}:r{refresh_every}",
                         us, f"avg_lag={avg:.1f}_commits"))
    return rows


def scan_path_report(rounds: int = 2000, seed: int = 7) -> dict:
    """Batched-scan vs per-key OLAP path on the single-node RSS system:
    same seed, same workload, same round budget."""
    out = {}
    for mode, scan in (("per_key", False), ("scan", True)):
        t0 = time.perf_counter()
        m = run_single_node(olap_mode="ssi+rss", oltp_clients=4,
                            olap_clients=2, rounds=rounds, seed=seed,
                            olap_scan=scan)
        out[mode] = {
            "wall_s": round(time.perf_counter() - t0, 3),
            "olap_commits": m.olap_commits,
            "olap_qps_per_round": round(m.olap_qps(), 6),
            "olap_scan_steps": m.olap_scan_steps,
        }
    per_key, scan = out["per_key"], out["scan"]
    out["olap_throughput_speedup"] = round(
        scan["olap_commits"] / max(per_key["olap_commits"], 1), 2)
    return out
