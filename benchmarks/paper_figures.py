"""Benchmarks reproducing the paper's figures (logical-time driver).

Fig 5 — OLTP throughput vs #OLTP clients (single node), per CC mode
Fig 6 — OLAP throughput vs #OLAP clients (single node), per CC mode
Fig 7 — abort rate vs #OLTP clients (single node), per CC mode
Fig 8/9/10 — same quantities, multinode (SSI+SI vs SSI+RSS), plus the
             measured wall-clock RSS-construction overhead (the paper's
             ~10% OLTP cost) from real engine timing.

Outputs CSV rows: figure,mode,x,oltp_tps,olap_qps,oltp_abort,olap_abort,
olap_waits.
"""

from __future__ import annotations

import time

from repro.mvcc import run_multi_node, run_single_node

SINGLE_MODES = ("ssi", "ssi+safesnapshots", "ssi+rss")
MULTI_MODES = ("ssi+si", "ssi+rss")


def fig_5_6_7(rounds: int = 4000, olap_fixed: int = 2,
              oltp_fixed: int = 8, seed: int = 7):
    rows = []
    for mode in SINGLE_MODES:
        for n_oltp in (1, 2, 4, 8, 12):
            m = run_single_node(olap_mode=mode, oltp_clients=n_oltp,
                                olap_clients=olap_fixed, rounds=rounds,
                                seed=seed)
            rows.append(("fig5_7", mode, n_oltp, m.oltp_tps(), m.olap_qps(),
                         m.oltp_abort_rate(), m.olap_abort_rate(),
                         m.olap_wait_rounds))
        for n_olap in (1, 2, 4, 8):
            m = run_single_node(olap_mode=mode, oltp_clients=oltp_fixed,
                                olap_clients=n_olap, rounds=rounds,
                                seed=seed)
            rows.append(("fig6", mode, n_olap, m.oltp_tps(), m.olap_qps(),
                         m.oltp_abort_rate(), m.olap_abort_rate(),
                         m.olap_wait_rounds))
    return rows


def fig_8_9_10(rounds: int = 4000, seed: int = 7):
    rows = []
    for mode in MULTI_MODES:
        for n_oltp in (1, 2, 4, 8, 12):
            t0 = time.perf_counter()
            m = run_multi_node(olap_mode=mode, oltp_clients=n_oltp,
                               olap_clients=2, rounds=rounds, seed=seed)
            wall = time.perf_counter() - t0
            rows.append(("fig8_10", mode, n_oltp, m.oltp_tps(),
                         m.olap_qps(), m.oltp_abort_rate(),
                         m.olap_abort_rate(), round(wall, 3)))
        for n_olap in (1, 2, 4, 8):
            m = run_multi_node(olap_mode=mode, oltp_clients=8,
                               olap_clients=n_olap, rounds=rounds, seed=seed)
            rows.append(("fig9", mode, n_olap, m.oltp_tps(), m.olap_qps(),
                         m.oltp_abort_rate(), m.olap_abort_rate(), 0))
    return rows


def rss_construction_overhead(rounds: int = 3000, seed: int = 7) -> dict:
    """Wall-clock cost of RSS machinery on the OLTP path (multinode): the
    paper reports ~10% OLTP throughput cost vs SSI+SI."""
    out = {}
    for mode in MULTI_MODES:
        t0 = time.perf_counter()
        m = run_multi_node(olap_mode=mode, oltp_clients=8, olap_clients=2,
                           rounds=rounds, seed=seed)
        wall = time.perf_counter() - t0
        out[mode] = {"wall_s": wall,
                     "oltp_commits_per_s": m.oltp_commits / wall,
                     "olap_q_per_s": m.olap_commits / wall}
    si, rss = out["ssi+si"], out["ssi+rss"]
    out["oltp_overhead_pct"] = 100 * (
        1 - rss["oltp_commits_per_s"] / max(si["oltp_commits_per_s"], 1e-9))
    out["olap_overhead_pct"] = 100 * (
        1 - rss["olap_q_per_s"] / max(si["olap_q_per_s"], 1e-9))
    return out


def headline_checks(rows) -> list[str]:
    """The paper's qualitative claims, asserted on our measurements."""
    import collections
    by = collections.defaultdict(dict)
    for fig, mode, x, tps, qps, oab, aab, waits in rows:
        by[(fig, x)][mode] = (tps, qps, oab, aab, waits)
    msgs = []
    f57 = [(x, d) for (fig, x), d in by.items() if fig == "fig5_7"
           and len(d) == 3]
    hi = max(f57, key=lambda t: t[0])
    x, d = hi
    ok1 = d["ssi+rss"][2] <= d["ssi"][2] + 1e-9
    msgs.append(f"claim: RSS OLTP abort rate <= SSI at {x} clients: "
                f"{d['ssi+rss'][2]:.3f} vs {d['ssi'][2]:.3f} -> "
                f"{'OK' if ok1 else 'VIOLATED'}")
    ok2 = d["ssi+rss"][4] == 0 and d["ssi+rss"][3] == 0
    msgs.append(f"claim: RSS wait-free & abort-free OLAP: waits="
                f"{d['ssi+rss'][4]} aborts={d['ssi+rss'][3]:.3f} -> "
                f"{'OK' if ok2 else 'VIOLATED'}")
    ok3 = d["ssi+safesnapshots"][4] > 0
    msgs.append(f"claim: SafeSnapshots reader-waits exist: "
                f"{d['ssi+safesnapshots'][4]} -> "
                f"{'OK' if ok3 else 'VIOLATED'}")
    ok4 = d["ssi+rss"][1] >= d["ssi"][1]
    msgs.append(f"claim: RSS OLAP qps >= SSI OLAP qps: "
                f"{d['ssi+rss'][1]:.5f} vs {d['ssi'][1]:.5f} -> "
                f"{'OK' if ok4 else 'VIOLATED'}")
    return msgs
