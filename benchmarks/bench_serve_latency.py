"""Serve-path latency characterization + observability overhead bound.

Two reports:

  * `serve_latency_sweep` — end-to-end OLAP serve latency (p50/p95/p99)
    per plan kind, with the per-stage breakdown (route / resolve /
    kernel dispatch / finalize) and OLTP commit latency, swept over
    plan batching (single-node) and routing policy (multi-node).  The
    numbers come straight from the registry's fixed-bucket histograms —
    the same series verify.sh prints — so the bench measures exactly
    what production-style scraping would see.

  * `overhead_report` — the cost of the observability layer itself:
    identical workloads run with timing instrumentation ON (default)
    and STUBBED (`set_timing(False)` turns tick/tock into no-ops), in
    interleaved pairs; the minimum pairwise ratio bounds the true
    overhead from above modulo noise.  Asserted <= OVERHEAD_BOUND_PCT.

Standalone: ``PYTHONPATH=src python -m benchmarks.bench_serve_latency``
(persists the ``serve_latency`` section of BENCH_kernels.json; --smoke
skips persistence).
"""

from __future__ import annotations

import argparse
import time

from repro.mvcc import run_multi_node, run_single_node
from repro.obs import TRACER, set_timing

# asserted ceiling for always-on instrumentation (counters + histogram
# observes) relative to a tick/tock-stubbed run of the same workload
OVERHEAD_BOUND_PCT = 5.0

_SINGLE = dict(olap_mode="ssi+rss", oltp_clients=3, olap_clients=3,
               olap_scan=True, paged_olap=True)
_MULTI = dict(_SINGLE, n_replicas=2)


def _collect(m) -> dict:
    return {
        "serve": m.serve_latency,
        "by_plan": m.serve_latency_by_plan,
        "stages": m.serve_stage_latency,
        "oltp_commit": m.oltp_commit_latency,
    }


def serve_latency_sweep(*, rounds: int = 1500,
                        policies=("freshest", "round_robin",
                                  "bounded_staleness",
                                  "predicted_staleness")) -> dict:
    """plan kind x batching x routing policy -> latency summaries."""
    sweep: dict[str, dict] = {}
    for batching in (False, True):
        m = run_single_node(rounds=rounds, seed=42, batch_plans=batching,
                            **_SINGLE)
        sweep[f"single|batch={'on' if batching else 'off'}"] = _collect(m)
    for pol in policies:
        m = run_multi_node(rounds=rounds, seed=42, route_policy=pol,
                           **_MULTI)
        sweep[f"multi|{pol}"] = _collect(m)
    return {"sweep": sweep, "rounds": rounds}


def overhead_report(*, rounds: int = 800, pairs: int = 3) -> dict:
    """Wall-clock ratio of instrumented vs instrumentation-stubbed runs.

    The first (untimed) run warms JIT caches so compilation doesn't land
    in either side; pairs are interleaved so drift hits both equally and
    the MIN ratio is the honest upper bound on steady-state overhead.

    Runs with `resolve_cache=False`: the bound divides a fixed
    instrumentation cost by the run's serve work, so the denominator
    must be the stable uncached resolve path — cached serves are cheap
    enough (and hit-rate-dependent enough) that the SAME absolute
    overhead would read as a flappy, inflated percentage."""
    args = dict(_SINGLE, rounds=rounds, seed=7, resolve_cache=False)
    TRACER.set_enabled(False)       # span capture off on both sides
    try:
        run_single_node(**args)     # warmup: JIT compile + page build
        ratios = []
        for _ in range(pairs):
            set_timing(False)
            t0 = time.perf_counter()
            run_single_node(**args)
            stubbed = time.perf_counter() - t0
            set_timing(True)
            t0 = time.perf_counter()
            run_single_node(**args)
            timed = time.perf_counter() - t0
            ratios.append(timed / stubbed)
    finally:
        set_timing(True)
        TRACER.set_enabled(None)
    overhead_pct = round((min(ratios) - 1.0) * 100.0, 2)
    report = {
        "pair_ratios": [round(r, 4) for r in ratios],
        "overhead_pct": overhead_pct,
        "bound_pct": OVERHEAD_BOUND_PCT,
    }
    assert overhead_pct <= OVERHEAD_BOUND_PCT, \
        f"observability overhead {overhead_pct}% exceeds " \
        f"{OVERHEAD_BOUND_PCT}% bound: {report}"
    return report


def bench_rows(report: dict) -> list[tuple[str, float, str]]:
    """CSV rows (name, us_per_call, derived) for benchmarks.run."""
    rows: list[tuple[str, float, str]] = []
    for cfg, r in report["sweep"].items():
        s = r["serve"]
        rows.append((f"serve_latency:{cfg}", s["p50_us"],
                     f"p95={s['p95_us']}us;p99={s['p99_us']}us;"
                     f"n={s['count']}"))
        for plan, ps in sorted(r["by_plan"].items()):
            rows.append((f"serve_latency:{cfg}:{plan}", ps["p50_us"],
                         f"p99={ps['p99_us']}us;n={ps['count']}"))
        stage_bits = ";".join(
            f"{st}={r['stages'][st]['p50_us']}us"
            for st in ("route", "resolve", "dispatch", "finalize")
            if st in r["stages"])
        rows.append((f"serve_stages:{cfg}", 0.0, stage_bits or "none"))
        c = r["oltp_commit"]
        rows.append((f"commit_latency:{cfg}", c["p50_us"],
                     f"p99={c['p99_us']}us;n={c['count']}"))
    ov = report.get("overhead")
    if ov:
        rows.append(("obs_overhead", 0.0,
                     f"{ov['overhead_pct']}%_vs_stubbed"
                     f"_(bound={ov['bound_pct']}%);"
                     f"pairs={ov['pair_ratios']}"))
    return rows


def full_report(*, smoke: bool = False) -> dict:
    report = serve_latency_sweep(
        rounds=300 if smoke else 1500,
        policies=("round_robin",) if smoke else ("freshest", "round_robin",
                                                 "bounded_staleness",
                                                 "predicted_staleness"))
    report["overhead"] = overhead_report(rounds=200 if smoke else 800,
                                         pairs=2 if smoke else 3)
    return report


def main(smoke: bool = False) -> None:
    report = full_report(smoke=smoke)
    print("name,us_per_call,derived")
    for name, us, derived in bench_rows(report):
        print(f"{name},{us:.1f},{derived}")
    if smoke:
        print("bench_kernels_json,0,skipped_(smoke_mode)")
        return
    from .persist import persist_bench_sections
    print(f"bench_kernels_json,0,"
          f"{persist_bench_sections(serve_latency=report)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-scale pass; does not write BENCH_kernels.json")
    main(smoke=ap.parse_args().smoke)
