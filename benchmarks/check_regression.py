"""Perf-regression gate over the persisted bench trajectory.

Compares the freshly-written BENCH_kernels.json (after a full
``benchmarks.run`` pass) against the committed baseline (``git show
HEAD:BENCH_kernels.json`` by default) and FAILS when any tracked
per-call cost regressed by more than ``TOLERANCE`` — i.e. throughput
dropped >25% on the scan_agg / group_agg / serve_latency / materialized
/ session_serve serve paths.  Missing sections or entries are reported and skipped (a
new bench's first persisted run has no baseline), so the gate only ever
compares like against like.

Usage (the verify.sh --bench path):
    PYTHONPATH=src python -m benchmarks.run            # persists fresh
    PYTHONPATH=src python -m benchmarks.check_regression
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys

from .persist import BENCH_PATH

TOLERANCE = 0.25          # fail when new_us > (1 + TOLERANCE) * old_us


def _tracked(blob: dict) -> dict[str, float]:
    """Flatten the gated sections into {metric_name: us_per_call}."""
    out: dict[str, float] = {}
    sweep = blob.get("scan_agg", {}).get("sweep", {})
    for p, r in sweep.items():
        out[f"scan_agg:P={p}"] = float(r["fused_agg_us"])
    sweep = blob.get("group_agg", {}).get("sweep", {})
    for shape, r in sweep.items():
        out[f"group_agg:{shape}"] = float(r["chunked_us"])
    sweep = blob.get("serve_latency", {}).get("sweep", {})
    for cfg, r in sweep.items():
        out[f"serve_latency:{cfg}:p50"] = float(r["serve"]["p50_us"])
    sweep = blob.get("materialized", {}).get("sweep", {})
    for p, r in sweep.items():
        out[f"materialized:P={p}"] = float(r["materialized_us"])
    sweep = blob.get("session_serve", {}).get("sweep", {})
    for cfg, r in sweep.items():
        out[f"session_serve:{cfg}"] = float(r["us_per_serve"])
    return out


def _load_baseline(ref: str) -> dict | None:
    if ref.endswith(".json"):
        try:
            with open(ref) as f:
                return json.load(f)
        except FileNotFoundError:
            return None
    try:
        raw = subprocess.run(
            ["git", "show", f"{ref}:BENCH_kernels.json"],
            capture_output=True, text=True, check=True,
            cwd=BENCH_PATH.rsplit("/", 1)[0]).stdout
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None
    return json.loads(raw)


def check(baseline_ref: str = "HEAD",
          tolerance: float = TOLERANCE) -> tuple[list[str], list[str]]:
    """(regressions, notes) between the committed baseline and the
    current BENCH_kernels.json."""
    base_blob = _load_baseline(baseline_ref)
    if base_blob is None:
        return [], [f"no baseline at {baseline_ref}: nothing to gate"]
    with open(BENCH_PATH) as f:
        cur_blob = json.load(f)
    base, cur = _tracked(base_blob), _tracked(cur_blob)
    regressions, notes = [], []
    for name, old_us in sorted(base.items()):
        new_us = cur.get(name)
        if new_us is None:
            notes.append(f"{name}: dropped from current run (skipped)")
            continue
        ratio = new_us / old_us if old_us else 1.0
        line = f"{name}: {old_us:.1f}us -> {new_us:.1f}us (x{ratio:.3f})"
        if ratio > 1.0 + tolerance:
            regressions.append(line)
        else:
            notes.append(line)
    for name in sorted(set(cur) - set(base)):
        notes.append(f"{name}: new metric, no baseline (skipped)")
    return regressions, notes


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="HEAD",
                    help="git ref, or a path ending in .json")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE,
                    help="allowed fractional us-per-call growth")
    args = ap.parse_args()
    regressions, notes = check(args.baseline, args.tolerance)
    for line in notes:
        print(f"ok   {line}")
    for line in regressions:
        print(f"FAIL {line}")
    if regressions:
        print(f"check_regression: {len(regressions)} metric(s) regressed "
              f">{args.tolerance:.0%} vs {args.baseline}")
        return 1
    print(f"check_regression: {len(notes)} metric(s) within "
          f"{args.tolerance:.0%} of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
