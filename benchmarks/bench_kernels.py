"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (not
representative of TPU), so wall-time here measures the REFERENCE jnp paths;
for each kernel we also report the analytic TPU roofline time (bytes moved /
819 GB/s, flops / 197 TF/s) that the §Perf analysis uses.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

HBM_BW = 819e9
PEAK = 197e12

# snapshot-read kernel bench shapes (shared by the timed benches and the
# gather_kernels_report JSON so bytes-moved never drifts from the labels)
GATHER_P, GATHER_K, GATHER_E = 4096, 4, 2048    # 64 MB bf16 payload
RSS_M = 1024                                    # RSS members


def _gather_bytes(members: int = 0) -> int:
    """HBM traffic of one snapshot-read gather: stream data + ts (+ member
    array) in, visible payloads out."""
    return (GATHER_P * GATHER_K * GATHER_E * 2 + GATHER_P * GATHER_K * 4 +
            members * 4 + GATHER_P * GATHER_E * 2)


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6   # us


def bench_version_gather():
    from repro.kernels.version_gather.ref import version_gather_ref
    P, K, E = GATHER_P, GATHER_K, GATHER_E
    key = jax.random.PRNGKey(0)
    data = jax.random.normal(key, (P, K, E)).astype(jnp.bfloat16)
    ts = jax.random.randint(key, (P, K), 0, 1000)
    f = jax.jit(lambda d, t: version_gather_ref(d, t, jnp.int32(500)))
    us = _time(f, data, ts)
    bytes_moved = _gather_bytes()
    tpu_us = bytes_moved / HBM_BW * 1e6
    return [("version_gather_ref_cpu", us, f"P={P},K={K},E={E}"),
            ("version_gather_tpu_roofline", tpu_us,
             f"{bytes_moved/1e6:.1f}MB @819GB/s")]


def bench_rss_gather():
    from repro.kernels.rss_gather.ref import rss_gather_ref
    P, K, E, M = GATHER_P, GATHER_K, GATHER_E, RSS_M
    key = jax.random.PRNGKey(1)
    data = jax.random.normal(key, (P, K, E)).astype(jnp.bfloat16)
    ts = jax.random.randint(key, (P, K), 0, 4096)
    members = jnp.sort(jax.random.choice(
        jax.random.fold_in(key, 1), 4096, (M,), replace=False)).astype(
        jnp.int32)
    f = jax.jit(lambda d, t, m: rss_gather_ref(d, t, m))
    us = _time(f, data, ts, members)
    bytes_moved = _gather_bytes(M)
    tpu_us = bytes_moved / HBM_BW * 1e6
    return [("rss_gather_ref_cpu", us, f"P={P},K={K},E={E},M={M}"),
            ("rss_gather_tpu_roofline", tpu_us,
             f"{bytes_moved/1e6:.1f}MB @819GB/s")]


def _workload_paged_store(P, K=4, E=32, seed=2):
    """A workload-shaped int-tagged paged store (what the mirror exports)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    data = np.zeros((P, K, E), np.int32)
    data[:, :, 0] = 1                                   # TAG_INT
    data[:, :, 1] = rng.integers(0, 200, (P, K))
    ts = rng.integers(0, 4 * P, (P, K)).astype(np.int32)
    members = np.sort(rng.choice(4 * P, size=min(512, P), replace=False)) \
        .astype(np.int32)
    floor = int(2 * P)
    return (jnp.asarray(data), jnp.asarray(ts), jnp.asarray(members), floor)


def _agg_paths(P):
    """(scan+host-decode+reduce closure, fused-agg closure, bytes per path)
    for one OLAP aggregate over P pages — the two executor shapes
    `scan_agg_report` sweeps."""
    import numpy as np
    from repro.kernels.rss_gather.ref import rss_gather_ref
    from repro.kernels.rss_scan_agg.ops import fold_partials
    from repro.kernels.rss_scan_agg.ref import rss_scan_agg_ref
    from repro.tensorstore.mirror import decode_value
    from repro.tensorstore.version_store import AggOp, apply_agg, finalize_agg

    data, ts, members, floor = _workload_paged_store(P)
    op = AggOp("sum", "int")
    gather = jax.jit(lambda d, t, m: rss_gather_ref(d, t, m, floor))
    fused = jax.jit(lambda d, t, m: rss_scan_agg_ref(d, t, m, floor,
                                                     tag_main=1, tag_alt=0))

    def scan_then_host():
        rows = np.asarray(gather(data, ts, members))    # leaves the device
        return apply_agg([decode_value(r) for r in rows], op)

    def fused_agg():
        # P/8 partial rows back, folded in Python ints (overflow-safe)
        return finalize_agg(fold_partials(fused(data, ts, members)), op)

    assert scan_then_host() == fused_agg()              # parity, in-bench
    K, E = data.shape[1], data.shape[2]
    in_bytes = P * K * E * 4 + P * K * 4 + members.shape[0] * 4
    return scan_then_host, fused_agg, {
        "in": in_bytes, "scan_out": P * E * 4, "fused_out": 5 * 4}


def _time_host(fn, iters=5, repeats=1):
    """Mean us over `iters` calls; with repeats > 1, the MIN of `repeats`
    such means (timeit.repeat discipline — the minimum is the least
    noise-contaminated estimate of the closure's cost)."""
    fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return best                                          # us


def bench_rss_scan_agg():
    P = GATHER_P
    scan_then_host, fused_agg, nbytes = _agg_paths(P)
    scan_us = _time_host(scan_then_host)
    fused_us = _time_host(fused_agg)
    scan_tpu = (nbytes["in"] + nbytes["scan_out"]) / HBM_BW * 1e6
    fused_tpu = (nbytes["in"] + nbytes["fused_out"]) / HBM_BW * 1e6
    return [("olap_agg_scan_host_decode_cpu", scan_us, f"P={P},sum(int)"),
            ("olap_agg_fused_cpu", fused_us,
             f"P={P},x{scan_us / max(fused_us, 1e-9):.1f}_vs_host_decode"),
            ("olap_agg_fused_tpu_roofline", fused_tpu,
             f"{(nbytes['in'] + nbytes['fused_out'])/1e6:.1f}MB@819GB/s;"
             f"scan_writes_{nbytes['scan_out']/1e6:.1f}MB_more;"
             f"device_roofline_{scan_tpu:.0f}us_excl_host_decode")]


def scan_agg_report(page_counts=(1024, 4096, 16384), iters=5) -> dict:
    """Scan-vs-fused-agg sweep: one OLAP aggregate (sum over int pages)
    executed as (a) today's scan path — device visibility gather, then
    page decode + reduction on host — and (b) the fused `rss_scan_agg`
    pass returning 5 scalars.  The fused path's win grows with P because
    the host decode loop it eliminates is linear in pages; persisted to
    BENCH_kernels.json under `scan_agg`."""
    sweep = {}
    for P in page_counts:
        scan_then_host, fused_agg, nbytes = _agg_paths(P)
        scan_us = _time_host(scan_then_host, iters)
        fused_us = _time_host(fused_agg, iters)
        sweep[str(P)] = {
            "scan_host_decode_us": round(scan_us, 1),
            "fused_agg_us": round(fused_us, 1),
            "speedup": round(scan_us / max(fused_us, 1e-9), 2),
            "scan_out_bytes": nbytes["scan_out"],
            "fused_out_bytes": nbytes["fused_out"],
        }
    top = str(max(page_counts))
    return {
        "op": "sum(int) over member-visible pages (K=4, E=32)",
        "sweep": sweep,
        "headline_speedup": sweep[top]["speedup"],
        "headline_pages": int(top),
        "tpu_roofline_note": "fused writes 20B instead of P*E*4B and "
                             "eliminates the host decode entirely",
    }


def _group_paths(P, G):
    """(scan+host-decode+groupby, flat-lane fused, chunked two-stage
    fused) closures for a GROUP BY aggregate over P pages in G groups —
    the three executor strategies `group_agg_report` sweeps.  Groups are
    contiguous page families (the page-range-locality layout
    `PagedMirror.reserve` produces)."""
    import numpy as np
    from repro.kernels.rss_gather.ref import rss_gather_ref
    from repro.kernels.rss_scan_agg.kernel import tree_fold_partials
    from repro.kernels.rss_scan_agg.ops import fold_group_partials
    from repro.kernels.rss_scan_agg.ref import (rss_scan_agg_chunked_ref,
                                                rss_scan_agg_grouped_ref)
    from repro.tensorstore.mirror import decode_value
    from repro.tensorstore.version_store import AggOp, apply_agg, finalize_agg

    data, ts, members, floor = _workload_paged_store(P)
    gid_flat = (np.arange(P, dtype=np.int64) * G // P).astype(np.int32)
    gid = jnp.asarray(gid_flat.reshape(P, 1))
    op = AggOp("sum", "int")
    gather = jax.jit(lambda d, t, m: rss_gather_ref(d, t, m, floor))
    flat = jax.jit(lambda d, t, g, m: rss_scan_agg_grouped_ref(
        d, t, g, m, floor, tag_main=1, tag_alt=0, n_groups=G))
    chunked = jax.jit(lambda d, t, g, m: rss_scan_agg_chunked_ref(
        d, t, g, m, floor, tag_main=1, tag_alt=0, n_groups=G))

    def scan_then_host_groupby():
        rows = np.asarray(gather(data, ts, members))    # leaves the device
        acc = [[] for _ in range(G)]
        for r, g in zip(rows, gid_flat):
            acc[g].append(decode_value(r))
        return [apply_agg(a, op) for a in acc]

    def flat_group_agg():
        # [P/8, G, 5] partial tiles back, folded per group in Python ints
        partials = fold_group_partials(flat(data, ts, gid, members))
        return [finalize_agg(row, op) for row in partials]

    def chunked_group_agg():
        # [chunks, G, 5] partials tree-folded ON DEVICE; only [G, 5] lands
        folded = np.asarray(tree_fold_partials(
            chunked(data, ts, gid, members))).tolist()
        return [finalize_agg(folded[g], op) for g in range(G)]

    # three-way parity, in-bench
    assert scan_then_host_groupby() == flat_group_agg() == chunked_group_agg()
    return scan_then_host_groupby, flat_group_agg, chunked_group_agg


def group_agg_report(page_counts=(1024, 4096), groups=(4, 16, 64, 256),
                     iters=5) -> dict:
    """Grouped-aggregate strategy sweep (groups × pages): one GROUP BY sum
    executed as (a) the scan path — device visibility gather, host
    decode, host group-by — (b) the flat-lane fused pass ([P/8, G, 5]
    partial tiles, VMEM and output linear in G), and (c) the chunked
    two-stage pass (select + tiled-group reduce + device tree fold, [G,5]
    out, VMEM bounded by the group tile).  The flat win decays as G
    grows; chunked stays flat-in-G — the crossover is what
    `ops.select_grouped_mode` encodes (recorded per shape as `mode`).
    Persisted to BENCH_kernels.json under `group_agg`."""
    from repro.kernels.rss_scan_agg.ops import select_grouped_mode

    sweep = {}
    for P in page_counts:
        for G in groups:
            scan_fn, flat_fn, chunked_fn = _group_paths(P, G)
            # interleave repeat rounds across the three strategies so
            # machine-load drift cancels in the speedup ratios
            t = {f: [] for f in (scan_fn, flat_fn, chunked_fn)}
            for _ in range(3):
                for f in t:
                    t[f].append(_time_host(f, iters))
            scan_us, flat_us, chunked_us = (min(t[f]) for f in t)
            sweep[f"P={P},G={G}"] = {
                "scan_host_groupby_us": round(scan_us, 1),
                "flat_us": round(flat_us, 1),
                "chunked_us": round(chunked_us, 1),
                "speedup_flat": round(scan_us / max(flat_us, 1e-9), 2),
                "speedup_chunked": round(scan_us / max(chunked_us, 1e-9), 2),
                "mode": select_grouped_mode(P, G),
                "launches": {"flat": 1, "chunked": 2},
                "flat_partial_bytes": (P // 8) * G * 5 * 4,
                "chunked_out_bytes": G * 5 * 4,
            }
    Pt = max(page_counts)
    tops = [sweep[f"P={Pt},G={G}"]["speedup_chunked"] for G in groups]
    decay_pct = round(100 * (1 - min(tops) / max(tops[0], 1e-9)), 1)
    head = f"P={Pt},G={64 if 64 in groups else max(groups)}"
    return {
        "op": "GROUP BY sum(int) over member-visible pages (K=4, E=32)",
        "sweep": sweep,
        "headline_speedup": sweep[head]["speedup_chunked"],
        "headline_shape": head,
        "chunked_decay_pct_across_groups": decay_pct,
        "tpu_roofline_note": "chunked writes G*20B after the device fold "
                             "(flat writes (P/8)*G*20B partials) and both "
                             "eliminate host decode + group-by entirely",
    }


def plan_batch_report(batch_sizes=(1, 2, 4, 8), P=4096, iters=3) -> dict:
    """Whole-batch plan fusion sweep: N same-horizon `MultiAggPlan`s over
    contiguous key slices of a WAL-mirrored paged store, executed (a)
    unbatched — one executor dispatch per plan — and (b) as ONE
    `BatchPlan` — a single fused grouped dispatch whose lane tile serves
    every plan.  Asserts in-bench that the batched results equal the
    unbatched ones AND the host `apply_plan` oracle, and that the batch
    really cost one dispatch.  Persisted to BENCH_kernels.json under
    `plan_batch`."""
    import numpy as np
    from repro.core import Wal
    from repro.tensorstore import (AggOp, BatchPlan, MultiAggPlan,
                                   PagedMirror, ScanPlan, apply_plan)

    rng = np.random.default_rng(4)
    keys = [f"k:{i}" for i in range(P)]
    wal = Wal()
    for c in range(0, P, 256):
        tid = c // 256 + 1
        wal.log_begin(tid)
        wal.log_commit(tid, [(k, int(rng.integers(0, 200)))
                             for k in keys[c:c + 256]],
                       seq=wal.head_lsn + 1)
    mirror = PagedMirror(slots=4)
    mirror.catch_up(wal)
    wm = P          # every commit visible at the head watermark
    ops = (AggOp("sum", "int"), AggOp("count", "int"),
           AggOp("count_below", "int", 100))
    slice_len = P // max(batch_sizes)
    sweep = {}
    for N in batch_sizes:
        plans = tuple(
            MultiAggPlan(tuple(keys[j * slice_len:(j + 1) * slice_len]), ops)
            for j in range(N))
        batch = BatchPlan(plans)

        def unbatched():
            return [mirror.execute_with_writers(p, wm, use_kernel=False)[0]
                    for p in plans]

        def batched():
            return list(mirror.execute_with_writers(
                batch, wm, use_kernel=False)[0])

        before = mirror.exec_stats["agg_dispatches"]
        got = batched()
        assert mirror.exec_stats["agg_dispatches"] - before == 1  # ONE launch
        assert got == unbatched()                                 # exact
        oracle = [apply_plan(
            mirror.execute_with_writers(ScanPlan(p.keys), wm)[0], p)
            for p in plans]
        assert got == oracle
        t = {f: [] for f in (unbatched, batched)}
        for _ in range(3):
            for f in t:
                t[f].append(_time_host(f, iters))
        un_us, ba_us = (min(t[f]) for f in t)
        sweep[str(N)] = {
            "unbatched_us": round(un_us, 1),
            "batched_us": round(ba_us, 1),
            "speedup": round(un_us / max(ba_us, 1e-9), 2),
            "unbatched_dispatches": N,
            "batched_dispatches": 1,
            "batched_out_bytes": N * len(ops) * 5 * 4,
        }
    top = str(max(batch_sizes))
    return {
        "op": f"N x MultiAggPlan(sum,count,count_below) over {slice_len} "
              f"keys each (P={P})",
        "sweep": sweep,
        "headline_speedup": sweep[top]["speedup"],
        "headline_batch": int(top),
        "note": "batched = ONE fused grouped dispatch (one visibility "
                "resolve, one lane per plan x config); unbatched = one "
                "dispatch per plan",
    }


def bench_flash_attention():
    from repro.models.layers import flash_attention_xla
    B, S, H, K, hd = 1, 2048, 8, 2, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(key, (B, S, K, hd), jnp.float32)
    v = jax.random.normal(key, (B, S, K, hd), jnp.float32)
    f = jax.jit(lambda a, b, c: flash_attention_xla(a, b, c, causal=True,
                                                    chunk=512))
    us = _time(f, q, k, v)
    flops = 4 * B * S * S * H * hd * 0.5
    tpu_us = flops / PEAK * 1e6
    return [("flash_attention_xla_cpu", us, f"S={S},H={H}"),
            ("flash_attention_tpu_roofline", tpu_us,
             f"{flops/1e9:.1f}GFLOP @197TF/s")]


def bench_decode_attention():
    from repro.kernels.decode_attention.ref import decode_attention_ref
    B, H, K, T, hd = 8, 32, 8, 8192, 128
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, hd), jnp.float32)
    kc = jax.random.normal(key, (B, K, T, hd)).astype(jnp.bfloat16)
    vc = jax.random.normal(key, (B, K, T, hd)).astype(jnp.bfloat16)
    f = jax.jit(lambda a, b, c: decode_attention_ref(a, b, c, jnp.int32(T)))
    us = _time(f, q, kc, vc)
    bytes_moved = kc.size * 2 * 2
    tpu_us = bytes_moved / HBM_BW * 1e6
    return [("decode_attention_ref_cpu", us, f"T={T},B={B}"),
            ("decode_attention_tpu_roofline", tpu_us,
             f"KV {bytes_moved/1e6:.0f}MB @819GB/s")]


def bench_wkv():
    from repro.models.layers import _wkv_chunked
    B, T, H, N = 2, 1024, 8, 64
    key = jax.random.PRNGKey(0)
    shp = (B, T, H, N)
    r = jax.random.normal(key, shp) * 0.5
    k = jax.random.normal(key, shp) * 0.5
    v = jax.random.normal(key, shp)
    w = -jnp.exp(jax.random.normal(key, shp) - 2)
    u = jax.random.normal(key, (H, N)) * 0.1
    f = jax.jit(lambda *a: _wkv_chunked(*a, chunk=32)[0])
    us = _time(f, r, k, v, w, u)
    flops = 4 * B * T * H * N * N
    return [("wkv_chunked_cpu", us, f"T={T},H={H},N={N}"),
            ("wkv_tpu_roofline", flops / PEAK * 1e6,
             f"{flops/1e9:.2f}GFLOP @197TF/s")]


def all_benches():
    rows = []
    for fn in (bench_version_gather, bench_rss_gather, bench_rss_scan_agg,
               bench_flash_attention, bench_decode_attention, bench_wkv):
        rows.extend(fn())
    return rows


def gather_kernels_report() -> dict:
    """Measured CPU-ref GB/s + roofline GB/s for the two snapshot-read
    kernels — the perf-trajectory record `benchmarks/run.py` persists to
    BENCH_kernels.json."""
    report = {}
    for name, rows, nbytes in (
            ("version_gather", bench_version_gather(), _gather_bytes()),
            ("rss_gather", bench_rss_gather(), _gather_bytes(RSS_M))):
        (_, cpu_us, shape), (_, tpu_us, _) = rows
        report[name] = {
            "shape": shape,
            "bytes_moved_mb": round(nbytes / 1e6, 1),
            "cpu_ref_us": round(cpu_us, 1),
            "cpu_ref_gbps": round(nbytes / 1e9 / (cpu_us / 1e6), 2),
            "tpu_roofline_us": round(tpu_us, 1),
            "tpu_roofline_gbps": HBM_BW / 1e9,
        }
    return report
