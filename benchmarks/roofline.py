"""Roofline postprocessing: dry-run JSON -> per-cell three-term table.

Terms (seconds/step/device), TPU v5e constants:
    compute    = HLO_FLOPs_total / 197e12
    memory     = HLO_bytes_total / 819e9
    collective = collective_bytes_total / 50e9   (per-link ICI)

HLO totals come from the scan-corrected cost-model lowerings
(results/costmodel_all.json, see launch/dryrun.py::run_cost_model);
per-device memory residency comes from the full compiles
(results/dryrun_all.json).  MODEL_FLOPS is the analytic useful-work
model (6·N_active·tokens for train, 2·N_active for inference, plus the
attention/SSM terms documented below); the ratio MODEL/HLO exposes
remat/dispatch/dequant waste.
"""

from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
def _pick(*names):
    for n in names:
        p = os.path.join(HERE, "results", n)
        if os.path.exists(p):
            return p
    return os.path.join(HERE, "results", names[0])


DRYRUN_JSON = _pick("dryrun_final.json", "dryrun_all.json")
COST_JSON = _pick("costmodel_final.json", "costmodel_all.json")


def model_flops_per_device(cfg, shape, n_dev: int) -> float:
    """Analytic useful FLOPs per device per step (documented in
    EXPERIMENTS.md §Roofline).  Matmul term + attention + SSM/WKV scans;
    MoE dispatch one-hot matmuls and remat recompute are deliberately
    EXCLUDED (they show up as HLO-vs-model waste)."""
    S, B = shape.seq_len, shape.global_batch
    train = shape.kind == "train"
    mult = 6 if train else 2
    if shape.kind == "decode":
        tokens = B                  # one new token per sequence
    else:
        tokens = B * S
    total = mult * cfg.active_param_count() * tokens

    d_attn = cfg.n_heads * cfg.head_dim
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.pattern[i % cfg.period].mixer == "attn")
    W = cfg.sliding_window or S
    ctx = min(S, W)
    if shape.kind == "decode":
        attn = 4.0 * B * ctx * d_attn * n_attn
        if cfg.is_encoder_decoder:
            attn += 4.0 * B * cfg.encoder_len * d_attn * cfg.n_layers
    else:
        pairs = B * S * ctx * (0.5 if ctx == S else 1.0)   # causal halves
        fwd = 4.0 * pairs * d_attn * n_attn
        attn = 3 * fwd if train else fwd
    total += attn

    n_mamba = sum(1 for i in range(cfg.n_layers)
                  if cfg.pattern[i % cfg.period].mixer == "mamba")
    if n_mamba:
        scan = 6.0 * tokens * cfg.mamba_d_inner * cfg.mamba_d_state * n_mamba
        total += (3 * scan if train else scan)
    n_rwkv = sum(1 for i in range(cfg.n_layers)
                 if cfg.pattern[i % cfg.period].mixer == "rwkv")
    if n_rwkv:
        scan = 4.0 * tokens * cfg.d_model * cfg.rwkv_head_dim * n_rwkv
        total += (3 * scan if train else scan)
    return total / n_dev


def load_cells():
    with open(DRYRUN_JSON) as f:
        dry = json.load(f)
    cost = []
    if os.path.exists(COST_JSON):
        with open(COST_JSON) as f:
            cost = json.load(f)
    cost_by = {(c["arch"], c["shape"]): c for c in cost
               if "skipped" not in c}
    return dry, cost_by


def build_table():
    from repro.configs import SHAPES, get_config
    dry, cost_by = load_cells()
    rows = []
    for cell in dry:
        if "skipped" in cell or "error" in cell:
            continue
        if cell.get("n_devices") != 256:      # roofline table: single pod
            continue
        arch, shape_name = cell["arch"], cell["shape"]
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        cm = cost_by.get((arch, shape_name))
        if cm is None:
            continue
        flops = cm["flops_total"]
        byts = cm["bytes_accessed_total"]
        coll = max(cm["collective_bytes_total"], 0.0)
        t_c = flops / PEAK_FLOPS
        t_m = byts / HBM_BW
        t_i = coll / ICI_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_i)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops_per_device(cfg, shape, 256)
        bound = max(t_c, t_m, t_i)
        rows.append({
            "arch": arch, "shape": shape_name,
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_i,
            "dominant": dom,
            "model_flops_dev": mf,
            "hlo_flops_dev": flops,
            "useful_ratio": mf / flops if flops else 0.0,
            "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
            "hbm_bytes_dev": cell.get("argument_size_in_bytes", -1),
            "temp_bytes_dev": cell.get("temp_size_in_bytes", -1),
        })
    return rows


NOTES = {
    "compute": "increase arithmetic efficiency: cut remat recompute / "
               "dispatch overhead or raise per-chip work (fewer, larger "
               "matmuls)",
    "memory": "cut HBM traffic: fuse elementwise chains, keep working set "
              "in VMEM (bigger kernel blocks), reduce optimizer/activation "
              "precision",
    "collective": "re-shard to shrink cross-chip traffic: FSDP prefetch "
                  "overlap, 2D sharding of the dominant all-gather, or move "
                  "the axis with the largest collectives onto faster links",
}


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO | roofline frac |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} |")
    return "\n".join(lines)


def main():
    rows = build_table()
    md = to_markdown(rows)
    out = os.path.join(HERE, "results", "roofline.md")
    with open(out, "w") as f:
        f.write(md + "\n")
    print(md)
    print(f"\nwrote {out} ({len(rows)} cells)")
    return rows


if __name__ == "__main__":
    main()
