"""Materialized-aggregate serve cost: O(delta) vs O(table).

Controlled mirror-level sweep (no driver noise): one `PagedMirror` with
a registered `MaterializedView` vs an identical mirror serving the same
plan through the fused-scan path.  Each iteration applies a
fixed-size write batch (the delta), then serves the aggregate both
ways and checks them against a host oracle — so the numbers measure
exactly the serve paths, and correctness is asserted in-run.

Headline: per-query materialized serve cost stays FLAT (within
``FLATNESS_BOUND``) as the table grows >= 8x, while the fused scan's
cost grows with table size — the incremental tile folds only the
delta, never rescans the table.

Standalone: ``PYTHONPATH=src python -m benchmarks.bench_materialized``
(persists the ``materialized`` section of BENCH_kernels.json; --smoke
skips persistence).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

# materialized serve cost across the table-size sweep must stay within
# this ratio of its smallest-table cost (the O(delta) claim)
FLATNESS_BOUND = 1.5
WRITES_PER_ITER = 16


def _ops():
    """Additive lanes only: the flatness headline measures the pure
    O(delta) fold.  Min/max lanes demote to a partial O(table) rescan
    when their bound is retracted — costed separately in
    `minmax_demotion_report`."""
    from repro.tensorstore import AggOp
    return (AggOp("sum", "int"), AggOp("count", "int"),
            AggOp("count_below", "int", 50),
            AggOp("count_above", "int", 150))


def _oracle(vals: dict) -> tuple:
    xs = list(vals.values())
    return (sum(xs), len(xs), sum(1 for x in xs if x < 50),
            sum(1 for x in xs if x > 150))


def _commit(mirrors, lsn: int, seq: int, writes) -> None:
    from repro.core.wal import WalRecord
    rec = WalRecord(lsn=lsn, type="commit", txn=seq, writes=tuple(writes),
                    seq=seq)
    for m in mirrors:
        m.apply(rec)


def _serve_us(fn, iters: int, warmup: int = 5) -> float:
    """Mean us/call.  Warmup covers the jit traces (fold, scan, demote
    rescan) AND runs the oracle assertion; timed iterations skip the
    O(table) host oracle so it can't mask the serve-path scaling."""
    for _ in range(warmup):
        fn(check=True)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(check=False)
    us = (time.perf_counter() - t0) / iters * 1e6
    fn(check=True)              # post-run: the timed state is still exact
    return us


def materialized_sweep(*, table_sizes=(256, 512, 1024, 2048),
                       iters: int = 20, seed: int = 11) -> dict:
    """table size -> per-serve cost of the materialized vs fused path,
    at a FIXED write rate (``WRITES_PER_ITER`` updates per iteration)."""
    from repro.tensorstore import MultiAggPlan, PagedMirror

    rng = np.random.default_rng(seed)
    ops = _ops()
    sweep: dict[int, dict] = {}
    for n in table_sizes:
        keys = tuple(f"it{i:06d}" for i in range(n))
        plan = MultiAggPlan(keys, ops)
        mat, fused = PagedMirror(), PagedMirror()
        vals = {k: int(rng.integers(0, 200)) for k in keys}
        _commit((mat, fused), 1, 1, vals.items())
        # one seeding scan, O(table).  use_kernel=False: on this CPU
        # container Pallas runs in interpret mode, so wall-time measures
        # the jitted REFERENCE fold (same convention as bench_kernels)
        mat.register_view(plan, use_kernel=False)
        lsn = seq = 1

        def step():
            nonlocal lsn, seq
            lsn, seq = lsn + 1, seq + 1
            batch = {keys[i]: int(rng.integers(0, 200))
                     for i in rng.choice(n, WRITES_PER_ITER,
                                         replace=False)}
            vals.update(batch)
            _commit((mat, fused), lsn, seq, batch.items())

        def serve(mirror, check):
            out, _ = mirror.execute_with_writers(plan, mirror.watermark,
                                                 need_writers=False)
            if check:
                assert tuple(out) == _oracle(vals), (n, out, _oracle(vals))
            # no pinned readers in this loop: the fold bookkeeping floor
            # advances with the watermark (what the facades' gc does)
            mirror.gc_views(mirror.watermark)
            return out

        mat_us = _serve_us(lambda check: (step(), serve(mat, check)), iters)
        fused_us = _serve_us(lambda check: (step(), serve(fused, check)),
                             iters)
        stats = dict(mat.exec_stats)
        assert stats["view_hits"] >= iters, stats    # every mat serve hit
        sweep[n] = {
            "materialized_us": round(mat_us, 1),
            "fused_scan_us": round(fused_us, 1),
            "view_hits": stats["view_hits"],
            "view_fallbacks": stats["view_fallbacks"],
        }

    lo, hi = min(table_sizes), max(table_sizes)
    flatness = round(
        max(r["materialized_us"] for r in sweep.values()) /
        max(min(r["materialized_us"] for r in sweep.values()), 1e-9), 3)
    fused_growth = round(
        sweep[hi]["fused_scan_us"] / max(sweep[lo]["fused_scan_us"], 1e-9),
        3)
    report = {
        "sweep": sweep,
        "writes_per_iter": WRITES_PER_ITER,
        "table_growth": round(hi / lo, 1),
        "materialized_flatness": flatness,
        "fused_growth": fused_growth,
        "flatness_bound": FLATNESS_BOUND,
        "headline_speedup": round(
            sweep[hi]["fused_scan_us"] / sweep[hi]["materialized_us"], 2),
    }
    # the O(delta) claim, asserted on real timings: flat materialized
    # serves across an >=8x table-growth sweep that visibly inflates the
    # fused scan.  Only enforced on full-scale sweeps — smoke tables are
    # too small for stable timing ratios.
    if hi >= 8 * lo:
        assert flatness <= FLATNESS_BOUND, report
        assert fused_growth > FLATNESS_BOUND, report
    return report


def minmax_demotion_report(*, n: int = 1024, iters: int = 40,
                           seed: int = 13) -> dict:
    """Cost of the non-subtractable lanes: a min/max view serves O(delta)
    until a write retracts the attained bound, then demotes that lane to
    ONE partial rescan.  Reports the demotion rate and the mean serve
    cost with demotions amortized in — bounded by the fused scan, since
    a demotion IS a (single-lane) scan."""
    from repro.tensorstore import AggOp, MultiAggPlan, PagedMirror

    rng = np.random.default_rng(seed)
    keys = tuple(f"mm{i:06d}" for i in range(n))
    plan = MultiAggPlan(keys, (AggOp("min", "int"), AggOp("max", "int")))
    mat, fused = PagedMirror(), PagedMirror()
    vals = {k: int(rng.integers(0, 200)) for k in keys}
    _commit((mat, fused), 1, 1, vals.items())
    mat.register_view(plan, use_kernel=False)
    lsn = seq = 1

    def step_serve(mirror, check):
        nonlocal lsn, seq
        lsn, seq = lsn + 1, seq + 1
        batch = {keys[i]: int(rng.integers(0, 200))
                 for i in rng.choice(n, WRITES_PER_ITER, replace=False)}
        vals.update(batch)
        _commit((mat, fused), lsn, seq, batch.items())
        out, _ = mirror.execute_with_writers(plan, mirror.watermark,
                                             need_writers=False)
        if check:
            xs = vals.values()
            assert tuple(out) == (min(xs), max(xs)), \
                (out, min(xs), max(xs))
        mirror.gc_views(mirror.watermark)

    mat_us = _serve_us(lambda check: step_serve(mat, check), iters)
    fused_us = _serve_us(lambda check: step_serve(fused, check), iters)
    stats = dict(mat.exec_stats)
    return {
        "table_size": n,
        "materialized_us": round(mat_us, 1),
        "fused_scan_us": round(fused_us, 1),
        "view_hits": stats["view_hits"],
        "demotions": stats["view_demotions"],
        "demotion_rate": round(stats["view_demotions"]
                               / max(stats["view_hits"], 1), 3),
    }


def bench_rows(report: dict) -> list[tuple[str, float, str]]:
    """CSV rows (name, us_per_call, derived) for benchmarks.run."""
    rows = []
    for n, r in report["sweep"].items():
        rows.append((f"materialized:P={n}", r["materialized_us"],
                     f"fused_scan={r['fused_scan_us']}us;"
                     f"hits={r['view_hits']}"))
    rows.append(("materialized:headline", 0.0,
                 f"flatness=x{report['materialized_flatness']}"
                 f"_over_x{report['table_growth']}_table_growth;"
                 f"fused_growth=x{report['fused_growth']};"
                 f"speedup=x{report['headline_speedup']}"))
    mm = report.get("minmax")
    if mm:
        rows.append((f"materialized:minmax:P={mm['table_size']}",
                     mm["materialized_us"],
                     f"fused_scan={mm['fused_scan_us']}us;"
                     f"demotions={mm['demotions']}/"
                     f"{mm['view_hits']}_serves"))
    return rows


def full_report(smoke: bool = False) -> dict:
    report = materialized_sweep(
        table_sizes=(64, 128) if smoke else (256, 512, 1024, 2048),
        iters=3 if smoke else 20)
    report["minmax"] = minmax_demotion_report(
        n=64 if smoke else 1024, iters=3 if smoke else 40)
    return report


def main(smoke: bool = False) -> None:
    report = full_report(smoke=smoke)
    for name, us, derived in bench_rows(report):
        print(f"{name},{us:.1f},{derived}")
    if not smoke:
        from .persist import persist_bench_sections
        print(persist_bench_sections(materialized=report))


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    main(smoke=ap.parse_args().smoke)
