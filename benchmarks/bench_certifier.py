"""Certifier abort-rate-vs-throughput sweep (certifier x contention).

Replays the contended write-skew stress workload (`repro.mvcc.workload.
write_skew` via `driver.run_write_skew`) under each commit-certification
policy and records, per (certifier, contention) cell: commit throughput,
total/certification abort counts, and the per-AbortReason breakdown.

The headline claim this bench pins down: the commit-order-precise SSI and
SSN certifiers admit strictly more behavior than the conservative
structural-pivot rule — strictly fewer certification (writer) aborts at
equal-or-better commit throughput, at every contention level — while every
committed history remains serializable (that part is asserted by the test
suite and `scripts/verify.sh`; here we record the performance side).

Standalone run persists the report to BENCH_kernels.json under the
``certifier_aborts`` section:  PYTHONPATH=src python -m benchmarks.bench_certifier
"""

from __future__ import annotations

import time

CERTS = ("conservative-ssi", "commit-order-ssi", "ssn")
REFINED = ("commit-order-ssi", "ssn")


def certifier_sweep(contentions=(0.25, 0.5, 0.9), rounds: int = 2000,
                    n_clients: int = 8, seed: int = 0) -> dict:
    """Run the certifier x contention matrix; returns a report dict with
    one cell per run plus the refined-strictly-better headline checks."""
    from repro.mvcc import run_write_skew

    sweep: dict = {}
    for contention in contentions:
        for cert in CERTS:
            t0 = time.perf_counter()
            m, e = run_write_skew(certifier=cert, n_clients=n_clients,
                                  contention=contention, rounds=rounds,
                                  seed=seed)
            wall = time.perf_counter() - t0
            denom = max(m.oltp_commits + m.oltp_aborts, 1)
            sweep[f"{cert}:c={contention}"] = {
                "certifier": m.certifier,
                "contention": contention,
                "commits": m.oltp_commits,
                "aborts": m.oltp_aborts,
                "writer_aborts": e.stats["writer_aborts"],
                "ww_aborts": e.stats["ww_aborts"],
                "by_reason": dict(e.stats["by_reason"]),
                "abort_rate": round(m.oltp_aborts / denom, 4),
                "tps": round(m.oltp_commits / rounds, 4),
                "wall_s": round(wall, 3),
            }

    checks = []
    for contention in contentions:
        base = sweep[f"conservative-ssi:c={contention}"]
        for cert in REFINED:
            r = sweep[f"{cert}:c={contention}"]
            checks.append({
                "certifier": cert,
                "contention": contention,
                "fewer_writer_aborts":
                    r["writer_aborts"] < base["writer_aborts"],
                "no_worse_commits": r["commits"] >= base["commits"],
                "ok": (r["writer_aborts"] < base["writer_aborts"]
                       and r["commits"] >= base["commits"]),
            })
    return {
        "sweep": sweep,
        "rounds": rounds,
        "n_clients": n_clients,
        "checks": checks,
        "refined_strictly_better": all(c["ok"] for c in checks),
    }


def bench_rows(report: dict):
    """CSV rows in the suite-wide ``name,us_per_call,derived`` shape."""
    for cell, r in report["sweep"].items():
        yield (f"certifier:{cell}", r["wall_s"] * 1e6 / max(r["commits"], 1),
               f"commits={r['commits']};aborts={r['aborts']};"
               f"writer_aborts={r['writer_aborts']};"
               f"abort_rate={r['abort_rate']}")
    yield ("certifier:headline", 0,
           "refined_strictly_fewer_writer_aborts="
           f"{report['refined_strictly_better']}")


def main() -> None:
    report = certifier_sweep()
    for name, us, derived in bench_rows(report):
        print(f"{name},{us:.1f},{derived}")
    from .persist import persist_bench_sections
    print(f"bench_kernels_json,0,"
          f"{persist_bench_sections(certifier_aborts=report)}")


if __name__ == "__main__":
    main()
