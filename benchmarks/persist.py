"""Shared BENCH_kernels.json persistence (merge semantics).

Every benchmark entry point updates only its own sections of the repo-root
BENCH_kernels.json, so standalone runs (`python -m benchmarks.bench_freshness`)
and the full suite (`python -m benchmarks.run`) never clobber each other's
records.
"""

from __future__ import annotations

import json
import os

BENCH_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_kernels.json")


def persist_bench_sections(**sections) -> str:
    """Merge the given top-level sections into BENCH_kernels.json; returns
    the file path."""
    blob = {}
    if os.path.exists(BENCH_PATH):
        with open(BENCH_PATH) as f:
            blob = json.load(f)
    blob.update(sections)
    with open(BENCH_PATH, "w") as f:
        json.dump(blob, f, indent=2, sort_keys=True)
    return BENCH_PATH
