"""Decoupled-storage replica cluster: N-way WAL fan-out with lag-aware
RSS snapshot routing (the paper's Sec 5.1 architecture at N > 1).

One OLTP primary ships its WAL to three replicas on skewed cadences, so the
fleet carries genuinely different replication lags.  The demo then shows:

  1. fan-out + bounded log: every replica applies the same stream; the
     primary recycles the WAL only up to min(applied LSN) across consumers,
  2. routing policies: freshest / round_robin / bounded_staleness, and the
     ship-then-serve fallback when every replica is too stale,
  3. serializability across the fleet: every replica's RSS snapshot serves
     the same wait-free, abort-free reads the primary's protected readers
     see — regardless of its lag,
  4. the cluster-wide GC floor: version chains prune everywhere once the
     laggiest replica (or oldest pin) moves past them.

    PYTHONPATH=src python examples/cluster_fanout.py
"""

import random

from repro.cluster import make_policy
from repro.mvcc import MultiNodeHTAP
from repro.tensorstore import ScanPlan


def oltp_burst(eng, rng, n_txns):
    """A burst of small writer transactions (some concurrency, some deps)."""
    for _ in range(n_txns):
        t = eng.begin()
        for _ in range(rng.randint(1, 3)):
            eng.write(t, f"k{rng.randrange(8)}", rng.randrange(1000))
        try:
            eng.commit(t)
        except Exception:
            pass


def show_lags(htap, label):
    cl = htap.cluster
    lags = [cl.lag_records(i) for i in range(len(cl))]
    print(f"  {label}: wal [{htap.primary.wal.base_lsn}.."
          f"{htap.primary.wal.head_lsn}]  replica lags {lags} records")


def main():
    rng = random.Random(0)
    htap = MultiNodeHTAP("ssi+rss", n_replicas=3,
                         route_policy="bounded_staleness", max_staleness=30)
    eng = htap.primary
    cl = htap.cluster
    print(f"cluster: 1 primary -> {len(cl)} replicas "
          f"(policy={cl.policy.name}, max_lag={cl.policy.max_lag} records)")

    # -- 1. skewed fan-out + min-LSN log recycling --------------------------
    print("\n-- skewed fan-out: replicas ship on different cadences --")
    for round_ in range(3):
        oltp_burst(eng, rng, 12)
        htap.ship_log(replica=0)                 # replica 0: every round
        if round_ % 2 == 0:
            htap.ship_log(replica=1)             # replica 1: every other
        show_lags(htap, f"round {round_} (replica 2 never shipped)")
    assert eng.wal.base_lsn == cl.min_applied_lsn() == 0
    print("  -> laggiest consumer holds the log: base_lsn stays 0")
    htap.ship_log(replica=2)
    show_lags(htap, "after replica 2 finally ships")
    print(f"  -> WAL recycled up to min applied LSN "
          f"({cl.stats['truncated_records']} records)")

    # -- 2. routing policies ------------------------------------------------
    print("\n-- routing: who serves the next snapshot? --")
    oltp_burst(eng, rng, 10)
    htap.ship_log(replica=0)                     # make the lags unequal
    show_lags(htap, "state")
    for policy in ("freshest", "round_robin"):
        picks = []
        cl.policy = make_policy(policy)
        for _ in range(4):
            h = htap.olap_snapshot()
            picks.append(h[1])
            htap.olap_release(h)
        print(f"  {policy:17s} -> replicas {picks}")
    cl.policy = make_policy("bounded_staleness", max_lag=5)
    oltp_burst(eng, rng, 6)                      # now EVERY replica is stale
    before = cl.stats["ship_then_serve"]
    h = htap.olap_snapshot()
    print(f"  bounded(max_lag=5) -> replica {h[1]} "
          f"(ship-then-serve: +{cl.stats['ship_then_serve'] - before} "
          f"sync round, lag now {cl.lag_records(h[1])})")
    htap.olap_release(h)

    # -- 3. fleet-wide serializable snapshot reads --------------------------
    print("\n-- every replica serves the same wait-free RSS reads --")
    t = eng.begin(); eng.write(t, "k0", 7777)    # stays active: not Clear
    oltp_burst(eng, rng, 4)
    htap.ship_log()                              # whole fleet to head
    keys = [f"k{i}" for i in range(4)]
    rows = []
    for i in range(len(cl)):
        rid, snap = cl.replicas[i].rss_snapshot()
        rows.append(cl.replicas[i].execute_rss(snap,
                                               ScanPlan(tuple(keys))))
        cl.replicas[i].release(rid)
    assert rows[0] == rows[1] == rows[2]
    print(f"  scan {keys} -> {rows[0]}  (identical on all 3 replicas; "
          f"active txn's write invisible)")
    eng.abort(t)

    # -- 4. cluster-wide GC floor -------------------------------------------
    print("\n-- cluster-wide GC floor --")
    oltp_burst(eng, rng, 20)
    htap.ship_log(replica=0)
    held = htap.gc_versions()
    floor = cl.gc_floor_seq()
    print(f"  replicas 1,2 lag -> floor seq {floor}, pruned {held} versions")
    htap.ship_log()
    pruned = htap.gc_versions()
    print(f"  fleet caught up  -> floor seq {cl.gc_floor_seq()}, "
          f"pruned {pruned} more (chains bounded everywhere)")
    print("\ncluster fan-out demo OK")


if __name__ == "__main__":
    main()
