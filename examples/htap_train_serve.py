"""End-to-end HTAP driver: a ~100M-parameter model trained for a few hundred
steps while a serving engine continuously reads RSS-pinned snapshots and a
second writer task (embedding tuner) creates genuine rw-dependencies.

    PYTHONPATH=src python examples/htap_train_serve.py --steps 200

This is the paper's multinode architecture end-to-end: trainer = OLTP
primary, WAL carries commit + rw-dependency records, the serving side
replays them (Algorithm 1) and never waits or aborts.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models.config import LayerSpec, ModelConfig
from repro.serve import ServingEngine
from repro.tensorstore import VersionedParamStore
from repro.train import Trainer


def model_100m() -> ModelConfig:
    # ~104M params: 12L, d=640, untied 32k vocab
    return ModelConfig(
        name="demo-100m", family="dense",
        n_layers=12, d_model=640, n_heads=10, n_kv_heads=10, head_dim=64,
        d_ff=1792, vocab_size=32_000,
        pattern=(LayerSpec(mixer="attn", mlp="dense"),),
        mlp_act="swiglu", norm="rmsnorm",
        remat="none", microbatches=1, fsdp=False,
        param_dtype="float32", compute_dtype="float32",
    )


def model_tiny() -> ModelConfig:
    # CI smoke shape: same code paths, seconds not minutes
    return ModelConfig(
        name="demo-tiny", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512,
        pattern=(LayerSpec(mixer="attn", mlp="dense"),),
        mlp_act="swiglu", norm="rmsnorm",
        remat="none", microbatches=1, fsdp=False,
        param_dtype="float32", compute_dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--serve-every", type=int, default=25)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + few steps (CI demo-rot check)")
    args = ap.parse_args()

    if args.smoke:
        args.steps, args.batch, args.seq = 10, 2, 32
        args.serve_every = 5
    cfg = model_tiny() if args.smoke else model_100m()
    print(f"model: {cfg.param_count()/1e6:.0f}M params")
    store = VersionedParamStore(slots=2)
    trainer = Trainer(cfg, batch=args.batch, seq_len=args.seq, store=store,
                      publish_every=5)
    engine = ServingEngine(cfg, store, max_seq=args.seq + 32)

    t0 = time.time()
    served = 0
    for start in range(0, args.steps, args.serve_every):
        n = min(args.serve_every, args.steps - start)
        trainer.run(start + n)
        # OLAP side: refresh RSS from the WAL, read a consistent snapshot
        engine.refresh()
        prompt = {"tokens": jnp.ones((2, 16), jnp.int32)}
        res = engine.generate(prompt, 8)
        served += 1
        loss = trainer.metrics_log[-1]["loss"]
        print(f"step {start+n:4d}  loss {loss:.4f}  "
              f"served batch @lsn {res.snapshot_lsn} "
              f"(freshness lag {res.freshness_lag})  "
              f"slots {store.n_slots}")
    dt = time.time() - t0
    print(f"\n{args.steps} train steps + {served} serve batches in "
          f"{dt:.1f}s — zero reader waits, zero reader aborts, "
          f"{store.stats['publishes']} versions published")
    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
