"""Unified observability layer, end to end on a live HTAP run.

One registry (`repro.obs.REGISTRY`) carries every layer's counters,
gauges, and fixed-bucket latency histograms; one tracer
(`repro.obs.TRACER`) captures span trees of the two hot paths:

    oltp_commit -> certify -> wal_emit
    olap_serve  -> route -> [mirror_execute] resolve -> kernel_dispatch
                   -> finalize

The demo runs the single-node HTAP driver with span capture ON, then
shows what an operator gets for free:

  1. p50/p95/p99 serve latency, per plan kind and per stage,
  2. OLTP commit latency with the certify/WAL split,
  3. a trace-tree dump of the most recent serves,
  4. cross-layer consistency (mirror dispatches == kernel launches;
     engine commits == driver-observed commits; span trees balanced),
  5. the Prometheus text exposition + JSON snapshot exports.

    PYTHONPATH=src python examples/observability_demo.py
"""

from repro.mvcc import run_single_node
from repro.obs import REGISTRY, TRACER


def fmt(s: dict) -> str:
    return (f"n={s['count']:<4d} p50={s['p50_us']:>8.1f}us "
            f"p95={s['p95_us']:>9.1f}us p99={s['p99_us']:>9.1f}us")


def main() -> None:
    TRACER.set_enabled(True)      # == REPRO_TRACE=1; off by default
    try:
        m = run_single_node(olap_mode="ssi+rss", oltp_clients=3,
                            olap_clients=3, rounds=600, seed=3,
                            olap_scan=True, paged_olap=True,
                            batch_plans=True)
    finally:
        TRACER.set_enabled(None)

    print("1) OLAP serve latency (end to end)")
    print(f"   all plans        {fmt(m.serve_latency)}")
    for plan, s in sorted(m.serve_latency_by_plan.items()):
        print(f"   {plan:<16s} {fmt(s)}")

    print("\n2) serve-path stages + OLTP commit latency")
    for stage in ("route", "resolve", "dispatch", "finalize"):
        if stage in m.serve_stage_latency:
            print(f"   {stage:<16s} {fmt(m.serve_stage_latency[stage])}")
    print(f"   oltp_commit      {fmt(m.oltp_commit_latency)}")
    print(f"     certify        "
          f"{fmt(REGISTRY.hist_summary('oltp_certify_seconds'))}")
    print(f"     wal_emit       "
          f"{fmt(REGISTRY.hist_summary('oltp_wal_seconds'))}")

    print("\n3) most recent trace trees (REPRO_TRACE=1)")
    print(TRACER.render(limit=2))

    print("\n4) cross-layer consistency")
    assert m.olap_agg_dispatches == m.olap_kernel_dispatches
    assert REGISTRY.total("engine_commits") == m.oltp_commits \
        + m.olap_commits
    assert TRACER.opened == TRACER.closed and TRACER.depth == 0
    print(f"   mirror agg dispatches == kernel dispatches "
          f"({m.olap_agg_dispatches})")
    print(f"   engine commits == driver oltp+olap commits "
          f"({m.oltp_commits + m.olap_commits})")
    print(f"   span trees balanced ({TRACER.opened} opened == "
          f"{TRACER.closed} closed, depth 0)")

    print("\n5) exports")
    prom = REGISTRY.render_prometheus()
    wanted = ("engine_commits", "olap_serve_seconds_bucket",
              "kernel_launch_dispatches")
    lines = [ln for ln in prom.splitlines()
             if any(ln.startswith(w) for w in wanted)]
    print("   prometheus text ({} lines total), e.g.:".format(
        len(prom.splitlines())))
    for ln in lines[:3] + lines[-2:]:
        print(f"     {ln}")
    print(f"   json snapshot: {len(REGISTRY.to_json())} bytes "
          f"(REGISTRY.to_json())")


if __name__ == "__main__":
    main()
