"""Quickstart: train a reduced model, publish versions to the RSS store,
serve wait-free snapshot reads while training continues.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.serve import ServingEngine
from repro.tensorstore import VersionedParamStore
from repro.train import Trainer


def main():
    # 1. pick an architecture (any of the 10 assigned ids) — reduced config
    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    print(f"arch: {cfg.name}  ({cfg.n_layers}L d={cfg.d_model})")

    # 2. the versioned parameter store is the HTAP boundary: the trainer is
    #    the OLTP writer, serving pins RSS snapshots (wait-/abort-free reads)
    store = VersionedParamStore(slots=2)
    trainer = Trainer(cfg, batch=4, seq_len=32, store=store)

    print("training 5 steps (each step commits a version to the WAL)...")
    logs = trainer.run(5)
    print(f"  loss: {logs[0]['loss']:.3f} -> {logs[-1]['loss']:.3f}")
    print(f"  published versions: {store.stats['publishes']}")

    # 3. serving replays the WAL (Algorithm 1) and reads through the RSS
    engine = ServingEngine(cfg, store, max_seq=64)
    engine.refresh()
    res = engine.generate({"tokens": jnp.ones((2, 8), jnp.int32)}, 6)
    print(f"generated tokens: {res.tokens.shape}, snapshot lsn "
          f"{res.snapshot_lsn}, freshness lag {res.freshness_lag}")

    # 4. wait-freedom: pin a snapshot, keep training — neither side blocks
    pin, _ = store.pin_snapshot()
    trainer.run(3)
    store.release(pin)
    print(f"trained 3 more steps while a reader was pinned "
          f"(ring slots: {store.n_slots}; no waits, no aborts)")


if __name__ == "__main__":
    main()
