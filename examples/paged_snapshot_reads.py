"""Page-granular snapshot reads: the SI-V read protocol on device, with the
version_gather and rss_gather Pallas kernels (interpret mode on CPU).

Part 1: a writer task streams page updates (embedding rows / adapter pages)
into a K-slot paged store while readers resolve consistent snapshots at
different watermarks — including an RSS *member-set* read that skips a newer
version whose writer is outside the RSS (the paper's previous-version read),
served by the rss_gather kernel.

Part 2: the same protocol end-to-end through the HTAP stack — an SSI engine
runs transactions, its WAL is mirrored into the paged store
(`tensorstore.mirror.PagedMirror`), an RSS snapshot is constructed from the
same WAL, and the rss_gather kernel answers a batched membership scan over
the mirrored pages that matches the engine's per-key protected reads.

    PYTHONPATH=src python examples/paged_snapshot_reads.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rss_gather.ops import snapshot_read_members as kernel_members
from repro.kernels.version_gather.ops import snapshot_read
from repro.tensorstore import (init_store, publish_page, snapshot_read_members,
                               snapshot_read_ref)


def main():
    P, K, E = 8, 3, 16
    store = init_store(P, K, E, jnp.float32,
                       initial=jnp.zeros((P, E)))
    print(f"paged store: {P} pages × {K} version slots × {E} elems")

    # writer commits at ts 10, 20, 30 touching different pages
    store = publish_page(store, 2, jnp.full((E,), 1.0), jnp.int32(10))
    store = publish_page(store, 2, jnp.full((E,), 2.0), jnp.int32(20))
    store = publish_page(store, 5, jnp.full((E,), 7.0), jnp.int32(30))

    for wm in (5, 15, 25, 35):
        out = snapshot_read(store, jnp.int32(wm))       # Pallas kernel
        ref = snapshot_read_ref(store, jnp.int32(wm))   # jnp oracle
        assert np.allclose(out, ref)
        print(f"watermark {wm:2d}: page2={float(out[2,0]):.0f} "
              f"page5={float(out[5,0]):.0f}  (kernel == oracle)")

    # RSS member-set read: ts=20's writer is NOT in the RSS (e.g. concurrent
    # with an active txn) -> the reader sees the PREVIOUS version (ts=10);
    # the rss_gather Pallas kernel and the jnp fallback agree.
    members = jnp.asarray([10, 30], jnp.int32)
    out = kernel_members(store, members)             # Pallas rss_gather
    ref = snapshot_read_members(store, members)      # jnp fallback
    assert np.allclose(out, ref)
    print(f"RSS member read (members ts=10,30): page2="
          f"{float(out[2,0]):.0f} (skipped ts=20 non-member) "
          f"page5={float(out[5,0]):.0f}  (rss_gather kernel == oracle)")

    # an EMPTY RSS resolves every page to its initial version
    out = kernel_members(store, jnp.zeros((0,), jnp.int32))
    print(f"empty-RSS read: page2={float(out[2,0]):.0f} "
          f"page5={float(out[5,0]):.0f}  (initial slots)")

    # columnar multi-page gather: a key-range of pages as a device
    # sub-store (dense ranges slice, arbitrary sets gather)
    from repro.tensorstore import gather_pages
    sub = gather_pages(store, [2, 5])
    out = snapshot_read(sub, jnp.int32(35))
    print(f"gather_pages([2,5]) @35: {float(out[0,0]):.0f}, "
          f"{float(out[1,0]):.0f}  (columnar sub-store scan)")

    mirrored_htap_demo()


def mirrored_htap_demo():
    """WAL -> paged mirror -> rss_gather: device-backed OLAP on live HTAP."""
    from repro.core.replica import PRoTManager, RSSManager
    from repro.mvcc import Engine
    from repro.tensorstore import PagedMirror
    from repro.tensorstore.mirror import decode_value

    print("\n-- WAL-mirrored paged store (device-backed OLAP surface) --")
    eng = Engine("ssi")
    t = eng.begin()
    for i in range(6):
        eng.write(t, f"stock:0:{i}", 100)
    eng.commit(t)
    t1 = eng.begin(); eng.write(t1, "stock:0:0", 61); eng.commit(t1)
    t2 = eng.begin()                                   # stays active ...
    eng.write(t2, "stock:0:1", 7)
    t3 = eng.begin(); eng.write(t3, "stock:0:2", 43); eng.commit(t3)
    # ... so t3 is committed but NOT Clear: outside the RSS

    rss = RSSManager()
    prot = PRoTManager(rss)
    rss.catch_up(eng.wal)
    rss.construct()
    mirror = PagedMirror()
    mirror.catch_up(eng.wal, gc_floor=prot.gc_floor_seq())
    _, snap = prot.acquire()
    print(f"mirror: {mirror.n_pages} pages @ lsn {mirror.applied_lsn}, "
          f"RSS floor_seq={snap.floor_seq} "
          f"above-floor members={sorted(snap.txns)}")

    keys = [f"stock:0:{i}" for i in range(6)]
    # batched membership scan on the mirror (numpy fast path)
    host = mirror.scan_members(keys, snap)
    # commit-seq -> member-ts mapping: compressed snapshots carry their own
    # above-floor seqs; the RSSManager export and the mirror's bookkeeping
    # agree (both stamped from WAL commit seqs)
    member_ts = rss.member_seqs(snap)
    assert list(mirror.member_seqs_for(snap)) == member_ts
    # the same scan through the rss_gather Pallas kernel on the exported
    # store: the floor covers the Clear prefix, so the member array stays
    # bounded by the concurrent window
    out = np.asarray(kernel_members(mirror.jnp_store(),
                                    jnp.asarray(member_ts, jnp.int32),
                                    snap.floor_seq))
    dev = [decode_value(out[mirror.page_of[k]]) for k in keys]
    # oracle: the engine's per-key protected reads
    r = eng.begin(read_only=True, rss=snap)
    oracle = [eng.read(r, k) for k in keys]
    assert host == dev == oracle, (host, dev, oracle)
    print(f"RSS scan over mirror: {host}")
    print("  stock:0:0=61 (t1 in RSS), stock:0:2=100 (t3 committed but "
          "concurrent with active t2 -> previous version)")
    print("  mirror scan == rss_gather kernel == engine per-key reads")

    # device-resident OLAP executor: the same read set as ONE fused
    # rss_scan_agg pass — visibility resolve + reduction on device, one
    # scalar back instead of 6 decoded pages
    from repro.tensorstore import (AggOp, AggPlan, ChainVersionStore,
                                   PagedVersionStore)
    plan = AggPlan(tuple(keys), AggOp("count_below", "int", 80))
    fused = PagedVersionStore(mirror).execute(plan, snap)
    chain = ChainVersionStore(eng.store).execute(plan, snap)
    assert fused == chain == sum(1 for v in oracle if v < 80)
    print(f"fused agg (count stock < 80) = {fused}  "
          "(rss_scan_agg kernel == chain-oracle plan == python reduce)")

    group_by_demo()


def group_by_demo():
    """GROUP BY district revenue through BOTH HTAP facades: one
    `GroupByPlan` with compound (sum, count) ops — AVG order value per
    district from a single fused device pass per facade."""
    from repro.mvcc.htap import MultiNodeHTAP, SingleNodeHTAP
    from repro.mvcc.workload import Scale, load_initial
    from repro.tensorstore import AggOp, GroupByPlan, ScanPlan

    print("\n-- plan-first executor: GROUP BY district revenue (AVG via "
          "compound sum+count) --")
    sc = Scale(warehouses=2, districts=2, customers=4, items=8)
    ops = (AggOp("sum", "total"), AggOp("count", "total"))

    def seed_orders(engine):
        load_initial(engine, sc)
        import random
        rng = random.Random(7)
        for w in range(sc.warehouses):
            for d in range(sc.districts):
                for o in range(rng.randrange(1, 4)):
                    t = engine.begin()
                    engine.write(t, f"district:{w}:{d}",
                                 {"next_o_id": o + 1, "ytd": 0})
                    engine.write(t, f"order:{w}:{d}:{o}",
                                 {"items": [1], "total": rng.randrange(50,
                                                                       500)})
                    engine.commit(t)

    def district_plan(dists, dkeys):
        groups = []
        for dk, dist in zip(dkeys, dists):
            _, w, d = dk.split(":")
            hi = (dist or {"next_o_id": 0})["next_o_id"]
            groups.append(tuple(f"order:{w}:{d}:{o}" for o in range(hi)))
        return GroupByPlan(tuple(groups), ops)

    dkeys = sc.all_district_keys()

    # single-node facade: protected reader over the paged mirror
    sn = SingleNodeHTAP("ssi+rss", paged=True, check_scans=True,
                        reserve_keys=sc.key_families())
    seed_orders(sn.engine)
    sn.refresh_rss()
    t = sn.olap_begin()
    dists = sn.olap_execute(t, ScanPlan(tuple(dkeys)))
    rows_single = sn.olap_execute(t, district_plan(dists, dkeys))
    sn.olap_commit(t)

    # multi-node facade: same plan routed through the replica cluster
    mn = MultiNodeHTAP("ssi+rss", paged_olap=True, check_scans=True,
                       n_replicas=2, reserve_keys=sc.key_families())
    seed_orders(mn.primary)
    mn.ship_log()
    snap = mn.olap_snapshot()
    dists = mn.olap_execute(snap, ScanPlan(tuple(dkeys)))
    rows_multi = mn.olap_execute(snap, district_plan(dists, dkeys))
    mn.olap_release(snap)

    assert rows_single == rows_multi    # same WAL -> same snapshot-set read
    for dk, (s, n) in zip(dkeys, rows_single):
        print(f"  {dk}: revenue={s:4d} orders={n} "
              f"avg={s // n if n else 0:3d}")
    print("  single-node == multi-node facade (one fused [groups, 5] tile "
          "per facade; check_scans asserted fused == per-key oracle)")

    materialized_dashboard_demo()


def materialized_dashboard_demo():
    """A production dashboard loop: hot plans registered as materialized
    views serve each refresh from a live device tile advanced by
    commit-delta folds — O(writes since last serve), not O(table)."""
    import random

    from repro.mvcc.htap import SingleNodeHTAP
    from repro.mvcc.workload import Scale, load_initial

    print("\n-- materialized dashboard: commit-delta folds, O(delta) "
          "serves --")
    sc = Scale(warehouses=2, districts=2, customers=4, items=8)
    plan = sc.stock_overview_plan()         # sum/count/min/count_above>90
    htap = SingleNodeHTAP("ssi+rss", paged=True, check_scans=True,
                          reserve_keys=sc.key_families(),
                          materialize=[plan])
    load_initial(htap.engine, sc)
    rng = random.Random(3)
    stock_keys = list(sc.all_stock_keys())
    for tick in range(4):
        for _ in range(3):                  # OLTP traffic between refreshes
            t = htap.oltp_begin()
            htap.engine.write(t, rng.choice(stock_keys),
                              rng.randrange(0, 120))
            htap.engine.commit(t)
        htap.refresh_rss()                  # ships delta, folds into tile
        t = htap.olap_begin()
        s, n, mn, hi = htap.olap_execute(t, plan)
        htap.olap_commit(t)
        print(f"  tick {tick}: stock sum={s} count={n} min={mn} "
              f">90={hi}")
    stats = dict(htap.mirror.exec_stats)
    assert stats["view_hits"] > 0, stats
    print(f"  view hits={stats['view_hits']} "
          f"fallbacks={stats['view_fallbacks']} "
          f"demotions={stats['view_demotions']}  (check_scans asserted "
          "tile == fused scan == per-key oracle every serve)")


if __name__ == "__main__":
    main()
