"""Page-granular snapshot reads: the SI-V read protocol on device, with the
version_gather Pallas kernel (interpret mode on CPU).

A writer task streams page updates (embedding rows / adapter pages) into a
K-slot paged store while readers resolve consistent snapshots at different
watermarks — including an RSS *member-set* read that skips a newer version
whose writer is outside the RSS (the paper's previous-version read).

    PYTHONPATH=src python examples/paged_snapshot_reads.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.version_gather.ops import snapshot_read
from repro.tensorstore import (init_store, publish_page, snapshot_read_members,
                               snapshot_read_ref)


def main():
    P, K, E = 8, 3, 16
    store = init_store(P, K, E, jnp.float32,
                       initial=jnp.zeros((P, E)))
    print(f"paged store: {P} pages × {K} version slots × {E} elems")

    # writer commits at ts 10, 20, 30 touching different pages
    store = publish_page(store, 2, jnp.full((E,), 1.0), jnp.int32(10))
    store = publish_page(store, 2, jnp.full((E,), 2.0), jnp.int32(20))
    store = publish_page(store, 5, jnp.full((E,), 7.0), jnp.int32(30))

    for wm in (5, 15, 25, 35):
        out = snapshot_read(store, jnp.int32(wm))       # Pallas kernel
        ref = snapshot_read_ref(store, jnp.int32(wm))   # jnp oracle
        assert np.allclose(out, ref)
        print(f"watermark {wm:2d}: page2={float(out[2,0]):.0f} "
              f"page5={float(out[5,0]):.0f}  (kernel == oracle)")

    # RSS member-set read: ts=20's writer is NOT in the RSS (e.g. concurrent
    # with an active txn) -> the reader sees the PREVIOUS version (ts=10)
    members = jnp.asarray([10, 30], jnp.int32)
    out = snapshot_read_members(store, members)
    print(f"RSS member read (members ts=10,30): page2="
          f"{float(out[2,0]):.0f} (skipped ts=20 non-member) "
          f"page5={float(out[5,0]):.0f}")


if __name__ == "__main__":
    main()
