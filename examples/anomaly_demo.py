"""The paper's read-only anomaly (Sec 3.3), executed four ways.

Shows h_s = R2(X0) R2(Y0) R1(Y0) W1(Y1) C1 [reader joins] W2(X2) C2 under:
  1. the history-level formalization (cycle T1 -> T3 -> T2 -> T1),
  2. plain SI        — accepts the anomaly (non-serializable!),
  3. SSI             — aborts a transaction (serializable, but costly),
  4. RSS             — the reader is steered to the PREVIOUS versions
                       (X0, Y0): serializable, nobody waits, nobody aborts.

    PYTHONPATH=src python examples/anomaly_demo.py
"""

from repro.core import (construct_rss, find_cycle, is_serializable,
                        latest_versions_in, read_only_anomaly_example)
from repro.mvcc import Engine, SerializationFailure, SingleNodeHTAP


def formal():
    h = read_only_anomaly_example()
    print("1) formal history:", h)
    print("   serializable?", is_serializable(h),
          "  cycle:", find_cycle(h))
    print("   (without the read-only T3 it IS serializable:",
          is_serializable(h.without_txn(3)), ")")


def under(mode: str):
    eng = Engine(mode, record=True)
    t2 = eng.begin()
    eng.read(t2, "X"), eng.read(t2, "Y")
    t1 = eng.begin()
    eng.read(t1, "Y")
    eng.write(t1, "Y", 20)
    eng.commit(t1)
    t3 = eng.begin(read_only=True)
    outcome = "committed all"
    try:
        x, y = eng.read(t3, "X"), eng.read(t3, "Y")
        eng.commit(t3)
        eng.write(t2, "X", -11)
        eng.commit(t2)
    except SerializationFailure as e:
        outcome = f"abort ({e.reason.value})"
        x = y = "-"
    print(f"   reader saw X={x} Y={y}; outcome: {outcome}; committed "
          f"history serializable? {is_serializable(eng.history)}")


def under_rss():
    htap = SingleNodeHTAP("ssi+rss")
    eng = htap.engine
    t2 = htap.oltp_begin()
    eng.read(t2, "X"), eng.read(t2, "Y")
    t1 = htap.oltp_begin()
    eng.read(t1, "Y")
    eng.write(t1, "Y", 20)
    eng.commit(t1)
    htap.refresh_rss()                 # T1 concurrent with active T2 -> NOT
    r = htap.olap_begin()              #   in RSS; reader gets previous Y
    x, y = htap.olap_read(r, "X"), htap.olap_read(r, "Y")
    htap.olap_commit(r)
    eng.write(t2, "X", -11)
    eng.commit(t2)
    print(f"   RSS reader saw X={x} Y={y} (previous versions) — no waits, "
          f"no aborts; writer T2 committed fine")
    rss = construct_rss(eng.history) if eng.history else None


def main():
    formal()
    print("2) plain SI (anomaly admitted):")
    under("si")
    print("3) SSI (serializable via abort):")
    under("ssi")
    print("4) RSS (serializable, wait-/abort-free — the paper):")
    under_rss()


if __name__ == "__main__":
    main()
