"""AdamW with global-norm clipping, configurable moment dtype, and an
optional int8 error-feedback gradient-compression stage (distributed-
optimization trick: quantize the DP-boundary gradient traffic; the residual
is fed back into the next step so the compression is unbiased over time).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    compress: bool = False            # int8 error-feedback compression


def init(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.compress:
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _compress_int8(g: jax.Array, ef: jax.Array):
    """Simulated int8 compression with error feedback: the value that crosses
    the DP boundary is the dequantized int8; the quantization error stays in
    `ef` and is added to the next step's gradient."""
    gf = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    if cfg.compress:
        # two passes (XLA CSE dedups the shared quantization work); avoids
        # is_leaf=tuple tricks that collide with tuple CONTAINERS in the
        # params tree (e.g. the per-period "blocks" tuple)
        new_ef = jax.tree.map(lambda g, e: _compress_int8(g, e)[1],
                              grads, state["ef"])
        grads = jax.tree.map(lambda g, e: _compress_int8(g, e)[0],
                             grads, state["ef"])
    gnorm = global_norm(grads)
    clip_scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    mdt = jnp.dtype(cfg.moment_dtype)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip_scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        mh = m32 / b1c
        vh = v32 / b2c
        step = cfg.lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                         + cfg.weight_decay * p.astype(jnp.float32))
        return ((p.astype(jnp.float32) - step).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    new_params = jax.tree.map(
        lambda p, g, m, v: upd(p, g, m, v)[0],
        params, grads, state["m"], state["v"])
    new_state = {
        "m": jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[1],
                          params, grads, state["m"], state["v"]),
        "v": jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[2],
                          params, grads, state["m"], state["v"]),
        "count": count,
    }
    if cfg.compress:
        new_state["ef"] = new_ef
    return new_params, new_state, {"grad_norm": gnorm}
