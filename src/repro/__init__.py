"""repro: Serializable HTAP with Abort-/Wait-free Snapshot Read (RSS),
reproduced as a multi-pod JAX training/serving framework.

Subpackages:
  core        the paper's contribution (RSS theory, Algorithm 1, SSI, WAL)
  mvcc        executable MVCC engine + HTAP architectures + CH-benchmark
  cluster     N-way WAL fan-out replica cluster + lag-aware RSS routing
  tensorstore versioned parameter/page stores (SI-V snapshot reads)
  models      the 10 assigned architectures, config-driven
  configs     architecture registry (get_config / list_archs)
  kernels     Pallas TPU kernels + jnp oracles
  train/serve training loop (fault-tolerant) and RSS-pinned serving
  optim/data/checkpoint  substrates
  launch      meshes, shardings, dry-run, CLI launchers
"""

__version__ = "1.0.0"
