"""Model / shape / run configuration dataclasses.

A model is a stack of `n_layers` blocks described by a repeating *pattern* of
`LayerSpec`s (period).  Uniform decoders have a period of 1; Jamba's period is
8 (attention at position 4, Mamba elsewhere, MoE on odd positions); Whisper is
an encoder stack + a decoder stack (cross-attention in the decoder).

Scan-over-layers: parameters of each period position are stacked across
periods and the stack is applied with `lax.scan`, keeping compiled HLO size
independent of depth.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence


@dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"        # 'attn' | 'mamba' | 'rwkv'
    mlp: str = "dense"         # 'dense' | 'moe' | 'rwkv_cmix' | 'none'
    causal: bool = True        # False for encoder (bidirectional) attention
    cross_attn: bool = False   # decoder block with cross-attention


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)

    # ---- attention options
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0         # nemotron-style partial rotary
    mrope_sections: tuple[int, ...] = ()   # qwen2-vl M-RoPE (t,h,w) sections
    sliding_window: int = 0            # 0 -> full attention; else SWA window

    # ---- mlp options
    mlp_act: str = "swiglu"            # swiglu | geglu | gelu | relu2
    norm: str = "rmsnorm"              # rmsnorm | layernorm

    # ---- MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25

    # ---- Mamba (hybrid archs)
    mamba_expand: int = 2
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_dt_rank: int = 0             # 0 -> ceil(d_model/16)

    # ---- RWKV6
    rwkv_head_dim: int = 64

    # ---- encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500            # whisper 30 s of audio frames

    # ---- frontend stubs
    input_kind: str = "tokens"         # 'tokens' | 'embeds' (vlm/audio stub)

    # ---- dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # ---- runtime knobs (per-arch defaults; shapes may override)
    remat: str = "full"                # full | dots | none
    unroll_layers: bool = False        # python-loop layers (cost-model HLO)
    scan_chunk: int = 0                # 0=defaults, -1=single-chunk (cost)
    microbatches: int = 1              # gradient-accumulation steps
    fsdp: bool = True                  # shard params/opt over the data axis
    zero2: bool = False                # ZeRO-2: opt-state sharded over data,
                                       # params model-sharded only (no
                                       # per-layer all-gathers in fwd/bwd)
    train_sharding: str = "tp"         # "tp": model axis = tensor parallel;
                                       # "fsdp2d": no TP — batch over data,
                                       # params/opt FSDP over data×model
                                       # (weight gathers cost << activation
                                       # psums at large tokens/device)
    moment_dtype: str = "float32"      # optimizer moments dtype

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.mamba_dt_rank == 0:
            object.__setattr__(self, "mamba_dt_rank",
                               -(-self.d_model // 16))
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.name}: n_layers {self.n_layers} % period " \
            f"{len(self.pattern)} != 0"

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k: SSM/hybrid (any state-based mixer) or all
        attention sliding-window.  Pure full-attention archs are excluded
        (per assignment)."""
        if any(spec.mixer in ("mamba", "rwkv") for spec in self.pattern):
            return True
        return all(spec.mixer != "attn" or self.sliding_window > 0
                   for spec in self.pattern)

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------- param counting
    def param_count(self) -> int:
        """Total parameters (embedding included once if tied)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, nh, nkv = self.head_dim, self.n_heads, self.n_kv_heads
        total = v * d                     # embed
        total += v * d                    # lm head (untied)
        total += d                        # final norm
        mlp_gated = self.mlp_act in ("swiglu", "geglu")

        def attn_params() -> int:
            p = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
            if self.qkv_bias:
                p += nh * hd + 2 * nkv * hd
            return p

        def dense_mlp() -> int:
            return (3 if mlp_gated else 2) * d * f

        def moe_mlp() -> int:
            return self.n_experts * (3 if mlp_gated else 2) * d * f \
                + d * self.n_experts

        def mamba_params() -> int:
            di, ds, dt = self.mamba_d_inner, self.mamba_d_state, self.mamba_dt_rank
            p = d * 2 * di                      # in_proj (x and z)
            p += di * self.mamba_d_conv         # depthwise conv
            p += di * (dt + 2 * ds)             # x -> dt, B, C
            p += dt * di                        # dt_proj
            p += di * ds + di + di              # A_log, D, dt bias
            p += di * d                         # out_proj
            return p

        def rwkv_params() -> int:
            # time-mix: r,k,v,g,o projections + data-dependent decay lora
            p = 5 * d * d
            p += d * 64 + 64 * d                # w lora (decay)
            p += 5 * (d * 32 + 32 * d)          # x lora mixers (tokenshift)
            p += 2 * d                          # time_first (u), decay base
            return p

        def rwkv_cmix() -> int:
            return d * f + f * d                # k, v projections (r gate: +d*d)

        for i in range(self.n_layers):
            spec = self.pattern[i % self.period]
            total += 2 * d                       # norms
            if spec.mixer == "attn":
                total += attn_params()
                if spec.cross_attn:
                    total += attn_params() + d
            elif spec.mixer == "mamba":
                total += mamba_params()
            elif spec.mixer == "rwkv":
                total += rwkv_params()
            if spec.mlp == "dense":
                total += dense_mlp()
            elif spec.mlp == "moe":
                total += moe_mlp()
            elif spec.mlp == "rwkv_cmix":
                total += rwkv_cmix() + d * d
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                total += 2 * d + attn_params() + dense_mlp()
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE top-k instead of all experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp_gated = self.mlp_act in ("swiglu", "geglu")
        per_expert = (3 if mlp_gated else 2) * d * f
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.pattern[i % self.period].mlp == "moe")
        return self.param_count() \
            - n_moe_layers * (self.n_experts - self.top_k) * per_expert


@dataclass(frozen=True)
class ShapeConfig:
    name: str                  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524_288, 1),
}
