"""Model zoo: config-driven transformer/SSM/hybrid stacks."""

from .config import LayerSpec, ModelConfig, ShapeConfig, SHAPES
from .transformer import (init_params, forward, loss_fn, prefill,
                          decode_step, init_cache, cache_spec, embed_inputs)
from .sharding import with_mesh, hint, current_mesh

__all__ = [
    "LayerSpec", "ModelConfig", "ShapeConfig", "SHAPES",
    "init_params", "forward", "loss_fn", "prefill", "decode_step",
    "init_cache", "cache_spec", "embed_inputs",
    "with_mesh", "hint", "current_mesh",
]
