"""Layer math: norms, RoPE/M-RoPE, attention (chunked-flash / decode), MLPs,
MoE (GShard-style capacity dispatch), Mamba (chunked selective scan) and
RWKV6 (chunked WKV).  Pure functions over parameter dicts; everything is
`lax.scan`/`jit`-friendly with static shapes only.

Attention note (TPU adaptation): prefill/train attention is an online-softmax
scan over KV chunks (flash-style) in pure jnp — it never materializes the
S×S score matrix, so 32k-token prefill fits HBM; the Pallas kernels in
`repro.kernels` implement the same contract for the TPU target and are
validated against these functions.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

Params = dict


def eff_chunk(cfg, default: int, T: int) -> int:
    """Scan chunk size: cfg.scan_chunk == -1 lowers single-chunk HLO (the
    cost-model variant where XLA cost analysis sees every op exactly once)."""
    sc = getattr(cfg, "scan_chunk", 0)
    if sc == -1:
        return T
    return sc if sc > 0 else default


# ---------------------------------------------------------------------- norms
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_apply(p: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


def norm_init(d: int, kind: str, dtype) -> Params:
    if kind == "layernorm":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {"w": jnp.ones((d,), dtype)}


# ----------------------------------------------------------------------- RoPE
def rope_cos_sin(positions: jax.Array, rot_dim: int, theta: float):
    """positions [...]; returns cos/sin [..., rot_dim/2] (fp32)."""
    half = rot_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., rot_dim]; cos/sin [..., rot_dim/2] broadcastable."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(x.dtype)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               fraction: float = 1.0) -> jax.Array:
    """x [B, S, H, hd]; positions [B, S] (or [S]).  Partial rotary supported
    (nemotron rope_fraction)."""
    hd = x.shape[-1]
    rot = int(hd * fraction) // 2 * 2
    if positions.ndim == 1:
        positions = positions[None, :]
    cos, sin = rope_cos_sin(positions, rot, theta)       # [B,S,rot/2]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]    # broadcast heads
    if rot == hd:
        return _rotate(x, cos, sin)
    xr, xp = x[..., :rot], x[..., rot:]
    return jnp.concatenate([_rotate(xr, cos, sin), xp], axis=-1)


def apply_mrope(x: jax.Array, positions3: jax.Array,
                sections: tuple[int, ...], theta: float) -> jax.Array:
    """Qwen2-VL multimodal RoPE.  x [B,S,H,hd]; positions3 [3,B,S] gives the
    (temporal, height, width) position streams; `sections` partitions the
    hd/2 frequency pairs among the three streams."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # pick the position stream per frequency-pair index
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=half)  # [half]
    pos = positions3.astype(jnp.float32)                  # [3,B,S]
    pos_sel = jnp.take(pos, sec_id, axis=0)               # [half,B,S]
    ang = jnp.moveaxis(pos_sel, 0, -1) * inv              # [B,S,half]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _rotate(x, cos, sin)


# ----------------------------------------------------------------- attention
def attn_init(key, cfg, dtype, *, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(k1, (d, nh * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, nkv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, nkv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (nh * hd, d)) * s).astype(dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((nh * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    return p


def _qkv(p: Params, x: jax.Array, cfg, kv_x: Optional[jax.Array] = None):
    B, S, _ = x.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    xkv = x if kv_x is None else kv_x
    T = xkv.shape[1]
    q = x @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, nh, hd), k.reshape(B, T, nkv, hd),
            v.reshape(B, T, nkv, hd))


def flash_attention_xla(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, window: int = 0,
                        q_offset: int = 0, kv_len: Optional[jax.Array] = None,
                        chunk: int = 1024) -> jax.Array:
    """Online-softmax attention, scanning KV in chunks.

    q [B,S,H,hd]; k/v [B,T,K,hd] with H = K*G (GQA).  `causal` masks with
    query positions `q_offset + i`; `window`>0 adds sliding-window masking;
    `kv_len` (scalar array) masks out KV positions >= kv_len (decode caches).
    Returns [B,S,H,hd].
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    chunk = min(chunk, T)
    n_chunks = -(-T // chunk)
    Tp = n_chunks * chunk
    if Tp != T:
        pad = [(0, 0), (0, Tp - T), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    qf = (q.reshape(B, S, K, G, hd).astype(jnp.float32)
          * (1.0 / math.sqrt(hd)))
    kc = k.reshape(B, n_chunks, chunk, K, hd)
    vc = v.reshape(B, n_chunks, chunk, K, hd)
    q_pos = q_offset + jnp.arange(S)

    def body(carry, inp):
        m, l, acc = carry
        kj, vj, j = inp
        kv_pos = j * chunk + jnp.arange(chunk)
        s = jnp.einsum("bskgh,btkh->bskgt", qf, kj.astype(jnp.float32))
        mask = jnp.ones((S, chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window > 0:
            mask &= q_pos[:, None] - kv_pos[None, :] < window
        if kv_len is not None:
            mask &= kv_pos[None, :] < kv_len
        mask &= (kv_pos < T)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + p.sum(axis=-1)
        acc_new = acc * scale[..., None] \
            + jnp.einsum("bskgt,btkh->bskgh", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, K, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, S, K, G), jnp.float32)
    a0 = jnp.zeros((B, S, K, G, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
         jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, H, hd).astype(q.dtype)


def attention(p: Params, x: jax.Array, cfg, *, positions, causal=True,
              mrope_positions=None, kv_x: Optional[jax.Array] = None,
              rope: bool = True) -> jax.Array:
    """Full-sequence (train / prefill) attention."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, kv_x)
    chunk = eff_chunk(cfg, 1024, k.shape[1] if kv_x is not None else S)
    if rope and kv_x is None:
        if cfg.mrope_sections and mrope_positions is not None:
            q = apply_mrope(q, mrope_positions, cfg.mrope_sections,
                            cfg.rope_theta)
            k = apply_mrope(k, mrope_positions, cfg.mrope_sections,
                            cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    o = flash_attention_xla(q, k, v, causal=causal,
                            window=cfg.sliding_window, chunk=chunk)
    return o.reshape(B, S, -1) @ p["wo"]


def attention_prefill(p: Params, x, cfg, *, positions, cache_len: int,
                      mrope_positions=None):
    """Prefill: run full attention AND return the KV cache to install.

    Returns (y, (k_cache, v_cache)) with caches [B, T_cache, K, hd]; for SWA
    archs T_cache == min(S, window) (rolling buffer)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    if cfg.mrope_sections and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    o = flash_attention_xla(q, k, v, causal=True, window=cfg.sliding_window,
                            chunk=eff_chunk(cfg, 1024, S))
    y = o.reshape(B, S, -1) @ p["wo"]
    if cache_len < S:                       # SWA rolling buffer
        k, v = k[:, S - cache_len:], v[:, S - cache_len:]
    elif cache_len > S:
        pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    return y, (k, v)


def attention_decode(p: Params, x: jax.Array, cfg, kv_cache, *,
                     pos: jax.Array, cache_len: jax.Array,
                     cross: bool = False):
    """One-token decode.  x [B,1,D]; kv_cache ([B,T,K,hd], [B,T,K,hd]).

    `pos` is the absolute position of the new token (for RoPE), `cache_len`
    the number of valid cache entries.  For self-attention the new KV is
    written at slot `cache_len % T` (rolling buffer — exact for SWA, and for
    full attention T is sized to hold the max sequence).  Cross-attention
    (`cross=True`) reads a precomputed immutable cache.
    """
    B = x.shape[0]
    kc, vc = kv_cache
    T = kc.shape[1]
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, 1, nh, hd)
    if not cross:
        k = (x @ p["wk"])
        v = (x @ p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = k.reshape(B, 1, nkv, hd)
        v = v.reshape(B, 1, nkv, hd)
        if cfg.mrope_sections:
            pos3 = jnp.broadcast_to(pos, (3, B, 1))
            q = apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
        else:
            posb = jnp.broadcast_to(pos, (B, 1))
            q = apply_rope(q, posb, cfg.rope_theta, cfg.rope_fraction)
            k = apply_rope(k, posb, cfg.rope_theta, cfg.rope_fraction)
        slot = (cache_len % T).astype(jnp.int32)
        kc = lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, slot, 0, 0))
        vc = lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, slot, 0, 0))
        valid = jnp.minimum(cache_len + 1, T)
    else:
        # cross-attention reads a precomputed immutable cache; no rotation
        valid = cache_len
    # scores over the whole cache (decode is O(T), memory [B,H,T])
    G = nh // nkv
    qf = q.reshape(B, nkv, G, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qf, kc.astype(jnp.float32))
    kv_pos = jnp.arange(T)
    mask = kv_pos[None, :] < valid
    if cfg.sliding_window and not cross:
        pass  # rolling buffer already bounds the window
    s = jnp.where(mask[:, None, None, :] if mask.ndim == 2
                  else mask[None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkh->bkgh", w, vc.astype(jnp.float32))
    y = o.reshape(B, 1, nh * hd).astype(x.dtype) @ p["wo"]
    return y, (kc, vc)


# ------------------------------------------------------------------------ MLP
def mlp_init(key, d: int, f: int, act: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {"w_up": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
         "w_down": (jax.random.normal(k2, (f, d)) * s_out).astype(dtype)}
    if act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k3, (d, f)) * s_in).astype(dtype)
    return p


def mlp_apply(p: Params, x: jax.Array, act: str) -> jax.Array:
    up = x @ p["w_up"]
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * up
    elif act == "relu2":                    # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(up))
    else:
        h = jax.nn.gelu(up)
    return h @ p["w_down"]


# ------------------------------------------------------------------------ MoE
def moe_init(key, cfg, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {"router": (jax.random.normal(k1, (d, e)) * s_in).astype(jnp.float32),
         "w_up": (jax.random.normal(k2, (e, d, f)) * s_in).astype(dtype),
         "w_down": (jax.random.normal(k3, (e, f, d)) * s_out).astype(dtype)}
    if cfg.mlp_act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k4, (e, d, f)) * s_in).astype(dtype)
    return p


def moe_apply(p: Params, x: jax.Array, cfg, *,
              capacity_factor: float = 0.0) -> jax.Array:
    """GShard-style top-k dispatch with per-sequence expert capacity.

    x [B,S,D] -> [B,S,D].  Static shapes: dispatch/combine are one-hot
    einsums sized [B,S,E,C]; tokens over capacity are dropped (standard TPU
    MoE).  Router in fp32.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    cf = capacity_factor or cfg.moe_capacity_factor
    C = max(int(cf * S * K / E), 4)
    C = min(C, S)
    logits = x.astype(jnp.float32) @ p["router"]            # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = lax.top_k(probs, K)               # [B,S,K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)   # [B,S,K,E]
    flat = onehot.reshape(B, S * K, E)
    pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(B, S, K, E)
    keep = (pos_in_e < C) * onehot                           # drop overflow
    pos = jnp.einsum("bske->bsk", pos_in_e * onehot).astype(jnp.int32)
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32)       # [B,S,K,C]
    dispatch = jnp.einsum("bske,bskc->bsec", keep, pos_oh)   # [B,S,E,C]
    combine = jnp.einsum("bsk,bske,bskc->bsec", gate_vals, keep, pos_oh)
    xe = jnp.einsum("bsec,bsd->becd", dispatch.astype(x.dtype), x)
    up = jnp.einsum("becd,edf->becf", xe, p["w_up"])
    if "w_gate" in p:
        g = jnp.einsum("becd,edf->becf", xe, p["w_gate"])
        h = (jax.nn.silu(g) if cfg.mlp_act == "swiglu"
             else jax.nn.gelu(g)) * up
    else:
        h = jnp.square(jax.nn.relu(up)) if cfg.mlp_act == "relu2" \
            else jax.nn.gelu(up)
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"])
    return jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), ye)


# ---------------------------------------------------------------------- Mamba
def mamba_init(key, cfg, dtype) -> Params:
    d, di = cfg.d_model, cfg.mamba_d_inner
    ds, dc, dtr = cfg.mamba_d_state, cfg.mamba_d_conv, cfg.mamba_dt_rank
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    si = 1.0 / math.sqrt(di)
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (jax.random.normal(ks[2], (di, dtr + 2 * ds)) * si
                   ).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (dtr, di)) *
                    (1.0 / math.sqrt(dtr))).astype(dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (di, d)) * si).astype(dtype),
    }


def _mamba_scan_chunked(u, dt, B_, C_, A, chunk: int):
    """Selective scan h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t;  y = C_t h_t.
    u [B,T,Di]; dt [B,T,Di]; B_/C_ [B,T,N]; A [Di,N].  Chunked over T."""
    B, T, Di = u.shape
    N = B_.shape[-1]
    chunk = min(chunk, T)
    n = -(-T // chunk)
    Tp = n * chunk
    if Tp != T:
        u = jnp.pad(u, ((0, 0), (0, Tp - T), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, Tp - T), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, Tp - T), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, Tp - T), (0, 0)))

    def chunk_body(h0, xs):
        uc, dtc, Bc, Cc = xs                  # [B,chunk,...]
        # per-step decay a_t = exp(dt_t A) in (0,1]: numerically safe
        a = jnp.exp(dtc[..., None] * A[None, None])         # [B,c,Di,N]
        inc = (dtc * uc)[..., None] * Bc[:, :, None, :]     # [B,c,Di,N]
        # h_t = a_t h_{t-1} + inc_t via associative scan (exact, bounded)
        aa, hh = lax.associative_scan(
            lambda p, q: (p[0] * q[0], q[1] + q[0] * p[1]),
            (a, inc), axis=1)
        h = hh + aa * h0[:, None]                           # [B,c,Di,N]
        y = jnp.einsum("bcdn,bcn->bcd", h, Cc)
        return h[:, -1], y

    xs = (u.reshape(B, n, chunk, Di).swapaxes(0, 1),
          dt.reshape(B, n, chunk, Di).swapaxes(0, 1),
          B_.reshape(B, n, chunk, N).swapaxes(0, 1),
          C_.reshape(B, n, chunk, N).swapaxes(0, 1))
    h_last, ys = lax.scan(chunk_body, jnp.zeros((B, Di, N), jnp.float32), xs)
    y = ys.swapaxes(0, 1).reshape(B, Tp, Di)[:, :T]
    return y, h_last


def mamba_apply(p: Params, x: jax.Array, cfg, *, chunk: int = 0):
    """Mamba block over a full sequence.  x [B,T,D] -> [B,T,D]."""
    B, T, D = x.shape
    chunk = chunk or eff_chunk(cfg, 256, T)
    di, ds, dtr = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_dt_rank
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)          # [B,T,Di] each
    # depthwise causal conv (k = d_conv)
    dc = p["conv_w"].shape[0]
    xp = jnp.pad(xin, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(xp[:, i:i + T] * p["conv_w"][i][None, None]
             for i in range(dc)) + p["conv_b"]
    xc = jax.nn.silu(xc)
    proj = (xc @ p["x_proj"]).astype(jnp.float32)
    dt_r, B_, C_ = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                    # [Di,N], negative
    y, h_last = _mamba_scan_chunked(xc.astype(jnp.float32), dt, B_, C_, A,
                                    chunk)
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    out = y @ p["out_proj"]
    # conv tail state (last d_conv-1 inputs) for decode handoff
    conv_state = xp[:, T:T + dc - 1]
    return out, {"ssm": h_last, "conv": conv_state.astype(x.dtype)}


def mamba_decode(p: Params, x: jax.Array, cfg, state: Params):
    """One-token Mamba step.  x [B,1,D]; state {'ssm':[B,Di,N],
    'conv':[B,k-1,Di]} -> (y [B,1,D], new state)."""
    B = x.shape[0]
    di, ds, dtr = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_dt_rank
    xz = x[:, 0] @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)          # [B,Di]
    conv = jnp.concatenate([state["conv"], xin[:, None]], axis=1)  # [B,k,Di]
    xc = jnp.einsum("bkd,kd->bd", conv, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    proj = (xc @ p["x_proj"]).astype(jnp.float32)
    dt_r, B_, C_ = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"])        # [B,Di]
    A = -jnp.exp(p["A_log"])
    h = state["ssm"]                            # [B,Di,N]
    dA = jnp.exp(dt[..., None] * A[None])
    h = dA * h + (dt * xc.astype(jnp.float32))[..., None] * B_[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C_) + xc.astype(jnp.float32) * p["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return y[:, None], {"ssm": h, "conv": conv[:, 1:]}


# ---------------------------------------------------------------------- RWKV6
def rwkv_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    lw, lx = 64, 32
    ks = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(d)
    p = {}
    for i, name in enumerate(("wr", "wk", "wv", "wg", "wo")):
        p[name] = (jax.random.normal(ks[i], (d, d)) * s).astype(dtype)
    p["w_lora_a"] = (jax.random.normal(ks[5], (d, lw)) * s).astype(dtype)
    p["w_lora_b"] = (jax.random.normal(ks[6], (lw, d)) * 0.1).astype(dtype)
    p["w_base"] = jnp.full((d,), -6.0, jnp.float32)      # decay base
    p["u"] = jnp.zeros((d,), jnp.float32)                # time_first bonus
    p["mix_base"] = jnp.zeros((6, d), jnp.float32)       # ddlerp bases
    p["mix_lora_a"] = (jax.random.normal(ks[7], (d, lx * 5)) * s
                       ).astype(dtype)
    p["mix_lora_b"] = (jax.random.normal(ks[8], (5, lx, d)) * 0.1
                       ).astype(dtype)
    p["ln_w"] = jnp.ones((d,), jnp.float32)              # post-wkv groupnorm
    p["ln_b"] = jnp.zeros((d,), jnp.float32)
    return p


def _rwkv_ddlerp(p: Params, x: jax.Array, x_prev: jax.Array):
    """Data-dependent token-shift (RWKV6 ddlerp): returns the 5 mixed
    streams (r,k,v,w,g).  x/x_prev [B,T,D]."""
    dx = x_prev - x
    base = x + dx * p["mix_base"][0]
    lora = jnp.tanh(base @ p["mix_lora_a"])             # [B,T,5*lx]
    lora = lora.reshape(*lora.shape[:-1], 5, -1)        # [B,T,5,lx]
    mixed = []
    for i in range(5):
        adj = jnp.einsum("btl,ld->btd", lora[..., i, :], p["mix_lora_b"][i])
        mixed.append(x + dx * (p["mix_base"][i + 1] + adj))
    return mixed  # [xr, xk, xv, xw, xg]


def _wkv_chunked(r, k, v, w_log, u, *, chunk: int, h0=None):
    """RWKV6 WKV with per-channel data-dependent decay, chunked.

    r,k,v [B,T,H,N]; w_log [B,T,H,N] (log decay, negative); u [H,N].
    Recurrence per head (state S [N,N] keyed by k-dim, valued by v-dim):
        S_t = diag(exp(w_log_t)) S_{t-1} + k_t v_t^T
        o_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t
    Returns o [B,T,H,N], S_last [B,H,N,N].
    """
    B, T, H, N = r.shape
    chunk = min(chunk, T)
    n = -(-T // chunk)
    Tp = n * chunk
    pad = ((0, 0), (0, Tp - T), (0, 0), (0, 0))
    if Tp != T:
        r, k, v = jnp.pad(r, pad), jnp.pad(k, pad), jnp.pad(v, pad)
        w_log = jnp.pad(w_log, pad)

    def body(S, xs):
        rc, kc, vc, wc = (x.astype(jnp.float32) for x in xs)  # [B,c,H,N]
        a = jnp.exp(wc)[..., None]                  # per-step decay, (0,1]
        inc = jnp.einsum("bchk,bchn->bchkn", kc, vc)
        # S_t = diag(a_t) S_{t-1} + k_t v_t^T via associative scan (exact)
        aa, hh = lax.associative_scan(
            lambda p, q: (p[0] * q[0], q[1] + q[0] * p[1]),
            (a, inc), axis=1)
        h_full = hh + aa * S[:, None]               # [B,c,H,N,N] inclusive
        h_prev = jnp.concatenate([S[:, None], h_full[:, :-1]], axis=1)
        o = jnp.einsum("bchkn,bchk->bchn",
                       h_prev + u[None, None, :, :, None] * inc, rc)
        return h_full[:, -1], o

    xs = tuple(x.reshape(B, n, chunk, H, N).swapaxes(0, 1)
               for x in (r, k, v, w_log))
    S0 = jnp.zeros((B, H, N, N), jnp.float32) if h0 is None else h0
    S_last, os_ = lax.scan(body, S0, xs)
    o = os_.swapaxes(0, 1).reshape(B, Tp, H, N)[:, :T]
    return o, S_last


def rwkv_apply(p: Params, x: jax.Array, cfg, *, chunk: int = 0):
    """RWKV6 time-mix over a sequence.  x [B,T,D] -> ([B,T,D], state)."""
    B, T, D = x.shape
    chunk = chunk or eff_chunk(cfg, 32, T)
    N = cfg.rwkv_head_dim
    H = D // N
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :T]
    xr, xk, xv, xw, xg = _rwkv_ddlerp(p, x, x_prev)
    rr = (xr @ p["wr"]).reshape(B, T, H, N)
    kk = (xk @ p["wk"]).reshape(B, T, H, N)
    vv = (xv @ p["wv"]).reshape(B, T, H, N)
    g = jax.nn.silu(xg @ p["wg"])
    w_log = -jnp.exp(
        (p["w_base"] + (jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"])
         .astype(jnp.float32))).reshape(B, T, H, N)
    u = p["u"].reshape(H, N)
    o, S_last = _wkv_chunked(rr, kk, vv, w_log, u, chunk=chunk)
    # per-head groupnorm then output proj
    o = o.reshape(B, T, H, N)
    mu = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = (o - mu) * lax.rsqrt(var + 1e-5)
    o = o.reshape(B, T, D) * p["ln_w"] + p["ln_b"]
    y = (o.astype(x.dtype) * g) @ p["wo"]
    state = {"shift": x[:, -1], "wkv": S_last}
    return y, state


def rwkv_decode(p: Params, x: jax.Array, cfg, state: Params):
    """One-token RWKV6 step.  x [B,1,D]; state {'shift':[B,D],
    'wkv':[B,H,N,N]}."""
    B, _, D = x.shape
    N = cfg.rwkv_head_dim
    H = D // N
    xt = x[:, 0]
    x_prev = state["shift"]
    xr, xk, xv, xw, xg = _rwkv_ddlerp(p, xt[:, None], x_prev[:, None])
    rr = (xr[:, 0] @ p["wr"]).reshape(B, H, N).astype(jnp.float32)
    kk = (xk[:, 0] @ p["wk"]).reshape(B, H, N).astype(jnp.float32)
    vv = (xv[:, 0] @ p["wv"]).reshape(B, H, N).astype(jnp.float32)
    g = jax.nn.silu(xg[:, 0] @ p["wg"])
    w = jnp.exp(-jnp.exp(
        (p["w_base"] + (jnp.tanh(xw[:, 0] @ p["w_lora_a"]) @ p["w_lora_b"])
         .astype(jnp.float32)))).reshape(B, H, N)
    u = p["u"].reshape(H, N)
    S = state["wkv"]                                   # [B,H,N,N]
    kv = jnp.einsum("bhk,bhn->bhkn", kk, vv)
    o = jnp.einsum("bhkn,bhk->bhn", S + u[None, :, :, None] * kv, rr)
    S = w[..., None] * S + kv
    mu = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = ((o - mu) * lax.rsqrt(var + 1e-5)).reshape(B, D)
    o = o * p["ln_w"] + p["ln_b"]
    y = ((o.astype(x.dtype) * g) @ p["wo"])[:, None]
    return y, {"shift": xt, "wkv": S}


def rwkv_cmix_init(key, cfg, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    return {"wk": (jax.random.normal(k1, (d, f)) * s).astype(dtype),
            "wv": (jax.random.normal(k2, (f, d)) *
                   (1.0 / math.sqrt(f))).astype(dtype),
            "wr": (jax.random.normal(k3, (d, d)) * s).astype(dtype),
            "mix_k": jnp.zeros((d,), jnp.float32),
            "mix_r": jnp.zeros((d,), jnp.float32)}


def rwkv_cmix_apply(p: Params, x: jax.Array, x_prev: jax.Array):
    """RWKV channel-mix.  x [B,T,D]; x_prev = token-shifted x."""
    dx = x_prev - x
    xk = x + dx * p["mix_k"]
    xr = x + dx * p["mix_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
