"""Activation-sharding hints that degrade gracefully off-mesh.

Model code calls `hint(x, "data", None, "model")`-style constraints; when no
mesh is active (CPU smoke tests) or a dimension is not divisible by its mesh
axis, the hint is skipped for that dim.  Under `with_mesh(mesh)` (used by the
launcher and dry-run) hints become real `with_sharding_constraint`s that GSPMD
propagates.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar[Optional[Mesh]] = \
    contextvars.ContextVar("repro_mesh", default=None)

# logical -> physical axis mapping; "data" may map to ("pod","data") multi-pod
_AXIS_MAP: contextvars.ContextVar[dict] = \
    contextvars.ContextVar("repro_axis_map", default={})


@contextlib.contextmanager
def with_mesh(mesh: Mesh, axis_map: Optional[dict] = None):
    """Activate a mesh for model-internal sharding hints."""
    amap = axis_map or {}
    tok1 = _MESH.set(mesh)
    tok2 = _AXIS_MAP.set(amap)
    try:
        # jax >= 0.6 spells mesh activation jax.set_mesh; older releases use
        # the Mesh object itself as the context manager.
        ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
        with ctx:
            yield mesh
    finally:
        _MESH.reset(tok1)
        _AXIS_MAP.reset(tok2)


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


def resolve_axis(logical: Optional[str]):
    """Map a logical axis name to physical mesh axis (or tuple)."""
    if logical is None:
        return None
    return _AXIS_MAP.get().get(logical, logical)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def hint(x: jax.Array, *spec):
    """Best-effort sharding constraint; skips non-divisible dims / no mesh."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    resolved = []
    for dim, axis in zip(x.shape, spec):
        phys = resolve_axis(axis)
        if phys is None or dim % _axis_size(mesh, phys) != 0:
            resolved.append(None)
        else:
            resolved.append(phys)
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*resolved)))
    except Exception:
        return x
