"""Model assembly: init / forward / loss / prefill / decode for every
assigned architecture, driven entirely by `ModelConfig`.

Depth is handled with `lax.scan` over *periods* of the layer pattern: the
parameters of pattern position i are stacked across periods, so compiled HLO
contains one instance of each distinct layer kind regardless of depth
(88-layer granite compiles as fast as 4-layer whisper).

Caches:
  attention -> (k, v) ring buffers [B, T_cache, K, hd]
  mamba     -> {"ssm": [B, Di, N], "conv": [B, k-1, Di]}
  rwkv      -> {"shift": [B, D], "wkv": [B, H, N, N], "cmix_shift": [B, D]}
stacked across periods (scan xs/ys) and grouped per pattern position.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .config import LayerSpec, ModelConfig
from .sharding import hint

Params = dict


def _dt(name: str):
    return jnp.dtype(name)


# ------------------------------------------------------------------ block init
def _block_init(key, spec: LayerSpec, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": L.norm_init(cfg.d_model, cfg.norm, dtype)}
    if spec.mixer == "attn":
        p["mixer"] = L.attn_init(ks[0], cfg, dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = L.mamba_init(ks[0], cfg, dtype)
    elif spec.mixer == "rwkv":
        p["mixer"] = L.rwkv_init(ks[0], cfg, dtype)
    if spec.cross_attn:
        p["norm_x"] = L.norm_init(cfg.d_model, cfg.norm, dtype)
        p["cross"] = L.attn_init(ks[1], cfg, dtype, cross=True)
    if spec.mlp != "none":
        p["norm2"] = L.norm_init(cfg.d_model, cfg.norm, dtype)
    if spec.mlp == "dense":
        p["mlp"] = L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
    elif spec.mlp == "moe":
        p["mlp"] = L.moe_init(ks[2], cfg, dtype)
    elif spec.mlp == "rwkv_cmix":
        p["mlp"] = L.rwkv_cmix_init(ks[2], cfg, dtype)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = _dt(cfg.param_dtype)
    kE, kH, kB, kEnc = jax.random.split(key, 4)
    d, v = cfg.d_model, cfg.vocab_size
    params: Params = {
        "embed": (jax.random.normal(kE, (v, d)) * 0.02).astype(dtype),
        "lm_head": (jax.random.normal(kH, (d, v)) /
                    math.sqrt(d)).astype(dtype),
        "final_norm": L.norm_init(d, cfg.norm, dtype),
    }
    blocks = []
    for i, spec in enumerate(cfg.pattern):
        pkeys = jax.random.split(jax.random.fold_in(kB, i), cfg.n_periods)
        blocks.append(jax.vmap(
            lambda k, s=spec: _block_init(k, s, cfg, dtype))(pkeys))
    params["blocks"] = tuple(blocks)
    if cfg.is_encoder_decoder:
        ekeys = jax.random.split(kEnc, cfg.n_encoder_layers)
        espec = LayerSpec(mixer="attn", mlp="dense", causal=False)
        params["enc_blocks"] = jax.vmap(
            lambda k: _block_init(k, espec, cfg, dtype))(ekeys)
        params["enc_final_norm"] = L.norm_init(d, cfg.norm, dtype)
    return params


# ----------------------------------------------------------------- block apply
def _apply_mixer_full(pp, spec, cfg, x, positions, mrope_positions, enc_out):
    """Full-sequence mixer; returns (y, cache_state or None)."""
    h = L.norm_apply(pp["norm1"], x, cfg.norm)
    if spec.mixer == "attn":
        y = L.attention(pp["mixer"], h, cfg, positions=positions,
                        causal=spec.causal, mrope_positions=mrope_positions)
        state = None
    elif spec.mixer == "mamba":
        y, state = L.mamba_apply(pp["mixer"], h, cfg)
    else:  # rwkv
        y, state = L.rwkv_apply(pp["mixer"], h, cfg)
    # pin the TP partial-sum point on the bf16 mixer output so the psum
    # happens here (2 collectives/layer, Megatron minimum) instead of
    # migrating into the fp32 norm internals downstream
    x = x + hint(y.astype(x.dtype), "data", None, None)
    if spec.cross_attn and enc_out is not None:
        h = L.norm_apply(pp["norm_x"], x, cfg.norm)
        x = x + L.attention(pp["cross"], h, cfg, positions=positions,
                            causal=False, kv_x=enc_out, rope=False)
    return x, state


def _apply_mlp(pp, spec, cfg, x):
    if spec.mlp == "none":
        return x
    h = L.norm_apply(pp["norm2"], x, cfg.norm)
    h = hint(h, "data", None, None)
    if spec.mlp == "dense":
        y = L.mlp_apply(pp["mlp"], h, cfg.mlp_act)
    elif spec.mlp == "moe":
        y = L.moe_apply(pp["mlp"], h, cfg)
    else:  # rwkv_cmix
        T = h.shape[1]
        h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :T]
        y = L.rwkv_cmix_apply(pp["mlp"], h, h_prev)
    return x + hint(y.astype(x.dtype), "data", None, None)


def _block_full(pp, spec, cfg, x, positions, mrope_positions, enc_out=None):
    x, state = _apply_mixer_full(pp, spec, cfg, x, positions,
                                 mrope_positions, enc_out)
    x = _apply_mlp(pp, spec, cfg, x)
    x = hint(x, "data", None, None)
    return x, state


def _scan_layers(cfg: ModelConfig, f, init, xs):
    """lax.scan over stacked periods, or an unrolled python loop when
    cfg.unroll_layers (the cost-model lowering: XLA cost analysis then sees
    every period's ops explicitly instead of one while-loop body)."""
    f = _remat(f, cfg)
    if not cfg.unroll_layers:
        return lax.scan(f, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# --------------------------------------------------------------------- encoder
def _encode(params, cfg, enc_embeds):
    """Whisper-style encoder over stub frontend embeddings [B,T,D]."""
    espec = LayerSpec(mixer="attn", mlp="dense", causal=False)
    positions = jnp.arange(enc_embeds.shape[1])

    def body(x, pp):
        x, _ = _block_full(pp, espec, cfg, x, positions, None)
        return x, None

    x, _ = _scan_layers(cfg, body, enc_embeds, params["enc_blocks"])
    return L.norm_apply(params["enc_final_norm"], x, cfg.norm)


# --------------------------------------------------------------------- forward
def embed_inputs(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Token / stub-frontend embedding.  For 'embeds' archs (audio encoder is
    separate), token embeddings are summed with provided frontend embeddings
    (padded to seq len) — the VLM merge stub."""
    if "tokens" in batch:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = hint(x, "data", None, None)
        if "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(x.dtype)
            pad = x.shape[1] - ve.shape[1]
            if pad > 0:
                ve = jnp.pad(ve, ((0, 0), (0, pad), (0, 0)))
            x = x + ve
        return x
    return batch["embeds"].astype(_dt(cfg.param_dtype))


def forward(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Training/scoring forward -> logits [B,S,V]."""
    x = embed_inputs(params, cfg, batch)
    B, S, _ = x.shape
    positions = batch.get("positions", jnp.arange(S))
    mrope_positions = batch.get("mrope_positions")
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, batch["enc_embeds"])

    def body(x, period_params):
        for spec, pp in zip(cfg.pattern, period_params):
            x, _ = _block_full(pp, spec, cfg, x, positions,
                               mrope_positions, enc_out)
        return x, None

    x, _ = _scan_layers(cfg, body, x, params["blocks"])
    x = L.norm_apply(params["final_norm"], x, cfg.norm)
    logits = x @ params["lm_head"]
    return hint(logits, "data", None, "model")


def loss_fn(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Causal-LM cross entropy (fp32 logsumexp; vocab-parallel friendly)."""
    logits = forward(params, cfg, batch).astype(jnp.float32)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None],
                                 axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - picked) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


# --------------------------------------------------------------------- serving
def cache_spec(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Abstract cache layout for a serving session (used by init & specs).

    For SWA archs the attention cache is the rolling window; for full
    attention it holds `seq_len` entries."""
    d, hd, nkv = cfg.d_model, cfg.head_dim, cfg.n_kv_heads
    T = min(cfg.sliding_window, seq_len) if cfg.sliding_window else seq_len
    per_pos = []
    cdt = _dt(cfg.compute_dtype)
    np_ = cfg.n_periods
    for spec in cfg.pattern:
        entry = {}
        if spec.mixer == "attn":
            entry["k"] = ((np_, batch, T, nkv, hd), cdt)
            entry["v"] = ((np_, batch, T, nkv, hd), cdt)
        elif spec.mixer == "mamba":
            entry["ssm"] = ((np_, batch, cfg.mamba_d_inner,
                             cfg.mamba_d_state), jnp.float32)
            entry["conv"] = ((np_, batch, cfg.mamba_d_conv - 1,
                              cfg.mamba_d_inner), cdt)
        elif spec.mixer == "rwkv":
            H = d // cfg.rwkv_head_dim
            entry["shift"] = ((np_, batch, d), cdt)
            entry["wkv"] = ((np_, batch, H, cfg.rwkv_head_dim,
                             cfg.rwkv_head_dim), jnp.float32)
        if spec.mlp == "rwkv_cmix":
            entry["cmix_shift"] = ((np_, batch, d), cdt)
        if spec.cross_attn:
            entry["xk"] = ((np_, batch, cfg.encoder_len, nkv, hd), cdt)
            entry["xv"] = ((np_, batch, cfg.encoder_len, nkv, hd), cdt)
        per_pos.append(entry)
    return {"blocks": tuple(per_pos)}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    spec = cache_spec(cfg, batch, seq_len)
    return jax.tree.map(lambda sd: jnp.zeros(*sd),
                        spec, is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and isinstance(x[0], tuple))


def prefill(params, cfg: ModelConfig, batch: dict, *, cache_len: int):
    """Process the prompt; returns (last-token logits, cache).

    cache_len: capacity of the per-layer attention cache (>= prompt len for
    full attention; the SWA window for sliding-window archs)."""
    x = embed_inputs(params, cfg, batch)
    B, S, _ = x.shape
    positions = batch.get("positions", jnp.arange(S))
    mrope_positions = batch.get("mrope_positions")
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, batch["enc_embeds"])

    def body(x, period_params):
        caches = []
        for spec, pp in zip(cfg.pattern, period_params):
            entry = {}
            h = L.norm_apply(pp["norm1"], x, cfg.norm)
            if spec.mixer == "attn":
                y, (kc, vc) = L.attention_prefill(
                    pp["mixer"], h, cfg, positions=positions,
                    cache_len=cache_len, mrope_positions=mrope_positions)
                entry["k"], entry["v"] = kc, vc
            elif spec.mixer == "mamba":
                y, st = L.mamba_apply(pp["mixer"], h, cfg)
                entry["ssm"], entry["conv"] = st["ssm"], st["conv"]
            else:
                y, st = L.rwkv_apply(pp["mixer"], h, cfg)
                entry["shift"], entry["wkv"] = st["shift"], st["wkv"]
            x = x + y.astype(x.dtype)
            if spec.cross_attn and enc_out is not None:
                hx = L.norm_apply(pp["norm_x"], x, cfg.norm)
                x = x + L.attention(pp["cross"], hx, cfg, positions=positions,
                                    causal=False, kv_x=enc_out, rope=False)
                # precompute immutable cross KV for decode
                _, xk, xv = L._qkv(pp["cross"], hx, cfg, enc_out)
                entry["xk"], entry["xv"] = xk, xv
            if spec.mlp == "rwkv_cmix":
                h2 = L.norm_apply(pp["norm2"], x, cfg.norm)
                entry["cmix_shift"] = h2[:, -1]
            x = _apply_mlp(pp, spec, cfg, x)
            caches.append(entry)
        return x, tuple(caches)

    x, cache_blocks = _scan_layers(cfg, body, x, params["blocks"])
    x = L.norm_apply(params["final_norm"], x[:, -1:], cfg.norm)
    logits = x @ params["lm_head"]
    return logits[:, 0], {"blocks": cache_blocks}


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, cache: dict,
                cache_len: jax.Array, enc_out: Optional[jax.Array] = None):
    """One decode step.  tokens [B,1]; cache from `prefill`/`init_cache`;
    cache_len: number of tokens already in the cache (scalar int32).
    Returns (logits [B,V], new cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = cache_len

    def body(x, inp):
        period_params, cache_in = inp
        cache_out = []
        for spec, pp, ce in zip(cfg.pattern, period_params, cache_in):
            ce = dict(ce)
            h = L.norm_apply(pp["norm1"], x, cfg.norm)
            if spec.mixer == "attn":
                y, (kc, vc) = L.attention_decode(
                    pp["mixer"], h, cfg, (ce["k"], ce["v"]),
                    pos=pos, cache_len=cache_len)
                ce["k"], ce["v"] = kc, vc
            elif spec.mixer == "mamba":
                y, st = L.mamba_decode(pp["mixer"], h, cfg,
                                       {"ssm": ce["ssm"], "conv": ce["conv"]})
                ce["ssm"], ce["conv"] = st["ssm"], st["conv"]
            else:
                y, st = L.rwkv_decode(pp["mixer"], h, cfg,
                                      {"shift": ce["shift"],
                                       "wkv": ce["wkv"]})
                ce["shift"], ce["wkv"] = st["shift"], st["wkv"]
            x = x + y.astype(x.dtype)
            if spec.cross_attn:
                hx = L.norm_apply(pp["norm_x"], x, cfg.norm)
                y, _ = L.attention_decode(
                    pp["cross"], hx, cfg, (ce["xk"], ce["xv"]),
                    pos=pos, cache_len=jnp.asarray(cfg.encoder_len),
                    cross=True)
                x = x + y.astype(x.dtype)
            if spec.mlp == "rwkv_cmix":
                h2 = L.norm_apply(pp["norm2"], x, cfg.norm)
                prev = ce["cmix_shift"]
                y2 = L.rwkv_cmix_apply(pp["mlp"], h2, prev[:, None])
                ce["cmix_shift"] = h2[:, 0]
                x = x + y2.astype(x.dtype)
            elif spec.mlp != "none":
                x = _apply_mlp(pp, spec, cfg, x)
            cache_out.append(ce)
        return x, tuple(cache_out)

    x, new_blocks = _scan_layers(cfg, body, x,
                                 (params["blocks"], cache["blocks"]))
    x = L.norm_apply(params["final_norm"], x, cfg.norm)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, {"blocks": new_blocks}
