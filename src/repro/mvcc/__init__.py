"""Executable MVCC engine + HTAP architectures (the paper's Sec 5 systems)."""

from .store import Store, Version, VersionChain
from .engine import (Engine, Txn, Status, AbortReason, SerializationFailure)
from .certify import (Certifier, ConservativeSSI, CommitOrderSSI, SSN,
                      make_certifier, CERTIFIERS)
from .htap import SingleNodeHTAP, MultiNodeHTAP, Replica
from .workload import (Scale, load_initial, oltp_transaction, olap_query,
                       olap_freshness, write_skew)
from .driver import (Metrics, run_multi_node, run_sessions, run_single_node,
                     run_write_skew)

__all__ = [
    "Store", "Version", "VersionChain",
    "Engine", "Txn", "Status", "AbortReason", "SerializationFailure",
    "Certifier", "ConservativeSSI", "CommitOrderSSI", "SSN",
    "make_certifier", "CERTIFIERS",
    "SingleNodeHTAP", "MultiNodeHTAP", "Replica",
    "Scale", "load_initial", "oltp_transaction", "olap_query",
    "olap_freshness", "write_skew",
    "Metrics", "run_single_node", "run_multi_node", "run_sessions",
    "run_write_skew",
]
