"""Executable MVCC engine + HTAP architectures (the paper's Sec 5 systems)."""

from .store import Store, Version, VersionChain
from .engine import (Engine, Txn, Status, AbortReason, SerializationFailure)
from .htap import SingleNodeHTAP, MultiNodeHTAP, Replica
from .workload import (Scale, load_initial, oltp_transaction, olap_query,
                       olap_freshness)
from .driver import Metrics, run_single_node, run_multi_node

__all__ = [
    "Store", "Version", "VersionChain",
    "Engine", "Txn", "Status", "AbortReason", "SerializationFailure",
    "SingleNodeHTAP", "MultiNodeHTAP", "Replica",
    "Scale", "load_initial", "oltp_transaction", "olap_query",
    "olap_freshness",
    "Metrics", "run_single_node", "run_multi_node",
]
