"""Deterministic logical-time workload driver for the HTAP benchmarks.

Model: N clients run concurrently; in every *round* each client advances by
exactly one step (one storage operation, one wait-poll, or one commit).  The
round counter is the logical clock, so a scan of 800 keys stays active for
800 rounds and overlaps hundreds of OLTP commits — reproducing the
concurrency structure the paper's figures measure (writer-aborts under SSI,
reader-waits under SafeSnapshots, neither under RSS).

Throughput  = commits / rounds (per class), abort rate = aborts/(commits+aborts).
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Optional

from ..core.replica import RssSnapshot
from ..obs import REGISTRY, reset_run
from ..tensorstore.version_store import (AggPlan, GroupByPlan, MultiAggPlan,
                                         ScanPlan)
from .engine import Engine, SerializationFailure, Status
from .htap import MultiNodeHTAP, SingleNodeHTAP
from .workload import (Scale, load_initial, olap_freshness, olap_query,
                       oltp_transaction, session_plan_families, session_write,
                       write_skew, zipf_assign)


@dataclass
class Metrics:
    certifier: str = ""          # commit-certification policy of the run
    oltp_commits: int = 0
    oltp_aborts: int = 0
    oltp_retries: int = 0
    olap_commits: int = 0
    olap_aborts: int = 0
    olap_wait_rounds: int = 0
    olap_scan_steps: int = 0     # ScanPlan steps served
    olap_agg_steps: int = 0      # fused AggPlan steps served
    olap_multi_agg_steps: int = 0   # compound MultiAggPlan steps served
    olap_group_steps: int = 0    # grouped GroupByPlan steps served
    # dense page-range fast path (paged mirrors): fused plan executions
    # that sliced the store vs gathered (page-range locality metric)
    olap_dense_range_hits: int = 0
    olap_dense_range_misses: int = 0
    # cross-reader plan batching (batch_plans=True): same-horizon
    # aggregate plans collected per round and served by one fused
    # BatchPlan dispatch each
    olap_batch_dispatches: int = 0   # fused multi-plan dispatches
    olap_batched_plans: int = 0      # plans served via those dispatches
    # grouped-kernel dispatch accounting (paged mirrors): fused aggregate
    # dispatches and which strategy the shape dispatcher picked
    olap_agg_dispatches: int = 0
    olap_mode_flat: int = 0
    olap_mode_chunked: int = 0
    olap_mode_host: int = 0
    # materialized-aggregate serving (materialize=True runs): plans served
    # from a live accumulator tile vs registered plans that fell back to
    # the fused scan, and dirty min/max lanes demoted to partial rescans
    olap_view_hits: int = 0
    olap_view_fallbacks: int = 0
    olap_view_demotions: int = 0
    max_engine_txns: int = 0     # peak engine per-txn state (bounded by GC)
    max_rss_tracked: int = 0     # peak RSSManager per-txn state (ditto)
    max_wal_records: int = 0     # peak primary WAL length (truncation bound)
    rounds: int = 0
    by_abort_reason: dict = field(default_factory=dict)
    olap_outputs: list = field(default_factory=list)  # ("out", v) results
    # replica-cluster routing (multi-node at N >= 1)
    olap_served_by: list = field(default_factory=list)  # per-replica serves
    olap_ship_then_serve: int = 0   # sync catch-ups forced by staleness
    olap_scheduled_ships: int = 0   # cadence-due ships run at serve time
    olap_avg_lag_records: float = 0.0  # mean served-snapshot lag (observed)
    olap_avg_predicted_lag: float = 0.0  # mean lag predicted at routing
    gc_versions_pruned: int = 0     # chain versions pruned cluster-wide
    # kernel-layer launch accounting (registry series kernel_launch_*)
    olap_kernel_dispatches: int = 0
    olap_kernel_pallas_calls: int = 0
    # latency distributions (registry histograms; {count, sum_us, p50_us,
    # p95_us, p99_us} summaries — no samples stored anywhere)
    serve_latency: dict = field(default_factory=dict)          # merged
    serve_latency_by_plan: dict = field(default_factory=dict)  # per plan kind
    serve_stage_latency: dict = field(default_factory=dict)    # per stage
    oltp_commit_latency: dict = field(default_factory=dict)
    # session serving (run_sessions / session_tokens runs): token-routed
    # acquires, cadence-owed delta ships run to cover a token, and serves
    # below the token floor (the guarantee counter — must stay 0)
    session_serves: int = 0
    session_token_acquires: int = 0
    session_token_ships: int = 0
    session_token_violations: int = 0
    # horizon-keyed resolve cache (PagedMirror): per-layer hit/miss
    cache_member_hits: int = 0
    cache_member_misses: int = 0
    cache_pindex_hits: int = 0
    cache_pindex_misses: int = 0
    cache_store_hits: int = 0
    cache_store_misses: int = 0

    def oltp_tps(self) -> float:
        return self.oltp_commits / max(self.rounds, 1)

    def olap_qps(self) -> float:
        return self.olap_commits / max(self.rounds, 1)

    def oltp_abort_rate(self) -> float:
        d = self.oltp_commits + self.oltp_aborts
        return self.oltp_aborts / d if d else 0.0

    def olap_abort_rate(self) -> float:
        d = self.olap_commits + self.olap_aborts
        return self.olap_aborts / d if d else 0.0

    def count_plan_step(self, plan) -> None:
        """Bump the per-plan-kind served-step counter."""
        if isinstance(plan, ScanPlan):
            self.olap_scan_steps += 1
        elif isinstance(plan, AggPlan):
            self.olap_agg_steps += 1
        elif isinstance(plan, MultiAggPlan):
            self.olap_multi_agg_steps += 1
        elif isinstance(plan, GroupByPlan):
            self.olap_group_steps += 1

    def dense_range_hit_rate(self) -> float:
        d = self.olap_dense_range_hits + self.olap_dense_range_misses
        return self.olap_dense_range_hits / d if d else 0.0

    def plans_per_dispatch(self) -> float:
        """Mean plans served per fused multi-plan dispatch (1.0 = no
        cross-reader batching happened)."""
        return self.olap_batched_plans / max(self.olap_batch_dispatches, 1)

    def cache_hit_rates(self) -> dict:
        """Per-layer resolve-cache hit rates (member / pindex / store)."""
        out = {}
        for layer in ("member", "pindex", "store"):
            h = getattr(self, f"cache_{layer}_hits")
            s = h + getattr(self, f"cache_{layer}_misses")
            out[layer] = h / s if s else 0.0
        return out


def _harvest_obs(m: Metrics) -> None:
    """Snapshot the run's layer metrics out of the registry into the
    Metrics record.  ONE harvest path for both architectures: family
    totals sum over every instance label set (mirrors of all replicas,
    the kernel layer's launch counters), so single-node assignment and
    multi-node summation can never diverge again — the registry was reset
    at run start, so totals are exactly this run's activity."""
    tot = REGISTRY.totals()
    m.olap_dense_range_hits = tot.get("mirror_range_dense", 0)
    m.olap_dense_range_misses = tot.get("mirror_range_gather", 0)
    m.olap_agg_dispatches = tot.get("mirror_exec_agg_dispatches", 0)
    m.olap_mode_flat = tot.get("mirror_exec_mode_flat", 0)
    m.olap_mode_chunked = tot.get("mirror_exec_mode_chunked", 0)
    m.olap_mode_host = tot.get("mirror_exec_mode_host", 0)
    m.olap_view_hits = tot.get("mirror_exec_view_hits", 0)
    m.olap_view_fallbacks = tot.get("mirror_exec_view_fallbacks", 0)
    m.olap_view_demotions = tot.get("mirror_exec_view_demotions", 0)
    m.olap_kernel_dispatches = tot.get("kernel_launch_dispatches", 0)
    m.olap_kernel_pallas_calls = tot.get("kernel_launch_pallas_calls", 0)
    m.cache_member_hits = tot.get("mirror_cache_member_hits", 0)
    m.cache_member_misses = tot.get("mirror_cache_member_misses", 0)
    m.cache_pindex_hits = tot.get("mirror_cache_pindex_hits", 0)
    m.cache_pindex_misses = tot.get("mirror_cache_pindex_misses", 0)
    m.cache_store_hits = tot.get("mirror_cache_store_hits", 0)
    m.cache_store_misses = tot.get("mirror_cache_store_misses", 0)
    m.session_token_acquires = tot.get("cluster_token_acquires", 0)
    m.session_token_ships = tot.get("cluster_token_ships", 0)
    m.session_token_violations = tot.get("cluster_token_violations", 0)
    m.serve_latency = REGISTRY.hist_summary("olap_serve_seconds")
    m.serve_latency_by_plan = REGISTRY.hist_group("olap_serve_seconds",
                                                  "plan")
    m.serve_stage_latency = REGISTRY.hist_group("olap_stage_seconds",
                                                "stage")
    m.oltp_commit_latency = REGISTRY.hist_summary("oltp_commit_seconds")
    # peaks as gauges, so snapshot()/export surfaces them alongside the
    # counter families
    REGISTRY.gauge("driver_peak_engine_txns").track_max(m.max_engine_txns)
    REGISTRY.gauge("driver_peak_rss_tracked").track_max(m.max_rss_tracked)
    REGISTRY.gauge("driver_peak_wal_records").track_max(m.max_wal_records)


class _PlanBatcher:
    """Round-scope cross-reader plan batcher: OLAP clients whose current
    step is an aggregate plan at a shared snapshot horizon enqueue
    (client, context, plan) instead of executing; at the end of the round
    the driver flushes each horizon group through ONE
    `olap_execute_batch` call — whole-batch plan fusion across readers
    (PRoT pin sharing means same-round RSS readers share a horizon
    almost always).  Results land in each client's `pending` slot exactly
    as an unbatched execution would.

    `dedup=True` (the session-serving scale mode) additionally collapses
    EQUAL plans within a horizon group before dispatch: a thousand
    sessions skewed onto a dozen plan families cost one BatchPlan of a
    dozen member plans, and every session gets its family's result.
    Only valid when results need no per-client side effects (snapshot-
    handle contexts — the multi-node serve path; single-node txn
    contexts record per-txn read sets, so they must not dedup)."""

    def __init__(self, htap, m: Metrics, *, dedup: bool = False) -> None:
        self.htap, self.m = htap, m
        self.dedup = dedup
        self.groups: dict = {}

    def add(self, key, client, ctx, plan) -> None:
        self.groups.setdefault(key, []).append((client, ctx, plan))

    def flush(self) -> None:
        for entries in self.groups.values():
            if self.dedup:
                unique = list(dict.fromkeys(p for _c, _x, p in entries))
                ctx = entries[0][1]
                results = self.htap.olap_execute_batch(
                    [(ctx, p) for p in unique])
                by_plan = dict(zip(unique, results))
                if len(entries) > 1:
                    self.m.olap_batch_dispatches += 1
                    self.m.olap_batched_plans += len(entries)
                for client, _ctx, plan in entries:
                    client.pending = by_plan[plan]
                continue
            results = self.htap.olap_execute_batch(
                [(ctx, plan) for _cl, ctx, plan in entries])
            if len(entries) > 1:
                self.m.olap_batch_dispatches += 1
                self.m.olap_batched_plans += len(entries)
            for (client, _ctx, _plan), result in zip(entries, results):
                client.pending = result
        self.groups.clear()


class _OltpClient:
    def __init__(self, engine, rng: random.Random, sc: Scale, m: Metrics,
                 *, txn_factory=None):
        """`txn_factory(rng) -> (step generator, name)` swaps the CH-style
        OLTP mix for another workload (e.g. `workload.write_skew`)."""
        self.engine, self.rng, self.sc, self.m = engine, rng, sc, m
        self.txn_factory = txn_factory
        self.txn = None
        self.gen = None
        self.pending = None  # value to send into the generator

    def _restart(self) -> None:
        if self.txn_factory is not None:
            self.gen, self.name = self.txn_factory(self.rng)
        else:
            self.gen, self.name = oltp_transaction(self.rng, self.sc)
        read_only = self.name == "order_status"
        self.txn = self.engine.begin(read_only=read_only)
        self.pending = None

    def step(self) -> None:
        if self.txn is None:
            self._restart()
            return
        if self.txn.status == Status.ABORTED:   # aborted by SSI mid-flight
            self.m.oltp_aborts += 1
            self.m.oltp_retries += 1
            self._bump_reason(self.txn.abort_reason)
            self._restart()
            return
        try:
            step = self.gen.send(self.pending)
            self.pending = None
        except StopIteration:
            try:
                self.engine.commit(self.txn)
                self.m.oltp_commits += 1
            except SerializationFailure as e:
                self.m.oltp_aborts += 1
                self.m.oltp_retries += 1
                self._bump_reason(e.reason)
            self.txn = None
            return
        try:
            if step[0] == "r":
                self.pending = self.engine.read(self.txn, step[1])
            elif step[0] == "w":
                self.engine.write(self.txn, step[1], step[2])
            # ("out", v) steps are free
        except SerializationFailure as e:
            self.m.oltp_aborts += 1
            self.m.oltp_retries += 1
            self._bump_reason(e.reason)
            self.txn = None

    def _bump_reason(self, reason) -> None:
        if reason is not None:
            k = getattr(reason, "value", str(reason))
            self.m.by_abort_reason[k] = self.m.by_abort_reason.get(k, 0) + 1


class _OlapClientSingle:
    """OLAP client against the unified (single-node) architecture."""

    def __init__(self, htap: SingleNodeHTAP, rng, sc: Scale, m: Metrics,
                 *, batched: bool = False,
                 batcher: Optional[_PlanBatcher] = None):
        self.htap, self.rng, self.sc, self.m = htap, rng, sc, m
        self.batched = batched
        self.batcher = batcher
        self.txn = None
        self.gen = None
        self.pending = None
        self.deferred: Optional[dict] = None  # SafeSnapshots wait state

    def step(self) -> None:
        eng = self.htap.engine
        if self.txn is None:
            if self.htap.olap_mode == "ssi+safesnapshots":
                self._step_deferred(eng)
                return
            self.txn = self.htap.olap_begin()
            self.gen, _ = olap_query(self.rng, self.sc,
                                     batched=self.batched)
            self.pending = None
            return
        if self.txn.status == Status.ABORTED:
            self.m.olap_aborts += 1
            self.htap.olap_abandon(self.txn)
            self.txn = None
            return
        try:
            step = self.gen.send(self.pending)
            self.pending = None
        except StopIteration:
            try:
                self.htap.olap_commit(self.txn)
                self.m.olap_commits += 1
            except SerializationFailure:
                self.m.olap_aborts += 1
            self.txn = None
            return
        try:
            if step[0] == "r":
                self.pending = eng.read(self.txn, step[1])
            elif step[0] == "olap":
                # ONE plan-execution seam serves every OLAP step kind;
                # aggregate plans at a shared RSS horizon may defer to the
                # round's cross-reader batcher (one fused dispatch)
                plan = step[1]
                if (self.batcher is not None and self.txn.rss is not None
                        and isinstance(plan, (AggPlan, MultiAggPlan,
                                              GroupByPlan))):
                    self.batcher.add(("rss", self.txn.rss.lsn), self,
                                     self.txn, plan)
                else:
                    self.pending = self.htap.olap_execute(self.txn, plan)
                self.m.count_plan_step(plan)
            elif step[0] == "scan":            # legacy step kind
                self.pending = self.htap.olap_execute(
                    self.txn, ScanPlan(tuple(step[1])))
                self.m.olap_scan_steps += 1
            elif step[0] == "agg":             # legacy step kind
                self.pending = self.htap.olap_execute(
                    self.txn, AggPlan(tuple(step[1]), step[2]))
                self.m.olap_agg_steps += 1
            elif step[0] == "out":
                self.m.olap_outputs.append(step[1])
        except SerializationFailure:
            self.m.olap_aborts += 1
            self.txn = None

    def _step_deferred(self, eng) -> None:
        """Ports & Grittner deferrable protocol: take a snapshot, wait for the
        read/write transactions concurrent with it; retry if any committed
        with an outgoing rw-conflict (unsafe); else run on that snapshot."""
        if self.deferred is None:
            watch = {tid for tid, t in eng.active.items() if not t.read_only}
            self.deferred = {"seq": eng.seq, "watch": watch}
            self.m.olap_wait_rounds += 1
            return
        watch = self.deferred["watch"]
        live = [tid for tid in watch if tid in eng.active]
        if live:
            self.m.olap_wait_rounds += 1
            return
        unsafe = any(t.out_rw for tid in watch
                     if (t := eng.txns.get(tid)) is not None
                     and t.status == Status.COMMITTED)
        if unsafe:
            self.deferred = None          # retry with a fresh snapshot
            self.m.olap_wait_rounds += 1
            return
        self.txn = eng.begin(read_only=True, skip_siread=True,
                             snapshot_seq=self.deferred["seq"])
        self.gen, _ = olap_query(self.rng, self.sc, batched=self.batched)
        self.pending = None
        self.deferred = None


class _OlapClientMulti:
    """OLAP client against the log-shipping replica cluster.  With
    `freshness_hints` the query's bounded-staleness requirement
    (`workload.olap_freshness`) narrows the routing policy's eligible
    replica set per acquisition."""

    def __init__(self, htap: MultiNodeHTAP, rng, sc: Scale, m: Metrics,
                 *, batched: bool = False, freshness_hints: bool = False,
                 batcher: Optional[_PlanBatcher] = None, session=None):
        self.htap, self.rng, self.sc, self.m = htap, rng, sc, m
        self.batched = batched
        self.freshness_hints = freshness_hints
        self.batcher = batcher
        self.session = session      # sticky client token (read-your-writes
        self.snap = None            # / monotonic reads across replicas)
        self.gen = None
        self.pending = None

    def step(self) -> None:
        if self.snap is None:
            self.gen, name = olap_query(self.rng, self.sc,
                                        batched=self.batched)
            max_lag = olap_freshness(name) if self.freshness_hints else None
            self.snap = self.htap.olap_snapshot(max_lag=max_lag,
                                                session=self.session)
            self.pending = None
            return
        try:
            step = self.gen.send(self.pending)
            self.pending = None
        except StopIteration:
            self.m.olap_commits += 1
            self.htap.olap_release(self.snap)
            self.snap = None
            return
        if step[0] == "r":
            self.pending = self.htap.olap_read(self.snap, step[1])
        elif step[0] == "olap":
            # ONE plan-execution seam serves every OLAP step kind; aggregate
            # plans may defer to the round's cross-reader batcher, keyed by
            # (snapshot kind, serving replica, horizon)
            plan = step[1]
            if (self.batcher is not None
                    and isinstance(plan, (AggPlan, MultiAggPlan,
                                          GroupByPlan))):
                kind, idx, _, s = self.snap
                horizon = s.lsn if isinstance(s, RssSnapshot) else int(s)
                self.batcher.add((kind, idx, horizon), self, self.snap, plan)
            else:
                self.pending = self.htap.olap_execute(self.snap, plan)
            self.m.count_plan_step(plan)
        elif step[0] == "scan":                # legacy step kind
            self.pending = self.htap.olap_execute(self.snap,
                                                  ScanPlan(tuple(step[1])))
            self.m.olap_scan_steps += 1
        elif step[0] == "agg":                 # legacy step kind
            self.pending = self.htap.olap_execute(
                self.snap, AggPlan(tuple(step[1]), step[2]))
            self.m.olap_agg_steps += 1
        elif step[0] == "out":
            self.m.olap_outputs.append(step[1])


def run_single_node(*, olap_mode: str, oltp_clients: int, olap_clients: int,
                    rounds: int = 20_000, seed: int = 0,
                    scale: Scale = Scale(),
                    rss_refresh_every: int = 50,
                    olap_scan: bool = False,
                    paged_olap: bool = False,
                    check_scans: bool = False,
                    batch_plans: bool = False,
                    materialize: bool = False,
                    resolve_cache: bool = True,
                    certifier=None) -> Metrics:
    """olap_scan=True routes OLAP queries through batched ("olap", plan)
    steps served by one plan-execution seam call each; paged_olap=True
    additionally serves protected readers from the WAL-mirrored paged store
    (workload key families reserved contiguously for the dense page-range
    fast path); check_scans=True asserts every plan result equals the
    per-key engine read path (the oracle); batch_plans=True collects
    each round's same-horizon aggregate plans into ONE fused BatchPlan
    dispatch (cross-reader whole-batch plan fusion); materialize=True
    registers the workload's fixed-key plans
    (`Scale.materialized_plans()`) for incremental materialization —
    serves become O(delta) on view hits, counted in olap_view_*;
    `resolve_cache` toggles the mirror's horizon-keyed resolve cache; and
    `certifier`
    selects the OLTP commit-certification policy (`repro.mvcc.certify`)."""
    htap = SingleNodeHTAP(olap_mode, paged=paged_olap,
                          check_scans=check_scans,
                          reserve_keys=scale.key_families(),
                          materialize=(scale.materialized_plans()
                                       if materialize else None),
                          certifier=certifier, resolve_cache=resolve_cache)
    load_initial(htap.engine, scale)
    m = Metrics(certifier=htap.engine.certifier.name)
    rng = random.Random(seed)
    batcher = _PlanBatcher(htap, m) if batch_plans else None
    clients = [_OltpClient(htap.engine, random.Random(rng.random()), scale, m)
               for _ in range(oltp_clients)]
    clients += [_OlapClientSingle(htap, random.Random(rng.random()), scale, m,
                                  batched=olap_scan, batcher=batcher)
                for _ in range(olap_clients)]
    if olap_mode == "ssi+rss":
        htap.refresh_rss()
    # fresh measurement window: zero every registry series (incl. the
    # kernel layer's LAUNCH_STATS and any prior run's engines/mirrors)
    # and drop captured traces — back-to-back runs both start from zero
    reset_run()
    for rnd in range(rounds):
        m.rounds = rnd + 1
        if olap_mode == "ssi+rss" and rnd % rss_refresh_every == 0:
            htap.refresh_rss()   # RSS construction invoker (fixed interval)
        for cl in clients:
            cl.step()
        if batcher is not None:
            batcher.flush()
        m.max_engine_txns = max(m.max_engine_txns, len(htap.engine.txns))
        m.max_rss_tracked = max(m.max_rss_tracked,
                                htap.rss_manager.tracked_txns())
        m.max_wal_records = max(m.max_wal_records,
                                len(htap.engine.wal.records))
    _harvest_obs(m)
    return m


def run_multi_node(*, olap_mode: str, oltp_clients: int, olap_clients: int,
                   rounds: int = 20_000, seed: int = 0,
                   scale: Scale = Scale(),
                   ship_every: int = 25,
                   olap_scan: bool = False,
                   paged_olap: bool = False,
                   check_scans: bool = False,
                   n_replicas: int = 1,
                   route_policy="freshest",
                   max_staleness: int = 100,
                   ship_skew: int = 0,
                   freshness_hints: bool = False,
                   batch_plans: bool = False,
                   materialize: bool = False,
                   session_tokens: bool = False,
                   resolve_cache: bool = True,
                   certifier=None) -> Metrics:
    """N-replica decoupled-storage run.  `ship_skew` staggers the fleet:
    replica i ships every `ship_every * (1 + i * ship_skew)` rounds, so the
    run exercises skewed per-replica lag (the routing policies' input);
    `freshness_hints` routes each OLAP query with its bounded-staleness
    requirement from `workload.OLAP_FRESHNESS`; `materialize` registers
    the workload's fixed-key plans on every replica's mirror — views
    advance during delta ships and serve O(delta) on gate hits;
    `session_tokens` gives every OLAP client a sticky `Session` (routing
    honours read-your-writes / monotonic reads per client);
    `resolve_cache` toggles the mirrors' horizon-keyed resolve cache."""
    htap = MultiNodeHTAP(olap_mode, paged_olap=paged_olap,
                         check_scans=check_scans, n_replicas=n_replicas,
                         route_policy=route_policy,
                         max_staleness=max_staleness,
                         reserve_keys=scale.key_families(),
                         materialize=(scale.materialized_plans()
                                      if materialize else None),
                         certifier=certifier, resolve_cache=resolve_cache)
    load_initial(htap.primary, scale)
    htap.ship_log()
    m = Metrics(certifier=htap.primary.certifier.name)
    rng = random.Random(seed)
    batcher = _PlanBatcher(htap, m) if batch_plans else None
    clients = [_OltpClient(htap.primary, random.Random(rng.random()), scale, m)
               for _ in range(oltp_clients)]
    clients += [_OlapClientMulti(htap, random.Random(rng.random()), scale, m,
                                 batched=olap_scan,
                                 freshness_hints=freshness_hints,
                                 batcher=batcher,
                                 session=(htap.session() if session_tokens
                                          else None))
                for _ in range(olap_clients)]
    reset_run()    # fresh measurement window (see run_single_node)
    for rnd in range(rounds):
        m.rounds = rnd + 1
        for i in range(n_replicas):   # asynchronous streaming replication,
            if rnd % (ship_every * (1 + i * ship_skew)) == 0:  # skewed lag
                htap.ship_log(replica=i)
        if rnd % ship_every == 0:
            # cluster-wide GC floor: replicas + primary prune versions
            # under min(replication horizon, oldest pin) per replica
            m.gc_versions_pruned += htap.gc_versions()
        for cl in clients:
            cl.step()
        if batcher is not None:
            batcher.flush()
        m.max_engine_txns = max(m.max_engine_txns, len(htap.primary.txns))
        for rep in htap.cluster.replicas:
            if rep.rss_manager is not None:
                m.max_rss_tracked = max(m.max_rss_tracked,
                                        rep.rss_manager.tracked_txns())
        m.max_wal_records = max(m.max_wal_records,
                                len(htap.primary.wal.records))
    _harvest_obs(m)
    st = htap.cluster.stats
    m.olap_served_by = list(st["served"])
    m.olap_ship_then_serve = st["ship_then_serve"]
    m.olap_scheduled_ships = st["scheduled_ships"]
    m.olap_avg_lag_records = round(htap.cluster.avg_served_lag(), 2)
    m.olap_avg_predicted_lag = round(htap.cluster.avg_predicted_lag(), 2)
    return m


class _SessionClient:
    """One serving fleet member: a sticky `Session` token plus the
    Zipf-assigned plan family it re-issues every round.  Exposes the
    `pending` slot `_PlanBatcher` delivers results into."""

    __slots__ = ("session", "name", "plan", "pending")

    def __init__(self, session, name: str, plan) -> None:
        self.session, self.name, self.plan = session, name, plan
        self.pending = None


def _run_oltp(engine, gen, m: Metrics) -> bool:
    """Run one OLTP step generator to completion synchronously (the
    session driver's write path — writers within a round are sequential,
    so certification aborts are rare but still only successful commits
    stamp a session).  Returns True on commit."""
    t = engine.begin()
    pending = None
    try:
        while True:
            try:
                step = gen.send(pending)
                pending = None
            except StopIteration:
                break
            if step[0] == "r":
                pending = engine.read(t, step[1])
            elif step[0] == "w":
                engine.write(t, step[1], step[2])
        engine.commit(t)
    except SerializationFailure as e:
        m.oltp_aborts += 1
        k = getattr(e.reason, "value", str(e.reason))
        m.by_abort_reason[k] = m.by_abort_reason.get(k, 0) + 1
        return False
    m.oltp_commits += 1
    return True


def run_sessions(*, n_sessions: int = 200, rounds: int = 8, seed: int = 0,
                 scale: Scale = Scale(),
                 n_replicas: int = 2,
                 route_policy="predicted_staleness",
                 max_staleness: int = 100,
                 ship_every: int = 2,
                 ship_skew: int = 1,
                 zipf_s: float = 1.2,
                 resolve_cache: bool = True,
                 batch_plans: bool = True,
                 write_fraction: float = 0.05,
                 check_scans: bool = False,
                 keep_history: bool = False,
                 olap_mode: str = "ssi+rss") -> tuple[Metrics, list]:
    """Million-session serving drill, scaled down: `n_sessions` sticky
    clients each hold a `Session` token and a Zipf(`zipf_s`)-assigned
    plan family from `workload.session_plan_families`.  Every round a
    `write_fraction` sample of the fleet commits a payment txn and
    stamps its token (read-your-writes pressure), then EVERY session
    acquires a snapshot through token-aware routing and serves its
    family plan.  With `batch_plans` the round's same-horizon serves
    fold through `_PlanBatcher(dedup=True)` — a thousand sessions skewed
    onto a dozen families dispatch one BatchPlan of unique plans per
    horizon group; with `resolve_cache` the replicas' paged mirrors keep
    horizon-keyed member/page-index/device-buffer caches warm between
    rounds.  Ships are cadence-skewed across replicas so tokens actually
    bind.  Asserts zero token-guarantee violations; returns
    `(metrics, session clients)` so callers can audit per-session
    history (`keep_history=True`)."""
    htap = MultiNodeHTAP(olap_mode, paged_olap=True, check_scans=check_scans,
                         n_replicas=n_replicas, route_policy=route_policy,
                         max_staleness=max_staleness,
                         reserve_keys=scale.key_families(),
                         resolve_cache=resolve_cache)
    load_initial(htap.primary, scale)
    htap.ship_log()
    m = Metrics(certifier=htap.primary.certifier.name)
    rng = random.Random(seed)
    fams = session_plan_families(scale)
    assign = zipf_assign(rng, n_sessions, len(fams), s=zipf_s)
    sessions = [_SessionClient(htap.session(keep_history=keep_history),
                               *fams[assign[i]])
                for i in range(n_sessions)]
    writers = min(n_sessions, max(1, round(write_fraction * n_sessions))) \
        if write_fraction > 0 else 0
    batcher = _PlanBatcher(htap, m, dedup=True) if batch_plans else None
    reset_run()    # fresh measurement window (see run_single_node)
    for rnd in range(rounds):
        m.rounds = rnd + 1
        for i in range(n_replicas):   # cadence-skewed async replication
            if rnd % (ship_every * (1 + i * ship_skew)) == 0:
                htap.ship_log(replica=i)
        if rnd and rnd % ship_every == 0:
            m.gc_versions_pruned += htap.gc_versions()
        for cl in rng.sample(sessions, writers):
            if _run_oltp(htap.primary, session_write(rng, scale), m):
                htap.note_commit(cl.session)
        handles = []
        for cl in sessions:
            handle = htap.olap_snapshot(session=cl.session)
            handles.append(handle)
            m.session_serves += 1
            if batcher is not None:
                _kind, idx, _rid, s = handle
                horizon = s.lsn if isinstance(s, RssSnapshot) else int(s)
                batcher.add((_kind, idx, horizon), cl, handle, cl.plan)
            else:
                cl.pending = htap.olap_execute(handle, cl.plan)
            m.count_plan_step(cl.plan)
        if batcher is not None:
            batcher.flush()
        for handle in handles:   # pins released only after the round's
            htap.olap_release(handle)   # serves — PRoT pin sharing
        m.max_engine_txns = max(m.max_engine_txns, len(htap.primary.txns))
        for rep in htap.cluster.replicas:
            if rep.rss_manager is not None:
                m.max_rss_tracked = max(m.max_rss_tracked,
                                        rep.rss_manager.tracked_txns())
    st = htap.cluster.stats
    assert st["token_violations"] == 0, \
        "session token guarantee violated (served below required LSN)"
    _harvest_obs(m)
    m.olap_served_by = list(st["served"])
    m.olap_ship_then_serve = st["ship_then_serve"]
    m.olap_scheduled_ships = st["scheduled_ships"]
    m.olap_avg_lag_records = round(htap.cluster.avg_served_lag(), 2)
    m.olap_avg_predicted_lag = round(htap.cluster.avg_predicted_lag(), 2)
    return m, sessions


def run_write_skew(*, certifier=None, n_clients: int = 8,
                   contention: float = 0.5, rounds: int = 4000,
                   seed: int = 0, record: bool = False
                   ) -> tuple[Metrics, Engine]:
    """Contended write-skew stress run (the certifier comparison bench):
    `n_clients` OLTP clients replay `workload.write_skew` transactions
    against one SSI engine under the chosen certifier.  Returns
    `(metrics, engine)` so callers can inspect engine stats, the final
    rota state (every on-call group must keep >= 1 doctor under any
    serializable execution), and — with `record=True` — check the Adya
    history against the `repro.core` serializability oracles."""
    txn_factory, load, _keys = write_skew(n_clients, contention)
    engine = Engine("ssi", record=record, certifier=certifier)
    load(engine)
    m = Metrics(certifier=engine.certifier.name)
    rng = random.Random(seed)
    clients = [_OltpClient(engine, random.Random(rng.random()), None, m,
                           txn_factory=txn_factory)
               for _ in range(n_clients)]
    reset_run()    # fresh measurement window (see run_single_node)
    for rnd in range(rounds):
        m.rounds = rnd + 1
        for cl in clients:
            cl.step()
        m.max_engine_txns = max(m.max_engine_txns, len(engine.txns))
    _harvest_obs(m)
    # the engine outlives this measurement window: detach its stats into a
    # plain dict so a later run's registry-wide reset can't zero the copy
    # the caller inspects (e.g. comparing engines across certifier runs)
    engine.stats = engine.stats.detach()
    return m, engine
