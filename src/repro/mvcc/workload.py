"""A miniature CH-BenCHmark: TPC-C-style writers + TPC-H-style analytics.

Schema (flat keyspace):
  warehouse:{w}              -> ytd balance
  district:{w}:{d}           -> {"next_o_id": int, "ytd": int}
  customer:{w}:{d}:{c}       -> balance
  stock:{w}:{i}              -> quantity
  order:{w}:{d}:{o}          -> {"items": [...], "total": int}

OLTP transactions (the paper's writers): new_order, payment, order_status
(read-only OLTP — runs under SSI, not RSS, per Sec 5.2).
OLAP queries (scan-heavy, long-running): stock_level_scan, customer_balance,
order_revenue, district_revenue_group (GROUP BY district, AVG via compound
sum+count), district_revenue_all (its statically-keyed, materializable
twin), stock_overview (multi-statistic compound incl. a pushed-down
count_above predicate) — read sets of
hundreds of keys, the shape that makes SSI writer-abort OLTP transactions
(Fig. 5/7) and SafeSnapshots reader-wait.  `Scale.materialized_plans()`
names the fixed-key plans worth a live accumulator tile.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Iterator

from ..tensorstore.version_store import (AggOp, AggPlan, GroupByPlan,
                                         MultiAggPlan, ScanPlan)


@dataclass(frozen=True)
class Scale:
    warehouses: int = 4
    districts: int = 4        # per warehouse
    customers: int = 20       # per district
    items: int = 50           # stock rows per warehouse
    order_capacity: int = 8   # statically-addressable orders per district

    def all_stock_keys(self) -> list[str]:
        return [f"stock:{w}:{i}" for w in range(self.warehouses)
                for i in range(self.items)]

    def all_customer_keys(self) -> list[str]:
        return [f"customer:{w}:{d}:{c}" for w in range(self.warehouses)
                for d in range(self.districts) for c in range(self.customers)]

    def all_district_keys(self) -> list[str]:
        return [f"district:{w}:{d}" for w in range(self.warehouses)
                for d in range(self.districts)]

    def order_range_keys(self, w: int, d: int) -> list[str]:
        """The district's statically-addressable order key range (the
        first `order_capacity` o_ids) — a FIXED key set, so plans over it
        fingerprint identically query to query and can be materialized
        (unwritten order keys decode to 0, which no "total"-field
        aggregate counts)."""
        return [f"order:{w}:{d}:{o}" for o in range(self.order_capacity)]

    def key_families(self) -> list[str]:
        """Every statically-known workload key, family-major and in the
        exact order the OLAP plans enumerate them — reserve these
        contiguously in a `PagedMirror` so dense plans resolve to page
        RANGES (the `paged.as_page_range` slice fast path) instead of
        gathers.  Each district's first `order_capacity` order keys are
        reserved too (the static revenue plan's ranges); o_ids past the
        capacity are allocated on demand."""
        return ([f"warehouse:{w}" for w in range(self.warehouses)]
                + self.all_district_keys()
                + self.all_customer_keys()
                + self.all_stock_keys()
                + [k for w in range(self.warehouses)
                   for d in range(self.districts)
                   for k in self.order_range_keys(w, d)])

    # ---------------------------------------------- registrable plan builders
    # Frozen plan dataclasses hash by value, so plans built here always
    # fingerprint-match the registry entries `materialized_plans` seeds —
    # the queries below construct their batched shapes through these.
    def stock_level_plan(self) -> AggPlan:
        return AggPlan(tuple(self.all_stock_keys()),
                       AggOp("count_below", "int", 50))

    def customer_balance_plan(self) -> AggPlan:
        return AggPlan(tuple(self.all_customer_keys()), AggOp("sum", "int"))

    def stock_overview_plan(self) -> MultiAggPlan:
        return MultiAggPlan(
            tuple(self.all_stock_keys()),
            (AggOp("sum", "int"), AggOp("count", "int"), AggOp("min", "int"),
             AggOp("count_above", "int", 90)))

    def district_revenue_plan(self) -> GroupByPlan:
        return GroupByPlan(
            tuple(tuple(self.order_range_keys(w, d))
                  for w in range(self.warehouses)
                  for d in range(self.districts)),
            (AggOp("sum", "total"), AggOp("count", "total")))

    def materialized_plans(self) -> tuple:
        """The hot statically-keyed OLAP plans worth a live accumulator
        tile (`materialize=` on the HTAP facades): every batched query
        over a fixed key range.  `district_revenue_group` stays
        unregistrable by design — its key ranges chase next_o_id, so its
        fingerprint changes query to query."""
        return (self.stock_level_plan(), self.customer_balance_plan(),
                self.stock_overview_plan(), self.district_revenue_plan())


# Each yielded step is ('r', key) or ('w', key, update_fn) where update_fn
# maps the read value to the written value;  ('olap', plan) to execute a
# query plan (`tensorstore.Plan`: ScanPlan / AggPlan / MultiAggPlan /
# GroupByPlan) in ONE plan-execution seam call — the generator receives
# the plan's result (a value list for ScanPlan; scalars/tuples for the
# aggregate plans, which never materialize values on host);  or
# ('out', value) to emit a result.  The driver executes steps against an
# engine transaction.  (Legacy ('scan', keys) / ('agg', keys, op) step
# kinds are still served, as ScanPlan/AggPlan shims.)
Step = tuple


def new_order(rng: random.Random, sc: Scale) -> Iterator[Step]:
    w = rng.randrange(sc.warehouses)
    d = rng.randrange(sc.districts)
    dk = f"district:{w}:{d}"
    dist = yield ("r", dk)
    o_id = (dist or {"next_o_id": 0})["next_o_id"]
    yield ("w", dk, {"next_o_id": o_id + 1, "ytd": (dist or {}).get("ytd", 0)})
    n_items = rng.randint(5, 15)
    total = 0
    items = []
    for _ in range(n_items):
        i = rng.randrange(sc.items)
        skey = f"stock:{w}:{i}"
        qty = yield ("r", skey)
        qty = qty if isinstance(qty, int) else 100
        take = rng.randint(1, 10)
        newq = qty - take if qty - take >= 10 else qty - take + 91
        yield ("w", skey, newq)
        total += take
        items.append(i)
    yield ("w", f"order:{w}:{d}:{o_id}", {"items": items, "total": total})


def payment(rng: random.Random, sc: Scale) -> Iterator[Step]:
    w = rng.randrange(sc.warehouses)
    d = rng.randrange(sc.districts)
    cu = rng.randrange(sc.customers)
    amount = rng.randint(1, 5000)
    wkey = f"warehouse:{w}"
    bal = yield ("r", wkey)
    yield ("w", wkey, (bal if isinstance(bal, int) else 0) + amount)
    ckey = f"customer:{w}:{d}:{cu}"
    cbal = yield ("r", ckey)
    yield ("w", ckey, (cbal if isinstance(cbal, int) else 0) - amount)


def order_status(rng: random.Random, sc: Scale) -> Iterator[Step]:
    """Read-only OLTP transaction (stays under SSI per the paper Sec 5.2)."""
    w = rng.randrange(sc.warehouses)
    d = rng.randrange(sc.districts)
    dist = yield ("r", f"district:{w}:{d}")
    o_id = max(((dist or {"next_o_id": 1})["next_o_id"]) - 1, 0)
    order = yield ("r", f"order:{w}:{d}:{o_id}")
    yield ("out", order)


OLTP_MIX = ((new_order, 0.45), (payment, 0.43), (order_status, 0.12))


def oltp_transaction(rng: random.Random, sc: Scale):
    x = rng.random()
    acc = 0.0
    for fn, p in OLTP_MIX:
        acc += p
        if x <= acc:
            return fn(rng, sc), fn.__name__
    return payment(rng, sc), "payment"


# ----------------------------------------------------------------- OLAP side
# Every query has two execution shapes over the SAME read set: the per-key
# generator walk (one engine.read per round — the oracle, and the shape that
# keeps a query active for hundreds of rounds) and the batched shape —
# ('olap', plan) steps, each answered by ONE plan-execution seam call
# (aggregate plans reduce in fused device passes; ScanPlan where the query
# needs the values themselves, e.g. the district pass that derives the
# order key range).
def stock_level_scan(rng: random.Random, sc: Scale,
                     batched: bool = False) -> Iterator[Step]:
    """CH Q-like: total stock below threshold across every warehouse."""
    low = 0
    if batched:
        low = yield ("olap", sc.stock_level_plan())
    else:
        for key in sc.all_stock_keys():
            q = yield ("r", key)
            if isinstance(q, int) and q < 50:
                low += 1
    yield ("out", low)


def customer_balance(rng: random.Random, sc: Scale,
                     batched: bool = False) -> Iterator[Step]:
    total = 0
    if batched:
        total = yield ("olap", sc.customer_balance_plan())
    else:
        for key in sc.all_customer_keys():
            v = yield ("r", key)
            if isinstance(v, int):
                total += v
    yield ("out", total)


def _recent_order_groups(dkeys, dists, last_n: int = 5):
    """Per-district key groups of the last `last_n` orders, derived from a
    scanned district pass (the GROUP BY key ranges)."""
    groups = []
    for dk, dist in zip(dkeys, dists):
        _, w, d = dk.split(":")
        hi = (dist or {"next_o_id": 0})["next_o_id"]
        groups.append(tuple(f"order:{w}:{d}:{o}"
                            for o in range(max(hi - last_n, 0), hi)))
    return tuple(groups)


def order_revenue(rng: random.Random, sc: Scale,
                  batched: bool = False) -> Iterator[Step]:
    """Scan districts then recent orders; aggregates revenue."""
    rev = 0
    if batched:
        dkeys = sc.all_district_keys()
        dists = yield ("olap", ScanPlan(tuple(dkeys)))  # derive key range
        okeys = [k for g in _recent_order_groups(dkeys, dists) for k in g]
        if okeys:
            rev = yield ("olap", AggPlan(tuple(okeys), AggOp("sum", "total")))
        yield ("out", rev)
        return
    for w in range(sc.warehouses):
        for d in range(sc.districts):
            dist = yield ("r", f"district:{w}:{d}")
            hi = (dist or {"next_o_id": 0})["next_o_id"]
            for o in range(max(hi - 5, 0), hi):
                order = yield ("r", f"order:{w}:{d}:{o}")
                if isinstance(order, dict):
                    rev += order.get("total", 0)
    yield ("out", rev)


def district_revenue_group(rng: random.Random, sc: Scale,
                           batched: bool = False) -> Iterator[Step]:
    """GROUP BY district: revenue and AVG order value per district over
    the recent orders — the batched shape is ONE `GroupByPlan` whose
    compound (sum, count) ops come back as a [districts × 2] tile from a
    single fused device pass (AVG derived on host from the two lanes;
    groups with no orders are empty groups)."""
    dkeys = sc.all_district_keys()
    if batched:
        dists = yield ("olap", ScanPlan(tuple(dkeys)))
        groups = _recent_order_groups(dkeys, dists)
        rows = yield ("olap", GroupByPlan(
            groups, (AggOp("sum", "total"), AggOp("count", "total"))))
        out = [(dk, s, s // n if n else 0) for dk, (s, n) in zip(dkeys, rows)]
        yield ("out", out)
        return
    out = []
    for dk in dkeys:
        dist = yield ("r", dk)
        _, w, d = dk.split(":")
        hi = (dist or {"next_o_id": 0})["next_o_id"]
        s = n = 0
        for o in range(max(hi - 5, 0), hi):
            order = yield ("r", f"order:{w}:{d}:{o}")
            if isinstance(order, dict) and "total" in order:
                s += order["total"]
                n += 1
        out.append((dk, s, s // n if n else 0))
    yield ("out", out)


def district_revenue_all(rng: random.Random, sc: Scale,
                         batched: bool = False) -> Iterator[Step]:
    """GROUP BY district over the STATIC order ranges (the first
    `order_capacity` o_ids per district): revenue and order count.  The
    registrable twin of `district_revenue_group` — that query's key
    ranges chase next_o_id, so its plan fingerprint changes query to
    query; this one's ranges are fixed, so its `GroupByPlan` can be
    served from a live materialized tile (`materialize=` on the
    facades)."""
    dkeys = sc.all_district_keys()
    if batched:
        rows = yield ("olap", sc.district_revenue_plan())
        out = [(dk, s, n) for dk, (s, n) in zip(dkeys, rows)]
        yield ("out", out)
        return
    out = []
    for dk in dkeys:
        _, w, d = dk.split(":")
        s = n = 0
        for key in sc.order_range_keys(int(w), int(d)):
            order = yield ("r", key)
            if isinstance(order, dict) and "total" in order:
                s += order["total"]
                n += 1
        out.append((dk, s, n))
    yield ("out", out)


def stock_overview(rng: random.Random, sc: Scale,
                   batched: bool = False) -> Iterator[Step]:
    """Compound multi-statistic dashboard: total, AVG, floor, and
    over-90 headcount of stock quantities — the batched shape is ONE
    `MultiAggPlan` answered from a single visibility pass (the kernel
    computes all seven statistic lanes anyway), never four scans.  The
    count_above op rides the predicate-pushdown seam: the (field,
    threshold) config lowers to its own kernel pass, with the count
    folded on device."""
    keys = sc.all_stock_keys()
    if batched:
        s, n, mn, hi = yield ("olap", sc.stock_overview_plan())
    else:
        s = n = hi = 0
        mn = None
        for key in keys:
            q = yield ("r", key)
            if isinstance(q, int):
                s += q
                n += 1
                mn = q if mn is None or q < mn else mn
                hi += 1 if q > 90 else 0
        mn = mn if mn is not None else 0
    yield ("out", (s, s // n if n else 0, mn, hi))


OLAP_QUERIES = (stock_level_scan, customer_balance, order_revenue,
                district_revenue_group, district_revenue_all,
                stock_overview)

# Per-query freshness requirements (bounded staleness, in WAL records) for
# replica-cluster snapshot routing: None tolerates any replication lag; a
# bound narrows the eligible replica set, and an unsatisfiable bound makes
# the cluster ship-then-serve.  Shapes the skewed-lag mix: trend scans ride
# the laggiest replica while the revenue dashboard demands near-real-time.
OLAP_FRESHNESS = {
    "stock_level_scan": None,     # historical trend: any replica will do
    "customer_balance": 400,      # moderately fresh balance sheet
    "order_revenue": 120,         # near-real-time revenue dashboard
    "district_revenue_group": 200,  # per-district drill-down, fairly fresh
    "district_revenue_all": 200,  # static drill-down twin, same freshness
    "stock_overview": None,       # inventory dashboard: staleness tolerant
}


def olap_freshness(name: str):
    """Max tolerated replication lag (WAL records) for a query, or None."""
    return OLAP_FRESHNESS.get(name)


# ----------------------------------------------------------- write-skew bench
def write_skew(n_clients: int, contention: float = 0.5, *,
               doctors: int = 6):
    """Doctor-on-call write-skew stress generator (the classic SSI
    anomaly, grown from the ddia-study-practice snippet into a driver/
    bench workload): doctors are partitioned into on-call groups; each
    transaction reads its whole group's rota, then — believing at least
    one colleague stays on call — writes only its OWN slot.  Two
    concurrent sign-offs in one group are write skew: disjoint writes,
    serializable only if a certifier kills one.

    `contention` in [0, 1] sets how many clients share a group:  0 gives
    ~one group per client (almost no conflicts), 1 gives a single group
    everyone fights over.  Returns `(txn_factory, load, keys)`:
    `txn_factory(rng) -> (step generator, name)` (the `_OltpClient`
    transaction-factory interface), `load(engine)` commits the initial
    everyone-on-call rota, and `keys` lists the rota keys."""
    assert 0.0 <= contention <= 1.0
    groups = max(1, round(n_clients * (1.0 - contention)))
    keys = [f"oncall:{g}:{d}" for g in range(groups)
            for d in range(doctors)]

    def load(engine) -> None:
        t = engine.begin()
        for k in keys:
            engine.write(t, k, 1)          # 1 = on call
        engine.commit(t)

    def txn_factory(rng: random.Random):
        return _write_skew_txn(rng, groups, doctors), "write_skew"

    return txn_factory, load, keys


def _write_skew_txn(rng: random.Random, groups: int,
                    doctors: int) -> Iterator[Step]:
    g = rng.randrange(groups)
    me = rng.randrange(doctors)
    on_call = 0
    mine = 0
    for d in range(doctors):
        v = yield ("r", f"oncall:{g}:{d}")
        v = v if isinstance(v, int) else 0
        on_call += v
        if d == me:
            mine = v
    if mine and on_call > 1:
        # someone else is on call: sign off (the write-skew write)
        yield ("w", f"oncall:{g}:{me}", 0)
    elif not mine:
        # understaffed rota oscillates back: go on call again
        yield ("w", f"oncall:{g}:{me}", 1)
    yield ("out", on_call)


def olap_query(rng: random.Random, sc: Scale, *, batched: bool = False):
    fn = OLAP_QUERIES[rng.randrange(len(OLAP_QUERIES))]
    return fn(rng, sc, batched=batched), fn.__name__


# ------------------------------------------------------- session workloads
def session_plan_families(sc: Scale) -> tuple:
    """The fixed-fingerprint plan families a session-serving fleet hands
    out: each family is a `(name, plan)` pair whose plan hashes
    identically serve to serve (frozen dataclasses), so same-horizon
    sessions on one family collapse onto one resolve/dispatch.  Beyond
    the four fleet-wide dashboards, every warehouse gets two drill-down
    families (stock + customer balance) — the per-tenant shape a
    million-user deployment skews over."""
    fams = [("stock_level", sc.stock_level_plan()),
            ("customer_balance", sc.customer_balance_plan()),
            ("stock_overview", sc.stock_overview_plan()),
            ("district_revenue", sc.district_revenue_plan())]
    for w in range(sc.warehouses):
        fams.append((f"stock_sum:w{w}", AggPlan(
            tuple(f"stock:{w}:{i}" for i in range(sc.items)),
            AggOp("sum", "int"))))
        fams.append((f"balance:w{w}", MultiAggPlan(
            tuple(f"customer:{w}:{d}:{c}" for d in range(sc.districts)
                  for c in range(sc.customers)),
            (AggOp("sum", "int"), AggOp("min", "int")))))
    return tuple(fams)


def zipf_assign(rng: random.Random, n_sessions: int, n_families: int,
                *, s: float = 1.2) -> list[int]:
    """Assign each of `n_sessions` a plan-family index, Zipf(s)-skewed
    over the families (rank r drawn with weight 1/r^s): a handful of hot
    dashboards dominate while the tail of per-tenant drill-downs stays
    thin — the popularity shape cross-session batching amortizes."""
    assert n_families >= 1
    weights = [1.0 / (r + 1) ** s for r in range(n_families)]
    total = sum(weights)
    cum, acc = [], 0.0
    for w in weights:
        acc += w / total
        cum.append(acc)
    out = []
    for _ in range(n_sessions):
        x = rng.random()
        out.append(next(i for i, c in enumerate(cum) if x <= c or
                        i == n_families - 1))
    return out


def session_write(rng: random.Random, sc: Scale) -> Iterator[Step]:
    """The session's own OLTP write (read-your-writes pressure): a
    payment-shaped balance move the session must observe on its very
    next read, whichever replica serves it."""
    return payment(rng, sc)


def load_initial(engine, sc: Scale) -> None:
    """Initial data load (one big transaction)."""
    t = engine.begin()
    for w in range(sc.warehouses):
        engine.write(t, f"warehouse:{w}", 0)
        for d in range(sc.districts):
            engine.write(t, f"district:{w}:{d}", {"next_o_id": 0, "ytd": 0})
            for cu in range(sc.customers):
                engine.write(t, f"customer:{w}:{d}:{cu}", 1000)
        for i in range(sc.items):
            engine.write(t, f"stock:{w}:{i}", 100)
    engine.commit(t)
