"""Pluggable commit certification for the SSI engine.

Every ABORT decision the engine makes (other than first-committer-wins,
which is an SI storage rule, not a serializability criterion) lives behind
the `Certifier` protocol.  The engine keeps the mechanism — version
install, WAL logging, SIRead bookkeeping, the in_rw/out_rw vulnerable-edge
sets that feed the WAL `deps` messages, and GC — and reports events to its
certifier; the certifier holds the policy and decides who dies.

Three certifiers, ordered by the schedules they admit
(SSN ⊇ CommitOrderSSI ⊇ ConservativeSSI):

  * `ConservativeSSI` — the structural pivot abort (PostgreSQL-style):
    any transaction with both an incoming and an outgoing vulnerable rw
    edge is killed, regardless of commit order.  Extracted verbatim from
    the seed engine and behaviour-pinned by the test suite.
  * `CommitOrderSSI` — the engine-level twin of
    `core.ssi.fatal_dangerous_structures`: a dangerous structure
    Ta -rw-> Tb -rw-> Tc is fatal only when Tc commits FIRST of the three
    (Ta == Tc allowed: plain write skew).  Tracks two sticky per-txn
    summaries — min commit seq over committed out-neighbours (`min_out`)
    and max commit seq over committed in-neighbours (`max_in`) — which
    survive engine edge-GC, the analogue of PostgreSQL's SLRU conflict
    summarization.
  * `SSN` — Wang et al.'s Serial Safety Net exclusion window: per-txn
    low/high watermarks pi(T)/eta(T) folded on edge events, abort iff
    pi(T) <= eta(T) at commit.  Admits some genuinely-serializable
    dangerous structures CommitOrderSSI still aborts.

Certifier instances are stateful and strictly per-engine (`attach`
asserts single ownership); pass a name or factory when configuring
several engines.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Union

# circular-import note: `engine` imports this module lazily (inside
# Engine.__init__), so a top-level import of engine names is safe here.
from .engine import AbortReason, SerializationFailure, Status, Txn

INF = 1 << 62

CertifierSpec = Union[None, str, "Certifier", Callable[[], "Certifier"]]


class Certifier:
    """Event hooks the engine calls; every default is a no-op.

    Hook contract (all `Txn` arguments are live engine transactions):

      * `on_begin(t)` — t entered the system.
      * `on_read(t, writer_tid, commit_seq)` — t read the version written
        by `writer_tid` (commit seq of that version; 0 for the initial).
      * `on_read_skipped_version(t, writer, commit_seq)` — t's snapshot
        read skipped a newer committed version (`writer` may be None when
        the writer was already GC'd).  Fired before the matching
        `on_rw_edge`.
      * `on_rw_edge(reader, writer)` — a vulnerable (concurrent) rw
        anti-dependency reader -> writer was recorded.  Neither endpoint
        is aborted at call time.  The certifier may abort either endpoint
        (or a neighbour) via `self.abort(...)`.
      * `on_precommit(t)` — t passed first-committer-wins and is about to
        commit; raise `SerializationFailure` to reject it.  If it returns,
        t's commit seq will be `engine.seq + 1`.
      * `on_end(t, committed)` — t committed (end_seq = its commit seq) or
        aborted; fired after the engine's own bookkeeping.
      * `on_gc(dead)` — the engine reaped these tids; drop any per-txn
        state keyed on them.
    """

    name = "abstract"

    def __init__(self) -> None:
        self.engine = None

    def attach(self, engine) -> None:
        assert self.engine is None, \
            "certifier instances are per-engine; pass a name or factory"
        self.engine = engine

    # ------------------------------------------------------------- hooks
    def on_begin(self, t: Txn) -> None:
        pass

    def on_read(self, t: Txn, writer_tid: int, commit_seq: int) -> None:
        pass

    def on_read_skipped_version(self, t: Txn, writer: Optional[Txn],
                                commit_seq: int) -> None:
        pass

    def on_rw_edge(self, reader: Txn, writer: Txn) -> None:
        pass

    def on_precommit(self, t: Txn) -> None:
        pass

    def on_end(self, t: Txn, committed: bool) -> None:
        pass

    def on_gc(self, dead: set[int]) -> None:
        pass

    # ----------------------------------------------------------- helpers
    def abort(self, t: Txn, reason: AbortReason) -> None:
        """Kill a transaction mid-flight (the engine logs/aborts it)."""
        self.engine._abort(t, reason)


class ConservativeSSI(Certifier):
    """The seed engine's structural dangerous-structure abort, extracted
    verbatim: any pivot (a txn with both in- and out- vulnerable rw edges)
    is aborted when the second edge appears — while still active, else an
    active neighbour dies in its place (PostgreSQL never aborts an
    already-committed transaction).  Commit order is ignored, so provably
    benign structures (Tc committing last) are still aborted."""

    name = "conservative-ssi"

    def on_rw_edge(self, reader: Txn, writer: Txn) -> None:
        eng = self.engine
        for cand in (writer, reader):
            if cand.is_pivot:
                if cand.status == Status.ACTIVE:
                    self.abort(cand, AbortReason.PIVOT)
                    return
                # pivot already committed: abort an active neighbour
                for nid in list(cand.in_rw) + list(cand.out_rw):
                    n = eng.txns.get(nid)
                    if n is not None and n.status == Status.ACTIVE:
                        self.abort(n, AbortReason.INCOMING_PIVOT)
                        return

    def on_precommit(self, t: Txn) -> None:
        if t.is_pivot and t.status == Status.ACTIVE:
            raise SerializationFailure(AbortReason.PIVOT)


@dataclass
class _CoState:
    """Sticky commit-order summary.  min_out/max_in fold in neighbour
    commit seqs as neighbours commit and are never un-folded, so the
    summary outlives engine edge-GC of the neighbour itself."""
    cstamp: int = 0          # own commit seq once committed
    min_out: int = INF       # min commit seq over committed out-neighbours
    max_in: int = 0          # max commit seq over committed in-neighbours


class CommitOrderSSI(Certifier):
    """Full Fekete-condition certification at commit time.

    A structure Ta -rw-> Tb -rw-> Tc is fatal iff Tc commits first of the
    three (Ta == Tc allowed).  Because aborts happen only at the aborting
    transaction's own commit, the LAST of the three to (attempt to) commit
    is the one rejected:

      * t is the pivot Tb: fatal iff some out-neighbour committed no later
        than some in-neighbour — `min_out <= max_in` (equality is the
        two-transaction write-skew cycle, where the out- and in-neighbour
        are the same transaction).
      * t is the in-neighbour Ta of a committed pivot W whose own
        out-neighbour committed before W did: `min_out(W) < cstamp(W)`.
        (Tc committing first of the three is implied: c(Tc) < c(W) and t,
        still uncommitted, necessarily commits after both.)

    The structural pivot (Tb) is never aborted mid-flight, so unlike
    ConservativeSSI this certifier admits every structure whose Tc
    commits last — exactly `core.ssi.fatal_dangerous_structures`."""

    name = "commit-order-ssi"

    def __init__(self) -> None:
        super().__init__()
        self.state: dict[int, _CoState] = {}

    def _st(self, tid: int) -> _CoState:
        st = self.state.get(tid)
        if st is None:
            st = self.state[tid] = _CoState()
        return st

    def on_begin(self, t: Txn) -> None:
        self._st(t.tid)

    def on_rw_edge(self, reader: Txn, writer: Txn) -> None:
        # edge to/from an already-committed endpoint: fold its cstamp now
        # (the on_end fan-out below only reaches then-live neighbours)
        if writer.status == Status.COMMITTED:
            st = self._st(reader.tid)
            st.min_out = min(st.min_out, writer.end_seq)
        if reader.status == Status.COMMITTED:
            st = self._st(writer.tid)
            st.max_in = max(st.max_in, reader.end_seq)

    def on_precommit(self, t: Txn) -> None:
        st = self._st(t.tid)
        if st.min_out <= st.max_in:                      # t is the pivot Tb
            raise SerializationFailure(AbortReason.FATAL_PIVOT)
        eng = self.engine
        for wid in t.out_rw:                             # t is Ta, W a pivot
            w = eng.txns.get(wid)
            wst = self.state.get(wid)
            if (w is not None and w.status == Status.COMMITTED
                    and wst is not None and wst.min_out < wst.cstamp):
                raise SerializationFailure(AbortReason.FATAL_NEIGHBOUR)

    def on_end(self, t: Txn, committed: bool) -> None:
        if not committed:
            self.state.pop(t.tid, None)
            return
        c = t.end_seq
        st = self._st(t.tid)
        st.cstamp = c
        eng = self.engine
        for rid in t.in_rw:          # r -rw-> t: t is r's committed out-nbr
            r = eng.txns.get(rid)
            if r is not None and r.status == Status.ACTIVE:
                rs = self._st(rid)
                rs.min_out = min(rs.min_out, c)
        for wid in t.out_rw:         # t -rw-> w: t is w's committed in-nbr
            w = eng.txns.get(wid)
            if w is not None and w.status == Status.ACTIVE:
                ws = self._st(wid)
                ws.max_in = max(ws.max_in, c)

    def on_gc(self, dead: set[int]) -> None:
        for tid in dead:
            self.state.pop(tid, None)


@dataclass
class _SsnState:
    """SSN watermarks.  pi(T) is the low watermark (min sstamp over T's
    committed rw successors, i.e. the earliest serial position forced
    *after* T); eta(T) the high watermark (max cstamp over T's committed
    predecessors — versions read, overwritten versions and their readers,
    committed in-rw readers).  The exclusion window inverts — pi <= eta —
    exactly when some predecessor is forced to serialize after some
    successor, i.e. a potential cycle through committed transactions."""
    pi: int = INF
    eta: int = 0
    cstamp: int = 0
    sstamp: int = INF        # min(pi, cstamp) at commit; propagated back


class SSN(Certifier):
    """Wang et al.'s Serial Safety Net (arXiv:1605.04292) on top of SI.

    Cheaper and more permissive than dangerous-structure certification:
    two per-txn watermarks folded on read/edge/commit events, one
    comparison at commit.  Admits serializable schedules CommitOrderSSI
    aborts (the committed-pivot Ta case when no cycle exists), and aborts
    only when the exclusion window pi(T) <= eta(T) proves a potential
    serial-order inversion through committed transactions."""

    name = "ssn"

    def __init__(self) -> None:
        super().__init__()
        self.state: dict[int, _SsnState] = {}
        # (key, writer_tid) -> max cstamp over committed readers of that
        # version: the v.pstamp of the paper, folded into eta(T) when T
        # overwrites the version.  Pruned against the concurrency horizon.
        self.pstamp: dict[tuple[str, int], int] = {}

    _PSTAMP_PRUNE = 4096     # amortized prune threshold

    def _st(self, tid: int) -> _SsnState:
        st = self.state.get(tid)
        if st is None:
            st = self.state[tid] = _SsnState()
        return st

    def on_begin(self, t: Txn) -> None:
        self._st(t.tid)

    def on_read(self, t: Txn, writer_tid: int, commit_seq: int) -> None:
        # wr predecessor: the version's writer committed before our read
        st = self._st(t.tid)
        st.eta = max(st.eta, commit_seq)

    def on_read_skipped_version(self, t: Txn, writer: Optional[Txn],
                                commit_seq: int) -> None:
        # t -rw-> writer with writer committed: successor's sstamp bounds pi
        st = self._st(t.tid)
        ws = self.state.get(writer.tid) if writer is not None else None
        s = min(ws.sstamp, commit_seq) if ws is not None else commit_seq
        st.pi = min(st.pi, s)

    def on_rw_edge(self, reader: Txn, writer: Txn) -> None:
        if writer.status == Status.COMMITTED:
            ws = self.state.get(writer.tid)
            s = min(ws.sstamp, writer.end_seq) if ws is not None \
                else writer.end_seq
            rs = self._st(reader.tid)
            rs.pi = min(rs.pi, s)
        if reader.status == Status.COMMITTED:
            st = self._st(writer.tid)
            st.eta = max(st.eta, reader.end_seq)

    def on_precommit(self, t: Txn) -> None:
        eng = self.engine
        st = self._st(t.tid)
        eta = st.eta
        for key in t.writes:
            # ww predecessor (the version we overwrite — FCW already
            # guarantees it is <= our snapshot) and the committed readers
            # of that version (rw predecessors through v.pstamp)
            v = eng.store.chain(key).newest()
            eta = max(eta, v.commit_seq,
                      self.pstamp.get((key, v.writer), 0))
        st.eta = eta
        pi = min(st.pi, eng.seq + 1)         # prospective cstamp
        if pi <= eta:
            raise SerializationFailure(AbortReason.EXCLUSION_WINDOW)

    def on_end(self, t: Txn, committed: bool) -> None:
        if not committed:
            self.state.pop(t.tid, None)
            return
        c = t.end_seq
        st = self._st(t.tid)
        st.cstamp = c
        st.sstamp = min(st.pi, c)
        eng = self.engine
        for rid in t.in_rw:          # r -rw-> t: t committed successor of r
            r = eng.txns.get(rid)
            if r is not None and r.status == Status.ACTIVE:
                rs = self._st(rid)
                rs.pi = min(rs.pi, st.sstamp)
        for wid in t.out_rw:         # t -rw-> w: t committed predecessor
            w = eng.txns.get(wid)
            if w is not None and w.status == Status.ACTIVE:
                ws = self._st(wid)
                ws.eta = max(ws.eta, c)
        for key, writer in t.reads.items():
            k = (key, writer)
            if self.pstamp.get(k, 0) < c:
                self.pstamp[k] = c

    def on_gc(self, dead: set[int]) -> None:
        for tid in dead:
            self.state.pop(tid, None)
        if len(self.pstamp) > self._PSTAMP_PRUNE:
            eng = self.engine
            horizon = min((t.begin_seq for t in eng.active.values()),
                          default=eng.seq)
            self.pstamp = {k: s for k, s in self.pstamp.items()
                           if s >= horizon}


CERTIFIERS: dict[str, Callable[[], Certifier]] = {
    "conservative": ConservativeSSI,
    "conservative-ssi": ConservativeSSI,
    "commit-order": CommitOrderSSI,
    "commit-order-ssi": CommitOrderSSI,
    "ssn": SSN,
}


def make_certifier(spec: CertifierSpec) -> Certifier:
    """Resolve a certifier spec: None -> ConservativeSSI (the seed
    behaviour), a registry name, a ready instance, or a zero-arg factory."""
    if spec is None:
        return ConservativeSSI()
    if isinstance(spec, str):
        try:
            return CERTIFIERS[spec]()
        except KeyError:
            raise ValueError(
                f"unknown certifier {spec!r}; known: "
                f"{sorted(set(CERTIFIERS))}") from None
    if isinstance(spec, Certifier):
        return spec
    return spec()
