"""HTAP system facades: the paper's two architectures × CC configurations.

Single-node (unified storage, Sec 5.2):
  * "ssi"                — OLAP readers are plain SSI transactions
                           (reader-/writer-aborts possible)
  * "ssi+safesnapshots"  — OLAP readers are READ ONLY DEFERRABLE
                           (reader-WAIT until a safe snapshot exists)
  * "ssi+rss"            — OLAP readers are PRoTs over the in-process RSS
                           (wait-free, abort-free; the paper's system)

Multi-node (decoupled storage, Sec 5.1): primary runs SSI; an asynchronous
log-shipping replica applies committed writesets and serves OLAP:
  * "ssi+si"   — replica readers use plain SI at the replication horizon
                 (NOT serializable: read-only anomalies possible; baseline)
  * "ssi+rss"  — replica-side RSSManager replays begin/commit/abort + deps
                 records and serves RSS snapshots (serializable, wait-free)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

from ..core.replica import PRoTManager, RSSManager, RssSnapshot
from .engine import AbortReason, Engine, SerializationFailure, Status, Txn
from .store import Store


# --------------------------------------------------------------- single node
class SingleNodeHTAP:
    def __init__(self, olap_mode: str = "ssi+rss") -> None:
        assert olap_mode in ("ssi", "ssi+safesnapshots", "ssi+rss")
        self.olap_mode = olap_mode
        self.engine = Engine("ssi")
        self.rss_manager = RSSManager()
        self.prot = PRoTManager(self.rss_manager)

    # OLTP path -------------------------------------------------------------
    def oltp_begin(self, *, read_only: bool = False) -> Txn:
        return self.engine.begin(read_only=read_only)

    # OLAP path -------------------------------------------------------------
    def refresh_rss(self) -> RssSnapshot:
        """RSS construction invoker: replay own WAL, rebuild RSS (Sec 5.2)."""
        self.rss_manager.catch_up(self.engine.wal)
        return self.rss_manager.construct()

    def olap_begin(self) -> Optional[Txn]:
        """Returns None when the reader must wait (SafeSnapshots only)."""
        if self.olap_mode == "ssi":
            return self.engine.begin(read_only=True)
        if self.olap_mode == "ssi+safesnapshots":
            return self.engine.begin_deferred()   # None => reader-wait
        # ssi+rss: wait-free protected read over the freshest constructed RSS
        _, snap = self.prot.acquire()
        return self.engine.begin(read_only=True, rss=snap)

    def olap_read(self, t: Txn, key: str) -> Any:
        return self.engine.read(t, key)

    def olap_commit(self, t: Txn) -> None:
        self.engine.commit(t)


# ---------------------------------------------------------------- multi node
class Replica:
    """Asynchronous log-shipping replica: applies committed writesets in LSN
    order into its own store; optionally maintains an RSSManager from the
    same stream (begin/commit/abort + deps records)."""

    def __init__(self, *, with_rss: bool) -> None:
        self.store = Store()
        self.applied_lsn = 0
        self.applied_seq = 0          # commit-seq horizon for SI readers
        self._commit_seq = 0
        self.with_rss = with_rss
        self.rss_manager = RSSManager() if with_rss else None
        self.prot = PRoTManager(self.rss_manager) if with_rss else None

    def catch_up(self, primary: Engine, *, max_records: int = 0) -> int:
        n = 0
        for rec in primary.wal.tail(self.applied_lsn):
            if max_records and n >= max_records:
                break
            self.applied_lsn = rec.lsn
            if self.rss_manager is not None:
                self.rss_manager.apply(rec)
            if rec.type == "commit":
                self._commit_seq += 1
                for key, value in rec.writes:
                    self.store.chain(key).install(self._commit_seq, rec.txn,
                                                  value)
                self.applied_seq = self._commit_seq
            n += 1
        if self.rss_manager is not None and n:
            self.rss_manager.construct()
        return n

    # reader snapshots -------------------------------------------------------
    def si_snapshot(self) -> int:
        return self.applied_seq

    def rss_snapshot(self) -> RssSnapshot:
        assert self.prot is not None
        _, snap = self.prot.acquire()
        return snap

    def read_si(self, snapshot_seq: int, key: str) -> Any:
        return self.store.chain(key).visible_at(snapshot_seq).value

    def read_rss(self, snap: RssSnapshot, key: str) -> Any:
        return self.store.chain(key).visible_in(snap.visible).value


class MultiNodeHTAP:
    def __init__(self, olap_mode: str = "ssi+rss") -> None:
        assert olap_mode in ("ssi+si", "ssi+rss")
        self.olap_mode = olap_mode
        self.primary = Engine("ssi")
        self.replica = Replica(with_rss=(olap_mode == "ssi+rss"))

    def oltp_begin(self, *, read_only: bool = False) -> Txn:
        return self.primary.begin(read_only=read_only)

    def ship_log(self, *, max_records: int = 0) -> int:
        """One asynchronous replication round."""
        return self.replica.catch_up(self.primary, max_records=max_records)

    def olap_snapshot(self):
        if self.olap_mode == "ssi+si":
            return ("si", self.replica.si_snapshot())
        return ("rss", self.replica.rss_snapshot())

    def olap_read(self, snap, key: str) -> Any:
        kind, s = snap
        if kind == "si":
            return self.replica.read_si(s, key)
        return self.replica.read_rss(s, key)
