"""HTAP system facades: the paper's two architectures × CC configurations.

Single-node (unified storage, Sec 5.2):
  * "ssi"                — OLAP readers are plain SSI transactions
                           (reader-/writer-aborts possible)
  * "ssi+safesnapshots"  — OLAP readers are READ ONLY DEFERRABLE
                           (reader-WAIT until a safe snapshot exists)
  * "ssi+rss"            — OLAP readers are PRoTs over the in-process RSS
                           (wait-free, abort-free; the paper's system)

Multi-node (decoupled storage, Sec 5.1): primary runs SSI; an asynchronous
log-shipping replica applies committed writesets and serves OLAP:
  * "ssi+si"   — replica readers use plain SI at the replication horizon
                 (NOT serializable: read-only anomalies possible; baseline)
  * "ssi+rss"  — replica-side RSSManager replays begin/commit/abort + deps
                 records and serves RSS snapshots (serializable, wait-free)

Both facades serve OLAP *scans* through the unified `VersionStore` interface:
one batched visibility resolution per key sequence instead of N per-key chain
walks.  With `paged=True` they additionally mirror committed writesets into
the device-resident K-slot paged store (`tensorstore.mirror.PagedMirror`) and
serve RSS scans from it — the Pallas-kernel-shaped OLAP surface.  With
`check_scans=True` every batched scan is asserted equal to the per-key engine
read path (the oracle).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

from ..core.replica import PRoTManager, RSSManager, RssSnapshot
from ..core.wal import effective_commit_seq
from ..tensorstore.mirror import PagedMirror
from ..tensorstore.version_store import (ChainVersionStore, PagedVersionStore,
                                         VersionStore)
from .engine import AbortReason, Engine, SerializationFailure, Status, Txn
from .store import Store


# --------------------------------------------------------------- single node
class SingleNodeHTAP:
    def __init__(self, olap_mode: str = "ssi+rss", *, paged: bool = False,
                 check_scans: bool = False) -> None:
        assert olap_mode in ("ssi", "ssi+safesnapshots", "ssi+rss")
        self.olap_mode = olap_mode
        self.engine = Engine("ssi")
        self.rss_manager = RSSManager()
        self.prot = PRoTManager(self.rss_manager)
        self.check_scans = check_scans
        # device-backed OLAP surface: WAL-mirrored paged store + kernel-shaped
        # scans for protected readers
        self.mirror: Optional[PagedMirror] = PagedMirror() if paged else None
        self.paged_store: Optional[PagedVersionStore] = \
            PagedVersionStore(self.mirror) if paged else None
        self._pins: dict[int, int] = {}       # txn tid -> PRoT reader id

    # OLTP path -------------------------------------------------------------
    def oltp_begin(self, *, read_only: bool = False) -> Txn:
        return self.engine.begin(read_only=read_only)

    # OLAP path -------------------------------------------------------------
    def refresh_rss(self) -> RssSnapshot:
        """RSS construction invoker: replay the WAL delta and advance the
        incrementally-maintained RSS — O(records since the last round), not
        O(history) (Sec 5.2).  With a paged mirror, also advance the device
        store to the same LSN under the pinned-reader GC floor.  Afterwards,
        bound the bookkeeping: prune RSS per-txn state below the oldest
        pinned PRoT snapshot and recycle the WAL prefix every consumer has
        applied."""
        self.rss_manager.catch_up(self.engine.wal)
        snap = self.rss_manager.construct()
        if self.mirror is not None:
            self.mirror.catch_up(self.engine.wal,
                                 gc_floor=self.prot.gc_floor_seq())
        self.rss_manager.gc(keep_lsn=self.prot.gc_floor(),
                            keep_seq=self.prot.gc_floor_seq())
        consumed = self.rss_manager.applied_lsn
        if self.mirror is not None:
            consumed = min(consumed, self.mirror.applied_lsn)
        self.engine.wal.truncate(consumed)
        return snap

    def olap_begin(self) -> Optional[Txn]:
        """Returns None when the reader must wait (SafeSnapshots only)."""
        if self.olap_mode == "ssi":
            return self.engine.begin(read_only=True)
        if self.olap_mode == "ssi+safesnapshots":
            return self.engine.begin_deferred()   # None => reader-wait
        # ssi+rss: wait-free protected read over the freshest constructed RSS
        rid, snap = self.prot.acquire()
        t = self.engine.begin(read_only=True, rss=snap)
        self._pins[t.tid] = rid
        return t

    def olap_read(self, t: Txn, key: str) -> Any:
        return self.engine.read(t, key)

    def olap_scan(self, t: Txn, keys: Sequence[str]) -> list[Any]:
        """Batched OLAP scan: ONE VersionStore.scan for the key sequence.
        Protected readers are served from the paged mirror when present
        (read-set recording included: the mirror resolves writers in the
        same vectorized pass)."""
        if self.paged_store is not None and t.rss is not None:
            self.engine._check_active(t)
            vals, writers = self.paged_store.scan_with_writers(keys, t.rss)
            self.engine.record_scan(t, keys, writers)
        else:
            vals = self.engine.scan(t, keys)
        if self.check_scans:
            # oracle reads bypass history recording: the scan above already
            # recorded the read set, and the check must not double it
            hist, self.engine.history = self.engine.history, None
            try:
                oracle = [self.engine.read(t, k) for k in keys]
            finally:
                self.engine.history = hist
            assert vals == oracle, (vals, oracle)
        return vals

    def olap_commit(self, t: Txn) -> None:
        try:
            self.engine.commit(t)
        finally:
            self._release(t)

    def olap_abandon(self, t: Txn) -> None:
        """Drop the PRoT pin of a finished/aborted OLAP transaction."""
        self._release(t)

    def _release(self, t: Txn) -> None:
        rid = self._pins.pop(t.tid, None)
        if rid is not None:
            self.prot.release(rid)

    # GC --------------------------------------------------------------------
    def gc_versions(self) -> int:
        """hot_standby_feedback loop: prune chain versions below the pinned
        PRoT floor (never above an active transaction's snapshot)."""
        floor = self.prot.gc_floor_seq()
        active = min((t.begin_seq for t in self.engine.active.values()),
                     default=self.engine.seq)
        return self.engine.prune_versions(min(floor, active))


# ---------------------------------------------------------------- multi node
class Replica:
    """Asynchronous log-shipping replica: applies committed writesets in LSN
    order into its own store; optionally maintains an RSSManager from the
    same stream (begin/commit/abort + deps records) and a device-resident
    paged mirror serving batched kernel-shaped scans."""

    def __init__(self, *, with_rss: bool, paged: bool = False,
                 check_scans: bool = False) -> None:
        self.store = Store()
        self.version_store: VersionStore = ChainVersionStore(self.store)
        self.applied_lsn = 0
        self.applied_seq = 0          # commit-seq horizon for SI readers
        self.with_rss = with_rss
        self.check_scans = check_scans
        self.rss_manager = RSSManager() if with_rss else None
        self.prot = PRoTManager(self.rss_manager) if with_rss else None
        self.mirror: Optional[PagedMirror] = PagedMirror() if paged else None
        self.paged_store: Optional[PagedVersionStore] = \
            PagedVersionStore(self.mirror) if paged else None

    def catch_up(self, primary: Engine, *, max_records: int = 0) -> int:
        n = 0
        # GC floor for mirror publishes: pinned PRoT snapshots (RSS) or the
        # pre-catch-up SI horizon.  Bounded, not absolute: an SI reader that
        # holds its snapshot across multiple ship rounds (or an RSS member
        # version above the prefix floor) is protected only while publishers
        # stay < K-1 versions ahead per page — the K-slot staleness bound.
        gc_floor = self.prot.gc_floor_seq() if self.prot is not None \
            else self.applied_seq
        for rec in primary.wal.tail(self.applied_lsn):
            if max_records and n >= max_records:
                break
            self.applied_lsn = rec.lsn
            if self.rss_manager is not None:
                self.rss_manager.apply(rec)
            if self.mirror is not None:
                self.mirror.apply(rec, gc_floor=gc_floor)
            if rec.type == "commit":
                # the shared WAL commit clock (effective_commit_seq), so
                # manager/mirror/store version stamps agree and installs
                # stay strictly monotone even across mixed record kinds
                seq = effective_commit_seq(self.applied_seq, rec.seq)
                for key, value in rec.writes:
                    self.store.chain(key).install(seq, rec.txn, value)
                self.applied_seq = seq
            n += 1
        if self.rss_manager is not None and n:
            self.rss_manager.construct()
            # bound replica-side RSS bookkeeping by the active/pinned window
            self.rss_manager.gc(keep_lsn=self.prot.gc_floor(),
                                keep_seq=self.prot.gc_floor_seq())
        return n

    # reader snapshots -------------------------------------------------------
    def si_snapshot(self) -> int:
        return self.applied_seq

    def rss_snapshot(self) -> tuple[int, RssSnapshot]:
        """Acquire (pin) the freshest exported snapshot; release the returned
        reader id via `release(rid)` when the reader finishes."""
        assert self.prot is not None
        return self.prot.acquire()

    def release(self, reader_id: int) -> None:
        if self.prot is not None:
            self.prot.release(reader_id)

    def read_si(self, snapshot_seq: int, key: str) -> Any:
        return self.version_store.read_at(key, snapshot_seq)

    def read_rss(self, snap: RssSnapshot, key: str) -> Any:
        return self.version_store.read_members(key, snap)

    # batched scans ----------------------------------------------------------
    def scan_si(self, snapshot_seq: int, keys: Sequence[str]) -> list[Any]:
        store = self.paged_store or self.version_store
        vals = store.scan_at(keys, snapshot_seq)
        if self.check_scans:
            oracle = [self.read_si(snapshot_seq, k) for k in keys]
            assert vals == oracle, (vals, oracle)
        return vals

    def scan_rss(self, snap: RssSnapshot, keys: Sequence[str]) -> list[Any]:
        store = self.paged_store or self.version_store
        vals = store.scan_members(keys, snap)
        if self.check_scans:
            oracle = [self.read_rss(snap, k) for k in keys]
            assert vals == oracle, (vals, oracle)
        return vals


class MultiNodeHTAP:
    def __init__(self, olap_mode: str = "ssi+rss", *, paged_olap: bool = False,
                 check_scans: bool = False) -> None:
        assert olap_mode in ("ssi+si", "ssi+rss")
        self.olap_mode = olap_mode
        self.primary = Engine("ssi")
        self.replica = Replica(with_rss=(olap_mode == "ssi+rss"),
                               paged=paged_olap, check_scans=check_scans)

    def oltp_begin(self, *, read_only: bool = False) -> Txn:
        return self.primary.begin(read_only=read_only)

    def ship_log(self, *, max_records: int = 0) -> int:
        """One asynchronous replication round; afterwards the primary
        recycles the WAL prefix the replica has applied (bounded log
        state)."""
        n = self.replica.catch_up(self.primary, max_records=max_records)
        self.primary.wal.truncate(self.replica.applied_lsn)
        return n

    def olap_snapshot(self):
        if self.olap_mode == "ssi+si":
            return ("si", 0, self.replica.si_snapshot())
        rid, snap = self.replica.rss_snapshot()
        return ("rss", rid, snap)

    def olap_read(self, snap, key: str) -> Any:
        kind, _, s = snap
        if kind == "si":
            return self.replica.read_si(s, key)
        return self.replica.read_rss(s, key)

    def olap_scan(self, snap, keys: Sequence[str]) -> list[Any]:
        kind, _, s = snap
        if kind == "si":
            return self.replica.scan_si(s, keys)
        return self.replica.scan_rss(s, keys)

    def olap_release(self, snap) -> None:
        kind, rid, _ = snap
        if kind == "rss":
            self.replica.release(rid)
