"""HTAP system facades: the paper's two architectures × CC configurations.

Single-node (unified storage, Sec 5.2):
  * "ssi"                — OLAP readers are plain SSI transactions
                           (reader-/writer-aborts possible)
  * "ssi+safesnapshots"  — OLAP readers are READ ONLY DEFERRABLE
                           (reader-WAIT until a safe snapshot exists)
  * "ssi+rss"            — OLAP readers are PRoTs over the in-process RSS
                           (wait-free, abort-free; the paper's system)

Multi-node (decoupled storage, Sec 5.1): primary runs SSI; an asynchronous
log-shipping replica applies committed writesets and serves OLAP:
  * "ssi+si"   — replica readers use plain SI at the replication horizon
                 (NOT serializable: read-only anomalies possible; baseline)
  * "ssi+rss"  — replica-side RSSManager replays begin/commit/abort + deps
                 records and serves RSS snapshots (serializable, wait-free)

Both facades serve every OLAP read through ONE plan-execution seam
(`olap_execute(plan)` here, `VersionStore.execute` below): a `Plan`
(`ScanPlan`/`AggPlan`/`MultiAggPlan`/`GroupByPlan`) in, one batched
visibility resolution for its whole key sequence instead of N per-key chain
walks.  With `paged=True` they additionally mirror committed writesets into
the device-resident K-slot paged store (`tensorstore.mirror.PagedMirror`)
and lower aggregate plans to the fused `rss_scan_agg` kernels.  With
`check_scans=True` every plan result is asserted equal to the per-key
engine read path (the `apply_plan` oracle).  The per-op methods
(`olap_scan`/`olap_agg`/`scan_si`/`agg_rss`/...) that survived PR 5 as
deprecated aliases are GONE: `execute(plan)` is the only OLAP read path.

`olap_execute_batch` is the cross-reader batching seam: aggregate plans
from several same-horizon readers (PRoT pin sharing hands them the SAME
snapshot object) fuse into one `BatchPlan` — ONE kernel dispatch serves
the whole batch, with per-transaction read-set recording and per-plan
oracle checks preserved.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

from ..cluster import ReplicaCluster
from ..core.replica import PRoTManager, RSSManager, RssSnapshot
from ..core.wal import effective_commit_seq
from ..obs import REGISTRY, TRACER, tick, tock
from ..tensorstore.mirror import PagedMirror
from ..tensorstore.version_store import (AggPlan, BatchPlan,
                                         ChainVersionStore, GroupByPlan,
                                         MultiAggPlan, PagedVersionStore,
                                         Plan, VersionStore, apply_plan,
                                         plan_keys)
from .engine import AbortReason, Engine, SerializationFailure, Status, Txn
from .store import Store

# single-node route stage: PRoT snapshot acquisition (the multi-node twin
# — policy choice + cadence/ship decision — is timed in cluster.acquire
# into the SAME series)
_ROUTE_H = REGISTRY.histogram("olap_stage_seconds", stage="route")


def _serve_hist(cache: dict, key: tuple, **labels):
    """Per-facade cache of olap_serve_seconds{facade, plan[, replica]}
    histograms: one dict hit per serve instead of a registry lookup."""
    h = cache.get(key)
    if h is None:
        h = cache[key] = REGISTRY.histogram("olap_serve_seconds", **labels)
    return h


# --------------------------------------------------------------- single node
class SingleNodeHTAP:
    def __init__(self, olap_mode: str = "ssi+rss", *, paged: bool = False,
                 check_scans: bool = False,
                 reserve_keys: Optional[Sequence[str]] = None,
                 materialize: Optional[Sequence[Plan]] = None,
                 certifier=None, resolve_cache: bool = True) -> None:
        """`certifier` picks the OLTP commit-certification policy
        (`repro.mvcc.certify`): name / instance / factory; None keeps the
        conservative structural SSI abort.  OLAP behaviour — RSS
        construction, the WAL deps messages it feeds on — is certifier-
        independent by design.  `materialize` registers aggregate plans
        for incremental materialization on the paged mirror
        (`tensorstore.materialized`): serves of an equal plan cost
        O(delta since last commit) instead of O(pages scanned), falling
        back to the fused scan whenever the snapshot gate can't prove
        consistency."""
        assert olap_mode in ("ssi", "ssi+safesnapshots", "ssi+rss")
        self.olap_mode = olap_mode
        self.engine = Engine("ssi", certifier=certifier)
        self.rss_manager = RSSManager()
        self.prot = PRoTManager(self.rss_manager)
        self.check_scans = check_scans
        # device-backed OLAP surface: WAL-mirrored paged store + kernel-shaped
        # scans for protected readers; `reserve_keys` pre-allocates workload
        # key families contiguously so dense plans hit the page-range slice
        # fast path instead of gathering
        self.mirror: Optional[PagedMirror] = \
            PagedMirror(resolve_cache=resolve_cache) if paged else None
        self.paged_store: Optional[PagedVersionStore] = \
            PagedVersionStore(self.mirror) if paged else None
        if self.mirror is not None and reserve_keys:
            self.mirror.reserve(reserve_keys)
        if materialize:
            assert self.mirror is not None, \
                "materialize= needs paged=True (views live on the mirror)"
            for p in materialize:
                self.mirror.register_view(p)
        self._pins: dict[int, int] = {}       # txn tid -> PRoT reader id
        self._serve_h: dict[tuple, Any] = {}  # plan kind -> serve histogram
        # in-process WAL consumers as registered slots: truncation goes
        # through the same min-acked accounting the replica cluster uses
        self.engine.wal.register_consumer("rss")
        if self.mirror is not None:
            self.engine.wal.register_consumer("mirror")

    # OLTP path -------------------------------------------------------------
    def oltp_begin(self, *, read_only: bool = False) -> Txn:
        return self.engine.begin(read_only=read_only)

    # OLAP path -------------------------------------------------------------
    def refresh_rss(self) -> RssSnapshot:
        """RSS construction invoker: replay the WAL delta and advance the
        incrementally-maintained RSS — O(records since the last round), not
        O(history) (Sec 5.2).  With a paged mirror, also advance the device
        store to the same LSN under the pinned-reader GC floor.  Afterwards,
        bound the bookkeeping: prune RSS per-txn state below the oldest
        pinned PRoT snapshot and recycle the WAL prefix every consumer has
        applied."""
        self.rss_manager.catch_up(self.engine.wal)
        snap = self.rss_manager.construct()
        if self.mirror is not None:
            self.mirror.catch_up(self.engine.wal,
                                 gc_floor=self.prot.gc_floor_seq())
            # fold commits the fresh snapshot admits into the view tiles
            self.mirror.advance_views(snap)
        self.rss_manager.gc(keep_lsn=self.prot.gc_floor(),
                            keep_seq=self.prot.gc_floor_seq())
        if self.mirror is not None:
            # bound view-gate bookkeeping by the same pinned floor
            self.mirror.gc_views(self.prot.gc_floor_seq())
        self.engine.wal.ack("rss", self.rss_manager.applied_lsn)
        if self.mirror is not None:
            self.engine.wal.ack("mirror", self.mirror.applied_lsn)
        self.engine.wal.truncate()
        return snap

    def olap_begin(self) -> Optional[Txn]:
        """Returns None when the reader must wait (SafeSnapshots only)."""
        if self.olap_mode == "ssi":
            return self.engine.begin(read_only=True)
        if self.olap_mode == "ssi+safesnapshots":
            return self.engine.begin_deferred()   # None => reader-wait
        # ssi+rss: wait-free protected read over the freshest constructed RSS
        t0 = tick()
        with TRACER.span("route", policy="prot"):
            rid, snap = self.prot.acquire()
        tock(_ROUTE_H, t0)
        t = self.engine.begin(read_only=True, rss=snap)
        self._pins[t.tid] = rid
        return t

    def olap_read(self, t: Txn, key: str) -> Any:
        return self.engine.read(t, key)

    def olap_execute(self, t: Txn, plan: Plan) -> Any:
        """The facade's ONE OLAP plan-execution seam: protected readers on
        the paged mirror run the plan's fused device lowering (visibility
        resolve + reduction in one `rss_scan_agg` pass per kernel config,
        batched scan for `ScanPlan`); everything else executes through the
        engine's chain-store seam (the oracle shape).  Read sets record
        identically either way — the mirror resolves writers in the same
        vectorized pass.  With `check_scans`, every result is asserted
        equal to the per-key engine read path (`apply_plan` oracle)."""
        kind = type(plan).__name__
        t0 = tick()
        with TRACER.span("olap_serve", facade="single", plan=kind):
            if self.paged_store is not None and t.rss is not None:
                self.engine._check_active(t)
                result, writers = self.paged_store.execute_with_writers(
                    plan, t.rss)
                self.engine.record_scan(t, plan_keys(plan), writers)
            else:
                result = self.engine.execute(t, plan)
        tock(_serve_hist(self._serve_h, (kind,), facade="single",
                         plan=kind), t0)
        if self.check_scans:
            # per-key oracle parity (history suppressed: the read set was
            # already recorded by the plan execution above, and the check
            # must not double it)
            hist, self.engine.history = self.engine.history, None
            try:
                oracle = apply_plan(
                    [self.engine.read(t, k) for k in plan_keys(plan)], plan)
            finally:
                self.engine.history = hist
            assert result == oracle, (result, oracle)
        return result

    def olap_execute_batch(self, entries: Sequence[tuple]) -> list[Any]:
        """Cross-reader whole-batch plan fusion: `entries` is a sequence
        of (txn, plan) pairs whose plans are aggregate-shaped and whose
        transactions share ONE RSS horizon (PRoT pin sharing hands
        same-round readers the same snapshot object).  The plans lower to
        a single `BatchPlan` — ONE fused kernel dispatch — and each
        transaction records exactly the read set its plan would record
        unbatched.  Entries that can't fuse (no paged mirror, non-RSS
        readers, mixed horizons, scan plans) fall back to per-plan
        `olap_execute`.  Returns per-entry results in order."""
        entries = list(entries)
        batchable = (
            self.paged_store is not None and len(entries) > 1 and
            all(isinstance(p, (AggPlan, MultiAggPlan, GroupByPlan))
                for _, p in entries) and
            all(t.rss is not None for t, _ in entries) and
            len({t.rss.lsn for t, _ in entries}) == 1)
        if not batchable:
            return [self.olap_execute(t, p) for t, p in entries]
        for t, _ in entries:
            self.engine._check_active(t)
        snap = entries[0][0].rss
        batch = BatchPlan(tuple(p for _, p in entries))
        t0 = tick()
        with TRACER.span("olap_serve", facade="single", plan="BatchPlan",
                         fused=len(entries)):
            results, writers = self.paged_store.execute_with_writers(batch,
                                                                     snap)
        # one observation per fused dispatch: histogram count stays equal
        # to the number of serve-path executions, not member plans
        tock(_serve_hist(self._serve_h, ("BatchPlan",), facade="single",
                         plan="BatchPlan"), t0)
        off = 0
        for (t, p), result in zip(entries, results):
            pk = plan_keys(p)
            self.engine.record_scan(t, pk, writers[off:off + len(pk)])
            off += len(pk)
            if self.check_scans:
                hist, self.engine.history = self.engine.history, None
                try:
                    oracle = apply_plan(
                        [self.engine.read(t, k) for k in pk], p)
                finally:
                    self.engine.history = hist
                assert result == oracle, (result, oracle)
        return list(results)

    def olap_commit(self, t: Txn) -> None:
        try:
            self.engine.commit(t)
        finally:
            self._release(t)

    def olap_abandon(self, t: Txn) -> None:
        """Drop the PRoT pin of a finished/aborted OLAP transaction."""
        self._release(t)

    def _release(self, t: Txn) -> None:
        rid = self._pins.pop(t.tid, None)
        if rid is not None:
            self.prot.release(rid)

    # GC --------------------------------------------------------------------
    def gc_versions(self) -> int:
        """hot_standby_feedback loop: prune chain versions below the pinned
        PRoT floor (never above an active transaction's snapshot)."""
        floor = self.prot.gc_floor_seq()
        active = min((t.begin_seq for t in self.engine.active.values()),
                     default=self.engine.seq)
        return self.engine.prune_versions(min(floor, active))


# ---------------------------------------------------------------- multi node
class Replica:
    """Asynchronous log-shipping replica: applies committed writesets in LSN
    order into its own store; optionally maintains an RSSManager from the
    same stream (begin/commit/abort + deps records) and a device-resident
    paged mirror serving batched kernel-shaped scans."""

    def __init__(self, *, with_rss: bool, paged: bool = False,
                 check_scans: bool = False,
                 reserve_keys: Optional[Sequence[str]] = None,
                 materialize: Optional[Sequence[Plan]] = None,
                 resolve_cache: bool = True) -> None:
        self.store = Store()
        self.version_store: VersionStore = ChainVersionStore(self.store)
        self.applied_lsn = 0
        self.applied_seq = 0          # commit-seq horizon for SI readers
        self.with_rss = with_rss
        self.check_scans = check_scans
        self.rss_manager = RSSManager() if with_rss else None
        self.prot = PRoTManager(self.rss_manager) if with_rss else None
        self.mirror: Optional[PagedMirror] = \
            PagedMirror(resolve_cache=resolve_cache) if paged else None
        self.paged_store: Optional[PagedVersionStore] = \
            PagedVersionStore(self.mirror) if paged else None
        if self.mirror is not None and reserve_keys:
            self.mirror.reserve(reserve_keys)   # page-range locality
        if materialize:
            assert self.mirror is not None, \
                "materialize= needs paged=True (views live on the mirror)"
            for p in materialize:
                self.mirror.register_view(p)    # advance during delta ships
        self._si_pins: dict[int, int] = {}    # reader id -> pinned seq
        self._next_si_reader = 1

    def catch_up(self, primary: Engine, *, max_records: int = 0) -> int:
        n = 0
        # GC floor for mirror publishes: pinned PRoT snapshots (RSS) or the
        # oldest pinned SI snapshot.  Bounded, not absolute: an SI reader
        # that holds its snapshot across multiple ship rounds (or an RSS
        # member version above the prefix floor) is protected only while
        # publishers stay < K-1 versions ahead per page — the K-slot
        # staleness bound.
        gc_floor = self.gc_floor_seq()
        for rec in primary.wal.tail(self.applied_lsn):
            if max_records and n >= max_records:
                break
            self.applied_lsn = rec.lsn
            if self.rss_manager is not None:
                self.rss_manager.apply(rec)
            if self.mirror is not None:
                self.mirror.apply(rec, gc_floor=gc_floor)
            if rec.type == "commit":
                # the shared WAL commit clock (effective_commit_seq), so
                # manager/mirror/store version stamps agree and installs
                # stay strictly monotone even across mixed record kinds
                seq = effective_commit_seq(self.applied_seq, rec.seq)
                for key, value in rec.writes:
                    self.store.chain(key).install(seq, rec.txn, value)
                self.applied_seq = seq
            n += 1
        if self.rss_manager is not None and n:
            snap = self.rss_manager.construct()
            if self.mirror is not None:
                # views advance with the delta ship, at the snapshot the
                # fresh construct admits
                self.mirror.advance_views(snap)
            # bound replica-side RSS bookkeeping by the active/pinned window
            self.rss_manager.gc(keep_lsn=self.prot.gc_floor(),
                                keep_seq=self.prot.gc_floor_seq())
        elif self.mirror is not None and n:
            self.mirror.advance_views(self.applied_seq)
        if self.mirror is not None and n:
            self.mirror.gc_views(self.gc_floor_seq())
        return n

    # reader snapshots -------------------------------------------------------
    def si_snapshot(self) -> int:
        return self.applied_seq

    def si_snapshot_pinned(self) -> tuple[int, int]:
        """Acquire (pin) the replication horizon as an SI snapshot; the pin
        holds this replica's version-GC floor until `release(rid)`.  SI
        reader ids are NEGATIVE — disjoint from the PRoT manager's positive
        ids, so releasing one kind of pin can never drop the other's."""
        rid = -self._next_si_reader
        self._next_si_reader += 1
        self._si_pins[rid] = self.applied_seq
        return rid, self.applied_seq

    def rss_snapshot(self) -> tuple[int, RssSnapshot]:
        """Acquire (pin) the freshest exported snapshot; release the returned
        reader id via `release(rid)` when the reader finishes."""
        assert self.prot is not None
        return self.prot.acquire()

    def release(self, reader_id: int) -> None:
        if reader_id < 0:
            self._si_pins.pop(reader_id, None)
        elif self.prot is not None:
            self.prot.release(reader_id)

    # GC ---------------------------------------------------------------------
    def gc_floor_seq(self) -> int:
        """This replica's version-GC floor: min(oldest pinned snapshot —
        PRoT or SI — and the replication horizon) in commit-seq units, the
        per-replica term of the cluster-wide GC floor."""
        floor = self.prot.gc_floor_seq() if self.prot is not None \
            else self.applied_seq
        si_floor = min(self._si_pins.values(), default=floor)
        return min(floor, si_floor)

    def gc_versions(self) -> int:
        """Prune replica-side chain versions below the pinned floor
        (hot_standby_feedback analogue on the replica's own store)."""
        return self.store.prune(self.gc_floor_seq())

    def read_si(self, snapshot_seq: int, key: str) -> Any:
        return self.version_store.read_at(key, snapshot_seq)

    def read_rss(self, snap: RssSnapshot, key: str) -> Any:
        return self.version_store.read_members(key, snap)

    # plan execution --------------------------------------------------------
    def _execute(self, snapshot, plan: Plan) -> Any:
        """The replica's ONE plan-execution seam: fused device lowering on
        the paged mirror, chain-walk + host `apply_plan` otherwise;
        parity-asserted against the per-key oracle under check_scans."""
        store = self.paged_store or self.version_store
        val = store.execute(plan, snapshot)
        if self.check_scans:
            if isinstance(snapshot, RssSnapshot):
                vals = [self.version_store.read_members(k, snapshot)
                        for k in plan_keys(plan)]
            else:
                vals = [self.version_store.read_at(k, snapshot)
                        for k in plan_keys(plan)]
            oracle = apply_plan(vals, plan)
            assert val == oracle, (val, oracle)
        return val

    def execute_si(self, snapshot_seq: int, plan: Plan) -> Any:
        """Execute a plan at an SI watermark (the replication horizon)."""
        return self._execute(int(snapshot_seq), plan)

    def execute_rss(self, snap: RssSnapshot, plan: Plan) -> Any:
        """Execute a plan under RSS membership visibility."""
        return self._execute(snap, plan)


class MultiNodeHTAP:
    """Primary + N-replica decoupled-storage cluster.  Snapshot handles are
    the cluster's `(kind, replica_idx, reader_id, snapshot)` tuples; all
    log shipping, WAL recycling (min applied LSN across consumers), snapshot
    routing, and version GC flow through `cluster.ReplicaCluster`."""

    def __init__(self, olap_mode: str = "ssi+rss", *, paged_olap: bool = False,
                 check_scans: bool = False, n_replicas: int = 1,
                 route_policy="freshest", max_staleness: int = 100,
                 reserve_keys: Optional[Sequence[str]] = None,
                 materialize: Optional[Sequence[Plan]] = None,
                 certifier=None, resolve_cache: bool = True) -> None:
        """`certifier` configures the PRIMARY's commit certification (see
        `repro.mvcc.certify`).  Replicas replay begin/commit/abort + deps
        WAL records, which are certifier-independent: only WHICH txns
        commit varies, never the shape of a committed txn's records — so
        replica-side RSS construction is untouched by the choice."""
        assert olap_mode in ("ssi+si", "ssi+rss")
        assert n_replicas >= 1
        self.olap_mode = olap_mode
        self.primary = Engine("ssi", certifier=certifier)
        replicas = [Replica(with_rss=(olap_mode == "ssi+rss"),
                            paged=paged_olap, check_scans=check_scans,
                            reserve_keys=reserve_keys,
                            materialize=materialize,
                            resolve_cache=resolve_cache)
                    for _ in range(n_replicas)]
        self.cluster = ReplicaCluster(self.primary, replicas,
                                      policy=route_policy,
                                      max_lag=max_staleness)
        self.replica = replicas[0]     # single-replica legacy surface
        self._serve_h: dict[tuple, Any] = {}   # (plan, replica) -> histogram

    def oltp_begin(self, *, read_only: bool = False) -> Txn:
        return self.primary.begin(read_only=read_only)

    def ship_log(self, *, max_records: int = 0,
                 replica: Optional[int] = None) -> int:
        """One asynchronous replication round into one replica (or all);
        afterwards the primary recycles the WAL prefix EVERY consumer has
        applied — truncation only ever discards records below the minimum
        applied LSN across the fleet (bounded log state at N > 1)."""
        return self.cluster.ship(replica, max_records=max_records)

    def session(self, *, keep_history: bool = False):
        """Open a client `Session` (cluster token: last-commit LSN +
        last-read horizon).  Pass it to `olap_snapshot(session=...)` for
        read-your-writes / monotonic reads, and call
        `note_commit(session)` after each of the client's OLTP commits."""
        return self.cluster.session(keep_history=keep_history)

    def note_commit(self, session) -> None:
        """Stamp a session with the client's just-committed OLTP write:
        any later read through this session is served at or above the WAL
        position holding that commit record."""
        session.note_commit(self.primary.wal.head_lsn)

    def olap_snapshot(self, *, max_lag: Optional[int] = None, session=None):
        """Route a snapshot acquisition through the cluster's policy;
        `max_lag` is a per-query freshness hint (bounded staleness in WAL
        records) — unsatisfiable hints trigger ship-then-serve.  A
        `session` token restricts routing to replicas covering the
        client's observed horizon (read-your-writes + monotonic reads),
        falling back to a cadence-owed delta ship when none does."""
        return self.cluster.acquire(max_lag=max_lag, session=session)

    def olap_read(self, snap, key: str) -> Any:
        return self.cluster.read(snap, key)

    def olap_execute(self, snap, plan: Plan) -> Any:
        """The facade's ONE OLAP plan-execution seam: plans route to the
        replica that served the handle's snapshot — the same
        freshness-policy decision as the acquisition."""
        kind, idx = type(plan).__name__, snap[1]
        t0 = tick()
        with TRACER.span("olap_serve", facade="multi", plan=kind,
                         replica=idx):
            result = self.cluster.execute(snap, plan)
        tock(_serve_hist(self._serve_h, (kind, idx), facade="multi",
                         plan=kind, replica=idx), t0)
        return result

    def olap_execute_batch(self, entries: Sequence[tuple]) -> list[Any]:
        """Cross-reader whole-batch plan fusion, cluster-routed: `entries`
        is a sequence of (snapshot handle, plan) pairs.  When every plan
        is aggregate-shaped and every handle names the same replica and
        snapshot horizon, the plans fuse into one `BatchPlan` served by a
        single replica dispatch (one fused kernel launch on a paged
        replica); otherwise each entry executes alone.  Returns per-entry
        results in order."""
        entries = list(entries)

        def _horizon(handle):
            kind, idx, _rid, snap = handle
            return (kind, idx,
                    snap.lsn if isinstance(snap, RssSnapshot) else int(snap))

        batchable = (
            len(entries) > 1 and
            all(isinstance(p, (AggPlan, MultiAggPlan, GroupByPlan))
                for _, p in entries) and
            len({_horizon(h) for h, _ in entries}) == 1)
        if not batchable:
            return [self.olap_execute(h, p) for h, p in entries]
        batch = BatchPlan(tuple(p for _, p in entries))
        idx = entries[0][0][1]
        t0 = tick()
        with TRACER.span("olap_serve", facade="multi", plan="BatchPlan",
                         replica=idx, fused=len(entries)):
            results = list(self.cluster.execute(entries[0][0], batch))
        tock(_serve_hist(self._serve_h, ("BatchPlan", idx), facade="multi",
                         plan="BatchPlan", replica=idx), t0)
        return results

    def olap_release(self, snap) -> None:
        self.cluster.release(snap)

    # GC --------------------------------------------------------------------
    def gc_versions(self) -> int:
        """Cluster-wide hot_standby_feedback: every replica prunes its chain
        versions under its own pinned floor, and the primary prunes under
        min(cluster-wide floor, active-transaction horizon) — the min over
        replicas of min(replication horizon, oldest pin)."""
        n = self.cluster.gc_versions()
        active = min((t.begin_seq for t in self.primary.active.values()),
                     default=self.primary.seq)
        n += self.primary.prune_versions(
            min(self.cluster.gc_floor_seq(), active))
        return n
