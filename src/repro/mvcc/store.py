"""In-memory multiversion storage (the paper's PostgreSQL-heap analogue).

Every key maps to a chain of committed versions, newest last.  Versions carry
(commit_seq, writer txn id, value).  Version 0 (writer T0==0, commit_seq 0) is
the initial version of every key.  Uncommitted writes never enter the chain —
transactions buffer their writesets until commit (install-at-commit, which
makes First-Committer-Wins the natural SI-W rule).

GC: `prune(floor_seq)` drops versions strictly older than the newest version
at-or-below `floor_seq` per key — the replica/PRoT pin (hot_standby_feedback
analogue) sets the floor.
"""

from __future__ import annotations

from bisect import bisect_right
from dataclasses import dataclass
from typing import Any, Callable, Iterable, Iterator, Optional


@dataclass(frozen=True)
class Version:
    commit_seq: int
    writer: int
    value: Any


class VersionChain:
    __slots__ = ("versions",)

    def __init__(self, initial: Any = 0) -> None:
        self.versions: list[Version] = [Version(0, 0, initial)]

    def install(self, commit_seq: int, writer: int, value: Any) -> None:
        assert commit_seq > self.versions[-1].commit_seq
        self.versions.append(Version(commit_seq, writer, value))

    def newest(self) -> Version:
        return self.versions[-1]

    def visible_at(self, snapshot_seq: int) -> Version:
        """SI-V: newest version with commit_seq <= snapshot_seq."""
        seqs = [v.commit_seq for v in self.versions]
        i = bisect_right(seqs, snapshot_seq) - 1
        return self.versions[max(i, 0)]

    def visible_in(self, member: Callable[[int, int], bool]) -> Version:
        """RSS read protocol: newest version whose writer is in the snapshot
        set (walk newest-to-oldest; RSS closure guarantees consistency).
        `member` is called with (writer txn id, commit seq) — the seq lets
        compressed snapshots resolve floor-covered members without per-txn
        bookkeeping (`RssSnapshot.visible`)."""
        for v in reversed(self.versions):
            if v.writer == 0 or member(v.writer, v.commit_seq):
                return v
        return self.versions[0]

    def prune(self, floor_seq: int) -> int:
        """Drop versions not visible at any snapshot >= floor_seq."""
        seqs = [v.commit_seq for v in self.versions]
        i = bisect_right(seqs, floor_seq) - 1
        if i > 0:
            dropped = i
            self.versions = self.versions[i:]
            return dropped
        return 0


class Store:
    def __init__(self) -> None:
        self.chains: dict[str, VersionChain] = {}

    def chain(self, key: str) -> VersionChain:
        ch = self.chains.get(key)
        if ch is None:
            ch = self.chains[key] = VersionChain()
        return ch

    def keys(self) -> Iterator[str]:
        return iter(self.chains)

    def newest_seq(self) -> int:
        return max((c.newest().commit_seq for c in self.chains.values()),
                   default=0)

    def prune(self, floor_seq: int) -> int:
        return sum(c.prune(floor_seq) for c in self.chains.values())

    def version_count(self) -> int:
        return sum(len(c.versions) for c in self.chains.values())
