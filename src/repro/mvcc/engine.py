"""Transactional engine: SI / SSI execution with RSS and SafeSnapshots modes.

This is the executable counterpart of `repro.core`: a single-node MVCC engine
whose accepted histories satisfy the specification-level checks (asserted by
property tests).  It implements:

  * SI        — snapshot reads (SI-V) + first-committer-wins (SI-W)
  * SSI       — SI + SIRead-lock rw-antidependency tracking + pluggable
                commit certification (`repro.mvcc.certify`): conservative
                PostgreSQL-style pivot aborts by default, commit-order-
                precise SSI or SSN by configuration
  * SafeSnapshots — READ ONLY DEFERRABLE readers: reader-WAITS until no
                read/write transaction is active, then reads snapshot without
                SSI validation (Ports & Grittner)
  * RSS       — protected read-only transactions read the newest version
                whose writer is inside the constructed RSS: wait-free,
                abort-free, no SIRead locks (the paper's contribution)

The engine emits the WAL records of Sec 5.1 (begin/commit/abort + outgoing
concurrent-rw "deps" logical messages, and the committed writeset for
log-shipping replication).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Iterable, Optional, Sequence

from ..core.history import History, b as op_b, r as op_r, w as op_w, \
    c as op_c, a as op_a
from ..core.replica import RssSnapshot
from ..core.wal import Wal, WalRecord
from ..obs import REGISTRY, TRACER, LabeledCounterMap, StatsView, tick, tock
from ..tensorstore.version_store import (ChainVersionStore, Plan,
                                         VersionStore, apply_plan, plan_keys)
from .store import Store, Version


class Status(Enum):
    ACTIVE = 0
    COMMITTED = 1
    ABORTED = 2


class AbortReason(Enum):
    WW_CONFLICT = "first-committer-wins"
    PIVOT = "dangerous-structure pivot"
    INCOMING_PIVOT = "dangerous-structure (in-edge to committed pivot)"
    FATAL_PIVOT = "fatal dangerous structure (out-neighbour committed first)"
    FATAL_NEIGHBOUR = "fatal dangerous structure (commit into fatal pivot)"
    EXCLUSION_WINDOW = "SSN exclusion window (pi <= eta)"
    USER = "user abort"


class SerializationFailure(Exception):
    def __init__(self, reason: AbortReason):
        super().__init__(reason.value)
        self.reason = reason


@dataclass
class Txn:
    tid: int
    begin_seq: int              # logical clock at begin (snapshot horizon)
    read_only: bool = False
    rss: Optional[RssSnapshot] = None        # protected reader snapshot
    skip_siread: bool = False   # safe-snapshot / RSS readers skip SSI locks
    status: Status = Status.ACTIVE
    end_seq: int = 0
    reads: dict[str, int] = field(default_factory=dict)   # key -> writer seen
    writes: dict[str, Any] = field(default_factory=dict)  # buffered writeset
    in_rw: set[int] = field(default_factory=set)          # readers -> self
    out_rw: set[int] = field(default_factory=set)         # self -> writers
    abort_reason: Optional[AbortReason] = None

    @property
    def is_pivot(self) -> bool:
        return bool(self.in_rw) and bool(self.out_rw)


class Engine:
    """mode: 'si' or 'ssi'.  SafeSnapshots/RSS are per-transaction options.

    `certifier` selects the commit-certification policy for SSI-tracked
    transactions (see `repro.mvcc.certify`): a registry name
    ('conservative' / 'commit-order' / 'ssn'), a `Certifier` instance, or
    a zero-arg factory.  Default is the conservative structural pivot
    abort — the seed behaviour.  The engine owns the mechanism (version
    install, WAL, rw-edge bookkeeping, GC); the certifier owns every
    serializability abort decision."""

    def __init__(self, mode: str = "ssi", *, record: bool = False,
                 certifier=None) -> None:
        assert mode in ("si", "ssi")
        self.mode = mode
        from .certify import make_certifier   # lazy: certify imports us
        self.certifier = make_certifier(certifier)
        self.certifier.attach(self)
        self.store = Store()
        # unified read surface over the chain store; HTAP facades may swap in
        # a paged/mirrored VersionStore for the batched OLAP scan path
        self.version_store: VersionStore = ChainVersionStore(self.store)
        self.wal = Wal()
        # optional Adya-history recorder for specification-level checks
        self.history: Optional[History] = History() if record else None
        self.clock = itertools.count(1)
        self.seq = 0                       # last assigned sequence number
        self.txns: dict[int, Txn] = {}     # all known txns (GC'd)
        self.active: dict[int, Txn] = {}
        self._next_tid = itertools.count(1)
        # SIRead "locks": key -> list of reader txn ids (kept past commit
        # while concurrency with future writers is possible)
        self.siread: dict[str, set[int]] = {}
        # registry-backed stats (series engine_* / engine_aborts_by_reason):
        # dict-shaped view per instance — the `engine` scope label keeps two
        # engines (e.g. per-test, or oracle vs primary) from aliasing, the
        # `certifier` label gives per-policy breakdowns for free
        lbl = {"engine": REGISTRY.scope("engine"),
               "certifier": self.certifier.name}
        self.stats = StatsView(
            REGISTRY, "engine",
            ("commits", "aborts", "writer_aborts", "reader_aborts",
             "ww_aborts", "gc_versions"), labels=lbl,
            sub={"by_reason": LabeledCounterMap(
                REGISTRY, "engine_aborts_by_reason", "reason", labels=lbl)})
        self._commit_hist = REGISTRY.histogram("oltp_commit_seconds", **lbl)
        self._certify_hist = REGISTRY.histogram("oltp_certify_seconds", **lbl)
        self._wal_hist = REGISTRY.histogram("oltp_wal_seconds", **lbl)

    # -------------------------------------------------------------- lifecycle
    def _tick(self) -> int:
        self.seq = next(self.clock)
        return self.seq

    def begin(self, *, read_only: bool = False,
              rss: Optional[RssSnapshot] = None,
              skip_siread: bool = False,
              snapshot_seq: Optional[int] = None) -> Txn:
        """snapshot_seq: pin visibility to an older snapshot (deferrable
        readers resuming a previously-taken safe snapshot)."""
        t = Txn(tid=next(self._next_tid),
                begin_seq=self.seq if snapshot_seq is None else snapshot_seq,
                read_only=read_only, rss=rss,
                skip_siread=skip_siread or rss is not None)
        self._tick()
        self.txns[t.tid] = t
        self.active[t.tid] = t
        self.wal.log_begin(t.tid)
        if self.history is not None:
            self.history.append(op_b(t.tid))
        if self._tracked(t):
            self.certifier.on_begin(t)
        return t

    def _tracked(self, t: Txn) -> bool:
        """Does t participate in SSI conflict tracking / certification?
        (Exactly the seed gate: RSS / safe-snapshot readers and plain-SI
        transactions are outside certification.)"""
        return self.mode == "ssi" and not t.skip_siread

    def safe_snapshot_ready(self) -> bool:
        """Deferrable-reader condition: no active read/write transaction."""
        return all(t.read_only for t in self.active.values())

    def begin_deferred(self) -> Optional[Txn]:
        """SafeSnapshots mode: returns a transaction only when the snapshot is
        safe; callers must retry (reader-wait) otherwise."""
        if not self.safe_snapshot_ready():
            return None
        return self.begin(read_only=True, skip_siread=True)

    def _check_active(self, t: Txn) -> None:
        """PostgreSQL-style: touching a transaction the SSI detector has
        already aborted surfaces the serialization failure to the client."""
        if t.status == Status.ABORTED:
            raise SerializationFailure(t.abort_reason or AbortReason.PIVOT)
        assert t.status == Status.ACTIVE, "transaction already committed"

    # ------------------------------------------------------------------ reads
    def read(self, t: Txn, key: str) -> Any:
        self._check_active(t)
        if key in t.writes:                       # read-your-own-writes
            return t.writes[key]
        ch = self.store.chain(key)
        if t.rss is not None:                     # protected (RSS) read
            v = ch.visible_in(t.rss.visible)
        else:                                     # SI-V
            v = ch.visible_at(t.begin_seq)
        t.reads[key] = v.writer
        if self.history is not None:
            self.history.append(op_r(t.tid, key, v.writer))
        if self._tracked(t):
            self.siread.setdefault(key, set()).add(t.tid)
            self.certifier.on_read(t, v.writer, v.commit_seq)
            # reading an old version while *committed* newer versions exist
            # creates an out-going rw edge to EVERY skipped writer still
            # concurrent with us (PostgreSQL's CheckForSerializableConflictOut
            # fires per skipped tuple version during the scan).
            for ver in ch.versions:
                if ver.commit_seq > t.begin_seq:
                    writer = self.txns.get(ver.writer)
                    self.certifier.on_read_skipped_version(t, writer,
                                                           ver.commit_seq)
                    self._add_rw_edge(t, writer)
            # ... and so is reading a key an in-progress transaction has an
            # uncommitted write for (the invisible-tuple case).
            for u in list(self.active.values()):
                if u.tid != t.tid and key in u.writes:
                    self._add_rw_edge(t, u)
        return v.value

    # ------------------------------------------------------------- OLAP plans
    def execute(self, t: Txn, plan: Plan) -> Any:
        """The engine's ONE OLAP plan-execution seam: resolve visibility
        for the plan's whole key sequence in ONE `VersionStore` call and
        apply the plan (`ScanPlan` materializes values; aggregate plans
        reduce — the paged store fuses resolve + reduction in a single
        device pass per kernel config).

        Only transactions outside SSI conflict tracking (RSS protected
        readers, safe-snapshot readers, plain-SI transactions) take the
        batched path — their reads are pure visibility resolution with no
        SIRead side effects.  SSI-tracked transactions fall back to per-key
        `read` so rw-antidependency detection observes every key, and a
        transaction with buffered writes on plan keys falls back to the
        batched scan + host `apply_plan` (read-your-own-writes never hits
        the store).

        Every path records the read set (`t.reads` and the Adya history
        when recording): resolved writers come out of the same visibility
        walk, so the serializability oracle sees an aggregate exactly as
        it sees the equivalent scan."""
        self._check_active(t)
        keys = plan_keys(plan)
        if self.mode == "ssi" and not t.skip_siread:
            return apply_plan([self.read(t, k) for k in keys], plan)
        snapshot = t.rss if t.rss is not None else t.begin_seq
        if t.writes and any(k in t.writes for k in keys):
            vals, writers = self.version_store.scan_with_writers(keys,
                                                                 snapshot)
            self.record_scan(t, keys, writers)
            vals = [t.writes.get(k, v) for k, v in zip(keys, vals)]
            return apply_plan(vals, plan)
        result, writers = self.version_store.execute_with_writers(plan,
                                                                  snapshot)
        self.record_scan(t, keys, writers)
        return result

    def record_scan(self, t: Txn, keys: Sequence[str],
                    writers: Sequence[int]) -> None:
        """Record a batched scan's resolved (key -> writer) read set, like
        per-key `read` does — skipping keys the transaction overwrote
        (read-your-own-writes never hits the store)."""
        hist = self.history
        for key, writer in zip(keys, writers):
            if key in t.writes:
                continue
            t.reads[key] = writer
            if hist is not None:
                hist.append(op_r(t.tid, key, writer))

    # ----------------------------------------------------------------- writes
    def write(self, t: Txn, key: str, value: Any) -> None:
        self._check_active(t)
        assert not t.read_only
        assert t.rss is None, "protected read-only transactions cannot write"
        if self.history is not None and key not in t.writes:
            self.history.append(op_w(t.tid, key))
        t.writes[key] = value
        if self.mode == "ssi":
            # writing over a version some concurrent/overlapping reader read:
            # reader -> self rw edge (SIRead check).
            for rid in self.siread.get(key, ()):
                reader = self.txns.get(rid)
                if reader is not None and rid != t.tid:
                    self._add_rw_edge(reader, t)

    # ----------------------------------------------------------------- commit
    def commit(self, t: Txn) -> None:
        self._check_active(t)
        t0 = tick()
        with TRACER.span("oltp_commit", certifier=self.certifier.name,
                         n_reads=len(t.reads), n_writes=len(t.writes)):
            tc = tick()
            try:
                with TRACER.span("certify"):
                    if t.writes:
                        # SI-W first-committer-wins: a version committed
                        # after our snapshot on any written key aborts us.
                        for key in t.writes:
                            if self.store.chain(key).newest().commit_seq \
                                    > t.begin_seq:
                                raise SerializationFailure(
                                    AbortReason.WW_CONFLICT)
                    if self._tracked(t):
                        self.certifier.on_precommit(t)
            except SerializationFailure as e:
                self._abort(t, e.reason)
                raise
            tock(self._certify_hist, tc)
            cseq = self._tick()
            for key, value in t.writes.items():
                self.store.chain(key).install(cseq, t.tid, value)
            t.status, t.end_seq = Status.COMMITTED, cseq
            self.active.pop(t.tid, None)
            tw = tick()
            with TRACER.span("wal_emit"):
                self.wal.log_commit(t.tid, sorted(t.writes.items()),
                                    seq=cseq)
                if t.out_rw:
                    # the paper's logical message: outgoing concurrent rw
                    # edges of a just-committed reader, for replica-side
                    # RSS construction.
                    self.wal.log_deps(t.tid, sorted(t.out_rw))
            tock(self._wal_hist, tw)
            if self.history is not None:
                self.history.append(op_c(t.tid))
            self.stats["commits"] += 1
            if self._tracked(t):
                self.certifier.on_end(t, committed=True)
            self._gc()
            # observed on success only: histogram count == engine commits
            tock(self._commit_hist, t0)

    def abort(self, t: Txn) -> None:
        self._abort(t, AbortReason.USER)

    def _abort(self, t: Txn, reason: AbortReason) -> None:
        if t.status != Status.ACTIVE:
            return
        t.status, t.end_seq = Status.ABORTED, self._tick()
        t.abort_reason = reason
        t.writes.clear()
        self.active.pop(t.tid, None)
        self.wal.log_abort(t.tid)
        if self.history is not None:
            self.history.append(op_a(t.tid))
        self.stats["aborts"] += 1
        if reason == AbortReason.WW_CONFLICT:
            self.stats["ww_aborts"] += 1
        elif reason is not AbortReason.USER:
            if t.read_only:
                self.stats["reader_aborts"] += 1
            else:
                self.stats["writer_aborts"] += 1
        self.stats["by_reason"][reason.value] = \
            self.stats["by_reason"].get(reason.value, 0) + 1
        # drop edges referencing the aborted txn — via its OWN edge sets
        # (edges are maintained symmetrically, so t's neighbours are exactly
        # the txns holding a reference to it; scanning all of `self.txns`
        # made every abort O(tracked transactions))
        for nid in t.in_rw | t.out_rw:
            n = self.txns.get(nid)
            if n is not None:
                n.in_rw.discard(t.tid)
                n.out_rw.discard(t.tid)
        t.in_rw.clear()
        t.out_rw.clear()
        if self._tracked(t):
            self.certifier.on_end(t, committed=False)

    # --------------------------------------------------------------- SSI core
    def _concurrent(self, a: Txn, b: Txn) -> bool:
        if a.tid == b.tid:
            return False
        ea = a.end_seq if a.status != Status.ACTIVE else (1 << 62)
        eb = b.end_seq if b.status != Status.ACTIVE else (1 << 62)
        return a.begin_seq < eb and b.begin_seq < ea

    def _add_rw_edge(self, reader: Optional[Txn], writer: Optional[Txn]) -> None:
        if reader is None or writer is None or reader.tid == writer.tid:
            return
        if reader.status == Status.ABORTED or writer.status == Status.ABORTED:
            return
        if not self._concurrent(reader, writer):
            return  # only *vulnerable* (concurrent) rw edges matter
        reader.out_rw.add(writer.tid)
        writer.in_rw.add(reader.tid)
        self.certifier.on_rw_edge(reader, writer)

    # --------------------------------------------------------------------- GC
    def _gc(self) -> None:
        """Forget ended txns (and their SIRead entries) that can no longer be
        concurrent with any future transaction.

        rw edges between two txns that are BOTH ended below the concurrency
        horizon are released first (the analogue of PostgreSQL's SSI SLRU
        summarization): such an edge can never participate in a future
        dangerous-structure decision — any new edge involves a transaction
        whose end is at-or-above the horizon, so every pivot check that
        could still fire only needs edges with at least one endpoint there.
        Without this, committed transactions joined by an rw edge pinned
        each other in `txns` forever (edges were only dropped on abort)."""
        horizon = min((t.begin_seq for t in self.active.values()),
                      default=self.seq)

        def _released(tid: int) -> bool:
            u = self.txns.get(tid)
            return u is None or (u.status != Status.ACTIVE
                                 and u.end_seq < horizon)

        dead = []
        for tid, t in self.txns.items():
            if t.status == Status.ACTIVE or t.end_seq >= horizon:
                continue
            if t.in_rw:
                t.in_rw = {x for x in t.in_rw if not _released(x)}
            if t.out_rw:
                t.out_rw = {x for x in t.out_rw if not _released(x)}
            if not t.in_rw and not t.out_rw:
                dead.append(tid)
        if not dead:
            return
        deadset = set(dead)
        for tid in dead:
            self.txns.pop(tid, None)
        for key in list(self.siread):
            self.siread[key] -= deadset
            if not self.siread[key]:
                del self.siread[key]
        self.certifier.on_gc(deadset)

    def prune_versions(self, floor_seq: int) -> int:
        n = self.store.prune(floor_seq)
        self.stats["gc_versions"] += n
        return n

    # ------------------------------------------------------------ convenience
    def run(self, ops: Iterable[tuple], t: Txn) -> Any:
        """Run ('r', key) / ('w', key, value) ops then commit. For tests."""
        out = []
        for op in ops:
            if op[0] == "r":
                out.append(self.read(t, op[1]))
            else:
                self.write(t, op[1], op[2])
        self.commit(t)
        return out
