"""Device-resident page-granular multiversion store (SI-V on TPU).

Layout:
  data [P, K, page_elems]   — K version slots per page, any dtype
  ts   [P, K] int32         — commit timestamp per slot (0 = initial)

Snapshot read (the paper's SI-V read protocol, vectorized): for each page,
select the slot with the largest `ts <= watermark` and gather its payload.
This is the memory-bound hot spot of wait-free snapshot reads over
fine-grained state (embedding rows, adapter pages, KV pages) — implemented
three ways:
  * `visible_slots` + `snapshot_read_ref`: pure-jnp oracle,
  * `repro.kernels.version_gather`: Pallas TPU kernel (same contract),
  * `snapshot_read_members`: RSS-set membership variant (watermark set,
    not prefix) — newest slot whose ts is in a sorted member-ts array.

Writes go to the LRU slot (`publish_page`); GC floor = the minimum pinned
watermark (hot_standby_feedback analogue), enforced by the caller.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def init_store(n_pages: int, n_slots: int, page_elems: int,
               dtype=jnp.bfloat16, initial=None) -> dict:
    data = jnp.zeros((n_pages, n_slots, page_elems), dtype)
    if initial is not None:
        data = data.at[:, 0, :].set(initial.astype(dtype))
    ts = jnp.zeros((n_pages, n_slots), jnp.int32)
    return {"data": data, "ts": ts}


def as_page_range(pages) -> Optional[tuple[int, int]]:
    """Dense key-range -> page-range resolution: when a page-index array is
    a contiguous ascending run, return its (start, stop) so multi-page
    scans can slice the store instead of gathering (the columnar fast
    path); None otherwise (holes, missing keys, or arbitrary order)."""
    import numpy as np

    arr = np.asarray(pages)
    if arr.size == 0 or arr[0] < 0:
        return None
    start = int(arr[0])
    if np.array_equal(arr, np.arange(start, start + arr.size)):
        return start, start + int(arr.size)
    return None


def gather_pages(store: dict, pages) -> dict:
    """Columnar multi-page gather on device: the `{'data','ts'}` sub-store
    for a key-range of pages (one `jnp.take` per buffer — no host round
    trip), sliced instead when the range is dense (`as_page_range`).

    The sub-store is padded to a sublane multiple of 8 pages with initial
    (ts == 0, zero-payload) pages so the gather kernels' block asserts
    hold for any page count — padding pages resolve to the initial value,
    same as `PagedMirror.jnp_store`'s padding."""
    rng = as_page_range(pages)
    if rng is not None:
        start, stop = rng
        data, ts = store["data"][start:stop], store["ts"][start:stop]
    else:
        idx = jnp.asarray(pages, jnp.int32)
        data = jnp.take(store["data"], idx, axis=0)
        ts = jnp.take(store["ts"], idx, axis=0)
    pad = (-data.shape[0]) % 8
    if pad:
        data = jnp.concatenate(
            [data, jnp.zeros((pad,) + data.shape[1:], data.dtype)])
        ts = jnp.concatenate(
            [ts, jnp.zeros((pad,) + ts.shape[1:], ts.dtype)])
    return {"data": data, "ts": ts}


def visible_slots(ts: jax.Array, watermark: jax.Array) -> jax.Array:
    """[P,K] ts, scalar watermark -> [P] slot index of newest visible
    version (largest ts <= watermark; ties impossible, ts unique per page)."""
    masked = jnp.where(ts <= watermark, ts, -1)
    return jnp.argmax(masked, axis=-1).astype(jnp.int32)


def snapshot_read_ref(store: dict, watermark: jax.Array) -> jax.Array:
    """Pure-jnp SI-V gather: [P, page_elems] visible payloads."""
    idx = visible_slots(store["ts"], watermark)
    return jnp.take_along_axis(
        store["data"], idx[:, None, None], axis=1)[:, 0]


def visible_slots_members(ts: jax.Array, member_ts: jax.Array,
                          floor: jax.Array | int = 0) -> jax.Array:
    """RSS-set variant: member_ts is a sorted [M] array of commit timestamps
    of RSS members ABOVE the snapshot's floor; a slot is visible iff its ts
    is at-or-below `floor` (0 = initial versions only — every committed
    version at seq <= floor belongs to a floor-covered member) or an
    explicit member.  Returns the newest visible slot per page.

    The floor is the compressed-snapshot watermark of `RssSnapshot`: it
    keeps the member array bounded by the concurrent window instead of
    growing with history.  An empty member array (M == 0) with floor 0
    resolves every page to its initial (ts == 0) slot: searchsorted/clip/
    take on a zero-length array would index garbage, so membership
    degenerates to the prefix test alone."""
    if member_ts.shape[0] == 0:
        is_member = ts <= floor
    else:
        pos = jnp.searchsorted(member_ts, ts)
        pos = jnp.clip(pos, 0, member_ts.shape[0] - 1)
        is_member = (jnp.take(member_ts, pos) == ts) | (ts <= floor)
    masked = jnp.where(is_member, ts, -1)
    return jnp.argmax(masked, axis=-1).astype(jnp.int32)


def snapshot_read_members(store: dict, member_ts: jax.Array,
                          floor: jax.Array | int = 0) -> jax.Array:
    idx = visible_slots_members(store["ts"], member_ts, floor)
    return jnp.take_along_axis(
        store["data"], idx[:, None, None], axis=1)[:, 0]


def publish_page(store: dict, page: jax.Array, payload: jax.Array,
                 commit_ts: jax.Array, *,
                 gc_floor: jax.Array | int = 0) -> dict:
    """Install a new version of one page into its oldest recyclable slot.

    Slots with ts >= gc_floor that are the newest visible at gc_floor are
    protected (a pinned reader may still need them); the oldest slot below
    the floor is recycled.  With K slots and publishers outrunning readers by
    at most K-1 versions this is wait-free."""
    ts_row = store["ts"][page]                         # [K]
    protected = visible_slots(ts_row[None], jnp.asarray(gc_floor))[0]
    order = jnp.where(jnp.arange(ts_row.shape[0]) == protected,
                      jnp.iinfo(jnp.int32).max, ts_row)
    victim = jnp.argmin(order)
    data = jax.lax.dynamic_update_index_in_dim(
        store["data"][page], payload.astype(store["data"].dtype), victim, 0)
    new_data = store["data"].at[page].set(data)
    new_ts = store["ts"].at[page, victim].set(commit_ts.astype(jnp.int32))
    return {"data": new_data, "ts": new_ts}
