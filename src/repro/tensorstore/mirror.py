"""WAL -> paged-store mirror: a device-shaped OLAP surface over the HTAP WAL.

`PagedMirror` applies committed writesets from `Wal.tail()` into K-slot page
versions (the `tensorstore.paged` layout), stamping each version with the
primary's commit seq shipped in the commit record — the SAME clock the
RSS membership mapping uses.  That gives replicas (and the single-node HTAP
facade) a columnar, batch-scannable image of the keyspace:

  * `scan_at(keys, watermark)`       — SI-V snapshot scan (prefix visibility)
  * `scan_members(keys, snapshot)`   — RSS membership scan (set visibility)

Both resolve visibility for all requested pages in one vectorized pass (the
`version_gather` / `rss_gather` algorithms on host numpy buffers — mutable
in-place, so publishes are O(K+E) and scans allocation-light), and
`jnp_store()` exports the live buffers as a `{'data','ts'}` paged store for
the Pallas kernels (interpret mode on CPU, compiled on TPU).

The key -> page codec is `encode_value`/`decode_value`: a fixed-width int32
payload per page tagged by value shape (int / district / order), chosen so
the CH-like workload of `mvcc.workload` round-trips bit-exactly — scans over
the mirror must equal per-key engine reads.

GC: publishes honour a `gc_floor` (commit-seq units, from
`PRoTManager.gc_floor_seq()`): the newest slot at-or-below the floor is never
recycled (hot_standby_feedback analogue).  Like the paper's K-slot design
this is a BOUNDED-staleness guarantee: pinned readers' versions above the
floor survive only while publishers outrun readers by fewer than K-1
versions per page — size K (`slots`) to the publish rate between reader
release points, and use `check_scans` to assert parity against the
unbounded chain store in-run.
"""

from __future__ import annotations

import bisect
from typing import Any, Iterable, Sequence

import numpy as np

from ..core.replica import RssSnapshot
from ..core.wal import Wal, WalRecord, effective_commit_seq
from ..obs import REGISTRY, TRACER, StatsView, tick, tock

# serve-path per-stage latency: visibility resolve, kernel dispatch, and
# result fold/finalize (the route stage is observed by the facades /
# cluster).  Shared across mirrors: summaries merge per stage.
_RESOLVE_H = REGISTRY.histogram("olap_stage_seconds", stage="resolve")
_DISPATCH_H = REGISTRY.histogram("olap_stage_seconds", stage="dispatch")
_FINALIZE_H = REGISTRY.histogram("olap_stage_seconds", stage="finalize")

# payload tags (element 0 of every page payload)
TAG_INIT = 0        # never-written page: decodes to the initial value 0
TAG_INT = 1         # [1, v]
TAG_DISTRICT = 2    # [2, next_o_id, ytd]
TAG_ORDER = 3       # [3, total, n_items, items...]
TAG_PAD = -1        # sublane-padding page: participates in NO aggregate
_NO_TAG = -2        # "no alternate tag": matches nothing (incl. TAG_PAD)

# aggregate-field -> (tag_main, tag_alt) payload validity for the fused
# device aggregation (`rss_scan_agg`): the kernel-side twin of
# `version_store.agg_value`.  "int" includes TAG_INIT because an initial
# page decodes to the int 0 (and its field element is 0).
AGG_FIELD_TAGS = {"int": (TAG_INT, TAG_INIT), "total": (TAG_ORDER, _NO_TAG)}

_INT32 = np.iinfo(np.int32)


# AggOp kinds whose lane depends on the threshold scalar (predicate
# pushdown: count_below / count_above / sum_below share one kernel pass
# per (field, threshold) config)
_THRESHOLDED_KINDS = ("count_below", "count_above", "sum_below")


def _op_config(op) -> tuple:
    """The fused-kernel pass an `AggOp` needs: (field, threshold) —
    threshold only matters to the thresholded kinds, so every other kind
    shares its field's default pass (the kernel emits all seven lanes
    regardless)."""
    return (op.field,
            op.threshold if op.kind in _THRESHOLDED_KINDS else None)


def _lane_layout(plans) -> tuple[list, list, dict]:
    """Accumulator-lane layout for a sequence of aggregate plans served by
    ONE fused grouped launch: one lane per (plan, kernel config, group),
    where a config is the (field, threshold) pass `_op_config` derives.
    Per-lane kernel params (tag_main, tag_alt, threshold) ride the
    kernel's group-param tile, so lanes from different plans/configs
    coexist in a single dispatch — whole-batch plan fusion.

    Returns (lane_groups, lane_params, lane_of): the key sequence feeding
    each lane, each lane's (field, tag_main, tag_alt, threshold), and
    (plan index, config, group index) -> lane index for result
    assembly."""
    from .version_store import AggPlan, GroupByPlan, MultiAggPlan

    lane_groups: list[tuple] = []
    lane_params: list[tuple] = []
    lane_of: dict[tuple, int] = {}
    for p_i, plan in enumerate(plans):
        if isinstance(plan, GroupByPlan):
            key_groups, ops = plan.key_groups, plan.ops
        elif isinstance(plan, MultiAggPlan):
            key_groups, ops = (plan.keys,), plan.ops
        elif isinstance(plan, AggPlan):
            key_groups, ops = (plan.keys,), (plan.op,)
        else:
            raise TypeError(f"not an aggregate plan: {type(plan).__name__}")
        for cfg in dict.fromkeys(_op_config(op) for op in ops):
            field, thr = cfg
            tag_main, tag_alt = AGG_FIELD_TAGS[field]
            for g_i, grp in enumerate(key_groups):
                lane_of[(p_i, cfg, g_i)] = len(lane_groups)
                lane_groups.append(tuple(grp))
                lane_params.append((field, tag_main, tag_alt, thr))
    return lane_groups, lane_params, lane_of


def encode_value(value: Any, elems: int) -> np.ndarray:
    """Encode a workload value into a fixed [elems] int32 payload."""
    out = np.zeros(elems, np.int32)
    if isinstance(value, (int, np.integer)) and not isinstance(value, bool):
        assert _INT32.min <= value <= _INT32.max, value
        out[0], out[1] = TAG_INT, value
        return out
    if isinstance(value, dict):
        if set(value) <= {"next_o_id", "ytd"}:
            out[0] = TAG_DISTRICT
            out[1] = value.get("next_o_id", 0)
            out[2] = value.get("ytd", 0)
            return out
        if set(value) <= {"items", "total"}:
            items = list(value.get("items", ()))
            assert len(items) + 3 <= elems, \
                f"order with {len(items)} items needs page_elems >= " \
                f"{len(items) + 3}"
            out[0], out[1], out[2] = TAG_ORDER, value.get("total", 0), \
                len(items)
            out[3:3 + len(items)] = items
            return out
    raise TypeError(f"no paged-store codec for value {value!r}")


def decode_value(row: np.ndarray) -> Any:
    """Inverse of encode_value; TAG_INIT decodes to the chain-store initial
    value 0."""
    tag = int(row[0])
    if tag == TAG_INIT:
        return 0
    if tag == TAG_INT:
        return int(row[1])
    if tag == TAG_DISTRICT:
        return {"next_o_id": int(row[1]), "ytd": int(row[2])}
    if tag == TAG_ORDER:
        n = int(row[2])
        return {"items": [int(x) for x in row[3:3 + n]],
                "total": int(row[1])}
    raise ValueError(f"corrupt page payload tag {tag}")


class PagedMirror:
    def __init__(self, *, slots: int = 8, page_elems: int = 32,
                 capacity: int = 64, resolve_cache: bool = True) -> None:
        assert page_elems >= 3
        self.slots = slots
        self.page_elems = page_elems
        self.data = np.zeros((capacity, slots, page_elems), np.int32)
        self.ts = np.zeros((capacity, slots), np.int32)
        self.writer = np.zeros((capacity, slots), np.int32)  # txn per slot
        self.page_of: dict[str, int] = {}
        self.keys: list[str] = []
        self.applied_lsn = 0
        self.commit_seq: dict[int, int] = {}   # txn -> commit seq
        self.watermark = 0                     # newest applied commit seq
        # registry-backed accounting (series mirror_range_* /
        # mirror_exec_*), scoped per mirror instance so replicas never
        # alias; dict-shaped views keep the old reader API.
        # range: dense-range fast-path hits for fused plan executions — a
        # contiguous ascending page run slices the store (no gather);
        # `reserve` key families contiguously to raise the hit rate.
        lbl = {"mirror": REGISTRY.scope("mirror")}
        self.range_stats = StatsView(REGISTRY, "mirror_range",
                                     ("dense", "gather"), labels=lbl)
        # grouped-strategy override (None = shape dispatch; "host" /
        # "flat" / "chunked" forces a mode — tests and benches pin it)
        self.grouped_mode: str | None = None
        # plan-execution accounting: plans served, fused batches, grouped
        # dispatches and which strategy each took (the driver surfaces
        # these as plans/dispatch and mode counters)
        self.exec_stats = StatsView(
            REGISTRY, "mirror_exec",
            ("plans", "batches", "batched_plans", "agg_dispatches",
             "mode_flat", "mode_chunked", "mode_host",
             "view_hits", "view_fallbacks", "view_demotions"), labels=lbl)
        # materialized-aggregate registry: plan (frozen dataclass, hashed
        # by value — the fingerprint) -> MaterializedView.  Applied
        # commits queue in `_unfolded` and fold into the tiles as they
        # become VISIBLE to a served/constructed snapshot
        # (`advance_views` — RSS member sets grow monotonically, so the
        # freshest snapshot serves from the tile while commits still
        # excluded for unresolved deps stay queued).  `_folded_seqs`
        # (sorted, pruned by `gc_views`) is what `view_gate` checks a
        # snapshot against; seqs at-or-below `_seqs_floor` are covered by
        # any snapshot floor >= it.
        self.views: dict = {}
        self._unfolded: list = []              # [(seq, WalRecord)], ascending
        self._folded_seqs: list[int] = []
        self._seqs_floor = 0
        # ------------------------------------------- horizon-keyed resolve
        # cache: N serves sharing one applied horizon (thousands of
        # sessions routed to one replica between ships) do the host-side
        # resolve work ONCE.  Three layers, each invalidated precisely by
        # the one event that can change its value:
        #   _member_cache  snapshot -> member-seq array.  Stamped
        #                  (compressed) snapshots are pure — the array is
        #                  a function of the frozen snapshot alone — and
        #                  never invalidate; explicit-set snapshots read
        #                  `commit_seq`, so commit applies drop them.
        #   _pindex_cache  plan key-tuple (the plan fingerprint's key
        #                  sequence) -> page-index array.  `page_of` is
        #                  append-only, so an entry with NO misses is
        #                  valid forever; entries holding a -1 are stamped
        #                  with `_page_gen` and die when `_ensure_page`
        #                  allocates (a reserve / first write may have
        #                  filled the hole).
        #   _store_cache   key-tuple -> gathered {'data','ts'} device
        #                  buffers (+ the dense/gather verdict).  The
        #                  buffers are device copies of page content, so
        #                  only `apply` installing writes changes their
        #                  value — it clears the cache; reserve-only page
        #                  allocation leaves entries valid (reserved
        #                  pages are all-zero: they decode to 0 exactly
        #                  like the missing keys they replace).
        #   _lane_cache    plan tuple -> `_lane_layout` (pure function of
        #                  the frozen plans; never invalidated).
        self.resolve_cache = resolve_cache
        self._member_cache: dict = {}
        self._pindex_cache: dict = {}
        self._store_cache: dict = {}
        self._lane_cache: dict = {}
        self._page_gen = 0
        self._last_range_verdict = "gather"
        self.cache_stats = StatsView(
            REGISTRY, "mirror_cache",
            ("member_hits", "member_misses",
             "pindex_hits", "pindex_misses",
             "store_hits", "store_misses",
             "invalidations"), labels=lbl)

    # ------------------------------------------------------- resolve cache
    _MEMBER_CAP = 64          # live horizons are few; FIFO-evict beyond
    _PINDEX_CAP = 256         # distinct plan key sequences
    _STORE_CAP = 32           # device buffers are the big entries

    def invalidate_caches(self) -> None:
        """Drop every resolve-cache layer (tests / recovery); counted so
        hit-rate accounting stays explainable."""
        self._member_cache.clear()
        self._pindex_cache.clear()
        self._store_cache.clear()
        self._lane_cache.clear()
        self.cache_stats["invalidations"] += 1

    @staticmethod
    def _cap(cache: dict, cap: int) -> None:
        while len(cache) >= cap:
            cache.pop(next(iter(cache)))       # FIFO: dicts keep insert order

    # ----------------------------------------------------------- page alloc
    @property
    def n_pages(self) -> int:
        return len(self.keys)

    def _ensure_page(self, key: str) -> int:
        page = self.page_of.get(key)
        if page is not None:
            return page
        page = len(self.keys)
        if page == self.data.shape[0]:         # grow by doubling
            self.data = np.concatenate([self.data, np.zeros_like(self.data)])
            self.ts = np.concatenate([self.ts, np.zeros_like(self.ts)])
            self.writer = np.concatenate([self.writer,
                                          np.zeros_like(self.writer)])
        self.page_of[key] = page
        self.keys.append(key)
        self._page_gen += 1        # page-index entries holding a -1 for
        return page                # this key are stale now

    def reserve(self, keys: Iterable[str]) -> int:
        """Pre-allocate pages for a key sequence IN ORDER (page-range
        locality): a workload key family reserved contiguously resolves to
        a dense ascending page run, so fused plan executions over it hit
        the `paged.as_page_range` slice fast path instead of gathering.
        Reserved-but-unwritten pages hold only the initial (ts == 0) slot
        and decode to 0 — exactly what a missing key reads as.  Returns
        the number of pages newly allocated."""
        before = len(self.keys)
        for key in keys:
            self._ensure_page(key)
        return len(self.keys) - before

    # -------------------------------------------------------------- publish
    def _publish(self, page: int, payload: np.ndarray, seq: int, writer: int,
                 gc_floor: int) -> None:
        """numpy twin of `paged.publish_page`: recycle the oldest slot, but
        never the newest slot at-or-below gc_floor (a pinned reader may still
        resolve to it)."""
        row = self.ts[page]
        masked = np.where(row <= gc_floor, row, -1)
        protected = int(masked.argmax())
        order = row.astype(np.int64).copy()
        order[protected] = np.iinfo(np.int64).max
        victim = int(order.argmin())
        self.data[page, victim] = payload
        self.ts[page, victim] = seq
        self.writer[page, victim] = writer

    # --------------------------------------------------------------- replay
    def apply(self, rec: WalRecord, *, gc_floor: int = 0) -> bool:
        """Apply one WAL record (idempotent by LSN); returns True when the
        record installed new versions."""
        if rec.lsn <= self.applied_lsn:
            return False
        self.applied_lsn = rec.lsn
        if rec.type != "commit":
            return False
        # the shared WAL commit clock (effective_commit_seq), so member-ts
        # mapping and mirrored version stamps never diverge from RSSManager
        seq = effective_commit_seq(self.watermark, rec.seq)
        self.commit_seq[rec.txn] = seq
        self.watermark = seq
        # precise cache invalidation: the new commit-seq mapping can extend
        # any explicit-set snapshot's member resolve (stamped snapshots are
        # pure and survive); installed writes change page content, killing
        # every gathered device buffer
        if self._member_cache:
            for s in [s for s in self._member_cache
                      if s.member_seqs is None]:
                del self._member_cache[s]
        if rec.writes and self._store_cache:
            self._store_cache.clear()
        for key, value in rec.writes:
            page = self._ensure_page(key)
            self._publish(page, encode_value(value, self.page_elems), seq,
                          rec.txn, gc_floor)
        if self.views:
            # queue the commit for folding; it advances into the tiles
            # once a served/constructed snapshot admits it (advance_views)
            self._unfolded.append((seq, rec))
        return bool(rec.writes)

    def catch_up(self, wal: Wal, *, gc_floor: int = 0) -> int:
        """Pull and apply all records past applied_lsn; returns #applied."""
        n = 0
        for rec in wal.tail(self.applied_lsn):
            self.apply(rec, gc_floor=gc_floor)
            n += 1
        return n

    # ------------------------------------------------- materialized views
    def register_view(self, plan, *, use_kernel: bool = True,
                      interpret=None):
        """Register an aggregate plan for incremental materialization:
        subsequent `execute_with_writers` calls with an equal plan (frozen
        dataclasses hash by value — the fingerprint) serve from a live
        accumulator tile advanced by commit-delta folds, when the
        snapshot gate proves consistency.  Idempotent per plan; seeds the
        tile with one full SI-prefix scan at the current watermark."""
        from .materialized import MaterializedView

        view = self.views.get(plan)
        if view is not None:
            return view
        if self.views and self._unfolded:
            # drain pending folds so the new view's full-prefix reseed
            # baseline matches the fold state of its siblings
            self.advance_views(self.watermark)
        view = MaterializedView(self, plan, use_kernel=use_kernel,
                                interpret=interpret)
        if not self.views:
            # the reseed scan folded every applied commit: record them
            # all so the gate can check each against a snapshot
            self._folded_seqs = sorted(
                s for s in self.commit_seq.values() if s > self._seqs_floor)
        self.views[plan] = view
        return view

    def gc_views(self, keep_seq: int) -> None:
        """Prune `_folded_seqs` bookkeeping below the protected floor
        (`PRoTManager.gc_floor_seq()` units): every live or future
        snapshot has floor_seq >= keep_seq, so individual membership of
        folded seqs at-or-below it never needs checking again.  Call
        wherever RSS gc runs — the view analogue of WAL truncation."""
        i = bisect.bisect_right(self._folded_seqs, keep_seq)
        if i:
            del self._folded_seqs[:i]
        self._seqs_floor = max(self._seqs_floor, keep_seq)

    def reseed_views(self) -> None:
        """Recovery path: re-materialize every registered view from a
        full SI-prefix scan at the current watermark (after deep GC, WAL
        truncation, or degradation invalidated incremental state) and
        re-baseline the fold bookkeeping to match — queued commits are
        already in the rescanned prefix, so they are marked folded, not
        re-applied."""
        if not self.views:
            return
        self._unfolded = []
        self._folded_seqs = sorted(
            s for s in self.commit_seq.values() if s > self._seqs_floor)
        for view in self.views.values():
            view.reseed()

    def _visible_fn(self, snapshot):
        """seq -> bool visibility predicate for an RSS snapshot or an int
        SI watermark."""
        if isinstance(snapshot, RssSnapshot):
            members = set(self.member_seqs_for(snapshot).tolist())
            floor = snapshot.floor_seq
            return lambda s: s <= floor or s in members
        wm = int(snapshot)
        return lambda s: s <= wm

    def advance_views(self, snapshot) -> int:
        """Fold every queued commit VISIBLE to `snapshot` into the
        registered views (ascending seq order) and leave the rest queued;
        returns the number folded.  RSS member sets grow monotonically,
        so advancing at each constructed/served snapshot keeps the tiles
        exactly at the freshest snapshot while commits still excluded
        for unresolved dependencies wait their turn."""
        if not self.views or not self._unfolded:
            return 0
        visible = self._visible_fn(snapshot)
        keep, folded = [], 0
        for seq, rec in self._unfolded:
            if visible(seq):
                for view in self.views.values():
                    view.on_commit(rec, seq)
                bisect.insort(self._folded_seqs, seq)
                folded += 1
            else:
                keep.append((seq, rec))
        self._unfolded = keep
        return folded

    def view_gate(self, snapshot) -> bool:
        """True when `snapshot` provably equals the fold prefix the
        materialized tiles hold: every folded seq visible to it, every
        still-queued applied seq invisible.  Unverifiable when an RSS
        snapshot's floor predates the tracking floor (`_seqs_floor`) ->
        clean fallback."""
        if isinstance(snapshot, RssSnapshot):
            if snapshot.floor_seq < self._seqs_floor:
                return False
            above = self._folded_seqs[
                bisect.bisect_right(self._folded_seqs, snapshot.floor_seq):]
            if not above and not self._unfolded:
                return True
            visible = self._visible_fn(snapshot)
            return (all(visible(s) for s in above)
                    and not any(visible(s) for s, _ in self._unfolded))
        wm = int(snapshot)
        if self._folded_seqs and self._folded_seqs[-1] > wm:
            return False
        return not any(s <= wm for s, _ in self._unfolded)

    def _try_views(self, plan, snapshot, need_writers: bool):
        """Serve a plan (or a whole fused batch, all-or-nothing) from the
        materialized registry: returns (result, writers) on a hit, None
        to fall through to the fused-scan path.  Fallbacks are counted
        only for REGISTERED plans that failed the gate (or degraded) —
        an unregistered plan is not a fallback, it never had a view."""
        from .version_store import BatchPlan, plan_keys

        plans = plan.plans if isinstance(plan, BatchPlan) else (plan,)
        views = [self.views.get(p) for p in plans]
        n_reg = sum(v is not None for v in views)
        if not n_reg:
            return None
        # fold whatever this snapshot admits before gating — serving the
        # freshest snapshot then hits; older pinned ones fall back
        self.advance_views(snapshot)
        if (any(v is None or v.degraded for v in views)
                or not self.view_gate(snapshot)):
            self.exec_stats["view_fallbacks"] += n_reg
            return None
        t0 = tick()
        with TRACER.span("view_serve", plans=len(views)):
            results = [v.result() for v in views]
        tock(_DISPATCH_H, t0)
        if need_writers:
            t0 = tick()
            with TRACER.span("resolve"):
                all_keys = [k for p in plans for k in plan_keys(p)]
                mask_fn, _m, _f = self._snapshot_mask(snapshot)
                writers = self._writers_for(self.page_index(all_keys),
                                            mask_fn)
            tock(_RESOLVE_H, t0)
        else:
            writers = []
        self.exec_stats["view_hits"] += len(views)
        self.exec_stats["plans"] += len(views)
        if isinstance(plan, BatchPlan):
            self.exec_stats["batches"] += 1
            self.exec_stats["batched_plans"] += len(views)
            return tuple(results), writers
        return results[0], writers

    # ------------------------------------------------------ batched reads
    def member_seqs_for(self, snap: RssSnapshot) -> np.ndarray:
        """Sorted member commit seqs ABOVE the snapshot's floor (with
        `snap.floor_seq`, the member-ts state the rss_gather kernel takes).
        Compressed snapshots carry their own seqs; explicit-set snapshots
        map `txns` through the mirror's commit-seq bookkeeping.  Cached per
        snapshot (frozen dataclass — identity IS the horizon), so repeat
        serves at one horizon skip the rebuild."""
        if self.resolve_cache:
            arr = self._member_cache.get(snap)
            if arr is not None:
                self.cache_stats["member_hits"] += 1
                return arr
        if snap.member_seqs is not None:
            arr = np.asarray(snap.member_seqs, np.int32)
        else:
            seqs = [self.commit_seq[t] for t in snap.txns
                    if t in self.commit_seq]
            arr = np.asarray(sorted(seqs), np.int32)
        if self.resolve_cache:
            self.cache_stats["member_misses"] += 1
            arr.flags.writeable = False
            self._cap(self._member_cache, self._MEMBER_CAP)
            self._member_cache[snap] = arr
        return arr

    def _visible_slots(self, rows: np.ndarray, mask_fn) -> np.ndarray:
        """Resolve visibility for a batch of pages: [n] slot indices."""
        ts = self.ts[rows]                                  # [n, K]
        masked = mask_fn(ts)
        return masked.argmax(1)                             # first max: ties
                                                            # toward slot 0

    def _scan(self, keys: Sequence[str], mask_fn, *,
              with_writers: bool = False):
        pages = self.page_index(keys)
        out: list[Any] = [0] * len(keys)
        writers = [0] * len(keys)
        hit = np.nonzero(pages >= 0)[0]
        if hit.size:
            rows = pages[hit]
            slot = self._visible_slots(rows, mask_fn)
            payloads = self.data[rows, slot]
            for i, row, wtr in zip(hit, payloads, self.writer[rows, slot]):
                out[int(i)] = decode_value(row)
                writers[int(i)] = int(wtr)
        return (out, writers) if with_writers else out

    def _writers_for(self, pages: np.ndarray, mask_fn) -> list[int]:
        """Writer txn per key out of the SAME visibility resolve `_scan`
        uses — no payload decode; the read-set half of a fused aggregate."""
        writers = [0] * len(pages)
        hit = np.nonzero(pages >= 0)[0]
        if hit.size:
            rows = pages[hit]
            slot = self._visible_slots(rows, mask_fn)
            for i, wtr in zip(hit, self.writer[rows, slot]):
                writers[int(i)] = int(wtr)
        return writers

    @staticmethod
    def _member_mask(snap: RssSnapshot, members: np.ndarray):
        """Slot visibility under a compressed snapshot: initial (ts == 0),
        floor-covered (ts <= floor_seq), or an explicit above-floor
        member."""
        floor = snap.floor_seq
        return lambda ts: np.where(
            (ts <= floor) | np.isin(ts, members), ts, -1)

    def scan_at(self, keys: Sequence[str], watermark: int) -> list[Any]:
        """SI-V batched snapshot scan: one vectorized visibility pass."""
        return self._scan(
            keys, lambda ts: np.where(ts <= watermark, ts, -1))

    def scan_members(self, keys: Sequence[str],
                     snap: RssSnapshot) -> list[Any]:
        """RSS membership batched scan (empty member set -> initial slots)."""
        return self._scan(
            keys, self._member_mask(snap, self.member_seqs_for(snap)))

    def scan_with_writers(self, keys: Sequence[str], snapshot) \
            -> tuple[list[Any], list[int]]:
        """Batched scan returning (values, writer txn ids) — the writers
        feed read-set recording on the engine's batched scan path."""
        if isinstance(snapshot, RssSnapshot):
            mask = self._member_mask(snapshot,
                                     self.member_seqs_for(snapshot))
        else:
            wm = int(snapshot)
            mask = lambda ts: np.where(ts <= wm, ts, -1)
        return self._scan(keys, mask, with_writers=True)

    def read_at(self, key: str, watermark: int) -> Any:
        return self.scan_at([key], watermark)[0]

    def read_members(self, key: str, snap: RssSnapshot) -> Any:
        return self.scan_members([key], snap)[0]

    # ------------------------------------------------------ fused aggregates
    def page_index(self, keys: Sequence[str]) -> np.ndarray:
        """Dense key -> page resolution for a plan's key sequence (-1 for
        keys never written: they read as the initial value 0).  Memoized
        per key-tuple (== per plan fingerprint, since `plan_keys` is a
        pure function of the frozen plan): `page_of` is append-only, so a
        fully-resolved entry never goes stale; an entry holding misses is
        stamped with the page-allocation generation and re-resolved after
        any `reserve`/first-write allocates (the hole may be filled)."""
        if not self.resolve_cache:
            return np.asarray([self.page_of.get(k, -1) for k in keys],
                              np.int64)
        keys_t = keys if isinstance(keys, tuple) else tuple(keys)
        ent = self._pindex_cache.get(keys_t)
        if ent is not None:
            pages, has_miss, gen = ent
            if not has_miss or gen == self._page_gen:
                self.cache_stats["pindex_hits"] += 1
                return pages
        self.cache_stats["pindex_misses"] += 1
        get = self.page_of.get
        pages = np.fromiter((get(k, -1) for k in keys_t), np.int64,
                            count=len(keys_t))
        pages.flags.writeable = False
        self._cap(self._pindex_cache, self._PINDEX_CAP)
        self._pindex_cache[keys_t] = (pages, bool((pages < 0).any()),
                                      self._page_gen)
        return pages

    def _store_for(self, keys, pages: np.ndarray) -> dict:
        """`jnp_store_for` behind the horizon-keyed store cache: the
        gathered `{'data','ts'}` device buffers for a plan's key sequence,
        reused until a publish changes page content (`apply` clears the
        cache).  The cached dense/gather verdict re-counts into
        `range_stats` on hits, so the fast-path hit RATE keeps meaning
        'per fused plan execution' with the cache on."""
        if not self.resolve_cache:
            return self.jnp_store_for(pages)
        keys_t = keys if isinstance(keys, tuple) else tuple(keys)
        ent = self._store_cache.get(keys_t)
        if ent is not None:
            store, verdict = ent
            self.range_stats[verdict] += 1
            self.cache_stats["store_hits"] += 1
            return store
        self.cache_stats["store_misses"] += 1
        store = self.jnp_store_for(pages)
        self._cap(self._store_cache, self._STORE_CAP)
        self._store_cache[keys_t] = (store, self._last_range_verdict)
        return store

    def _lane_layout_for(self, plans) -> tuple[list, list, dict]:
        """`_lane_layout` memoized per plan tuple (frozen dataclasses hash
        by value, so the tuple IS the batch fingerprint)."""
        if not self.resolve_cache:
            return _lane_layout(plans)
        plans_t = tuple(plans)
        layout = self._lane_cache.get(plans_t)
        if layout is None:
            layout = _lane_layout(plans_t)
            self._cap(self._lane_cache, self._PINDEX_CAP)
            self._lane_cache[plans_t] = layout
        return layout

    def _snapshot_mask(self, snapshot):
        """(mask_fn, member_ts, floor) for either snapshot kind: an RSS
        snapshot masks by floor + above-floor members; an int watermark is
        the degenerate empty-member case (floor == watermark), so the same
        fused kernel serves SI-V aggregates."""
        if isinstance(snapshot, RssSnapshot):
            members = self.member_seqs_for(snapshot)
            return (self._member_mask(snapshot, members), members,
                    snapshot.floor_seq)
        wm = int(snapshot)
        return (lambda ts: np.where(ts <= wm, ts, -1),
                np.zeros((0,), np.int32), wm)

    def jnp_store_for(self, pages: np.ndarray) -> dict:
        """Columnar multi-page gather: the `{'data','ts'}` sub-store for a
        resolved page-index array, device-shaped for the fused scan
        kernels.  Missing keys (-1) become initial pages (ts == 0, decode
        to 0); sublane-padding pages are tagged TAG_PAD so fused aggregates
        never count them.  A contiguous ascending page range
        (`paged.as_page_range`) skips the gather entirely (pure slice —
        the dense key-range fast path)."""
        import jax.numpy as jnp

        from .paged import as_page_range

        n = int(pages.shape[0])
        pad = (-n) % 8 if n else 8
        rng = as_page_range(pages)
        self._last_range_verdict = "dense" if rng is not None else "gather"
        self.range_stats[self._last_range_verdict] += 1
        if rng is not None:
            data, ts = self.data[rng[0]:rng[1]], self.ts[rng[0]:rng[1]]
        else:
            safe = np.where(pages >= 0, pages, 0)
            data, ts = self.data[safe], self.ts[safe]
            miss = pages < 0
            if miss.any():
                data[miss] = 0
                ts[miss] = 0
        if pad:
            pd = np.zeros((pad,) + self.data.shape[1:], np.int32)
            pd[:, :, 0] = TAG_PAD
            data = np.concatenate([data, pd])
            ts = np.concatenate(
                [ts, np.zeros((pad,) + self.ts.shape[1:], np.int32)])
        return {"data": jnp.asarray(data), "ts": jnp.asarray(ts)}

    def _scalar_raws(self, pages: np.ndarray, member_ts, floor, ops, *,
                     keys: Sequence[str] | None = None,
                     use_kernel: bool = True, interpret=None) -> dict:
        """One fused `rss_scan_agg` pass per distinct kernel config the op
        list needs (ops sharing a field — and a threshold for count_below —
        fold into one pass, since the kernel emits all seven statistic
        lanes).  The gathered sub-store is built ONCE and shared across
        configs.  Returns {config: [sum, count, count_below, min, max,
        count_above, sum_below]}."""
        configs = list(dict.fromkeys(_op_config(op) for op in ops))
        empty = [0, 0, 0, int(_INT32.max), int(_INT32.min), 0, 0]
        if not len(pages):
            return {cfg: list(empty) for cfg in configs}
        from ..kernels.rss_scan_agg.ops import snapshot_agg_members

        store = self.jnp_store_for(pages) if keys is None \
            else self._store_for(keys, pages)
        mem = np.asarray(member_ts, np.int32)
        raws = {}
        for field, thr in configs:
            tag_main, tag_alt = AGG_FIELD_TAGS[field]
            raws[(field, thr)] = snapshot_agg_members(
                store, mem, floor, tag_main=tag_main, tag_alt=tag_alt,
                threshold=thr, use_kernel=use_kernel, interpret=interpret)
        return raws

    def _grouped_rows(self, lane_groups, lane_params, mask_fn, member_ts,
                      floor, n_plans, *, use_kernel: bool = True,
                      interpret=None) -> list:
        """Serve one fused grouped dispatch: every accumulator lane of a
        `_lane_layout` reduced in ONE strategy-dispatched pass.  The
        strategy comes from `ops.select_grouped_mode` (or the mirror's
        `grouped_mode` override): "host" decodes the scanned values and
        aggregates in Python (small scans — launch overhead dominates);
        "flat"/"chunked" gather the lane-major sub-store once, hand every
        lane its own kernel params, and launch a single grouped kernel
        pipeline.  Returns [lane][sum, count, count_below, min, max,
        count_above, sum_below]."""
        from ..kernels.rss_scan_agg import ops as kops
        from .version_store import agg_value

        empty = [0, 0, 0, int(_INT32.max), int(_INT32.min), 0, 0]
        flat_keys = [k for grp in lane_groups for k in grp]
        if not lane_groups or not flat_keys:
            return [list(empty) for _ in lane_groups]
        self.exec_stats["agg_dispatches"] += 1
        mode = kops.select_grouped_mode(
            len(flat_keys), len(lane_groups), n_plans,
            override=self.grouped_mode)
        if mode == "host":
            with TRACER.span("kernel_dispatch", mode="host",
                             lanes=len(lane_groups)):
                kops.LAUNCH_STATS["dispatches"] += 1
                kops.LAUNCH_STATS["host"] += 1
                self.exec_stats["mode_host"] += 1
                vals = self._scan(flat_keys, mask_fn)
                rows, off = [], 0
                for grp, (field, _tm, _ta, thr) in zip(lane_groups,
                                                       lane_params):
                    xs = [x for v in vals[off:off + len(grp)]
                          if (x := agg_value(v, field)) is not None]
                    off += len(grp)
                    thr_eff = int(_INT32.max) if thr is None else int(thr)
                    rows.append([sum(xs), len(xs),
                                 sum(1 for x in xs if x < thr_eff),
                                 min(xs, default=int(_INT32.max)),
                                 max(xs, default=int(_INT32.min)),
                                 sum(1 for x in xs if x > thr_eff),
                                 sum(x for x in xs if x < thr_eff)])
                return rows
        with TRACER.span("kernel_dispatch", lanes=len(lane_groups)):
            flat_keys = tuple(flat_keys)
            pages = self.page_index(flat_keys)
            store = self._store_for(flat_keys, pages)
            gid = np.full(int(store["ts"].shape[0]), -1, np.int32)
            gid[:len(pages)] = np.concatenate(
                [np.full(len(grp), g, np.int32)
                 for g, grp in enumerate(lane_groups)])
            gparams = np.asarray(
                [[tm, ta, int(_INT32.max) if thr is None else int(thr)]
                 for _f, tm, ta, thr in lane_params], np.int32)
            rows, used = kops.grouped_agg_auto(
                store, gid, len(lane_groups),
                np.asarray(member_ts, np.int32), floor,
                group_params=gparams, n_plans=n_plans, mode=mode,
                use_kernel=use_kernel, interpret=interpret)
            TRACER.annotate(mode=used)
        self.exec_stats["mode_" + used] += 1
        return rows

    def _grouped_execute(self, plans, snapshot, *, use_kernel: bool = True,
                         interpret=None) -> tuple:
        """Execute a sequence of aggregate plans sharing ONE snapshot in a
        single fused grouped dispatch (one visibility resolve, one pass
        over the gathered pages, one accumulator lane per plan × config ×
        group).  Returns (per-plan results list, writers over the
        plan-major flat key sequence)."""
        from .version_store import (AggPlan, GroupByPlan, MultiAggPlan,
                                    finalize_agg, plan_keys)

        lane_groups, lane_params, lane_of = self._lane_layout_for(plans)
        t0 = tick()
        with TRACER.span("resolve"):
            mask_fn, member_ts, floor = self._snapshot_mask(snapshot)
            all_keys = [k for p in plans for k in plan_keys(p)]
            writers = self._writers_for(self.page_index(all_keys), mask_fn)
        tock(_RESOLVE_H, t0)
        t0 = tick()
        rows = self._grouped_rows(lane_groups, lane_params, mask_fn,
                                  member_ts, floor, len(plans),
                                  use_kernel=use_kernel,
                                  interpret=interpret)
        tock(_DISPATCH_H, t0)
        t0 = tick()
        results = []
        for p_i, plan in enumerate(plans):
            if isinstance(plan, GroupByPlan):
                results.append(tuple(
                    tuple(finalize_agg(
                        rows[lane_of[(p_i, _op_config(op), g)]], op)
                        for op in plan.ops)
                    for g in range(len(plan.key_groups))))
            elif isinstance(plan, MultiAggPlan):
                results.append(tuple(finalize_agg(
                    rows[lane_of[(p_i, _op_config(op), 0)]], op)
                    for op in plan.ops))
            else:
                assert isinstance(plan, AggPlan), plan
                results.append(finalize_agg(
                    rows[lane_of[(p_i, _op_config(plan.op), 0)]], plan.op))
        tock(_FINALIZE_H, t0)
        return results, writers

    def execute_with_writers(self, plan, snapshot, *,
                             use_kernel: bool = True,
                             interpret=None,
                             need_writers: bool = True) -> tuple:
        """The paged store's ONE plan-execution seam (what
        `PagedVersionStore.execute_with_writers` delegates to): `ScanPlan`
        takes the batched scan path; aggregate plans first try the
        materialized-view registry (`register_view` — O(delta) serve when
        the snapshot gate holds, whole batches all-or-nothing), then
        lower to the fused kernels — `AggPlan`/`MultiAggPlan` to
        `rss_scan_agg` (one pass per
        distinct field/threshold config, all of a compound's statistics
        from the same pass), `GroupByPlan` to the strategy-dispatched
        grouped reduction (flat accumulator lanes, chunked two-stage, or
        host — `kernels.rss_scan_agg.ops.select_grouped_mode`), and
        `BatchPlan` to ONE fused grouped dispatch for ALL its member
        plans (whole-batch plan fusion: one lane per plan × config ×
        group).  Writers always cover the plan's flat key sequence from
        the same host-side slot resolve, so read-set recording is
        identical for every plan kind; `need_writers=False` (execute-only
        callers: replica serves, benches) skips that O(keys) host resolve
        — on a view hit the serve then does NO per-key work at all."""
        from .version_store import (AggPlan, BatchPlan, GroupByPlan,
                                    MultiAggPlan, ScanPlan, finalize_agg,
                                    plan_keys)

        with TRACER.span("mirror_execute", plan=type(plan).__name__):
            if self.views and not isinstance(plan, ScanPlan):
                served = self._try_views(plan, snapshot, need_writers)
                if served is not None:
                    return served
            if isinstance(plan, ScanPlan):
                self.exec_stats["plans"] += 1
                t0 = tick()
                out = self.scan_with_writers(plan.keys, snapshot)
                tock(_RESOLVE_H, t0)       # a scan IS its visibility resolve
                return out
            if isinstance(plan, BatchPlan):
                self.exec_stats["plans"] += len(plan.plans)
                self.exec_stats["batches"] += 1
                self.exec_stats["batched_plans"] += len(plan.plans)
                results, writers = self._grouped_execute(
                    plan.plans, snapshot, use_kernel=use_kernel,
                    interpret=interpret)
                return tuple(results), writers
            self.exec_stats["plans"] += 1
            if isinstance(plan, GroupByPlan):
                results, writers = self._grouped_execute(
                    [plan], snapshot, use_kernel=use_kernel,
                    interpret=interpret)
                return results[0], writers
            keys = plan_keys(plan)
            t0 = tick()
            with TRACER.span("resolve"):
                pages = self.page_index(keys)
                mask_fn, member_ts, floor = self._snapshot_mask(snapshot)
                writers = self._writers_for(pages, mask_fn)
            tock(_RESOLVE_H, t0)
            ops = (plan.op,) if isinstance(plan, AggPlan) else plan.ops
            t0 = tick()
            with TRACER.span("kernel_dispatch", mode="scalar",
                             configs=len(set(_op_config(op) for op in ops))):
                raws = self._scalar_raws(pages, member_ts, floor, ops,
                                         keys=keys, use_kernel=use_kernel,
                                         interpret=interpret)
            tock(_DISPATCH_H, t0)
            t0 = tick()
            vals = tuple(finalize_agg(raws[_op_config(op)], op)
                         for op in ops)
            tock(_FINALIZE_H, t0)
            if isinstance(plan, AggPlan):
                return vals[0], writers
            assert isinstance(plan, MultiAggPlan), plan
            return vals, writers

    # -------------------------------------------------------- device export
    def jnp_store(self) -> dict:
        """The live mirror as a `{'data','ts'}` paged store for the Pallas
        kernels, pages padded to a sublane multiple (padding pages hold only
        the initial ts=0 slot and decode to 0)."""
        import jax.numpy as jnp

        p = max(self.n_pages, 1)
        pad = (-p) % 8
        data = self.data[:p + pad] if p + pad <= self.data.shape[0] else \
            np.concatenate([self.data[:p],
                            np.zeros((pad,) + self.data.shape[1:], np.int32)])
        ts = self.ts[:p + pad] if p + pad <= self.ts.shape[0] else \
            np.concatenate([self.ts[:p],
                            np.zeros((pad,) + self.ts.shape[1:], np.int32)])
        return {"data": jnp.asarray(data), "ts": jnp.asarray(ts)}
