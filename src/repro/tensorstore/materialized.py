"""Incremental materialized aggregates: commit-time delta folds make hot
OLAP O(delta), not O(table).

A `MaterializedView` pins one registered aggregate plan (`AggPlan` /
`MultiAggPlan` / `GroupByPlan`) to a live device-resident accumulator
tile: `[Lp, 128]` int32, one sublane-aligned row per accumulator lane of
the plan's `_lane_layout` (the same lane decomposition the fused grouped
kernels use), lanes 0..6 = [sum, count, count_below, min, max,
count_above, sum_below].  Every commit the mirror applies is folded into
the tile AT COMMIT TIME by the `rss_delta_fold` kernel — one dense
`[Dp, 128]` buffer of (retract old, apply new) change rows — so serving
the plan costs O(pending delta), independent of how many pages the plan
scans.  The fused full scan stays as the always-correct fallback.

Version supersession without reading old page versions: the view keeps a
host-side contribution shadow (`contrib[lane][key]` = the value currently
folded in, or None when the key's visible value does not participate in
the lane's field).  A commit overwriting a key emits a delta row that
retracts the shadowed old contribution and applies the new one, then
advances the shadow — the mirror's K-slot recycling can drop the old
version whenever it likes, the view never needs it again.

Subtractability split: sum / count / count_below / count_above /
sum_below are linear, so retract-then-apply is exact.  min / max are NOT
subtractable — the fold only TIGHTENS them.  Retracting a value equal to
the lane's attained bound sets a per-lane dirty bit; a serve that needs a
dirty lane's min/max DEMOTES just that lane to a partial rescan of its
own pages (one fused `rss_scan_agg` pass over the affected key range at
the view's watermark), replaces the bound, and clears the bit.

Consistency: views fold every applied commit synchronously, so the tile
always equals the SI prefix at the mirror's watermark.  The mirror's
`view_gate` proves a requested snapshot equals that prefix (every applied
above-floor commit seq is a snapshot member — tracked in
`PagedMirror._recent_seqs`); when it can't, the serve falls back to the
fused scan.  `check_scans` keeps asserting materialized == fused == chain
oracle in-run at every facade.

Overflow ladder (the tile is int32): |contribution| is bounded by
`MAX_CONTRIB` and the pending buffer flushes at `FLUSH_ROWS`, so neither
a fold's row deltas nor their sum can wrap; host int64 shadow sums bound
every additive accumulator lane by `MAX_ACC`.  Any violation permanently
degrades the view to fused-scan fallback (counted) — wrong is worse than
slow.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

_I32 = np.iinfo(np.int32)

# overflow ladder: |contribution| bound, pending-buffer flush threshold,
# additive-accumulator bound.  MAX_CONTRIB * 2 * FLUSH_ROWS and
# MAX_ACC + MAX_CONTRIB * 2 * FLUSH_ROWS both fit int32.
MAX_CONTRIB = 2 ** 20
FLUSH_ROWS = 256
MAX_ACC = 2 ** 30

_EMPTY_LANE = (0, 0, 0, int(_I32.max), int(_I32.min), 0, 0)


def _pad_dim(n: int, floor: int = 8) -> int:
    """Next power-of-two >= max(n, floor): bounds the set of (Lp, Dp)
    shapes the jitted fold sees, so recompiles stay O(log) in view size."""
    p = floor
    while p < n:
        p *= 2
    return p


class MaterializedView:
    """Live incremental accumulator for ONE registered aggregate plan over
    a `PagedMirror`.  Construct via `PagedMirror.register_view` — the
    mirror owns the commit hook, the serve gate, and the hit/fallback
    accounting; the view owns the tile, the contribution shadow, the
    dirty-bit demotion ladder, and the overflow guard."""

    def __init__(self, mirror, plan, *, use_kernel: bool = True,
                 interpret: Optional[bool] = None) -> None:
        from .mirror import _lane_layout, _op_config
        from .version_store import AggPlan, GroupByPlan, MultiAggPlan

        assert isinstance(plan, (AggPlan, MultiAggPlan, GroupByPlan)), plan
        self.mirror = mirror
        self.plan = plan
        self.use_kernel = use_kernel
        self.interpret = interpret
        lane_groups, lane_params, lane_of = _lane_layout([plan])
        for grp in lane_groups:
            if len(set(grp)) != len(grp):
                raise ValueError(
                    "materialized plans need duplicate-free key groups "
                    "(the contribution shadow is keyed per key)")
        self.lane_groups = lane_groups
        self.lane_params = lane_params          # (field, tag_main, tag_alt, thr)
        self.lane_of = lane_of
        self.n_lanes = len(lane_groups)
        self.lp = _pad_dim(self.n_lanes)
        # key -> [(lane, field, effective threshold)]
        self.key_lanes: dict[str, list] = {}
        for lane, (grp, prm) in enumerate(zip(lane_groups, lane_params)):
            field, _tm, _ta, thr = prm
            thr_eff = int(_I32.max) if thr is None else int(thr)
            for k in grp:
                self.key_lanes.setdefault(k, []).append((lane, field, thr_eff))
        # lanes whose plan ops actually read min/max (only these demote)
        ops = plan.ops if hasattr(plan, "ops") else (plan.op,)
        n_groups = len(lane_groups) // max(
            1, len(dict.fromkeys(_op_config(op) for op in ops)))
        self.minmax_lanes = frozenset(
            lane_of[(0, _op_config(op), g)]
            for op in ops if op.kind in ("min", "max")
            for g in range(n_groups))
        # serve/fold state (filled by reseed)
        self.acc = None                         # device [Lp, 128] int32
        self.shadow = None                      # host int64 [n_lanes, 7]
        self.contrib: list[dict] = []
        self._key_seq: dict = {}       # key -> highest folded commit seq
        self.pending: list[tuple] = []
        self.dirty_min: set[int] = set()
        self.dirty_max: set[int] = set()
        self.degraded = False
        self.seed_seq = 0                       # watermark floor of the tile
        self.last_lsn = 0
        self.reseed()

    # ------------------------------------------------------------- seeding
    def reseed(self) -> None:
        """(Re-)materialize the tile from a full SI-prefix scan of the
        mirror at its current watermark — the registration path, and the
        recovery path after anything that invalidates incremental state
        (late registration behind WAL truncation, overflow degradation a
        caller wants to retry after a workload change)."""
        import jax.numpy as jnp

        from .version_store import agg_value

        wm = self.mirror.watermark
        flat_keys = [k for grp in self.lane_groups for k in grp]
        vals = self.mirror._scan(
            flat_keys, lambda ts: np.where(ts <= wm, ts, -1))
        self.contrib = []
        self.shadow = np.zeros((self.n_lanes, 7), np.int64)
        tile = np.zeros((self.lp, 128), np.int32)
        tile[:, :7] = _EMPTY_LANE
        self.degraded = False
        off = 0
        for lane, (grp, prm) in enumerate(zip(self.lane_groups,
                                              self.lane_params)):
            field, _tm, _ta, thr = prm
            thr_eff = int(_I32.max) if thr is None else int(thr)
            contrib = {k: agg_value(v, field)
                       for k, v in zip(grp, vals[off:off + len(grp)])}
            off += len(grp)
            self.contrib.append(contrib)
            xs = [x for x in contrib.values() if x is not None]
            if any(abs(x) > MAX_CONTRIB for x in xs):
                self.degraded = True
            row = [sum(xs), len(xs), sum(1 for x in xs if x < thr_eff),
                   min(xs, default=int(_I32.max)),
                   max(xs, default=int(_I32.min)),
                   sum(1 for x in xs if x > thr_eff),
                   sum(x for x in xs if x < thr_eff)]
            if abs(row[0]) > MAX_ACC or abs(row[6]) > MAX_ACC:
                self.degraded = True
            self.shadow[lane] = row
            if not self.degraded:
                tile[lane, :7] = row
        self.acc = jnp.asarray(tile)
        self._key_seq.clear()
        self.pending = []
        self.dirty_min.clear()
        self.dirty_max.clear()
        self.seed_seq = wm
        self.last_lsn = self.mirror.applied_lsn

    # -------------------------------------------------------- commit fold
    def on_commit(self, rec, seq: int) -> None:
        """Fold one applied commit record: per written key per lane, emit
        a delta row retracting the shadowed old contribution and applying
        the new one, advance the shadow/bounds/dirty-bits, and flush the
        pending buffer through the fold kernel when it fills.  O(writes),
        never O(table)."""
        if self.degraded:
            return
        from .version_store import agg_value

        for key, value in rec.writes:
            lanes = self.key_lanes.get(key)
            if not lanes:
                continue
            if seq < self._key_seq.get(key, 0):
                # a same-key fold arriving below an already-folded seq
                # would retract the newer version; RSS dependency closure
                # should forbid this — degrade rather than serve it
                self.degraded = True
                return
            self._key_seq[key] = seq
            for lane, field, thr_eff in lanes:
                new = agg_value(value, field)
                old = self.contrib[lane].get(key)
                if new == old:
                    continue
                self.contrib[lane][key] = new
                ov, oldv = (0, 0) if old is None else (1, int(old))
                nv, newv = (0, 0) if new is None else (1, int(new))
                if abs(newv) > MAX_CONTRIB:
                    self.degraded = True
                    return
                self.pending.append((lane, oldv, ov, newv, nv, thr_eff))
                sh = self.shadow[lane]
                sh[0] += newv * nv - oldv * ov
                sh[1] += nv - ov
                sh[2] += nv * (newv < thr_eff) - ov * (oldv < thr_eff)
                sh[5] += nv * (newv > thr_eff) - ov * (oldv > thr_eff)
                sh[6] += (newv * nv * (newv < thr_eff)
                          - oldv * ov * (oldv < thr_eff))
                if abs(sh[0]) > MAX_ACC or abs(sh[6]) > MAX_ACC:
                    self.degraded = True
                    return
                # min/max only tighten on device: retracting the attained
                # bound makes the lane's bound stale -> dirty
                if ov and oldv == sh[3]:
                    self.dirty_min.add(lane)
                if ov and oldv == sh[4]:
                    self.dirty_max.add(lane)
                if nv:
                    sh[3] = min(sh[3], newv)
                    sh[4] = max(sh[4], newv)
        self.seed_seq = seq
        self.last_lsn = rec.lsn
        if len(self.pending) >= FLUSH_ROWS:
            self._flush()

    def _flush(self) -> None:
        """Fold the pending delta rows into the device tile — ONE
        `rss_delta_fold` launch over a dense padded [Dp, 128] buffer."""
        if not self.pending:
            return
        from ..kernels.rss_scan_agg import ops as kops

        dp = _pad_dim(len(self.pending))
        delta = np.zeros((dp, 128), np.int32)
        delta[:, 0] = -1                        # padding rows fold nowhere
        delta[:len(self.pending), :6] = np.asarray(self.pending, np.int32)
        self.acc = kops.delta_fold(self.acc, delta,
                                   use_kernel=self.use_kernel,
                                   interpret=self.interpret)
        self.pending = []

    # -------------------------------------------------------------- serve
    def _demote(self, lanes: list[int]) -> None:
        """Dirty-bit demotion: partial rescan of ONLY the dirty lanes'
        pages (one fused member-ts pass per lane at the view's fold
        visibility — floor plus folded member seqs), replacing the
        lane's min/max and clearing its bits.  Counted per lane on the
        mirror's exec stats."""
        import jax.numpy as jnp

        from ..kernels.rss_scan_agg.ops import snapshot_agg_members

        floor = self.mirror._seqs_floor
        members = np.asarray(self.mirror._folded_seqs, np.int32)
        for lane in lanes:
            field, tag_main, tag_alt, thr = self.lane_params[lane]
            pages = self.mirror.page_index(self.lane_groups[lane])
            raw = snapshot_agg_members(
                self.mirror.jnp_store_for(pages), members, floor,
                tag_main=tag_main, tag_alt=tag_alt, threshold=thr,
                use_kernel=self.use_kernel, interpret=self.interpret)
            self.shadow[lane, 3], self.shadow[lane, 4] = raw[3], raw[4]
            self.acc = self.acc.at[lane, 3].set(jnp.int32(raw[3])) \
                               .at[lane, 4].set(jnp.int32(raw[4]))
            self.dirty_min.discard(lane)
            self.dirty_max.discard(lane)
            self.mirror.exec_stats["view_demotions"] += 1

    def serve_rows(self) -> list[list[int]]:
        """The tile's lane rows as Python ints — only valid AFTER the
        mirror's `view_gate` proved the requested snapshot equals the SI
        prefix at the watermark.  Flushes pending deltas, demotes any
        dirty lane whose min/max the plan actually reads, and returns
        [lane][sum, count, count_below, min, max, count_above,
        sum_below]."""
        assert not self.degraded
        self._flush()
        dirty = sorted((self.dirty_min | self.dirty_max)
                       & self.minmax_lanes)
        if dirty:
            self._demote(dirty)
        arr = np.asarray(self.acc)
        return [[int(x) for x in arr[lane, :7]]
                for lane in range(self.n_lanes)]

    def result(self):
        """Serve the registered plan from the tile (post-gate): assembled
        exactly like the fused path's finalize stage, so results are
        indistinguishable from a full scan."""
        from .mirror import _op_config
        from .version_store import (AggPlan, GroupByPlan, MultiAggPlan,
                                    finalize_agg)

        rows = self.serve_rows()
        plan = self.plan
        if isinstance(plan, GroupByPlan):
            return tuple(
                tuple(finalize_agg(rows[self.lane_of[(0, _op_config(op), g)]],
                                   op) for op in plan.ops)
                for g in range(len(plan.key_groups)))
        if isinstance(plan, MultiAggPlan):
            return tuple(finalize_agg(rows[self.lane_of[(0, _op_config(op),
                                                         0)]], op)
                         for op in plan.ops)
        assert isinstance(plan, AggPlan), plan
        return finalize_agg(rows[self.lane_of[(0, _op_config(plan.op), 0)]],
                            plan.op)

    # ---------------------------------------------------------------- misc
    @property
    def watermark(self) -> tuple[int, int]:
        """(commit seq, lsn) horizon of the tile — every commit the mirror
        applied through this point is folded in."""
        return (self.seed_seq, self.last_lsn)
