"""RSS-versioned tensor stores (the paper's technique at the ML boundary)."""

from .versioned import VersionedParamStore
from .paged import (init_store, visible_slots, snapshot_read_ref,
                    visible_slots_members, snapshot_read_members,
                    publish_page, as_page_range, gather_pages)
from .materialized import MaterializedView
from .mirror import PagedMirror, decode_value, encode_value
from .version_store import (AggOp, AggPlan, BatchPlan, ChainVersionStore,
                            GroupByPlan, MultiAggPlan, PagedVersionStore,
                            Plan, ScanPlan, VersionStore, agg_value,
                            apply_agg, apply_plan, finalize_agg, group_by,
                            plan_keys)

__all__ = [
    "VersionedParamStore",
    "init_store", "visible_slots", "snapshot_read_ref",
    "visible_slots_members", "snapshot_read_members", "publish_page",
    "as_page_range", "gather_pages",
    "PagedMirror", "MaterializedView", "encode_value", "decode_value",
    "VersionStore", "ChainVersionStore", "PagedVersionStore",
    "AggOp", "AggPlan", "BatchPlan", "MultiAggPlan", "GroupByPlan",
    "ScanPlan", "Plan",
    "agg_value", "apply_agg", "apply_plan", "finalize_agg", "group_by",
    "plan_keys",
]
