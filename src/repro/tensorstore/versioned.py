"""Versioned parameter store with RSS snapshot export — the paper's
multinode architecture mapped onto the training/serving boundary.

Roles (mirrors Sec 5.1):
  * the TRAINER (OLTP primary) publishes committed parameter versions and
    appends begin/commit/abort (+ rw-dependency) records to a WAL,
  * the SERVING pod (OLAP replica) replays the WAL through `RSSManager`
    (Algorithm 1) and reads *pinned* RSS snapshots — wait-free and
    abort-free: `pin_snapshot()` never blocks publishers, `publish()` never
    invalidates pinned readers,
  * slot GC honours reader pins (PostgreSQL hot_standby_feedback analogue):
    a slot is recyclable only when no pin references it and a newer RSS
    snapshot exists.

Snapshot pinning is a host-side buffer selection (zero device copies) — the
TPU adaptation of "reading the prepared view": the expensive page-granular
path (interleaved in-flight versions) is `repro.tensorstore.paged` +
the `version_gather` Pallas kernel.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

from ..core.replica import RSSManager, RssSnapshot
from ..core.wal import Wal
from ..obs import REGISTRY, StatsView


@dataclass
class _Slot:
    txn_id: int = 0            # writer transaction (0 = initial version)
    commit_lsn: int = 0
    params: Any = None
    pins: int = 0
    valid: bool = False


class VersionedParamStore:
    """K-slot ring of full parameter versions + RSS watermark export."""

    def __init__(self, *, slots: int = 2, wal: Optional[Wal] = None) -> None:
        assert slots >= 1
        self.wal = wal if wal is not None else Wal()
        self.rss = RSSManager()
        self.slots: list[_Slot] = [_Slot() for _ in range(slots)]
        self._txn_ids = itertools.count(1)
        self._pin_ids = itertools.count(1)
        self._pins: dict[int, int] = {}       # pin id -> slot index
        self.stats = StatsView(REGISTRY, "param_store",
                               ("publishes", "gc_blocked", "pins"),
                               labels={"store": REGISTRY.scope("pstore")})

    # --------------------------------------------------------------- writers
    def begin_txn(self) -> int:
        tid = next(self._txn_ids)
        self.wal.log_begin(tid)
        return tid

    def publish(self, params, *, txn_id: Optional[int] = None,
                out_rw: tuple[int, ...] = ()) -> int:
        """Commit a new parameter version.  Wait-free w.r.t. readers: if every
        slot is pinned or is the newest visible version, publishing *extends*
        the ring rather than blocking (bounded by reader count)."""
        tid = self.begin_txn() if txn_id is None else txn_id
        slot = self._free_slot()
        if slot is None:
            self.stats["gc_blocked"] += 1
            slot = _Slot()
            self.slots.append(slot)           # grow rather than wait/abort
        rec = self.wal.log_commit(tid)
        if out_rw:
            self.wal.log_deps(tid, list(out_rw))
        slot.txn_id, slot.commit_lsn = tid, rec.lsn
        slot.params, slot.valid, slot.pins = params, True, 0
        self.stats["publishes"] += 1
        return tid

    def _newest_visible(self, snap: RssSnapshot) -> Optional[_Slot]:
        best = None
        commit_seq = self.rss.commit_seq
        for s in self.slots:
            # compressed snapshots fold Clear members into floor_seq, so
            # membership needs the writer's commit seq (resolved through
            # this store's own RSS manager — never GC'd here)
            if s.valid and (s.txn_id == 0
                            or snap.visible(s.txn_id,
                                            commit_seq.get(s.txn_id))):
                if best is None or s.commit_lsn > best.commit_lsn:
                    best = s
        return best

    def _newest(self) -> Optional[_Slot]:
        best = None
        for s in self.slots:
            if s.valid and (best is None or s.commit_lsn > best.commit_lsn):
                best = s
        return best

    def _free_slot(self) -> Optional[_Slot]:
        newest = self._newest()
        for s in self.slots:
            if not s.valid:
                return s
        for s in self.slots:
            if s.pins == 0 and s is not newest:
                return s                      # recycle oldest unpinned
        return None

    # --------------------------------------------------------------- readers
    def refresh(self) -> RssSnapshot:
        """Replica-side: replay WAL, run Algorithm 1."""
        self.rss.catch_up(self.wal)
        return self.rss.construct()

    def pin_snapshot(self) -> tuple[int, Any]:
        """Wait-free protected read: pin the newest version inside the
        current RSS.  Returns (pin_id, params)."""
        snap = self.rss.snapshot
        slot = self._newest_visible(snap)
        if slot is None:
            raise RuntimeError("no committed version inside RSS yet; "
                               "call refresh() after the first publish")
        slot.pins += 1
        pid = next(self._pin_ids)
        self._pins[pid] = self.slots.index(slot)
        self.stats["pins"] += 1
        return pid, slot.params

    def release(self, pin_id: int) -> None:
        idx = self._pins.pop(pin_id, None)
        if idx is not None:
            self.slots[idx].pins = max(self.slots[idx].pins - 1, 0)

    # ------------------------------------------------------------------ info
    @property
    def n_slots(self) -> int:
        return len(self.slots)

    def visible_lsn(self) -> int:
        slot = self._newest_visible(self.rss.snapshot)
        return 0 if slot is None else slot.commit_lsn

    def freshness_lag(self) -> int:
        """LSNs between the newest committed version and the newest
        RSS-visible version — the staleness RSS trades for wait-freedom."""
        newest = self._newest()
        return 0 if newest is None else newest.commit_lsn - self.visible_lsn()
