"""VersionStore: one read interface over the Python chain store and the
device-resident paged mirror.

The HTAP stack has two multiversion stores with the same visibility
semantics but different shapes:

  * `mvcc.store.Store` — per-key Python version chains (the PostgreSQL-heap
    analogue; the engine's source of truth),
  * `tensorstore.mirror.PagedMirror` — the WAL-mirrored K-slot paged store
    (the Pallas-kernel-shaped OLAP surface).

`VersionStore` unifies them behind four operations:

  * point read at a watermark        (SI-V prefix visibility),
  * point read under RSS membership  (the paper's protected read),
  * **batched snapshot scan** over a key sequence — ONE visibility
    resolution for the whole read set instead of N per-key walks,
  * **plan execution** — the query-plan IR of the device-resident OLAP
    executor: `ScanPlan` (materialize the visible values), `AggPlan`
    (reduce a tagged field of the visible values: sum / count /
    count-below / min / max), `MultiAggPlan` (a compound of several
    statistics over ONE read set, e.g. sum+count for AVG, served by a
    single visibility pass — the kernel computes all five lanes anyway),
    and `GroupByPlan` (GROUP BY: per-group key sequences reduced to a
    small [groups × ops] tile in one fused pass).  `BatchPlan` fuses
    several same-horizon aggregate plans into ONE kernel launch
    (whole-batch plan fusion — the device half of cross-reader
    batching).  `ChainVersionStore`
    executes plans on the per-key Python path (the oracle);
    `PagedVersionStore` lowers aggregate plans to the fused
    `rss_scan_agg` Pallas kernels, so results come back as a handful of
    scalars — page payloads never decode back to Python.

`execute(plan, snapshot)` is the ONE OLAP seam every layer above exposes
(engine, HTAP facades, replica, cluster, driver): new plan kinds are a
one-layer change here plus a kernel lowering, never a new method pair at
six layers.

Snapshots are either an int commit-seq watermark or an exported
`RssSnapshot`; `scan()`/`execute()` dispatch on the type.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Protocol, Sequence, Union, runtime_checkable

from ..core.replica import RssSnapshot
from .mirror import PagedMirror

Snapshot = Union[int, RssSnapshot]


# ------------------------------------------------------------- query-plan IR
@dataclass(frozen=True)
class AggOp:
    """One aggregate over a tagged scalar field of the visible values.

    kind:  "sum" | "count" | "count_below" | "min" | "max" |
           "count_above" | "sum_below"
    field: "int"   — plain integer values (an unwritten/initial key IS the
                     int 0, so it participates — matching the per-key
                     oracle's `isinstance(v, int)` test),
           "total" — the "total" field of order-shaped dict values.
    threshold: the predicate bound of the thresholded kinds — count_below
               and sum_below take x < threshold, count_above takes
               x > threshold (predicate pushdown through the one
               (field, threshold) kernel-config seam).
    """
    kind: str
    field: str = "int"
    threshold: Optional[int] = None


@dataclass(frozen=True)
class ScanPlan:
    keys: tuple[str, ...]


@dataclass(frozen=True)
class AggPlan:
    keys: tuple[str, ...]
    op: AggOp


@dataclass(frozen=True)
class MultiAggPlan:
    """Compound multi-statistic plan: several `AggOp`s over ONE key
    sequence, answered from a single visibility resolve (the fused kernel
    emits all five statistic lanes per pass, so e.g. AVG = sum+count costs
    one device pass, not two).  Result: a tuple of ints aligned with
    `ops`."""
    keys: tuple[str, ...]
    ops: tuple[AggOp, ...]


@dataclass(frozen=True)
class GroupByPlan:
    """Grouped aggregate (GROUP BY district / warehouse / ...): group i is
    the key sequence `key_groups[i]`, and every group is reduced under
    every op in ONE fused pass emitting a small [groups × ops] tile.
    Result: a tuple over groups of tuples of ints aligned with `ops`.
    Groups may be empty (count 0, min/max fold to 0) and a key may appear
    in more than one group.  Build from a key-classifier function with
    `group_by`."""
    key_groups: tuple[tuple[str, ...], ...]
    ops: tuple[AggOp, ...]

    @property
    def keys(self) -> tuple[str, ...]:
        """The flat read set, group-major — what read-set recording and
        the per-key oracle walk."""
        return tuple(k for grp in self.key_groups for k in grp)


@dataclass(frozen=True)
class BatchPlan:
    """Whole-batch plan fusion: several aggregate-shaped plans sharing ONE
    snapshot horizon, lowered to a single fused kernel launch — one
    visibility resolve, one pass over the pages, one accumulator lane per
    (plan, kernel config, group) — instead of one launch per plan.  This
    is the device half of cross-reader batching: PRoT pin sharing already
    hands same-horizon readers the same `RssSnapshot` object, and a
    `BatchPlan` lets their plans ride one kernel dispatch.  Result: a
    tuple of per-plan results in `plans` order, each exactly what the
    plan would return unbatched.  `ScanPlan`s don't batch (they
    materialize values, not lanes)."""
    plans: tuple[Plan, ...]

    def __post_init__(self) -> None:
        assert self.plans, "empty BatchPlan"
        for p in self.plans:
            assert isinstance(p, (AggPlan, MultiAggPlan, GroupByPlan)), \
                f"BatchPlan takes aggregate plans, not {type(p).__name__}"

    @property
    def keys(self) -> tuple[str, ...]:
        """Flat read set: every member plan's keys, plan-major."""
        return tuple(k for p in self.plans for k in plan_keys(p))


Plan = Union[ScanPlan, AggPlan, MultiAggPlan, GroupByPlan, BatchPlan]


def plan_keys(plan: Plan) -> tuple[str, ...]:
    """Every plan's flat key sequence (group-major for `GroupByPlan`) —
    the read set a plan execution records, in oracle-walk order."""
    return plan.keys


def group_by(keys: Sequence[str], group_key_fn,
             ops: Sequence[AggOp]) -> tuple[tuple, GroupByPlan]:
    """Build a `GroupByPlan` from a key-classifier: groups appear in
    first-appearance order of `group_key_fn(key)`.  Returns (group labels,
    plan) so callers can zip labels with the per-group result rows."""
    groups: dict[Any, list[str]] = {}
    for k in keys:
        groups.setdefault(group_key_fn(k), []).append(k)
    return tuple(groups), GroupByPlan(
        tuple(tuple(g) for g in groups.values()), tuple(ops))


def agg_value(value: Any, field: str) -> Optional[int]:
    """The aggregable scalar of a decoded value under `field`, or None when
    the value does not participate (the Python-side twin of the kernel's
    tag test — `tensorstore.mirror.AGG_FIELD_TAGS` maps fields to payload
    tags)."""
    if field == "int":
        if isinstance(value, int) and not isinstance(value, bool):
            return int(value)
        return None
    if field == "total":
        if isinstance(value, dict) and "total" in value:
            return int(value["total"])
        return None
    raise ValueError(f"unknown aggregate field {field!r}")


def apply_agg(values: Sequence[Any], op: AggOp) -> int:
    """Reduce decoded values under `op` — the per-key oracle the fused
    kernel path must equal bitwise."""
    xs = [x for v in values if (x := agg_value(v, op.field)) is not None]
    if op.kind == "sum":
        return sum(xs)
    if op.kind == "count":
        return len(xs)
    if op.kind == "count_below":
        assert op.threshold is not None, "count_below needs a threshold"
        return sum(1 for x in xs if x < op.threshold)
    if op.kind == "count_above":
        assert op.threshold is not None, "count_above needs a threshold"
        return sum(1 for x in xs if x > op.threshold)
    if op.kind == "sum_below":
        assert op.threshold is not None, "sum_below needs a threshold"
        return sum(x for x in xs if x < op.threshold)
    if op.kind == "min":
        return min(xs, default=0)
    if op.kind == "max":
        return max(xs, default=0)
    raise ValueError(f"unknown aggregate kind {op.kind!r}")


def apply_plan(values: Sequence[Any], plan: Plan) -> Any:
    """Host-side plan application over the flat scanned values (in
    `plan_keys` order) — the per-key oracle every fused lowering must
    equal bitwise.  `ScanPlan` -> list of values; `AggPlan` -> int;
    `MultiAggPlan` -> tuple[int] per op; `GroupByPlan` -> tuple over
    groups of tuple[int] per op."""
    if isinstance(plan, ScanPlan):
        return list(values)
    if isinstance(plan, AggPlan):
        return apply_agg(values, plan.op)
    if isinstance(plan, MultiAggPlan):
        return tuple(apply_agg(values, op) for op in plan.ops)
    if isinstance(plan, GroupByPlan):
        out, i = [], 0
        for grp in plan.key_groups:
            gvals = values[i:i + len(grp)]
            i += len(grp)
            out.append(tuple(apply_agg(gvals, op) for op in plan.ops))
        return tuple(out)
    if isinstance(plan, BatchPlan):
        out, i = [], 0
        for p in plan.plans:
            pk = plan_keys(p)
            out.append(apply_plan(values[i:i + len(pk)], p))
            i += len(pk)
        return tuple(out)
    raise TypeError(f"unknown plan kind {type(plan).__name__}")


def finalize_agg(raw: Sequence[int], op: AggOp) -> int:
    """Pick `op`'s statistic out of the kernel's [sum, count, count_below,
    min, max, count_above, sum_below] vector (min/max fold their empty-set
    sentinels to 0, matching `apply_agg`).  Legacy 5-lane raws still
    finalize every pre-pushdown kind."""
    vals = [int(v) for v in raw]
    s, n, below, mn, mx = vals[:5]
    if op.kind == "sum":
        return s
    if op.kind == "count":
        return n
    if op.kind == "count_below":
        return below
    if op.kind == "min":
        return mn if n else 0
    if op.kind == "max":
        return mx if n else 0
    if op.kind == "count_above":
        return vals[5]
    if op.kind == "sum_below":
        return vals[6]
    raise ValueError(f"unknown aggregate kind {op.kind!r}")


@runtime_checkable
class VersionStore(Protocol):
    def read_at(self, key: str, watermark: int) -> Any: ...

    def read_members(self, key: str, snap: RssSnapshot) -> Any: ...

    def scan_at(self, keys: Sequence[str], watermark: int) -> list[Any]: ...

    def scan_members(self, keys: Sequence[str],
                     snap: RssSnapshot) -> list[Any]: ...

    def scan(self, keys: Sequence[str], snapshot: Snapshot) -> list[Any]: ...

    def scan_with_writers(self, keys: Sequence[str], snapshot: Snapshot) \
        -> tuple[list[Any], list[int]]: ...

    def execute(self, plan: Plan, snapshot: Snapshot) -> Any: ...

    def execute_with_writers(self, plan: Plan, snapshot: Snapshot) \
        -> tuple[Any, list[int]]: ...


class _ScanDispatch:
    def scan(self, keys: Sequence[str], snapshot: Snapshot) -> list[Any]:
        if isinstance(snapshot, RssSnapshot):
            return self.scan_members(keys, snapshot)
        return self.scan_at(keys, int(snapshot))

    # ------------------------------------------------------ plan execution
    def execute(self, plan: Plan, snapshot: Snapshot) -> Any:
        """Execute a query plan at a snapshot: a list of values for
        `ScanPlan`, one int for `AggPlan`."""
        return self.execute_with_writers(plan, snapshot)[0]

    def execute_with_writers(self, plan: Plan, snapshot: Snapshot) \
            -> tuple[Any, list[int]]:
        """Default lowering: one batched visibility walk over the plan's
        flat key sequence, then a host-side `apply_plan` — the per-key
        oracle path for every plan kind.  Stores with a device-resident
        image override this to fuse resolve + reduce in one kernel pass.
        The writers always cover every plan key (group-major for
        `GroupByPlan`), so the engine records aggregate read sets exactly
        like scan read sets."""
        vals, writers = self.scan_with_writers(plan_keys(plan), snapshot)
        return apply_plan(vals, plan), writers


class ChainVersionStore(_ScanDispatch):
    """VersionStore over a `mvcc.store.Store` (or anything exposing a
    `chains: dict[str, VersionChain]` mapping).  Reads never materialize
    missing chains: an unwritten key is the initial value 0."""

    def __init__(self, store) -> None:
        self.store = store

    def read_at(self, key: str, watermark: int) -> Any:
        ch = self.store.chains.get(key)
        return ch.visible_at(watermark).value if ch is not None else 0

    def read_members(self, key: str, snap: RssSnapshot) -> Any:
        ch = self.store.chains.get(key)
        return ch.visible_in(snap.visible).value if ch is not None else 0

    def scan_at(self, keys: Sequence[str], watermark: int) -> list[Any]:
        return self.scan_with_writers(keys, watermark)[0]

    def scan_members(self, keys: Sequence[str],
                     snap: RssSnapshot) -> list[Any]:
        return self.scan_with_writers(keys, snap)[0]

    def scan_with_writers(self, keys: Sequence[str], snapshot: Snapshot) \
            -> tuple[list[Any], list[int]]:
        """Batched scan returning (values, writer txn ids) in one chain
        walk — the single visibility-resolution loop `scan_at` and
        `scan_members` delegate to; the writers let the engine record the
        read set without a second per-key pass."""
        chains = self.store.chains
        if isinstance(snapshot, RssSnapshot):
            visible = snapshot.visible
            resolve = lambda ch: ch.visible_in(visible)
        else:
            wm = int(snapshot)
            resolve = lambda ch: ch.visible_at(wm)
        vals, writers = [], []
        for key in keys:
            ch = chains.get(key)
            if ch is None:
                vals.append(0)
                writers.append(0)
            else:
                v = resolve(ch)
                vals.append(v.value)
                writers.append(v.writer)
        return vals, writers


class PagedVersionStore(_ScanDispatch):
    """VersionStore over the WAL-mirrored paged store: scans are single
    vectorized visibility passes (`version_gather`/`rss_gather` algorithm);
    `mirror.jnp_store()` exposes the same state to the Pallas kernels, and
    aggregate plans (`AggPlan`/`MultiAggPlan`/`GroupByPlan`) lower to the
    fused `rss_scan_agg` kernel family via
    `PagedMirror.execute_with_writers` — visibility resolve + reduction in
    one device pass per kernel config over the plan's page range."""

    def __init__(self, mirror: PagedMirror) -> None:
        self.mirror = mirror

    def execute_with_writers(self, plan: Plan, snapshot: Snapshot) \
            -> tuple[Any, list[int]]:
        return self.mirror.execute_with_writers(plan, snapshot)

    def execute(self, plan: Plan, snapshot: Snapshot) -> Any:
        """Execute-only fast path: no writer resolve — a materialized-view
        hit serves with NO per-key host work (the replica/bench serve
        path, where nothing records read sets)."""
        return self.mirror.execute_with_writers(plan, snapshot,
                                                need_writers=False)[0]

    def register_view(self, plan: Plan, *, use_kernel: bool = True,
                      interpret=None):
        """Register `plan` for incremental materialization on the backing
        mirror (see `tensorstore.materialized`)."""
        return self.mirror.register_view(plan, use_kernel=use_kernel,
                                         interpret=interpret)

    def read_at(self, key: str, watermark: int) -> Any:
        return self.mirror.read_at(key, watermark)

    def read_members(self, key: str, snap: RssSnapshot) -> Any:
        return self.mirror.read_members(key, snap)

    def scan_at(self, keys: Sequence[str], watermark: int) -> list[Any]:
        return self.mirror.scan_at(keys, watermark)

    def scan_members(self, keys: Sequence[str],
                     snap: RssSnapshot) -> list[Any]:
        return self.mirror.scan_members(keys, snap)

    def scan_with_writers(self, keys: Sequence[str], snapshot: Snapshot) \
            -> tuple[list[Any], list[int]]:
        return self.mirror.scan_with_writers(keys, snapshot)
