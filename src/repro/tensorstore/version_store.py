"""VersionStore: one read interface over the Python chain store and the
device-resident paged mirror.

The HTAP stack has two multiversion stores with the same visibility
semantics but different shapes:

  * `mvcc.store.Store` — per-key Python version chains (the PostgreSQL-heap
    analogue; the engine's source of truth),
  * `tensorstore.mirror.PagedMirror` — the WAL-mirrored K-slot paged store
    (the Pallas-kernel-shaped OLAP surface).

`VersionStore` unifies them behind three operations:

  * point read at a watermark        (SI-V prefix visibility),
  * point read under RSS membership  (the paper's protected read),
  * **batched snapshot scan** over a key sequence — ONE visibility
    resolution for the whole read set instead of N per-key walks; this is
    the OLAP hot path the driver routes through.

Snapshots are either an int commit-seq watermark or an exported
`RssSnapshot`; `scan()` dispatches on the type.
"""

from __future__ import annotations

from typing import Any, Protocol, Sequence, Union, runtime_checkable

from ..core.replica import RssSnapshot
from .mirror import PagedMirror

Snapshot = Union[int, RssSnapshot]


@runtime_checkable
class VersionStore(Protocol):
    def read_at(self, key: str, watermark: int) -> Any: ...

    def read_members(self, key: str, snap: RssSnapshot) -> Any: ...

    def scan_at(self, keys: Sequence[str], watermark: int) -> list[Any]: ...

    def scan_members(self, keys: Sequence[str],
                     snap: RssSnapshot) -> list[Any]: ...

    def scan(self, keys: Sequence[str], snapshot: Snapshot) -> list[Any]: ...

    def scan_with_writers(self, keys: Sequence[str], snapshot: Snapshot) \
        -> tuple[list[Any], list[int]]: ...


class _ScanDispatch:
    def scan(self, keys: Sequence[str], snapshot: Snapshot) -> list[Any]:
        if isinstance(snapshot, RssSnapshot):
            return self.scan_members(keys, snapshot)
        return self.scan_at(keys, int(snapshot))


class ChainVersionStore(_ScanDispatch):
    """VersionStore over a `mvcc.store.Store` (or anything exposing a
    `chains: dict[str, VersionChain]` mapping).  Reads never materialize
    missing chains: an unwritten key is the initial value 0."""

    def __init__(self, store) -> None:
        self.store = store

    def read_at(self, key: str, watermark: int) -> Any:
        ch = self.store.chains.get(key)
        return ch.visible_at(watermark).value if ch is not None else 0

    def read_members(self, key: str, snap: RssSnapshot) -> Any:
        ch = self.store.chains.get(key)
        return ch.visible_in(snap.visible).value if ch is not None else 0

    def scan_at(self, keys: Sequence[str], watermark: int) -> list[Any]:
        return self.scan_with_writers(keys, watermark)[0]

    def scan_members(self, keys: Sequence[str],
                     snap: RssSnapshot) -> list[Any]:
        return self.scan_with_writers(keys, snap)[0]

    def scan_with_writers(self, keys: Sequence[str], snapshot: Snapshot) \
            -> tuple[list[Any], list[int]]:
        """Batched scan returning (values, writer txn ids) in one chain
        walk — the single visibility-resolution loop `scan_at` and
        `scan_members` delegate to; the writers let the engine record the
        read set without a second per-key pass."""
        chains = self.store.chains
        if isinstance(snapshot, RssSnapshot):
            visible = snapshot.visible
            resolve = lambda ch: ch.visible_in(visible)
        else:
            wm = int(snapshot)
            resolve = lambda ch: ch.visible_at(wm)
        vals, writers = [], []
        for key in keys:
            ch = chains.get(key)
            if ch is None:
                vals.append(0)
                writers.append(0)
            else:
                v = resolve(ch)
                vals.append(v.value)
                writers.append(v.writer)
        return vals, writers


class PagedVersionStore(_ScanDispatch):
    """VersionStore over the WAL-mirrored paged store: scans are single
    vectorized visibility passes (`version_gather`/`rss_gather` algorithm);
    `mirror.jnp_store()` exposes the same state to the Pallas kernels."""

    def __init__(self, mirror: PagedMirror) -> None:
        self.mirror = mirror

    def read_at(self, key: str, watermark: int) -> Any:
        return self.mirror.read_at(key, watermark)

    def read_members(self, key: str, snap: RssSnapshot) -> Any:
        return self.mirror.read_members(key, snap)

    def scan_at(self, keys: Sequence[str], watermark: int) -> list[Any]:
        return self.mirror.scan_at(keys, watermark)

    def scan_members(self, keys: Sequence[str],
                     snap: RssSnapshot) -> list[Any]:
        return self.mirror.scan_members(keys, snap)

    def scan_with_writers(self, keys: Sequence[str], snapshot: Snapshot) \
            -> tuple[list[Any], list[int]]:
        return self.mirror.scan_with_writers(keys, snapshot)
