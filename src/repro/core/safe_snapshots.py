"""Safe snapshots (Ports & Grittner) — the paper's principal baseline.

PostgreSQL's READ ONLY DEFERRABLE transactions wait for a *safe snapshot*: a
snapshot taken at a moment when no concurrent read/write transaction is
active (then the read-only transaction can never be part of a dangerous
structure, so SSI validation can be skipped entirely).

This module provides the prefix-level predicate and the reader-wait oracle
used by the `mvcc` engine's SSI+SafeSnapshots mode and by benchmarks to
account reader-wait time — the cost RSS eliminates.
"""

from __future__ import annotations

from .history import History


def snapshot_is_safe(h: History, *, read_only: set[int] = frozenset()) -> bool:
    """True iff taking a snapshot at the current prefix end is *safe*: there
    is no active (begun, unended) read/write transaction.

    `read_only` lists txn ids known to be read-only (they never endanger a
    deferrable snapshot).
    """
    for t in h.active():
        if t not in read_only:
            return False  # any active (potential) writer makes it unsafe
    return True


def earliest_safe_point(h: History, from_pos: int,
                        *, read_only: set[int] = frozenset()) -> int | None:
    """The earliest prefix length >= from_pos at which a snapshot is safe.

    Returns None if no safe point exists within the history (the deferrable
    transaction would still be waiting at the end) — unbounded reader-wait,
    the pathology the paper's Sec. 2.2/6.1 describes.
    """
    for n in range(from_pos, len(h.ops) + 1):
        if snapshot_is_safe(h.prefix(n), read_only=read_only):
            return n
    return None


def reader_wait(h: History, request_pos: int,
                *, read_only: set[int] = frozenset()) -> int | None:
    """Number of history positions a deferrable read-only transaction
    requested at `request_pos` must wait before its snapshot is safe.
    None == never within this history."""
    pt = earliest_safe_point(h, request_pos, read_only=read_only)
    return None if pt is None else pt - request_pos
