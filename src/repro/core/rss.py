"""Read Safe Snapshot (RSS): Definitions 4.1/4.2, Algorithm 1 and oracles.

The executable artifacts:
  * `is_rss(h, P)`            — Definition 4.1 checker (oracle, brute force)
  * `clear_set / done_set`    — Definition 4.6 transaction states
  * `construct_rss_ssi(...)`  — Algorithm 1 (SSI-based construction) given
                                only begin/commit/abort events and the
                                concurrent-rw (vulnerable) edges observed so
                                far — exactly the information the paper ships
                                through the WAL.
  * `protected_read(...)`     — build a PRoT (Def 4.2) reading the
                                most-recent-in-P version of each key.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Iterable, Mapping, Sequence

from .dsg import build_dsg
from .history import History, Op, READ, T0, b, c, r


# --------------------------------------------------------------------- oracle
def is_rss(h: History, P: set[int]) -> bool:
    """Definition 4.1: P is RSS iff for all Tp in P and committed Tq not in P,
    Tp is unreachable from Tq in the DSG of h's committed projection."""
    committed = h.committed
    if not P <= committed:
        return False
    g = build_dsg(h)
    outside = committed - P
    for q in outside:
        if g.reachable_from(q) & P:
            return False
    return True


def rss_violations(h: History, P: set[int]) -> list[tuple[int, int]]:
    """(Tq outside, Tp inside) witnesses that P is not an RSS of h."""
    g = build_dsg(h)
    out = []
    for q in h.committed - P:
        hit = g.reachable_from(q) & P
        for p in sorted(hit):
            out.append((q, p))
    return out


# --------------------------------------------------- Definition 4.6: states
def done_set(h: History) -> set[int]:
    """Done(p): transactions whose End (commit or abort) is in the prefix."""
    return {t for t in h.txns if h.end_pos(t) < (1 << 62)}


def clear_set(h: History) -> set[int]:
    """Clear(p): Ta with End(Ta) preceding Begin(Tb) of every not-Done Tb.

    Only committed transactions are returned (aborted ones can never be part
    of an RSS; their ops leave the committed projection).
    """
    done = done_set(h)
    not_done = h.txns - done
    if not_done:
        horizon = min(h.begin_pos(t) for t in not_done)
    else:
        horizon = 1 << 62
    return {t for t in h.committed if h.end_pos(t) < horizon}


def obscure_set(h: History) -> set[int]:
    """Done but not Clear (possibly concurrent with an active transaction)."""
    return (done_set(h) & h.committed) - clear_set(h)


# ------------------------------------------------------------- Algorithm 1
def construct_rss_ssi(
    clear: set[int],
    committed: set[int],
    rw_edges: Iterable[tuple[int, int]],
) -> set[int]:
    """Algorithm 1 (paper Sec 4.2) on pre-extracted state.

      (1) contain the entire Clear(p) in RSS
      (2)-(5) for every dependency edge Tu -> Tc with Tc in Clear(p) and
              Tu not in Clear(p), add Tu to RSS.

    Per Lemma 4.9 every such incoming edge is a *vulnerable* (concurrent rw)
    dependency, so tracking only SSI's rw-conflict list suffices — this is the
    cost reduction the paper claims.  Tu must itself be committed (Fig. 2:
    uncommitted or aborted transactions never join RSS).
    """
    rss = set(clear)
    for tu, tc in rw_edges:
        if tc in clear and tu not in clear and tu in committed:
            rss.add(tu)
    return rss


def construct_rss(h: History) -> set[int]:
    """Algorithm 1 driven directly from a history prefix.

    Uses only the information the WAL would carry: begin/end events (for
    Clear/Done) and concurrent rw anti-dependency edges among committed txns.
    """
    from .ssi import vulnerable_edges  # local import to avoid cycle

    clear = clear_set(h)
    edges = [(v.src, v.dst) for v in vulnerable_edges(h)]
    return construct_rss_ssi(clear, h.committed, edges)


# ------------------------------------------------------- PRoT (Def 4.2)
def latest_versions_in(h: History, P: set[int]) -> dict[str, int]:
    """For every key, the writer of the most recent committed version among
    transactions in P (T0 if no P-transaction wrote the key)."""
    latest: dict[str, int] = {}
    keys: set[str] = set()
    for t in h.txns:
        keys |= h.writeset(t)
        keys |= h.readset(t)
    for key in keys:
        latest[key] = T0
    for t in h.commit_order():
        if t in P:
            for key in h.writeset(t):
                latest[key] = t
    return latest


def protected_read(h: History, P: set[int], keys: Sequence[str],
                   txn_id: int) -> list[Op]:
    """Operations of a PRoT (Def 4.2): a read-only transaction reading, for
    each requested key, the most recent committed version in P."""
    latest = latest_versions_in(h, P)
    ops: list[Op] = [b(txn_id)]
    for key in keys:
        ops.append(r(txn_id, key, latest.get(key, T0)))
    ops.append(c(txn_id))
    return ops


def with_protected_reader(h: History, P: set[int], keys: Sequence[str],
                          txn_id: int) -> History:
    """h extended by a PRoT over `keys` — the Theorem 4.4 construction."""
    h2 = History(h.ops)
    h2.extend(protected_read(h, P, keys, txn_id))
    return h2
