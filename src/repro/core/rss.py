"""Read Safe Snapshot (RSS): Definitions 4.1/4.2, Algorithm 1 and oracles.

The executable artifacts:
  * `is_rss(h, P)`            — Definition 4.1 checker (oracle, brute force)
  * `clear_set / done_set`    — Definition 4.6 transaction states
  * `construct_rss_ssi(...)`  — Algorithm 1 (SSI-based construction) given
                                only begin/commit/abort events and the
                                concurrent-rw (vulnerable) edges observed so
                                far — exactly the information the paper ships
                                through the WAL.
  * `IncrementalRss`/`advance` — the same Algorithm 1 applied only to the
                                *delta* of newly-committed/newly-Clear
                                transactions and newly-shipped edges: O(1)
                                amortized per event instead of O(history)
                                per construction round.
  * `protected_read(...)`     — build a PRoT (Def 4.2) reading the
                                most-recent-in-P version of each key.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Iterable, Mapping, Sequence

from .dsg import build_dsg
from .history import History, Op, READ, T0, b, c, r


# --------------------------------------------------------------------- oracle
def is_rss(h: History, P: set[int]) -> bool:
    """Definition 4.1: P is RSS iff for all Tp in P and committed Tq not in P,
    Tp is unreachable from Tq in the DSG of h's committed projection."""
    committed = h.committed
    if not P <= committed:
        return False
    g = build_dsg(h)
    outside = committed - P
    for q in outside:
        if g.reachable_from(q) & P:
            return False
    return True


def rss_violations(h: History, P: set[int]) -> list[tuple[int, int]]:
    """(Tq outside, Tp inside) witnesses that P is not an RSS of h."""
    g = build_dsg(h)
    out = []
    for q in h.committed - P:
        hit = g.reachable_from(q) & P
        for p in sorted(hit):
            out.append((q, p))
    return out


# --------------------------------------------------- Definition 4.6: states
def done_set(h: History) -> set[int]:
    """Done(p): transactions whose End (commit or abort) is in the prefix."""
    return {t for t in h.txns if h.end_pos(t) < (1 << 62)}


def clear_set(h: History) -> set[int]:
    """Clear(p): Ta with End(Ta) preceding Begin(Tb) of every not-Done Tb.

    Only committed transactions are returned (aborted ones can never be part
    of an RSS; their ops leave the committed projection).
    """
    done = done_set(h)
    not_done = h.txns - done
    if not_done:
        horizon = min(h.begin_pos(t) for t in not_done)
    else:
        horizon = 1 << 62
    return {t for t in h.committed if h.end_pos(t) < horizon}


def obscure_set(h: History) -> set[int]:
    """Done but not Clear (possibly concurrent with an active transaction)."""
    return (done_set(h) & h.committed) - clear_set(h)


# ------------------------------------------------------------- Algorithm 1
def construct_rss_ssi(
    clear: set[int],
    committed: set[int],
    rw_edges: Iterable[tuple[int, int]],
) -> set[int]:
    """Algorithm 1 (paper Sec 4.2) on pre-extracted state.

      (1) contain the entire Clear(p) in RSS
      (2)-(5) for every dependency edge Tu -> Tc with Tc in Clear(p) and
              Tu not in Clear(p), add Tu to RSS.

    Per Lemma 4.9 every such incoming edge is a *vulnerable* (concurrent rw)
    dependency, so tracking only SSI's rw-conflict list suffices — this is the
    cost reduction the paper claims.  Tu must itself be committed (Fig. 2:
    uncommitted or aborted transactions never join RSS).
    """
    rss = set(clear)
    for tu, tc in rw_edges:
        if tc in clear and tu not in clear and tu in committed:
            rss.add(tu)
    return rss


class IncrementalRss:
    """Incremental Algorithm 1: equal to ``construct_rss_ssi(clear,
    committed, edges)`` over the cumulative event stream, maintained in O(1)
    amortized per event.

    Events (any interleaving; each is idempotent):
      * ``add_committed(t)`` — Tc's commit observed,
      * ``add_clear(t)``     — Tc entered Clear(p) (caller derives Clear from
                               begin/end ordering; see `RSSManager`),
      * ``add_edge(u, w)``   — concurrent rw antidependency Tu -> Tw shipped.

    Rule (2)-(5) of Algorithm 1 — pull committed Tu with an edge into a Clear
    transaction — is re-checked only for the endpoints an event touches:
    a new edge checks (u, w) directly; a transaction entering Clear drains
    the stashed in-edges (`rw_in`); a late commit of Tu re-checks Tu's
    stashed out-edges.  `rss` only ever grows (the monotonicity Theorem 4.4
    readers rely on).
    """

    def __init__(self) -> None:
        self.rss: set[int] = set()
        self.clear: set[int] = set()
        self.committed: set[int] = set()
        self.rw_out: dict[int, set[int]] = {}   # reader -> shipped writers
        self.rw_in: dict[int, set[int]] = {}    # writer -> shipped readers
        self._new: set[int] = set()             # members added, undrained
        self._pending_pull: set[int] = set()    # pulled before commit seen

    # ------------------------------------------------------------- events
    def _join(self, t: int) -> None:
        if t not in self.rss:
            self.rss.add(t)
            self._new.add(t)

    def add_committed(self, t: int) -> None:
        if t in self.committed:
            return
        self.committed.add(t)
        if t in self._pending_pull:
            self._pending_pull.discard(t)
            self._join(t)
        # edges shipped before the commit (lagged/batched streams)
        for w in self.rw_out.get(t, ()):
            if w in self.clear:
                self._join(t)
                break

    def add_clear(self, t: int) -> None:
        if t in self.clear:
            return
        self.clear.add(t)
        self._join(t)                       # step (1): Clear(p) ⊆ RSS
        for u in self.rw_in.get(t, ()):     # steps (2)-(5): drain in-edges
            if u in self.committed:
                self._join(u)

    def add_edge(self, u: int, w: int) -> None:
        self.rw_out.setdefault(u, set()).add(w)
        self.rw_in.setdefault(w, set()).add(u)
        if w in self.clear and u in self.committed:
            self._join(u)

    def pull(self, u: int) -> None:
        """Force-join a committed reader whose witness writer is no longer
        tracked (the writer's bookkeeping was GC'd below the state
        watermark, which implies it was Clear)."""
        if u in self.committed:
            self._join(u)
        else:
            # commit event not applied yet: joined on add_committed(u)
            self._pending_pull.add(u)

    # ------------------------------------------------------------ draining
    def drain_new(self) -> set[int]:
        """Members added since the last drain (the construction delta)."""
        out, self._new = self._new, set()
        return out

    # ------------------------------------------------------------------ GC
    def forget(self, t: int) -> None:
        """Drop Tt's bookkeeping.  Only safe for transactions already
        resolved below the caller's state watermark (Clear members or
        aborted): their membership is covered by the snapshot floor and no
        future event can reference them as a non-Clear endpoint."""
        self.rss.discard(t)
        self.clear.discard(t)
        self.committed.discard(t)
        self._new.discard(t)
        self._pending_pull.discard(t)
        for w in self.rw_out.pop(t, ()):
            ins = self.rw_in.get(w)
            if ins is not None:
                ins.discard(t)
                if not ins:
                    del self.rw_in[w]
        for u in self.rw_in.pop(t, ()):
            outs = self.rw_out.get(u)
            if outs is not None:
                outs.discard(t)
                if not outs:
                    del self.rw_out[u]


def advance(state: IncrementalRss, *,
            committed: Iterable[int] = (),
            clear: Iterable[int] = (),
            edges: Iterable[tuple[int, int]] = ()) -> set[int]:
    """Apply one delta of events to an `IncrementalRss` and return the set
    of NEW members — Algorithm 1 restricted to the delta.  Feeding every
    prefix delta reproduces `construct_rss_ssi` over the cumulative state
    (property-tested in tests/test_rss_incremental.py)."""
    for t in committed:
        state.add_committed(t)
    for u, w in edges:
        state.add_edge(u, w)
    for t in clear:
        state.add_clear(t)
    return state.drain_new()


def construct_rss(h: History) -> set[int]:
    """Algorithm 1 driven directly from a history prefix.

    Uses only the information the WAL would carry: begin/end events (for
    Clear/Done) and concurrent rw anti-dependency edges among committed txns.
    """
    from .ssi import vulnerable_edges  # local import to avoid cycle

    clear = clear_set(h)
    edges = [(v.src, v.dst) for v in vulnerable_edges(h)]
    return construct_rss_ssi(clear, h.committed, edges)


# ------------------------------------------------------- PRoT (Def 4.2)
def latest_versions_in(h: History, P: set[int]) -> dict[str, int]:
    """For every key, the writer of the most recent committed version among
    transactions in P (T0 if no P-transaction wrote the key)."""
    latest: dict[str, int] = {}
    keys: set[str] = set()
    for t in h.txns:
        keys |= h.writeset(t)
        keys |= h.readset(t)
    for key in keys:
        latest[key] = T0
    for t in h.commit_order():
        if t in P:
            for key in h.writeset(t):
                latest[key] = t
    return latest


def protected_read(h: History, P: set[int], keys: Sequence[str],
                   txn_id: int) -> list[Op]:
    """Operations of a PRoT (Def 4.2): a read-only transaction reading, for
    each requested key, the most recent committed version in P."""
    latest = latest_versions_in(h, P)
    ops: list[Op] = [b(txn_id)]
    for key in keys:
        ops.append(r(txn_id, key, latest.get(key, T0)))
    ops.append(c(txn_id))
    return ops


def with_protected_reader(h: History, P: set[int], keys: Sequence[str],
                          txn_id: int) -> History:
    """h extended by a PRoT over `keys` — the Theorem 4.4 construction."""
    h2 = History(h.ops)
    h2.extend(protected_read(h, P, keys, txn_id))
    return h2
