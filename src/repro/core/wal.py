"""Write-ahead-log records for RSS construction (paper Sec 5.1).

The OLTP side ships, per transaction:
  * BEGIN  (start information; induced by the first operation)
  * COMMIT / ABORT (end information)
  * DEPS   (logical message: the transaction's *outgoing* concurrent
            rw-antidependency edges, written immediately after the reader
            commits — "an array of writer transaction IDs")

Records carry a monotonically increasing LSN assigned by the log. Shipping is
asynchronous (streaming replication); the replica replays records in LSN
order (`repro.core.replica.RSSManager`).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Iterator, Literal, Optional, Sequence

RecordType = Literal["begin", "commit", "abort", "deps"]


def effective_commit_seq(max_seen: int, shipped_seq: int) -> int:
    """THE commit clock every WAL consumer (RSSManager, PagedMirror,
    Replica) derives version stamps from, so their seq mappings stay
    bit-identical.

    Stamped records normally carry a seq above everything seen and keep the
    primary's clock.  A legacy record (shipped_seq == 0) — or a stamped seq
    that collides with / regresses below a locally-minted fallback when
    record kinds mix — takes max(seen) + 1: the clock is strictly monotone
    in apply order, so commit-seq order always equals commit-LSN order
    (floor_seq prefix-safety and VersionChain.install both rely on it)."""
    if shipped_seq > max_seen:
        return shipped_seq
    return max_seen + 1


@dataclass(frozen=True)
class WalRecord:
    lsn: int
    type: RecordType
    txn: int
    # for "deps": ids of writers this (committed reader) txn has outgoing
    # concurrent rw-antidependency edges to.
    out_rw: tuple[int, ...] = ()
    # for "commit": the committed writeset (key, value) — the data payload a
    # physical/logical replication stream ships to replicas.
    writes: tuple[tuple[str, object], ...] = ()
    # for "commit": the primary's commit sequence number (the version
    # timestamp installed into the store).  Lets replicas stamp mirrored
    # versions with the SAME clock the RSS membership mapping uses (0 =
    # unknown / legacy record; replicas then fall back to a local counter).
    seq: int = 0

    def to_json(self) -> str:
        d = {"lsn": self.lsn, "type": self.type, "txn": self.txn}
        if self.type == "deps":
            d["out_rw"] = list(self.out_rw)
        if self.writes:
            d["writes"] = [list(kv) for kv in self.writes]
        if self.seq:
            d["seq"] = self.seq
        return json.dumps(d, separators=(",", ":"))

    @staticmethod
    def from_json(s: str) -> "WalRecord":
        d = json.loads(s)
        return WalRecord(d["lsn"], d["type"], d["txn"],
                         tuple(d.get("out_rw", ())),
                         tuple((k, v) for k, v in d.get("writes", ())),
                         d.get("seq", 0))


class Wal:
    """An append-only in-memory WAL with optional persistence.

    `tail(from_lsn)` is the streaming-replication read path: it yields
    records with lsn > from_lsn, letting a replica poll asynchronously.

    `truncate(up_to_lsn)` is WAL segment recycling: once every consumer
    (RSS manager, paged mirror, replica) has applied a prefix, the primary
    drops it so log state stays bounded by replication lag, not history.
    LSNs keep counting from `base_lsn`; tailing below a truncated prefix is
    an error (a real system would re-seed the replica from a basebackup).

    Multi-consumer accounting (replication slots): `register_consumer`
    declares a named consumer, `ack(name, lsn)` records the prefix it has
    durably applied, and `truncate` then never discards a record any
    registered consumer still needs — the recycle point is clamped to
    `min_acked_lsn()`, the minimum applied LSN across all consumers.  A WAL
    with no registered consumers keeps the legacy single-consumer contract:
    the caller is the only consumer and `truncate(lsn)` is taken at face
    value.
    """

    def __init__(self) -> None:
        self.records: list[WalRecord] = []
        self.base_lsn = 0          # lsn of the newest truncated-away record
        self.consumers: dict[str, int] = {}   # name -> acked (applied) lsn

    @property
    def head_lsn(self) -> int:
        return self.base_lsn + len(self.records)

    def _append(self, type: RecordType, txn: int,
                out_rw: Sequence[int] = (),
                writes: Sequence[tuple[str, object]] = (),
                seq: int = 0) -> WalRecord:
        rec = WalRecord(self.head_lsn + 1, type, txn, tuple(out_rw),
                        tuple(writes), seq)
        self.records.append(rec)
        return rec

    def log_begin(self, txn: int) -> WalRecord:
        return self._append("begin", txn)

    def log_commit(self, txn: int,
                   writes: Sequence[tuple[str, object]] = (),
                   seq: int = 0) -> WalRecord:
        return self._append("commit", txn, writes=writes, seq=seq)

    def log_abort(self, txn: int) -> WalRecord:
        return self._append("abort", txn)

    def log_deps(self, txn: int, out_rw: Sequence[int]) -> WalRecord:
        return self._append("deps", txn, out_rw)

    def tail(self, from_lsn: int) -> Iterator[WalRecord]:
        if from_lsn < self.base_lsn:
            raise LookupError(
                f"WAL truncated to lsn {self.base_lsn}; cannot tail from "
                f"{from_lsn} (re-seed the consumer from a base snapshot)")
        yield from self.records[from_lsn - self.base_lsn:]

    # ---------------------------------------------------- consumer slots
    def register_consumer(self, name: str, *,
                          start_lsn: Optional[int] = None) -> str:
        """Declare a named consumer (replication-slot analogue).  It holds
        the truncation point at `start_lsn` (default: the current base —
        the earliest prefix still tailable) until it acks progress."""
        start = self.base_lsn if start_lsn is None else start_lsn
        if start < self.base_lsn:
            raise LookupError(
                f"WAL truncated to lsn {self.base_lsn}; consumer {name!r} "
                f"cannot start at {start} (re-seed from a base snapshot)")
        self.consumers[name] = start
        return name

    def deregister_consumer(self, name: str) -> None:
        self.consumers.pop(name, None)

    def ack(self, name: str, lsn: int) -> None:
        """Record that `name` has applied the prefix up to `lsn` (monotone:
        a stale ack never moves a slot backwards)."""
        if name not in self.consumers:
            raise KeyError(f"unregistered WAL consumer {name!r}")
        self.consumers[name] = max(self.consumers[name], lsn)

    def min_acked_lsn(self) -> int:
        """The cluster-wide recycle horizon: the minimum applied LSN across
        registered consumers (head when none are registered)."""
        return min(self.consumers.values(), default=self.head_lsn)

    def truncate(self, up_to_lsn: Optional[int] = None) -> int:
        """Drop records with lsn <= up_to_lsn (already applied by every
        consumer); returns the number of records recycled.

        With registered consumers the cut is clamped to `min_acked_lsn()`,
        so no consumer can ever be handed a recycled prefix; passing no
        argument recycles exactly up to that horizon."""
        if up_to_lsn is None:
            up_to_lsn = self.min_acked_lsn()
        elif self.consumers:
            up_to_lsn = min(up_to_lsn, self.min_acked_lsn())
        cut = min(max(up_to_lsn - self.base_lsn, 0), len(self.records))
        if cut:
            del self.records[:cut]
            self.base_lsn += cut
        return cut

    # -------------------------------------------------------- persistence
    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            if self.base_lsn or self.consumers:
                # header so a fully-truncated WAL reloads with its LSN
                # clock intact (no records left to infer it from) and
                # consumer slots survive restarts
                hdr = {"base_lsn": self.base_lsn}
                if self.consumers:
                    hdr["consumers"] = self.consumers
                f.write(json.dumps(hdr) + "\n")
            for rec in self.records:
                f.write(rec.to_json() + "\n")

    @staticmethod
    def load(path: str) -> "Wal":
        wal = Wal()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                d = json.loads(line)
                if "type" not in d:                  # base_lsn header
                    wal.base_lsn = d["base_lsn"]
                    wal.consumers = dict(d.get("consumers", {}))
                else:
                    wal.records.append(WalRecord.from_json(line))
        if wal.records and not wal.base_lsn:
            wal.base_lsn = wal.records[0].lsn - 1    # headerless legacy dump
        return wal
