"""Write-ahead-log records for RSS construction (paper Sec 5.1).

The OLTP side ships, per transaction:
  * BEGIN  (start information; induced by the first operation)
  * COMMIT / ABORT (end information)
  * DEPS   (logical message: the transaction's *outgoing* concurrent
            rw-antidependency edges, written immediately after the reader
            commits — "an array of writer transaction IDs")

Records carry a monotonically increasing LSN assigned by the log. Shipping is
asynchronous (streaming replication); the replica replays records in LSN
order (`repro.core.replica.RSSManager`).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Iterator, Literal, Sequence

RecordType = Literal["begin", "commit", "abort", "deps"]


@dataclass(frozen=True)
class WalRecord:
    lsn: int
    type: RecordType
    txn: int
    # for "deps": ids of writers this (committed reader) txn has outgoing
    # concurrent rw-antidependency edges to.
    out_rw: tuple[int, ...] = ()
    # for "commit": the committed writeset (key, value) — the data payload a
    # physical/logical replication stream ships to replicas.
    writes: tuple[tuple[str, object], ...] = ()
    # for "commit": the primary's commit sequence number (the version
    # timestamp installed into the store).  Lets replicas stamp mirrored
    # versions with the SAME clock the RSS membership mapping uses (0 =
    # unknown / legacy record; replicas then fall back to a local counter).
    seq: int = 0

    def to_json(self) -> str:
        d = {"lsn": self.lsn, "type": self.type, "txn": self.txn}
        if self.type == "deps":
            d["out_rw"] = list(self.out_rw)
        if self.writes:
            d["writes"] = [list(kv) for kv in self.writes]
        if self.seq:
            d["seq"] = self.seq
        return json.dumps(d, separators=(",", ":"))

    @staticmethod
    def from_json(s: str) -> "WalRecord":
        d = json.loads(s)
        return WalRecord(d["lsn"], d["type"], d["txn"],
                         tuple(d.get("out_rw", ())),
                         tuple((k, v) for k, v in d.get("writes", ())),
                         d.get("seq", 0))


class Wal:
    """An append-only in-memory WAL with optional persistence.

    `tail(from_lsn)` is the streaming-replication read path: it yields
    records with lsn > from_lsn, letting a replica poll asynchronously.
    """

    def __init__(self) -> None:
        self.records: list[WalRecord] = []

    @property
    def head_lsn(self) -> int:
        return len(self.records)

    def _append(self, type: RecordType, txn: int,
                out_rw: Sequence[int] = (),
                writes: Sequence[tuple[str, object]] = (),
                seq: int = 0) -> WalRecord:
        rec = WalRecord(len(self.records) + 1, type, txn, tuple(out_rw),
                        tuple(writes), seq)
        self.records.append(rec)
        return rec

    def log_begin(self, txn: int) -> WalRecord:
        return self._append("begin", txn)

    def log_commit(self, txn: int,
                   writes: Sequence[tuple[str, object]] = (),
                   seq: int = 0) -> WalRecord:
        return self._append("commit", txn, writes=writes, seq=seq)

    def log_abort(self, txn: int) -> WalRecord:
        return self._append("abort", txn)

    def log_deps(self, txn: int, out_rw: Sequence[int]) -> WalRecord:
        return self._append("deps", txn, out_rw)

    def tail(self, from_lsn: int) -> Iterator[WalRecord]:
        yield from self.records[from_lsn:]

    # -------------------------------------------------------- persistence
    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            for rec in self.records:
                f.write(rec.to_json() + "\n")

    @staticmethod
    def load(path: str) -> "Wal":
        wal = Wal()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    wal.records.append(WalRecord.from_json(line))
        return wal
