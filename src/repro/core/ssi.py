"""SSI (serializable snapshot isolation) properties at the history level.

Implements, over `History` objects:
  * SI-V / SI-W validation (the Schenkel-Weikum SI conditions, paper Sec 3.2)
  * vulnerable dependencies (concurrent rw anti-dependencies, paper Sec 4.3)
  * dangerous structures (Fekete et al.): two successive vulnerable edges
  * `ssi_accepts(h)` — would an SSI scheduler accept this committed history?

These are the *specification-level* checks; the executable SSI engine lives in
`repro.mvcc` and must only ever produce histories that pass these checks
(asserted by property tests).
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass

from .dsg import RW, build_dsg
from .history import History, T0


def si_v_holds(h: History) -> bool:
    """SI read protocol: every read of X by T returns the version written by
    the most recent committed writer of X as of Begin(T) (or T's own write)."""
    # committed writers of each key by end position
    for t in h.txns:
        begin = h.begin_pos(t)
        own_writes: set[str] = set()
        # iterate T's ops in order to honour read-your-own-writes
        for op in h.ops:
            if op.txn != t:
                continue
            if op.kind == "w":
                own_writes.add(op.key)
            elif op.kind == "r":
                if op.key in own_writes:
                    if op.version != t:
                        return False
                    continue
                expected = T0
                best = -1
                for u in h.committed:
                    if u == t or op.key not in h.writeset(u):
                        continue
                    e = h.end_pos(u)
                    if e < begin and e > best:
                        best, expected = e, u
                if op.version != expected:
                    return False
    return True


def si_w_holds(h: History) -> bool:
    """First-committer-wins: concurrent committed txns have disjoint writesets."""
    committed = sorted(h.committed)
    for i, ta in enumerate(committed):
        for tb in committed[i + 1:]:
            if h.concurrent(ta, tb) and (h.writeset(ta) & h.writeset(tb)):
                return False
    return True


def is_si_history(h: History) -> bool:
    return si_v_holds(h) and si_w_holds(h)


@dataclass(frozen=True)
class Vulnerable:
    src: int
    dst: int
    key: str


def vulnerable_edges(h: History) -> list[Vulnerable]:
    """Concurrent rw anti-dependencies among committed txns (paper Sec 4.3:
    the only conflicts that can be vulnerable under SSI are concurrent rw)."""
    g = build_dsg(h)
    out: list[Vulnerable] = []
    for e in g.edges:
        if e.kind == RW and h.concurrent(e.src, e.dst):
            out.append(Vulnerable(e.src, e.dst, e.key))
    return out


def dangerous_structures(h: History) -> list[tuple[int, int, int]]:
    """(Ta, Tb, Tc) with vulnerable Ta->Tb and vulnerable Tb->Tc.

    Fekete et al.: every non-serializable SI execution contains such a
    structure where additionally Tc is the first of the three to commit; we
    report the structural condition (what PostgreSQL's conservative detector
    aborts on) — tests that need the exact theorem add the commit-order check.
    """
    vul = vulnerable_edges(h)
    by_src: dict[int, list[Vulnerable]] = defaultdict(list)
    for v in vul:
        by_src[v.src].append(v)
    found: list[tuple[int, int, int]] = []
    for v1 in vul:
        for v2 in by_src.get(v1.dst, ()):
            found.append((v1.src, v1.dst, v2.dst))
    return found


def fatal_dangerous_structures(h: History) -> list[tuple[int, int, int]]:
    """Dangerous structures satisfying the full Fekete condition: the
    structure can close a cycle only if Tc (the pivot's out-neighbour)
    commits FIRST of the three.  PostgreSQL's commit-time check aborts
    exactly these; a structure whose Tc commits last is provably benign.

    Fekete et al. allow Ta and Tc to coincide (plain two-transaction write
    skew is the structure Tc -> Tb -> Tc): then "Tc first" only constrains
    Tc against Tb."""
    out = []
    for (ta, tb, tc) in dangerous_structures(h):
        ec = h.end_pos(tc)
        if ec < h.end_pos(tb) and (ta == tc or ec < h.end_pos(ta)):
            out.append((ta, tb, tc))
    return out


def ssi_accepts(h: History) -> bool:
    """A committed SI history is SSI-acceptable iff it is SI and contains no
    *fatal* dangerous structure (two successive vulnerable edges whose
    out-neighbour committed first — the Fekete et al. necessary condition
    for non-serializability under SI)."""
    return is_si_history(h) and not fatal_dangerous_structures(h)
