"""Replica-side RSS construction from a shipped WAL (paper Sec 5.1).

`RSSManager` replays WAL records (in LSN order, possibly in batches — the
log-shipping is asynchronous) and maintains:

  * Active / Done / Clear transaction states (Definition 4.6) keyed by the
    replayed prefix — *incrementally*: an ordered begin-LSN heap of active
    transactions replaces the full min-scan, so one replication round costs
    O(records applied), not O(history),
  * the concurrent-rw dependency adjacency shipped via "deps" records,
  * the current RSS via `core.rss.IncrementalRss` (Algorithm 1 applied only
    to the delta of newly-Clear transactions and newly-shipped edges) and
    its *watermark*: RSS only ever grows forward, so exporting a snapshot is
    O(active-window) for readers — this is the abort-/wait-free property.

Exported snapshots are COMPRESSED: `floor_seq` covers every committed
transaction with commit seq <= floor (Clear members fold into the floor as
it advances), and only the members ABOVE the floor are carried explicitly.
Snapshot size and construction cost are therefore bounded by the concurrent
window, independent of replayed-history length.

`gc(keep_lsn=...)` prunes per-transaction bookkeeping (begun/ended/rw_out/
commit_seq) below min(active horizon, oldest pinned PRoT snapshot) — the
replica-state analogue of PostgreSQL's SSI SLRU summarization (Ports &
Grittner): state is bounded by the active/pinned window under sustained
load.

`PRoTManager` pins exported snapshots until readers release them, the
analogue of the paper's snapshot-preserving transactions +
hot_standby_feedback (it prevents version GC below the oldest pinned
snapshot).
"""

from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Iterable, Mapping, Optional

from ..obs import REGISTRY, StatsView
from .rss import IncrementalRss, advance, construct_rss_ssi
from .wal import Wal, WalRecord, effective_commit_seq

_INF = 1 << 62


@dataclass(frozen=True)
class RssSnapshot:
    """An immutable exported snapshot: the RSS transaction set at some LSN.

    Compressed membership: a transaction is a member iff its commit seq is
    <= `floor_seq` (the *prefix-safe* horizon: every transaction committed
    at seq <= floor is a member) or its id is in `txns` (the sparse members
    above the floor — bounded by the concurrent window).  Snapshots built
    directly with an explicit `txns` set and floor_seq == 0 (tests, oracle
    harnesses) degenerate to plain set membership.

    `member_seqs` carries the sorted commit seqs of the above-floor members
    for device-resident scans (`rss_gather`); None means "not stamped"
    (explicit-set snapshots) and consumers fall back to mapping `txns`
    through their own commit-seq bookkeeping.

    Pruning versions below floor_seq can never remove a version a member
    read resolves to (any version in (s, floor] overwriting a
    member-visible version at seq s would itself be a member and newer) —
    so floor_seq is the safe GC floor for a pinned reader."""
    lsn: int
    txns: frozenset[int]
    floor_seq: int = 0
    member_seqs: Optional[tuple[int, ...]] = None

    def visible(self, writer_txn: int, commit_seq: Optional[int] = None) \
            -> bool:
        """Is a version written by `writer_txn` (committed at `commit_seq`,
        when known) inside this snapshot?  T0 (writer 0) is always
        visible."""
        if writer_txn == 0 or writer_txn in self.txns:
            return True
        return commit_seq is not None and 0 < commit_seq <= self.floor_seq


class RSSManager:
    def __init__(self) -> None:
        self.applied_lsn = 0
        self.begun: dict[int, int] = {}      # txn -> begin lsn
        self.ended: dict[int, int] = {}      # txn -> end lsn
        self.committed: set[int] = set()
        self.aborted: set[int] = set()
        # commit bookkeeping, in LSN (== commit-seq) order: the shipped
        # commit-seq of every committed txn, for the commit-seq -> member-ts
        # mapping a device-resident mirror needs.
        self.commit_seq: dict[int, int] = {}
        self.commit_order: deque[int] = deque()  # txn ids, commit-seq asc
        self.max_seq = 0                     # newest seq seen (fallback base)
        # incremental Algorithm 1 state (shares the shipped rw adjacency)
        self._inc = IncrementalRss()
        # --- incremental Done/Clear machinery -------------------------
        self._active_heap: list[tuple[int, int]] = []   # (begin_lsn, txn)
        self._pending_clear: list[tuple[int, int]] = []  # (end_lsn, txn)
        self._resolved: deque[tuple[int, int]] = deque()  # (end_lsn, txn)
        # --- compressed-snapshot export state -------------------------
        self.floor_seq = 0
        self._floor_pending: deque[tuple[int, int]] = deque()  # (seq, txn)
        self._above_floor: set[int] = set()  # RSS members with seq > floor
        self._gc_lsn = 0                     # state pruned below this lsn
        self._snapshot: RssSnapshot = RssSnapshot(0, frozenset(),
                                                  member_seqs=())
        self.members_total = 0               # monotone member count
        self.stats = StatsView(REGISTRY, "rss",
                               ("gc_txns", "edges_pruned_pull"),
                               labels={"rss": REGISTRY.scope("rss")})

    @property
    def rw_out(self) -> dict[int, set[int]]:
        """Shipped outgoing concurrent rw edges: reader -> {writers}."""
        return self._inc.rw_out

    # ------------------------------------------------------------- replay
    def apply(self, rec: WalRecord) -> None:
        if rec.lsn <= self.applied_lsn:
            return  # idempotent replay (restart safety)
        self.applied_lsn = rec.lsn
        if rec.type == "begin":
            if rec.txn not in self.begun:
                self.begun[rec.txn] = rec.lsn
                heapq.heappush(self._active_heap, (rec.lsn, rec.txn))
        elif rec.type == "commit":
            self.begun.setdefault(rec.txn, rec.lsn)
            self.ended[rec.txn] = rec.lsn
            self.committed.add(rec.txn)
            # shared strictly-monotone clock (see effective_commit_seq):
            # legacy records mint max(seen) + 1 — a dense local clock could
            # collide with or regress below shipped seqs when record kinds
            # mix, corrupting floor_seq.
            seq = effective_commit_seq(self.max_seq, rec.seq)
            self.max_seq = seq
            self.commit_seq[rec.txn] = seq
            self.commit_order.append(rec.txn)
            self._floor_pending.append((seq, rec.txn))
            self._resolved.append((rec.lsn, rec.txn))
            self._inc.add_committed(rec.txn)
            heapq.heappush(self._pending_clear, (rec.lsn, rec.txn))
        elif rec.type == "abort":
            self.begun.setdefault(rec.txn, rec.lsn)
            self.ended[rec.txn] = rec.lsn
            self.aborted.add(rec.txn)
            self._resolved.append((rec.lsn, rec.txn))
        elif rec.type == "deps":
            if rec.txn not in self.begun and self._gc_lsn:
                # the READER itself was already GC'd (its commit landed in a
                # previous ship batch and state GC ran before this deps
                # record arrived): it is a floor-covered member, and a deps
                # edge (u, w) only ever affects u's membership — drop the
                # record instead of stashing edges that would never drain.
                pass
            else:
                for w in rec.out_rw:
                    if w not in self.begun and self._gc_lsn:
                        # writer bookkeeping already GC'd: its End preceded
                        # the GC watermark, and deps ship in LSN order right
                        # after the reader's commit, so the writer can only
                        # have been pruned as a Clear member — pull the
                        # reader directly.
                        self._inc.pull(rec.txn)
                        self.stats["edges_pruned_pull"] += 1
                    else:
                        self._inc.add_edge(rec.txn, w)
        self._drain_clear()

    def _drain_clear(self) -> None:
        """Advance the Clear horizon: pop ended txns off the active heap,
        then promote every committed txn whose End precedes the horizon."""
        heap = self._active_heap
        while heap and heap[0][1] in self.ended:
            heapq.heappop(heap)
        horizon = heap[0][0] if heap else _INF
        pend = self._pending_clear
        while pend and pend[0][0] < horizon:
            _, txn = heapq.heappop(pend)
            self._inc.add_clear(txn)

    def catch_up(self, wal: Wal) -> int:
        """Pull and apply all records past applied_lsn; returns #applied."""
        n = 0
        for rec in wal.tail(self.applied_lsn):
            self.apply(rec)
            n += 1
        return n

    # -------------------------------------------------------------- states
    def active(self) -> set[int]:
        return {t for t in self.begun if t not in self.ended}

    def done(self) -> set[int]:
        return set(self.ended)

    def clear(self) -> set[int]:
        """Clear(p) among retained (non-GC'd) transactions."""
        return set(self._inc.clear)

    def obscure(self) -> set[int]:
        return self.committed - self._inc.clear - self.active()

    # ----------------------------------------------------------- Algorithm 1
    def _fold_floor(self) -> None:
        """Fold the contiguous commit-seq prefix of members into floor_seq,
        leaving only the (bounded) above-floor remainder explicit."""
        new = self._inc.drain_new()
        self.members_total += len(new)
        for t in new:
            self._above_floor.add(t)
        pend = self._floor_pending
        rss = self._inc.rss
        while pend and pend[0][1] in rss:
            seq, txn = pend.popleft()
            self.floor_seq = seq
            self._above_floor.discard(txn)

    def construct(self) -> RssSnapshot:
        """Export the incrementally-maintained RSS: fold newly-added members
        into the floor and snapshot the (bounded) above-floor remainder.
        O(delta) amortized per round.  RSS is monotone across calls (older
        members stay valid for already-pinned readers; the exported set is
        the newest)."""
        self._fold_floor()
        seqs = sorted(self.commit_seq[t] for t in self._above_floor)
        self._snapshot = RssSnapshot(self.applied_lsn,
                                     frozenset(self._above_floor),
                                     self.floor_seq, tuple(seqs))
        return self._snapshot

    def construct_batch(self) -> RssSnapshot:
        """The pre-incremental O(history) construction path, kept as the
        cost baseline for `benchmarks.bench_freshness` and as an oracle.
        Requires an un-GC'd manager (full begin/end bookkeeping)."""
        act = self.active()
        horizon = min((self.begun[t] for t in act), default=_INF)
        clear = {t for t in self.committed if self.ended[t] < horizon}
        edges = [(u, w) for u, outs in self._inc.rw_out.items() for w in outs]
        rss = construct_rss_ssi(clear, self.committed, edges)
        floor = 0
        for t in self.commit_order:          # commit-seq ascending
            if t not in rss:
                break
            floor = self.commit_seq[t]
        above = {t for t in rss if self.commit_seq[t] > floor}
        seqs = sorted(self.commit_seq[t] for t in above)
        return RssSnapshot(self.applied_lsn, frozenset(above), floor,
                           tuple(seqs))

    @property
    def snapshot(self) -> RssSnapshot:
        return self._snapshot

    def is_member(self, txn: int, snap: Optional[RssSnapshot] = None) -> bool:
        """Membership of a COMMITTED transaction in `snap` (default: the
        current snapshot), resolving txn -> commit seq through this
        manager's bookkeeping.  GC'd transactions resolve via the floor:
        `gc()` only ever prunes commits below every live snapshot's
        floor_seq, so a pruned id is a member of any snapshot this manager
        still serves."""
        seq = self.commit_seq.get(txn)
        if seq is None and self._gc_lsn and txn not in self.begun:
            return True
        return (snap or self._snapshot).visible(txn, seq)

    def member_seqs(self, snap: RssSnapshot) -> list[int]:
        """Sorted commit seqs of the snapshot's ABOVE-FLOOR members — with
        `snap.floor_seq`, the member-ts state a device-resident paged mirror
        feeds to `rss_gather`.  Explicit-set snapshots (member_seqs not
        stamped) map their full `txns` through the local clock."""
        if snap.member_seqs is not None:
            return list(snap.member_seqs)
        return sorted(self.commit_seq[t] for t in snap.txns
                      if t in self.commit_seq)

    # --------------------------------------------------------------- state GC
    def gc(self, *, keep_lsn: Optional[int] = None,
           keep_seq: Optional[int] = None) -> int:
        """Prune per-transaction bookkeeping (begun/ended/rw edges/commit
        seq) below the state watermark.  A transaction is prunable when

          * its End precedes the active-transaction horizon AND `keep_lsn`
            (the oldest pinned PRoT snapshot's LSN) — so it is Clear (or
            aborted) and can never gain a non-Clear role in a future
            Algorithm 1 step, and
          * if committed, its commit seq is at-or-below every live
            snapshot's floor (`keep_seq`, bounded by the current exported
            floor) — so membership queries stay exact: pruned commits are
            floor-covered members of every snapshot this manager serves.

        Returns #transactions pruned.  State left behind is bounded by the
        active/pinned window, independent of replayed-history length."""
        self._fold_floor()
        heap = self._active_heap
        while heap and heap[0][1] in self.ended:
            heapq.heappop(heap)
        watermark = heap[0][0] if heap else self.applied_lsn + 1
        if keep_lsn is not None:
            watermark = min(watermark, keep_lsn + 1)
        seq_cap = self._snapshot.floor_seq
        if keep_seq is not None:
            seq_cap = min(seq_cap, keep_seq)
        n = 0
        resolved = self._resolved
        while resolved and resolved[0][0] < watermark:
            end_lsn, txn = resolved.popleft()
            if txn in self.committed and self.commit_seq[txn] > seq_cap:
                resolved.appendleft((end_lsn, txn))
                break
            self.begun.pop(txn, None)
            self.ended.pop(txn, None)
            self.committed.discard(txn)
            self.aborted.discard(txn)
            self.commit_seq.pop(txn, None)
            self._above_floor.discard(txn)
            self._inc.forget(txn)
            n += 1
        order = self.commit_order
        while order and order[0] not in self.commit_seq:
            order.popleft()
        if n:
            self._gc_lsn = max(self._gc_lsn, watermark - 1)
            self.stats["gc_txns"] += n
        return n

    def tracked_txns(self) -> int:
        """Per-transaction bookkeeping size (the bounded-state metric)."""
        return len(self.begun)


class PRoTManager:
    """Export/pin/release snapshots for protected read-only transactions.

    GC boundary: versions written by transactions committed at-or-below every
    pinned snapshot's LSN horizon must be preserved (hot_standby_feedback
    analogue).  `gc_floor()` returns the lowest pinned LSN, or the current
    snapshot's LSN when nothing is pinned.

    Pins are SHARED: every reader acquiring at the same horizon (the same
    constructed-snapshot LSN) refcounts ONE pin-table entry holding one
    `RssSnapshot`, instead of one entry per reader — at high PRoT reader
    counts the pin table is bounded by the number of distinct live horizons
    (<= refresh rounds spanned by the oldest reader), not by reader count.
    The floor semantics are unchanged: an entry holds the GC floor until its
    LAST sharer releases, and because readers only ever pin the newest
    snapshot (whose floor is monotone in LSN), `gc_floor_seq()` can never
    regress while any sharer is live.
    """

    def __init__(self, manager: RSSManager) -> None:
        self.manager = manager
        self._readers: dict[int, int] = {}    # reader id -> pinned horizon lsn
        # horizon lsn -> [snapshot, sharer refcount]: ONE entry per horizon
        self._pins: dict[int, list] = {}
        self._next_reader = 1

    def acquire(self) -> tuple[int, RssSnapshot]:
        """Wait-free: returns the most recent constructed snapshot, sharing
        the pin-table entry with every other reader at the same horizon."""
        snap = self.manager.snapshot
        rid = self._next_reader
        self._next_reader += 1
        ent = self._pins.get(snap.lsn)
        if ent is None:
            self._pins[snap.lsn] = [snap, 1]
        else:
            ent[1] += 1
            snap = ent[0]                     # all sharers see one snapshot
        self._readers[rid] = snap.lsn
        return rid, snap

    def release(self, reader_id: int) -> None:
        lsn = self._readers.pop(reader_id, None)
        if lsn is None:
            return
        ent = self._pins[lsn]
        ent[1] -= 1
        if ent[1] == 0:                       # last sharer drops the pin
            del self._pins[lsn]

    def gc_floor(self) -> int:
        if not self._pins:
            return self.manager.snapshot.lsn
        return min(self._pins)

    def gc_floor_seq(self) -> int:
        """Version-GC floor in commit-seq units: the minimum prefix-safe
        horizon over pinned snapshots.  `Store.prune(floor)` at this floor
        preserves every version any pinned RSS reader can still resolve to
        (prune only drops versions below the floor, and below the floor the
        member-visible version IS the newest at-or-below it).  K-slot paged
        stores (`publish_page(..., gc_floor=floor)`) give the weaker bounded
        guarantee: the floor-visible slot is never recycled, but member
        versions above the floor survive only while publishers outrun
        readers by fewer than K-1 versions per page."""
        if not self._pins:
            return self.manager.snapshot.floor_seq
        return min(s.floor_seq for s, _ in self._pins.values())

    @property
    def pinned(self) -> int:
        """Live pin-table entries (one per distinct pinned horizon)."""
        return len(self._pins)

    @property
    def readers(self) -> int:
        """Live sharers across all pinned horizons (>= pinned)."""
        return len(self._readers)


def replicate(wal: Wal, manager: RSSManager, *, batch: int = 0) -> RssSnapshot:
    """One asynchronous replication round: catch up on the WAL (optionally in
    bounded batches, modelling streaming-lag) and advance the RSS."""
    if batch <= 0:
        manager.catch_up(wal)
    else:
        applied = 0
        for rec in wal.tail(manager.applied_lsn):
            manager.apply(rec)
            applied += 1
            if applied >= batch:
                break
    return manager.construct()
