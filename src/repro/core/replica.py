"""Replica-side RSS construction from a shipped WAL (paper Sec 5.1).

`RSSManager` replays WAL records (in LSN order, possibly in batches — the
log-shipping is asynchronous) and maintains:

  * Active / Done / Clear transaction states (Definition 4.6) keyed by the
    replayed prefix,
  * the concurrent-rw dependency adjacency shipped via "deps" records,
  * the current RSS (Algorithm 1) and its *watermark*: RSS only ever grows
    forward, so exporting a snapshot is O(1) for readers — this is the
    abort-/wait-free property.

`PRoTManager` pins exported snapshots until readers release them, the analogue
of the paper's snapshot-preserving transactions + hot_standby_feedback (it
prevents version GC below the oldest pinned snapshot).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Mapping

from .rss import construct_rss_ssi
from .wal import Wal, WalRecord


@dataclass(frozen=True)
class RssSnapshot:
    """An immutable exported snapshot: the RSS transaction set at some LSN.

    `floor_seq` is the snapshot's *prefix-safe* commit-seq horizon: the
    largest commit seq h such that every transaction committed at seq <= h is
    a member.  Pruning versions below h can never remove a version this
    snapshot's membership read resolves to (any version in (s, h] overwriting
    a member-visible version at seq s would itself be a member and newer) —
    so h is the safe GC floor for a pinned reader."""
    lsn: int
    txns: frozenset[int]
    floor_seq: int = 0

    def visible(self, writer_txn: int) -> bool:
        return writer_txn == 0 or writer_txn in self.txns


class RSSManager:
    def __init__(self) -> None:
        self.applied_lsn = 0
        self.begun: dict[int, int] = {}      # txn -> begin lsn
        self.ended: dict[int, int] = {}      # txn -> end lsn
        self.committed: set[int] = set()
        self.aborted: set[int] = set()
        # commit bookkeeping, in LSN (== commit-seq) order: the shipped
        # commit-seq of every committed txn, for the commit-seq -> member-ts
        # mapping a device-resident mirror needs.
        self.commit_seq: dict[int, int] = {}
        self.commit_order: list[int] = []    # txn ids, commit-seq ascending
        # shipped outgoing concurrent rw edges: reader -> {writers}
        self.rw_out: dict[int, set[int]] = {}
        self._snapshot: RssSnapshot = RssSnapshot(0, frozenset())

    # ------------------------------------------------------------- replay
    def apply(self, rec: WalRecord) -> None:
        if rec.lsn <= self.applied_lsn:
            return  # idempotent replay (restart safety)
        self.applied_lsn = rec.lsn
        if rec.type == "begin":
            self.begun.setdefault(rec.txn, rec.lsn)
        elif rec.type == "commit":
            self.begun.setdefault(rec.txn, rec.lsn)
            self.ended[rec.txn] = rec.lsn
            self.committed.add(rec.txn)
            # records without a shipped seq (legacy) get a local dense clock
            seq = rec.seq if rec.seq else len(self.commit_order) + 1
            self.commit_seq[rec.txn] = seq
            self.commit_order.append(rec.txn)
        elif rec.type == "abort":
            self.begun.setdefault(rec.txn, rec.lsn)
            self.ended[rec.txn] = rec.lsn
            self.aborted.add(rec.txn)
        elif rec.type == "deps":
            self.rw_out.setdefault(rec.txn, set()).update(rec.out_rw)

    def catch_up(self, wal: Wal) -> int:
        """Pull and apply all records past applied_lsn; returns #applied."""
        n = 0
        for rec in wal.tail(self.applied_lsn):
            self.apply(rec)
            n += 1
        return n

    # -------------------------------------------------------------- states
    def active(self) -> set[int]:
        return {t for t in self.begun if t not in self.ended}

    def done(self) -> set[int]:
        return set(self.ended)

    def clear(self) -> set[int]:
        act = self.active()
        horizon = min((self.begun[t] for t in act), default=1 << 62)
        return {t for t in self.committed if self.ended[t] < horizon}

    def obscure(self) -> set[int]:
        return self.committed - self.clear() - self.active()

    # ----------------------------------------------------------- Algorithm 1
    def construct(self) -> RssSnapshot:
        """Run Algorithm 1 over the replayed prefix and refresh the exported
        snapshot. RSS is monotone across calls (older members stay valid for
        already-pinned readers; the exported set is the newest)."""
        clear = self.clear()
        edges = [(u, w) for u, outs in self.rw_out.items() for w in outs]
        rss = construct_rss_ssi(clear, self.committed, edges)
        floor = 0
        for t in self.commit_order:          # commit-seq ascending
            if t not in rss:
                break
            floor = self.commit_seq[t]
        self._snapshot = RssSnapshot(self.applied_lsn, frozenset(rss), floor)
        return self._snapshot

    @property
    def snapshot(self) -> RssSnapshot:
        return self._snapshot

    def member_seqs(self, snap: RssSnapshot) -> list[int]:
        """Sorted commit seqs of the snapshot's members — the member-ts array
        a device-resident paged mirror feeds to `rss_gather`."""
        return sorted(self.commit_seq[t] for t in snap.txns
                      if t in self.commit_seq)


class PRoTManager:
    """Export/pin/release snapshots for protected read-only transactions.

    GC boundary: versions written by transactions committed at-or-below every
    pinned snapshot's LSN horizon must be preserved (hot_standby_feedback
    analogue).  `gc_floor()` returns the lowest pinned LSN, or the current
    snapshot's LSN when nothing is pinned.
    """

    def __init__(self, manager: RSSManager) -> None:
        self.manager = manager
        self._pins: dict[int, RssSnapshot] = {}
        self._next_reader = 1

    def acquire(self) -> tuple[int, RssSnapshot]:
        """Wait-free: returns the most recent constructed snapshot."""
        snap = self.manager.snapshot
        rid = self._next_reader
        self._next_reader += 1
        self._pins[rid] = snap
        return rid, snap

    def release(self, reader_id: int) -> None:
        self._pins.pop(reader_id, None)

    def gc_floor(self) -> int:
        if not self._pins:
            return self.manager.snapshot.lsn
        return min(s.lsn for s in self._pins.values())

    def gc_floor_seq(self) -> int:
        """Version-GC floor in commit-seq units: the minimum prefix-safe
        horizon over pinned snapshots.  `Store.prune(floor)` at this floor
        preserves every version any pinned RSS reader can still resolve to
        (prune only drops versions below the floor, and below the floor the
        member-visible version IS the newest at-or-below it).  K-slot paged
        stores (`publish_page(..., gc_floor=floor)`) give the weaker bounded
        guarantee: the floor-visible slot is never recycled, but member
        versions above the floor survive only while publishers outrun
        readers by fewer than K-1 versions per page."""
        if not self._pins:
            return self.manager.snapshot.floor_seq
        return min(s.floor_seq for s in self._pins.values())

    @property
    def pinned(self) -> int:
        return len(self._pins)


def replicate(wal: Wal, manager: RSSManager, *, batch: int = 0) -> RssSnapshot:
    """One asynchronous replication round: catch up on the WAL (optionally in
    bounded batches, modelling streaming-lag) and rebuild RSS."""
    if batch <= 0:
        manager.catch_up(wal)
    else:
        applied = 0
        for rec in wal.tail(manager.applied_lsn):
            manager.apply(rec)
            applied += 1
            if applied >= batch:
                break
    return manager.construct()
