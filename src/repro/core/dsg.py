"""Direct serialization graph (DSG) over a multiversion history (Adya).

Edges over committed transactions (committed projection of the prefix):
  ww  Ta -> Tb : Ta installs a version of X, Tb installs the *next* version
                 of X in the version order (== commit order; SI version order).
  wr  Ta -> Tb : Tb reads the version of X that Ta wrote.
  rw  Ta -> Tb : Ta reads a version of X, and Tb installs the version of X
                 that *immediately follows* the read version (anti-dependency).

Serializable (VOCSR / PL-3) == DSG acyclic.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from typing import Iterable

from .history import History, T0

WW, WR, RW = "ww", "wr", "rw"


@dataclass(frozen=True)
class Edge:
    src: int
    dst: int
    kind: str
    key: str

    def __repr__(self) -> str:
        return f"{self.src} -{self.kind}({self.key})-> {self.dst}"


class DSG:
    def __init__(self, nodes: Iterable[int], edges: Iterable[Edge]):
        self.nodes: set[int] = set(nodes)
        self.edges: list[Edge] = list(edges)
        self.adj: dict[int, set[int]] = defaultdict(set)
        for e in self.edges:
            if e.src != e.dst:  # T ->* T reflexivity is not a cycle (paper 3.2)
                self.adj[e.src].add(e.dst)

    # ------------------------------------------------------------ reachability
    def reachable_from(self, src: int) -> set[int]:
        """All nodes reachable from src via directed edges (excl. src itself
        unless on a real cycle)."""
        seen: set[int] = set()
        stack = list(self.adj.get(src, ()))
        while stack:
            n = stack.pop()
            if n in seen:
                continue
            seen.add(n)
            stack.extend(self.adj.get(n, ()))
        return seen

    def reaches(self, src: int, dst: int) -> bool:
        if src == dst:
            return True  # reflexive ->* per the paper's notation
        return dst in self.reachable_from(src)

    def has_cycle(self) -> bool:
        WHITE, GRAY, BLACK = 0, 1, 2
        color = {n: WHITE for n in self.nodes}
        for root in self.nodes:
            if color[root] != WHITE:
                continue
            stack: list[tuple[int, Iterable[int]]] = [(root, iter(self.adj.get(root, ())))]
            color[root] = GRAY
            while stack:
                node, it = stack[-1]
                advanced = False
                for nxt in it:
                    if color.get(nxt, WHITE) == GRAY:
                        return True
                    if color.get(nxt, WHITE) == WHITE:
                        color[nxt] = GRAY
                        stack.append((nxt, iter(self.adj.get(nxt, ()))))
                        advanced = True
                        break
                if not advanced:
                    color[node] = BLACK
                    stack.pop()
        return False

    def edges_between(self, src: int, dst: int) -> list[Edge]:
        return [e for e in self.edges if e.src == src and e.dst == dst]


def build_dsg(h: History, *, restrict_to: set[int] | None = None) -> DSG:
    """Build the DSG of the committed projection of history h.

    restrict_to: optionally only consider this subset of committed txns
    (used for H(S_1..S_{n-1}) style restrictions).
    """
    committed = h.committed if restrict_to is None else (h.committed & restrict_to)

    # Version order per key: T0 first, then committed writers by commit order.
    order = [t for t in h.commit_order() if t in committed]
    versions: dict[str, list[int]] = defaultdict(lambda: [T0])
    for t in order:
        for key in sorted(h.writeset(t)):
            versions[key].append(t)

    # also include keys only ever read
    nxt: dict[tuple[str, int], int] = {}
    for key, chain in versions.items():
        for i, t in enumerate(chain[:-1]):
            nxt[(key, t)] = chain[i + 1]

    edges: list[Edge] = []
    # ww edges: consecutive writers
    for key, chain in versions.items():
        for i in range(1, len(chain) - 1):
            edges.append(Edge(chain[i], chain[i + 1], WW, key))

    for t in committed:
        for _, key, ver in h.reads_of(t):
            if ver != t and ver in committed or ver == T0:
                # wr edge from the writer of the read version
                if ver != T0 and ver != t:
                    edges.append(Edge(ver, t, WR, key))
                # rw anti-dependency to the writer of the *next* version
                follower = nxt.get((key, ver))
                if follower is not None and follower != t:
                    edges.append(Edge(t, follower, RW, key))
    return DSG(committed, edges)


def is_serializable(h: History) -> bool:
    """VOCSR membership: DSG of the committed projection is acyclic."""
    return not build_dsg(h).has_cycle()


def find_cycle(h: History) -> list[int] | None:
    """Return one dependency cycle (list of txn ids) if the DSG has one."""
    g = build_dsg(h)
    path: list[int] = []
    on_path: set[int] = set()
    visited: set[int] = set()

    def dfs(n: int) -> list[int] | None:
        visited.add(n)
        path.append(n)
        on_path.add(n)
        for m in g.adj.get(n, ()):
            if m in on_path:
                return path[path.index(m):] + [m]
            if m not in visited:
                res = dfs(m)
                if res is not None:
                    return res
        path.pop()
        on_path.discard(n)
        return None

    for node in g.nodes:
        if node not in visited:
            res = dfs(node)
            if res is not None:
                return res
    return None
