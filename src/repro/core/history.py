"""Multiversion histories in the Adya formalization used by the paper.

The paper (Sec. 3) adopts Adya et al.'s multiversion history model with a
version order induced by commit order (the "SI version order" of Schenkel &
Weikum), and calls the serializable class VOCSR (version-ordered
conflict-serializability, PL-3).

A history is a totally ordered sequence of operations:
    b(T)        Begin(T)
    r(T, X, V)  T reads the version of X written by transaction V
    w(T, X)     T writes (installs a new version of) X
    c(T)        Commit(T) == End(T) for committed transactions
    a(T)        Abort(T)  == End(T) for aborted transactions

Version identity: the version of X written by T is denoted (X, T).  The
initial (pre-history) version of every key is (X, T0) with T0 == 0.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Iterator, Optional, Sequence

T0 = 0  # the fictitious initial transaction that installed all initial versions

BEGIN, READ, WRITE, COMMIT, ABORT = "b", "r", "w", "c", "a"


@dataclass(frozen=True)
class Op:
    kind: str              # one of b/r/w/c/a
    txn: int               # transaction id (> 0)
    key: Optional[str] = None
    # for READ ops: id of the transaction that wrote the version being read.
    version: Optional[int] = None

    def __repr__(self) -> str:  # compact, paper-like notation
        if self.kind == READ:
            return f"R{self.txn}({self.key}_{self.version})"
        if self.kind == WRITE:
            return f"W{self.txn}({self.key}_{self.txn})"
        return f"{self.kind.upper()}{self.txn}"


def b(t: int) -> Op:
    return Op(BEGIN, t)


def r(t: int, key: str, version: int) -> Op:
    return Op(READ, t, key, version)


def w(t: int, key: str) -> Op:
    return Op(WRITE, t, key)


def c(t: int) -> Op:
    return Op(COMMIT, t)


def a(t: int) -> Op:
    return Op(ABORT, t)


class History:
    """An (interleaved) multiversion history with helpers used throughout.

    Histories are append-only; every accessor works on the current prefix, so
    the same object can serve as "the current prefix p" while a workload runs.
    """

    def __init__(self, ops: Iterable[Op] = ()) -> None:
        self.ops: list[Op] = []
        # index caches, maintained incrementally
        self._begin_pos: dict[int, int] = {}
        self._end_pos: dict[int, int] = {}
        self._committed: set[int] = set()
        self._aborted: set[int] = set()
        self._writes: dict[int, list[tuple[int, str]]] = {}   # txn -> [(pos, key)]
        self._reads: dict[int, list[tuple[int, str, int]]] = {}  # txn -> [(pos, key, ver)]
        self._txns: set[int] = set()
        for op in ops:
            self.append(op)

    # ------------------------------------------------------------------ build
    def append(self, op: Op) -> None:
        pos = len(self.ops)
        self.ops.append(op)
        t = op.txn
        self._txns.add(t)
        if op.kind == BEGIN:
            self._begin_pos.setdefault(t, pos)
        elif op.kind == COMMIT:
            self._end_pos[t] = pos
            self._committed.add(t)
        elif op.kind == ABORT:
            self._end_pos[t] = pos
            self._aborted.add(t)
        elif op.kind == WRITE:
            self._writes.setdefault(t, []).append((pos, op.key))
            self._begin_pos.setdefault(t, pos)  # implicit begin at first op
        elif op.kind == READ:
            self._reads.setdefault(t, []).append((pos, op.key, op.version))
            self._begin_pos.setdefault(t, pos)

    def extend(self, ops: Iterable[Op]) -> None:
        for op in ops:
            self.append(op)

    def __iter__(self) -> Iterator[Op]:
        return iter(self.ops)

    def __len__(self) -> int:
        return len(self.ops)

    # ---------------------------------------------------------------- queries
    @property
    def txns(self) -> set[int]:
        return set(self._txns)

    @property
    def committed(self) -> set[int]:
        return set(self._committed)

    @property
    def aborted(self) -> set[int]:
        return set(self._aborted)

    def active(self) -> set[int]:
        """Transactions that have begun but not ended in the current prefix."""
        return {t for t in self._txns if t in self._begin_pos and t not in self._end_pos}

    def begin_pos(self, t: int) -> int:
        return self._begin_pos[t]

    def end_pos(self, t: int) -> int:
        """Position of End(T); +inf if T has not ended in this prefix."""
        return self._end_pos.get(t, 1 << 62)

    def is_committed(self, t: int) -> bool:
        return t in self._committed

    def commit_order(self) -> list[int]:
        """Committed transactions in End() order — the SI version order."""
        return sorted(self._committed, key=self._end_pos.__getitem__)

    def reads_of(self, t: int) -> list[tuple[int, str, int]]:
        return list(self._reads.get(t, ()))

    def writes_of(self, t: int) -> list[tuple[int, str]]:
        return list(self._writes.get(t, ()))

    def writeset(self, t: int) -> set[str]:
        return {k for _, k in self._writes.get(t, ())}

    def readset(self, t: int) -> set[str]:
        return {k for _, k, _ in self._reads.get(t, ())}

    def is_read_only(self, t: int) -> bool:
        return not self._writes.get(t)

    def concurrent(self, ta: int, tb: int) -> bool:
        """Lifetime intervals [Begin, End] overlap (paper Sec. 4.3)."""
        if ta == tb:
            return False
        ba, ea = self._begin_pos.get(ta, 1 << 62), self.end_pos(ta)
        bb, eb = self._begin_pos.get(tb, 1 << 62), self.end_pos(tb)
        return not (ea < bb or eb < ba)

    # ------------------------------------------------------------- projections
    def committed_projection(self) -> "History":
        """The committed projection: ops of committed transactions only."""
        keep = self._committed
        return History(op for op in self.ops if op.txn in keep)

    def without_txn(self, t: int) -> "History":
        """h' in Theorem 4.4: h with all operations of txn t removed."""
        return History(op for op in self.ops if op.txn != t)

    def prefix(self, n: int) -> "History":
        return History(self.ops[:n])

    def __repr__(self) -> str:
        return " ".join(repr(op) for op in self.ops)


def read_only_anomaly_example() -> History:
    """The paper's h_s (Sec 3.3), Fekete/O'Neil read-only anomaly.

    h_s: R2(X0,0) R2(Y0,0) R1(Y0,0) W1(Y1,20) C1 R3(X0,0) R3(Y1,20) C3
         W2(X2,-11) C2

    T3 is the read-only transaction whose participation creates the cycle
    T1 -wr-> T3 -rw-> T2 -rw-> T1.
    """
    h = History()
    h.extend([
        b(2), r(2, "X", T0), r(2, "Y", T0),
        b(1), r(1, "Y", T0), w(1, "Y"), c(1),
        b(3), r(3, "X", T0), r(3, "Y", 1), c(3),
        w(2, "X"), c(2),
    ])
    return h
