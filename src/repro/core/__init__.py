"""Core: the paper's contribution — RSS theory + SSI-based construction.

Layers:
  history.py        Adya-style multiversion histories (VOCSR prerequisites)
  dsg.py            direct serialization graph, cycles, reachability
  ssi.py            SI-V / SI-W / vulnerable deps / dangerous structures
  rss.py            Definition 4.1/4.2, Algorithm 1, PRoT construction
  safe_snapshots.py Ports & Grittner deferrable-snapshot baseline
  wal.py            begin/commit/abort + rw-dependency logical messages
  replica.py        log-shipping replay, RSS manager, PRoT manager
"""

from .history import (History, Op, T0, b, r, w, c, a,
                      read_only_anomaly_example)
from .dsg import DSG, Edge, build_dsg, is_serializable, find_cycle, WW, WR, RW
from .ssi import (si_v_holds, si_w_holds, is_si_history, vulnerable_edges,
                  dangerous_structures, fatal_dangerous_structures,
                  ssi_accepts, Vulnerable)
from .rss import (is_rss, rss_violations, done_set, clear_set, obscure_set,
                  construct_rss, construct_rss_ssi, IncrementalRss, advance,
                  latest_versions_in, protected_read, with_protected_reader)
from .safe_snapshots import snapshot_is_safe, earliest_safe_point, reader_wait
from .wal import Wal, WalRecord
from .replica import RSSManager, PRoTManager, RssSnapshot, replicate

__all__ = [
    "History", "Op", "T0", "b", "r", "w", "c", "a",
    "read_only_anomaly_example",
    "DSG", "Edge", "build_dsg", "is_serializable", "find_cycle",
    "WW", "WR", "RW",
    "si_v_holds", "si_w_holds", "is_si_history", "vulnerable_edges",
    "dangerous_structures", "fatal_dangerous_structures",
    "ssi_accepts", "Vulnerable",
    "is_rss", "rss_violations", "done_set", "clear_set", "obscure_set",
    "construct_rss", "construct_rss_ssi", "IncrementalRss", "advance",
    "latest_versions_in",
    "protected_read", "with_protected_reader",
    "snapshot_is_safe", "earliest_safe_point", "reader_wait",
    "Wal", "WalRecord", "RSSManager", "PRoTManager", "RssSnapshot",
    "replicate",
]
