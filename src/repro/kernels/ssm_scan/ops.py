"""Public op wrapper for the selective-scan kernel."""

from ..config import resolve_interpret
from .kernel import ssm_scan
from .ref import ssm_scan_ref


def selective_scan(u, dt, B, C, A, D, *, use_kernel=True, interpret=None):
    if use_kernel:
        return ssm_scan(u, dt, B, C, A, D,
                        interpret=resolve_interpret(interpret))
    return ssm_scan_ref(u, dt, B, C, A, D)
