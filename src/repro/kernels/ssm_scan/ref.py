"""Sequential oracle for the Mamba selective scan."""

import jax
import jax.numpy as jnp


def ssm_scan_ref(u, dt, B, C, A, D):
    """u/dt [Bb,T,Di]; B/C [Bb,T,N]; A [Di,N]; D [Di]."""
    uf, dtf = u.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    Af, Df = A.astype(jnp.float32), D.astype(jnp.float32)

    def per_seq(u1, dt1, B1, C1):
        def step(h, xs):
            ut, dtt, Bt, Ct = xs
            h = jnp.exp(dtt[:, None] * Af) * h \
                + (dtt * ut)[:, None] * Bt[None, :]
            y = (h * Ct[None, :]).sum(-1) + Df * ut
            return h, y
        h, y = jax.lax.scan(step,
                            jnp.zeros((u1.shape[1], Bf.shape[-1]),
                                      jnp.float32),
                            (u1, dt1, B1, C1))
        return y, h

    y, h = jax.vmap(per_seq)(uf, dtf, Bf, Cf)
    return y, h
