"""Pallas TPU kernel: Mamba selective-state-space scan.

    h_t = exp(dt_t · A) ⊙ h_{t-1} + (dt_t · u_t) ⊗ B_t
    y_t = h_t · C_t + D ⊙ u_t

Grid (B, Di_blocks, nC) with the chunk axis innermost: the [bDi, N] state
carries in VMEM scratch across chunk iterations (sequential on-core), so HBM
traffic is a single stream over u/dt/B/C and one y write — the memory-bound
optimum for the recurrence.  dt·A decays are computed in fp32 in-kernel
(numerically bounded: every factor is in (0,1]).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, dt_ref, B_ref, C_ref, A_ref, D_ref, y_ref, hf_ref,
            h_scr, *, chunk: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    u = u_ref[0].astype(jnp.float32)          # [c, bDi]
    dt = dt_ref[0].astype(jnp.float32)        # [c, bDi]
    Bm = B_ref[0].astype(jnp.float32)         # [c, N]
    Cm = C_ref[0].astype(jnp.float32)         # [c, N]
    A = A_ref[...].astype(jnp.float32)        # [bDi, N]
    D = D_ref[...].astype(jnp.float32)        # [bDi]

    def step(t, carry):
        h, ys = carry
        dtt = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]    # [bDi]
        ut = jax.lax.dynamic_slice_in_dim(u, t, 1, 0)[0]      # [bDi]
        Bt = jax.lax.dynamic_slice_in_dim(Bm, t, 1, 0)[0]     # [N]
        Ct = jax.lax.dynamic_slice_in_dim(Cm, t, 1, 0)[0]     # [N]
        a = jnp.exp(dtt[:, None] * A)                         # [bDi,N]
        h = a * h + (dtt * ut)[:, None] * Bt[None, :]
        yt = (h * Ct[None, :]).sum(axis=1) + D * ut           # [bDi]
        ys = jax.lax.dynamic_update_slice_in_dim(ys, yt[None], t, 0)
        return h, ys

    h0 = h_scr[...]
    h, ys = jax.lax.fori_loop(
        0, chunk, step, (h0, jnp.zeros((chunk, u.shape[1]), jnp.float32)))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _final():
        hf_ref[0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "block_di",
                                             "interpret"))
def ssm_scan(u, dt, B, C, A, D, *, chunk: int = 128, block_di: int = 128,
             interpret: bool = True):
    """u/dt [Bb, T, Di]; B/C [Bb, T, N]; A [Di, N]; D [Di].
    Returns (y [Bb,T,Di] fp32, h_final [Bb, Di, N] fp32)."""
    Bb, T, Di = u.shape
    N = B.shape[-1]
    c = min(chunk, T)
    bdi = min(block_di, Di)
    assert T % c == 0 and Di % bdi == 0
    nc, ndi = T // c, Di // bdi
    kernel = functools.partial(_kernel, chunk=c, nc=nc)
    y, hf = pl.pallas_call(
        kernel,
        grid=(Bb, ndi, nc),
        in_specs=[
            pl.BlockSpec((1, c, bdi), lambda b, d, i: (b, i, d)),   # u
            pl.BlockSpec((1, c, bdi), lambda b, d, i: (b, i, d)),   # dt
            pl.BlockSpec((1, c, N), lambda b, d, i: (b, i, 0)),     # B
            pl.BlockSpec((1, c, N), lambda b, d, i: (b, i, 0)),     # C
            pl.BlockSpec((bdi, N), lambda b, d, i: (d, 0)),         # A
            pl.BlockSpec((bdi,), lambda b, d, i: (d,)),             # D
        ],
        out_specs=[
            pl.BlockSpec((1, c, bdi), lambda b, d, i: (b, i, d)),
            pl.BlockSpec((1, bdi, N), lambda b, d, i: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, T, Di), jnp.float32),
            jax.ShapeDtypeStruct((Bb, Di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bdi, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, B, C, A, D)
    return y, hf
