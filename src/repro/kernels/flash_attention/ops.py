"""Public op: model-layout adapter for the flash attention kernel."""

from __future__ import annotations

import jax

from ..config import resolve_interpret
from .kernel import flash_attention
from .ref import attention_ref


def attention_bshd(q, k, v, *, causal=True, window=0, use_kernel=True,
                   interpret=None):
    """Model layout [B,S,H,hd] / [B,T,K,hd] wrapper (kernel uses [B,H,S,hd])."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if use_kernel:
        o = flash_attention(qt, kt, vt, causal=causal, window=window,
                            interpret=resolve_interpret(interpret))
    else:
        o = attention_ref(qt, kt, vt, causal=causal, window=window)
    return o.transpose(0, 2, 1, 3)
