"""Naive full-materialization oracle for flash_attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q [B,H,S,hd]; k/v [B,K,T,hd] (H = K·G) -> [B,H,S,hd].  fp32 math."""
    B, H, S, hd = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    qf = q.reshape(B, K, G, S, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bkgsh,bkth->bkgst", qf, k.astype(jnp.float32))
    q_pos = jnp.arange(S)[:, None]
    kv_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= q_pos >= kv_pos
    if window > 0:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bkth->bkgsh", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, hd).astype(q.dtype)
