"""Pallas TPU flash attention (causal / sliding-window, GQA).

Grid (B, H, nQ, nK) with the KV axis innermost: the online-softmax state
(m, l, acc) lives in VMEM scratch and persists across the nK iterations of a
fixed (b, h, iq); the output tile is written on the last KV step.  Causal and
sliding-window masking prune whole KV blocks via a cheap in-kernel
early-exit predicate (pl.when), so SWA cost is O(S·W) not O(S²).

Block shapes default to (128 q × 128 kv × head_dim) — MXU-aligned (the two
matmuls are [bq,hd]×[hd,bk] and [bq,bk]×[bk,hd]); fp32 accumulation.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kv_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    # block-level relevance: any (q, kv) pair in range?
    block_live = True
    if causal:
        block_live = (iq * bq + bq - 1) >= (ik * bk)
    if window > 0:
        block_live = jnp.logical_and(
            block_live, (iq * bq) - (ik * bk + bk - 1) < window)

    @pl.when(block_live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)               # [bk, hd]
        s = q @ k.T                                       # [bq, bk]
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= kv_pos
        if window > 0:
            mask &= (q_pos - kv_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        correction = jnp.exp(m_prev - m_new)
        v = v_ref[0, 0].astype(jnp.float32)               # [bk, hd]
        acc_scr[...] = acc_scr[...] * correction[:, None] + p @ v
        l_scr[...] = l_scr[...] * correction + jnp.sum(p, axis=1)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q [B,H,S,hd]; k/v [B,K,T,hd] with H = K·G (GQA) -> [B,H,S,hd]."""
    B, H, S, hd = q.shape
    Bk, K, T, _ = k.shape
    assert Bk == B and H % K == 0
    G = H // K
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0
    nq, nk = S // bq, T // bk
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            _scratch((bq,), jnp.float32),          # running max m
            _scratch((bq,), jnp.float32),          # running denom l
            _scratch((bq, hd), jnp.float32),       # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def _scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
