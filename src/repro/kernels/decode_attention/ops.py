"""Public op wrapper for decode attention."""

from ..config import resolve_interpret
from .kernel import decode_attention
from .ref import decode_attention_ref


def decode_gqa(q, k, v, valid_len, *, use_kernel=True, interpret=None):
    if use_kernel:
        return decode_attention(q, k, v, valid_len,
                                interpret=resolve_interpret(interpret))
    return decode_attention_ref(q, k, v, valid_len)
