"""Pallas TPU decode attention (one query token, GQA, ring/length-masked KV).

Grid (B, K, nT): per (batch, kv-head) the G grouped query rows attend over
the KV cache in bT-sized blocks with online-softmax state in VMEM scratch —
the flash-decoding split-KV pattern adapted to a sequential TPU grid (state
carry instead of a cross-core reduction; the `model`-axis split-KV variant
lives at the GSPMD level, see launch/shardings.py cache rules).

`valid_len` masks cache slots >= the current length (scalar prefetch-style
operand, broadcast into the block mask).  Blocks entirely past `valid_len`
skip compute via pl.when.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(vl_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, bt: int, nt: int):
    it = pl.program_id(2)
    valid = vl_ref[0]

    @pl.when(it == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(it * bt < valid)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [G, hd]
        k = k_ref[0, 0].astype(jnp.float32)                # [bt, hd]
        s = q @ k.T                                        # [G, bt]
        kv_pos = it * bt + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kv_pos < valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        v = v_ref[0, 0].astype(jnp.float32)                # [bt, hd]
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        m_scr[...] = m_new

    @pl.when(it == nt - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len, *, block_t: int = 256,
                     interpret: bool = True) -> jax.Array:
    """q [B,H,hd]; k/v [B,K,T,hd]; valid_len scalar int32 -> [B,H,hd]."""
    B, H, hd = q.shape
    _, K, T, _ = k.shape
    G = H // K
    bt = min(block_t, T)
    assert T % bt == 0
    nt = T // bt
    qg = q.reshape(B, K, G, hd)
    vl = jnp.asarray(valid_len, jnp.int32).reshape(1)
    kernel = functools.partial(_kernel, scale=1.0 / math.sqrt(hd),
                               bt=bt, nt=nt)
    out = pl.pallas_call(
        kernel,
        grid=(B, K, nt),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, t: (0,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bt, hd), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bt, hd), lambda b, h, t: (b, h, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(vl, qg, k, v)
    return out.reshape(B, H, hd)
