"""Oracle for decode attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid_len) -> jax.Array:
    """q [B,H,hd]; k/v [B,K,T,hd]; -> [B,H,hd] over the first valid_len
    cache slots."""
    B, H, hd = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    qf = q.reshape(B, K, G, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bkgh,bkth->bkgt", qf, k.astype(jnp.float32))
    mask = jnp.arange(T)[None, None, None, :] < valid_len
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,bkth->bkgh", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
