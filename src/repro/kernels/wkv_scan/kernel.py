"""Pallas TPU kernel: RWKV6 WKV recurrence (data-dependent per-channel decay).

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state S: [N, N])
    o_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t

Grid (B·H, nC): the time axis is innermost and executes sequentially on a
TPU core, so the [N, N] state lives in VMEM scratch and carries across chunk
iterations — HBM traffic is exactly one streaming read of r/k/v/w and one
write of o (plus the final state), the memory-bound optimum.  Inside a chunk
the recurrence is an explicit fori_loop of rank-1 updates: N=64 keeps
S at 16 KB fp32, far under VMEM, and each update is VPU-friendly
elementwise work on [N, N].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_final_ref, s_scr, *,
            chunk: int, nc: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)          # [c, N]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = jnp.exp(w_ref[0].astype(jnp.float32))  # per-step decay in (0,1]
    u = u_ref[0].astype(jnp.float32)          # [N]

    def step(t, carry):
        S, out = carry
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)      # [1, N]
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        kv = kt.T @ vt                                     # [N, N]
        ot = rt @ (S + u[:, None] * kv)                    # [1, N]
        S = wt.T * S + kv
        out = jax.lax.dynamic_update_slice_in_dim(out, ot, t, 0)
        return S, out

    S0 = s_scr[...]
    S, out = jax.lax.fori_loop(0, chunk, step,
                               (S0, jnp.zeros((chunk, r.shape[1]),
                                              jnp.float32)))
    s_scr[...] = S
    o_ref[0] = out.astype(o_ref.dtype)

    @pl.when(ic == nc - 1)
    def _final():
        s_final_ref[0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_scan(r, k, v, w_log, u, *, chunk: int = 128,
             interpret: bool = True):
    """r/k/v/w_log [BH, T, N] (batch×heads flattened); u [BH, N].
    Returns (o [BH,T,N] fp32, S_final [BH,N,N] fp32)."""
    BH, T, N = r.shape
    c = min(chunk, T)
    assert T % c == 0
    nc = T // c
    kernel = functools.partial(_kernel, chunk=c, nc=nc)
    o, s_final = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, c, N), lambda b, i: (b, i, 0)),   # r
            pl.BlockSpec((1, c, N), lambda b, i: (b, i, 0)),   # k
            pl.BlockSpec((1, c, N), lambda b, i: (b, i, 0)),   # v
            pl.BlockSpec((1, c, N), lambda b, i: (b, i, 0)),   # w_log
            pl.BlockSpec((1, N), lambda b, i: (b, 0)),         # u
        ],
        out_specs=[
            pl.BlockSpec((1, c, N), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, N, N), lambda b, i: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, N), jnp.float32),
            jax.ShapeDtypeStruct((BH, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w_log, u)
    return o, s_final
