"""Public op wrapper for the WKV6 scan kernel."""

import jax.numpy as jnp

from ..config import resolve_interpret
from .kernel import wkv_scan
from .ref import wkv_scan_ref


def wkv(r, k, v, w_log, u, *, use_kernel=True, interpret=None):
    """Model layout [B,T,H,N] + u [H,N] -> (o [B,T,H,N], S [B,H,N,N])."""
    B, T, H, N = r.shape
    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, N)
    uf = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, N)
    fn = wkv_scan if use_kernel else (lambda *a, **kw: wkv_scan_ref(*a))
    o, S = fn(flat(r), flat(k), flat(v), flat(w_log), uf,
              **({"interpret": resolve_interpret(interpret)}
                 if use_kernel else {}))
    o = o.reshape(B, H, T, N).transpose(0, 2, 1, 3)
    return o, S.reshape(B, H, N, N)
