"""Sequential oracle for the WKV6 recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_scan_ref(r, k, v, w_log, u):
    """r/k/v/w_log [BH,T,N]; u [BH,N] -> (o [BH,T,N] fp32, S [BH,N,N])."""
    BH, T, N = r.shape
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    wf = jnp.exp(w_log.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def per_seq(r1, k1, v1, w1, u1):
        def step(S, xs):
            rt, kt, vt, wt = xs
            kv = jnp.outer(kt, vt)
            ot = (S + u1[:, None] * kv).T @ rt
            S = wt[:, None] * S + kv
            return S, ot
        S, o = jax.lax.scan(step, jnp.zeros((N, N), jnp.float32),
                            (r1, k1, v1, w1))
        return o, S

    o, S = jax.vmap(per_seq)(rf, kf, vf, wf, uf)
    return o, S
