"""Public op: snapshot_read_members — Pallas kernel or jnp fallback."""

from __future__ import annotations

from typing import Optional

import jax

from ..config import resolve_interpret
from .kernel import rss_gather
from .ref import rss_gather_ref


def snapshot_read_members(store: dict, member_ts, floor=0, *,
                          use_kernel: bool = True,
                          interpret: Optional[bool] = None) -> jax.Array:
    """RSS membership read over a paged store {'data': [P,K,E], 'ts': [P,K]}.

    member_ts is the sorted int32 array of member commit timestamps ABOVE
    the snapshot floor (the commit-seq image of an exported `RssSnapshot`:
    `snap.member_seqs` + `snap.floor_seq`); every version at ts <= floor is
    a floor-covered member's.  interpret defaults to the REPRO_INTERPRET
    switch (`repro.kernels.config`): interpret mode validates the kernel
    code path on CPU; REPRO_INTERPRET=0 (or interpret=False) compiles for
    TPU."""
    if not use_kernel:
        return rss_gather_ref(store["data"], store["ts"], member_ts, floor)
    return rss_gather(store["data"], store["ts"], member_ts, floor,
                      interpret=resolve_interpret(interpret))
