"""Public op: snapshot_read_members — Pallas kernel or jnp fallback."""

from __future__ import annotations

import jax

from .kernel import rss_gather
from .ref import rss_gather_ref


def snapshot_read_members(store: dict, member_ts, floor=0, *,
                          use_kernel: bool = True,
                          interpret: bool = True) -> jax.Array:
    """RSS membership read over a paged store {'data': [P,K,E], 'ts': [P,K]}.

    member_ts is the sorted int32 array of member commit timestamps ABOVE
    the snapshot floor (the commit-seq image of an exported `RssSnapshot`:
    `snap.member_seqs` + `snap.floor_seq`); every version at ts <= floor is
    a floor-covered member's.  interpret=True (default) runs the Pallas
    kernel in interpret mode so the same code path validates on CPU; on TPU
    pass interpret=False."""
    if not use_kernel:
        return rss_gather_ref(store["data"], store["ts"], member_ts, floor)
    return rss_gather(store["data"], store["ts"], member_ts, floor,
                      interpret=interpret)
