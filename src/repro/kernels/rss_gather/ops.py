"""Public op: snapshot_read_members — Pallas kernel or jnp fallback."""

from __future__ import annotations

import jax

from .kernel import rss_gather
from .ref import rss_gather_ref


def snapshot_read_members(store: dict, member_ts, *, use_kernel: bool = True,
                          interpret: bool = True) -> jax.Array:
    """RSS membership read over a paged store {'data': [P,K,E], 'ts': [P,K]}.

    member_ts is the sorted int32 array of member commit timestamps (the
    commit-seq image of an exported `RssSnapshot`).  interpret=True (default)
    runs the Pallas kernel in interpret mode so the same code path validates
    on CPU; on TPU pass interpret=False."""
    if not use_kernel:
        return rss_gather_ref(store["data"], store["ts"], member_ts)
    return rss_gather(store["data"], store["ts"], member_ts,
                      interpret=interpret)
