"""Pure-jnp oracle for the rss_gather kernel (RSS membership read protocol)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rss_visible_slots_ref(ts: jax.Array, member_ts: jax.Array,
                          floor: jax.Array | int = 0) -> jax.Array:
    """ts [P,K] int32, member_ts sorted [M] int32, scalar floor -> [P] slot
    index of the newest slot whose ts is at-or-below `floor` (compressed-
    snapshot watermark; 0 = initial versions only) or a member (ties:
    lowest slot).

    M == 0 with floor 0 (empty RSS) resolves every page to its newest
    ts == 0 slot."""
    if member_ts.shape[0] == 0:
        is_member = ts <= floor
    else:
        is_member = (ts <= floor) | jnp.any(
            ts[:, :, None] == member_ts[None, None, :], axis=-1)
    masked = jnp.where(is_member, ts, -1)                   # [P,K]
    best = jnp.max(masked, axis=1, keepdims=True)
    onehot = masked == best
    idx = jnp.arange(ts.shape[1], dtype=jnp.int32)[None, :]
    return jnp.min(jnp.where(onehot, idx, ts.shape[1]), axis=1).astype(
        jnp.int32)


def rss_gather_ref(data: jax.Array, ts: jax.Array, member_ts: jax.Array,
                   floor: jax.Array | int = 0) -> jax.Array:
    """data [P,K,E], ts [P,K], sorted member_ts [M], scalar floor -> [P,E]:
    payload of the newest slot whose commit-ts is floor-covered or in the
    RSS member-ts set."""
    first = rss_visible_slots_ref(ts, member_ts, floor)
    return jnp.take_along_axis(data, first[:, None, None], axis=1)[:, 0]
