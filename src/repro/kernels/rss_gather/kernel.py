"""Pallas TPU kernel: RSS set-membership visibility resolution + page gather.

Contract (matches ref.py and `tensorstore.paged.visible_slots_members`):
    data      [P, K, E]  page payloads, K version slots per page
    ts        [P, K]     int32 commit timestamp per slot (0 = initial version)
    member_ts [M]        sorted int32 commit timestamps of RSS members
                         ABOVE the snapshot floor
    floor     scalar     compressed-snapshot watermark: every committed
                         version at ts <= floor belongs to a member
                         (0 = no floor: initial versions only)
    out       [P, E]     payload of the newest slot whose ts is <= floor
                         or a member

The floor keeps the member array bounded by the concurrent transaction
window instead of growing with history — the kernel-side half of the
incremental-RSS compressed snapshot export.

This is the RSS read protocol of the paper vectorized for TPU: instead of a
prefix watermark (`version_gather`), visibility is membership in the exported
snapshot set — the previous-version read that skips committed-but-not-member
writers.  Same block/VMEM tiling discipline as `version_gather`: pages are
blocked into VMEM tiles, slot selection is a masked arg-max over the small K
axis via a one-hot reduction (VPU-friendly, no scalar loops).

Membership is a broadcast compare against the member array, padded to a
lane-aligned [1, Mp] tile with -1 sentinels (valid commit-ts are >= 0, so
padding never matches).  An EMPTY member set (M == 0) therefore degenerates
to the ts == 0 test alone and resolves every page to its initial slot — the
empty-RSS edge case the jnp searchsorted formulation got wrong.

Arithmetic intensity ≈ (K·M compares + K FMA) per K·E-byte page read — still
memory-bound for realistic M, so the roofline target stays HBM bandwidth.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(mem_ref, floor_ref, ts_ref, data_ref, out_ref):
    ts = ts_ref[...]                           # [BP, K] int32
    mem = mem_ref[...]                         # [1, Mp] int32 (-1 padded)
    floor = floor_ref[0, 0]                    # scalar watermark
    is_member = (ts <= floor) | jnp.any(
        ts[:, :, None] == mem[0][None, None, :], axis=-1)
    masked = jnp.where(is_member, ts, -1)      # non-member slots -> -1
    best = jnp.max(masked, axis=1, keepdims=True)          # [BP, 1]
    onehot = masked == best                                # [BP, K] bool
    # deterministic tie-break toward the lowest slot index (matches the
    # argmax-first semantics of the jnp oracle)
    idx = jnp.arange(ts.shape[1], dtype=jnp.int32)[None, :]
    first = jnp.min(jnp.where(onehot, idx, ts.shape[1]), axis=1,
                    keepdims=True)
    onehot = idx == first
    data = data_ref[...]                       # [BP, K, BE]
    sel = onehot.astype(data.dtype)[:, :, None] * data
    out_ref[...] = jnp.sum(sel, axis=1)


@functools.partial(jax.jit, static_argnames=("block_pages", "block_elems",
                                             "interpret"))
def rss_gather(data: jax.Array, ts: jax.Array, member_ts: jax.Array,
               floor: jax.Array | int = 0,
               *, block_pages: int = 8, block_elems: int = 512,
               interpret: bool = True) -> jax.Array:
    """Pallas RSS membership read.  interpret=True executes on CPU
    (validation); interpret=False targets TPU."""
    P, K, E = data.shape
    assert ts.shape == (P, K)
    bp = min(block_pages, P)
    be = min(block_elems, E)
    assert P % bp == 0 and E % be == 0, (P, bp, E, be)
    M = member_ts.shape[0]
    mp = max(128, -(-M // 128) * 128)          # lane-aligned, >= 1 tile
    mem = jnp.full((1, mp), -1, jnp.int32)
    if M:
        mem = mem.at[0, :M].set(member_ts.astype(jnp.int32))
    # scalar floor as a lane-aligned [1, 128] tile (same idiom as members;
    # valid commit-ts are >= 0 so the kernel only reads element [0, 0])
    floor_tile = jnp.full((1, 128), jnp.asarray(floor, jnp.int32))
    grid = (P // bp, E // be)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, mp), lambda i, j: (0, 0)),       # members
            pl.BlockSpec((1, 128), lambda i, j: (0, 0)),      # floor
            pl.BlockSpec((bp, K), lambda i, j: (i, 0)),       # ts
            pl.BlockSpec((bp, K, be), lambda i, j: (i, 0, j)),  # data
        ],
        out_specs=pl.BlockSpec((bp, be), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((P, E), data.dtype),
        interpret=interpret,
    )(mem, floor_tile, ts, data)
