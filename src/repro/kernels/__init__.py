"""Pallas TPU kernels (validated in interpret mode on CPU).

version_gather   — SI-V snapshot visibility gather (the paper's hot spot)
rss_gather       — RSS set-membership visibility gather (previous-version read)
rss_scan_agg     — fused RSS visibility resolve + on-device aggregate
                   (sum/count/count-below/min/max over member-visible pages)
flash_attention  — causal/SWA GQA prefill-train attention
decode_attention — one-token GQA decode over ring caches
wkv_scan         — RWKV6 data-dependent-decay recurrence

Every op's `interpret` argument defaults to the REPRO_INTERPRET environment
switch (`repro.kernels.config`): =1 interpret mode (CPU validation, the
default), =0 compiled for TPU — the one-flag flip for hardware runs.
"""

from .config import default_interpret, resolve_interpret

__all__ = ["default_interpret", "resolve_interpret"]
