"""Pallas TPU kernels (validated in interpret mode on CPU).

version_gather   — SI-V snapshot visibility gather (the paper's hot spot)
rss_gather       — RSS set-membership visibility gather (previous-version read)
flash_attention  — causal/SWA GQA prefill-train attention
decode_attention — one-token GQA decode over ring caches
wkv_scan         — RWKV6 data-dependent-decay recurrence
"""
