"""Public op: snapshot_read — dispatches Pallas kernel or jnp fallback."""

from __future__ import annotations

from typing import Optional

import jax

from ..config import resolve_interpret
from .kernel import version_gather
from .ref import version_gather_ref


def snapshot_read(store: dict, watermark, *, use_kernel: bool = True,
                  interpret: Optional[bool] = None) -> jax.Array:
    """SI-V read over a paged store {'data': [P,K,E], 'ts': [P,K]}.

    interpret defaults to the REPRO_INTERPRET switch
    (`repro.kernels.config`): interpret mode validates the kernel code path
    on CPU; REPRO_INTERPRET=0 (or interpret=False) compiles for TPU."""
    if not use_kernel:
        return version_gather_ref(store["data"], store["ts"], watermark)
    return version_gather(store["data"], store["ts"], watermark,
                          interpret=resolve_interpret(interpret))
