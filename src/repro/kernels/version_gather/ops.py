"""Public op: snapshot_read — dispatches Pallas kernel or jnp fallback."""

from __future__ import annotations

import jax

from .kernel import version_gather
from .ref import version_gather_ref


def snapshot_read(store: dict, watermark, *, use_kernel: bool = True,
                  interpret: bool = True) -> jax.Array:
    """SI-V read over a paged store {'data': [P,K,E], 'ts': [P,K]}.

    interpret=True (default) runs the Pallas kernel in interpret mode so the
    same code path validates on CPU; on TPU pass interpret=False."""
    if not use_kernel:
        return version_gather_ref(store["data"], store["ts"], watermark)
    return version_gather(store["data"], store["ts"], watermark,
                          interpret=interpret)
