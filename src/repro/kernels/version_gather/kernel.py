"""Pallas TPU kernel: SI-V snapshot visibility resolution + page gather.

Contract (matches ref.py):
    data [P, K, E]   page payloads, K version slots per page
    ts   [P, K]      int32 commit timestamp per slot (0 = initial version)
    watermark        scalar int32 snapshot horizon
    out  [P, E]      payload of the newest slot with ts <= watermark

TPU adaptation of the paper's tuple-visibility walk: pages are blocked into
VMEM tiles; slot selection is a masked arg-max over the K (small) slot axis
done as a one-hot reduction so it vectorizes on the VPU — no per-page scalar
loop, no HBM round-trips beyond the single streaming read of `data`.

Block shapes: (BP pages × K slots × BE elems); BE is lane-aligned (128) and
BP sublane-aligned (8).  The slot one-hot multiply-add reads K·BP·BE elems
and writes BP·BE — the kernel is purely memory-bound (arithmetic intensity
≈ 1 FLOP / K·bytes), so the roofline target is HBM bandwidth.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(wm_ref, ts_ref, data_ref, out_ref):
    ts = ts_ref[...]                         # [BP, K] int32
    wm = wm_ref[0]
    masked = jnp.where(ts <= wm, ts, -1)     # invisible slots -> -1
    best = jnp.max(masked, axis=1, keepdims=True)        # [BP, 1]
    onehot = (masked == best)                            # [BP, K] bool
    # break ties toward the lowest slot index (unique ts makes this moot,
    # but the kernel must be deterministic regardless)
    idx = jnp.arange(ts.shape[1], dtype=jnp.int32)[None, :]
    first = jnp.min(jnp.where(onehot, idx, ts.shape[1]), axis=1,
                    keepdims=True)
    onehot = (idx == first)
    data = data_ref[...]                     # [BP, K, BE]
    sel = onehot.astype(data.dtype)[:, :, None] * data
    out_ref[...] = jnp.sum(sel, axis=1)


@functools.partial(jax.jit, static_argnames=("block_pages", "block_elems",
                                             "interpret"))
def version_gather(data: jax.Array, ts: jax.Array, watermark: jax.Array,
                   *, block_pages: int = 8, block_elems: int = 512,
                   interpret: bool = True) -> jax.Array:
    """Pallas snapshot read.  interpret=True executes on CPU (validation);
    interpret=False targets TPU."""
    P, K, E = data.shape
    assert ts.shape == (P, K)
    bp = min(block_pages, P)
    be = min(block_elems, E)
    assert P % bp == 0 and E % be == 0, (P, bp, E, be)
    wm = jnp.asarray(watermark, jnp.int32).reshape(1)
    grid = (P // bp, E // be)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),            # watermark
            pl.BlockSpec((bp, K), lambda i, j: (i, 0)),       # ts
            pl.BlockSpec((bp, K, be), lambda i, j: (i, 0, j)),  # data
        ],
        out_specs=pl.BlockSpec((bp, be), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((P, E), data.dtype),
        interpret=interpret,
    )(wm, ts, data)
