"""Pure-jnp oracle for the version_gather kernel (SI-V read protocol)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def version_gather_ref(data: jax.Array, ts: jax.Array,
                       watermark) -> jax.Array:
    """data [P,K,E], ts [P,K], scalar watermark -> [P,E]: payload of the
    newest slot with ts <= watermark (ties: lowest slot index)."""
    wm = jnp.asarray(watermark, jnp.int32)
    masked = jnp.where(ts <= wm, ts, -1)                    # [P,K]
    best = jnp.max(masked, axis=1, keepdims=True)
    onehot = masked == best
    idx = jnp.arange(ts.shape[1], dtype=jnp.int32)[None, :]
    first = jnp.min(jnp.where(onehot, idx, ts.shape[1]), axis=1)
    return jnp.take_along_axis(data, first[:, None, None], axis=1)[:, 0]
