"""One execution-mode switch for every Pallas kernel op.

All kernel ops (`repro.kernels.*.ops`) default their `interpret` argument to
None, which resolves through `resolve_interpret` against the REPRO_INTERPRET
environment variable:

    REPRO_INTERPRET=1 (default)  — Pallas interpret mode: the kernels execute
                                   on CPU, validating the exact kernel code
                                   path in every test/CI run.
    REPRO_INTERPRET=0            — compiled mode for real TPU hardware: the
                                   one-flag flip for the roofline-validating
                                   benchmark run (ROADMAP "TPU-compiled
                                   benchmark run").

An explicit `interpret=True/False` at a call site always wins over the
environment, so tests can pin a mode regardless of how CI is configured.
"""

from __future__ import annotations

import os
from typing import Optional

_FALSE = ("0", "false", "no", "off")


def default_interpret() -> bool:
    """The environment-configured Pallas execution mode (True = interpret)."""
    return os.environ.get("REPRO_INTERPRET", "1").strip().lower() not in _FALSE


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve an op's `interpret` argument: None defers to REPRO_INTERPRET;
    an explicit boolean wins."""
    return default_interpret() if interpret is None else bool(interpret)
