"""Pure-jnp oracle for the fused rss_scan_agg kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..rss_gather.ref import rss_visible_slots_ref
from .kernel import SELECT_BLOCK, _chunk_shape

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min


def rss_scan_agg_ref(data: jax.Array, ts: jax.Array, member_ts: jax.Array,
                     floor: jax.Array | int = 0,
                     tag_main: jax.Array | int = 1,
                     tag_alt: jax.Array | int = -2,
                     threshold: jax.Array | int = _I32_MAX,
                     *, block_pages: int = 8) -> jax.Array:
    """data [P,K,E] int32, ts [P,K], sorted member_ts [M], scalars ->
    [P/BP, 5] int32 per-block partials of [sum, count, count_below, min,
    max] of payload element 1 over member-visible pages whose tag (element
    0) is tag_main or tag_alt — the kernel's exact blocking, so kernel and
    oracle are bitwise comparable; fold the block axis on host (lanes 0-2
    add, 3 min, 4 max; `ops.fold_partials`) in Python ints so whole-scan
    sums never wrap int32.  Empty member set with floor 0 resolves initial
    slots only (rss_gather semantics); min/max carry INT32_MAX/INT32_MIN
    sentinels for blocks where nothing matched (count disambiguates)."""
    P = data.shape[0]
    bp = min(block_pages, P)
    assert P % bp == 0, (P, bp)
    slot = rss_visible_slots_ref(ts, member_ts, floor)
    sel = jnp.take_along_axis(data, slot[:, None, None], axis=1)[:, 0]
    tag = sel[:, 0].reshape(P // bp, bp)
    x = sel[:, 1].reshape(P // bp, bp)
    valid = (tag == tag_main) | (tag == tag_alt)
    return jnp.stack([
        jnp.sum(jnp.where(valid, x, 0), axis=1),
        jnp.sum(valid.astype(jnp.int32), axis=1),
        jnp.sum((valid & (x < threshold)).astype(jnp.int32), axis=1),
        jnp.min(jnp.where(valid, x, _I32_MAX), axis=1),
        jnp.max(jnp.where(valid, x, _I32_MIN), axis=1),
    ], axis=1).astype(jnp.int32)


def _group_param_cols(n_groups, tag_main, tag_alt, threshold, group_params):
    """Per-group (tag_main, tag_alt, threshold) columns [G] — scalar args
    broadcast when group_params is None (same contract as the kernel's
    group-param tile)."""
    if group_params is None:
        return (jnp.full((n_groups,), jnp.asarray(tag_main, jnp.int32)),
                jnp.full((n_groups,), jnp.asarray(tag_alt, jnp.int32)),
                jnp.full((n_groups,), jnp.asarray(threshold, jnp.int32)))
    prm = jnp.asarray(group_params, jnp.int32)
    return prm[:, 0], prm[:, 1], prm[:, 2]


def rss_scan_agg_grouped_ref(data: jax.Array, ts: jax.Array, gid: jax.Array,
                             member_ts: jax.Array,
                             floor: jax.Array | int = 0,
                             tag_main: jax.Array | int = 1,
                             tag_alt: jax.Array | int = -2,
                             threshold: jax.Array | int = _I32_MAX,
                             *, n_groups: int = 1,
                             group_params: jax.Array | None = None,
                             block_pages: int = 8) -> jax.Array:
    """GROUP BY twin of `rss_scan_agg_ref` (flat-lane blocking): `gid`
    [P, 1] int32 group id per page (-1 = no group), `n_groups`
    accumulator rows -> [P/BP, n_groups, 5] per-block per-group partials
    with the kernel's exact blocking (bitwise comparable; fold the block
    axis per group on host — `ops.fold_group_partials`).  group_params
    [n_groups, 3] gives each lane its own (tag_main, tag_alt, threshold).
    A group no page maps to folds to count 0 with min/max sentinels
    (empty-group semantics)."""
    P = data.shape[0]
    bp = min(block_pages, P)
    assert P % bp == 0, (P, bp)
    assert gid.shape == (P, 1)
    slot = rss_visible_slots_ref(ts, member_ts, floor)
    sel = jnp.take_along_axis(data, slot[:, None, None], axis=1)[:, 0]
    tag = sel[:, 0]
    x = sel[:, 1]                                          # [P]
    tmain, talt, thr = _group_param_cols(n_groups, tag_main, tag_alt,
                                         threshold, group_params)
    tagm = ((tag[:, None] == tmain[None, :]) |
            (tag[:, None] == talt[None, :]))               # [P, G]
    grp = (gid[:, 0][:, None] ==
           jnp.arange(n_groups, dtype=jnp.int32)[None, :]) & tagm
    grp = grp.reshape(P // bp, bp, n_groups)               # [NB, BP, G]
    xb = x.reshape(P // bp, bp)[:, :, None]
    thr3 = thr[None, None, :]
    return jnp.stack([
        jnp.sum(jnp.where(grp, xb, 0), axis=1),
        jnp.sum(grp.astype(jnp.int32), axis=1),
        jnp.sum((grp & (xb < thr3)).astype(jnp.int32), axis=1),
        jnp.min(jnp.where(grp, xb, _I32_MAX), axis=1),
        jnp.max(jnp.where(grp, xb, _I32_MIN), axis=1),
    ], axis=2).astype(jnp.int32)


def rss_scan_agg_chunked_ref(data: jax.Array, ts: jax.Array,
                             gid: jax.Array, member_ts: jax.Array,
                             floor: jax.Array | int = 0,
                             tag_main: jax.Array | int = 1,
                             tag_alt: jax.Array | int = -2,
                             threshold: jax.Array | int = _I32_MAX,
                             *, n_groups: int = 1,
                             group_params: jax.Array | None = None,
                             rows_per_step: int = 8,
                             fold_chunks: int = 8) -> jax.Array:
    """Oracle for `rss_scan_agg_chunked`: same chunk-aligned padding math
    (`_chunk_shape`), but each chunk reduces via `jax.ops.segment_*` —
    O(P) regardless of G, and bitwise equal to the kernel's one-hot sums
    (int32 addition is order-independent; segment_min/max identities are
    the kernel's sentinels).  Returns [chunks, n_groups, 5] int32."""
    P = data.shape[0]
    assert gid.shape == (P, 1)
    rows, _r, nc, Pp = _chunk_shape(P, rows_per_step, fold_chunks)
    del rows
    slot = rss_visible_slots_ref(ts, member_ts, floor)
    sel = jnp.take_along_axis(data, slot[:, None, None], axis=1)[:, 0]
    tag = sel[:, 0]
    x = sel[:, 1]
    g = gid[:, 0].astype(jnp.int32)
    if Pp != P:
        pad = Pp - P
        tag = jnp.concatenate([tag, jnp.full((pad,), -1, jnp.int32)])
        x = jnp.concatenate([x, jnp.zeros((pad,), jnp.int32)])
        g = jnp.concatenate([g, jnp.full((pad,), -1, jnp.int32)])
    tmain, talt, thr = _group_param_cols(n_groups, tag_main, tag_alt,
                                         threshold, group_params)
    gc = jnp.clip(g, 0, n_groups - 1)
    valid = (((tag == tmain[gc]) | (tag == talt[gc])) &
             (g >= 0) & (g < n_groups))
    seg = jnp.where(valid, g, n_groups)        # invalid -> spill segment
    below = (valid & (x < thr[gc])).astype(jnp.int32)
    cp = Pp // nc                              # pages per chunk
    out = []
    for c in range(nc):
        sl = slice(c * cp, (c + 1) * cp)
        s, v, b = seg[sl], valid[sl], x[sl]
        args = dict(num_segments=n_groups + 1)
        out.append(jnp.stack([
            jax.ops.segment_sum(jnp.where(v, b, 0), s, **args),
            jax.ops.segment_sum(v.astype(jnp.int32), s, **args),
            jax.ops.segment_sum(below[sl], s, **args),
            jax.ops.segment_min(jnp.where(v, b, _I32_MAX), s, **args),
            jax.ops.segment_max(jnp.where(v, b, _I32_MIN), s, **args),
        ], axis=1)[:n_groups])
    return jnp.stack(out).astype(jnp.int32)
