"""Pure-jnp oracle for the fused rss_scan_agg kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..rss_gather.ref import rss_visible_slots_ref

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min


def rss_scan_agg_ref(data: jax.Array, ts: jax.Array, member_ts: jax.Array,
                     floor: jax.Array | int = 0,
                     tag_main: jax.Array | int = 1,
                     tag_alt: jax.Array | int = -2,
                     threshold: jax.Array | int = _I32_MAX,
                     *, block_pages: int = 8) -> jax.Array:
    """data [P,K,E] int32, ts [P,K], sorted member_ts [M], scalars ->
    [P/BP, 5] int32 per-block partials of [sum, count, count_below, min,
    max] of payload element 1 over member-visible pages whose tag (element
    0) is tag_main or tag_alt — the kernel's exact blocking, so kernel and
    oracle are bitwise comparable; fold the block axis on host (lanes 0-2
    add, 3 min, 4 max; `ops.fold_partials`) in Python ints so whole-scan
    sums never wrap int32.  Empty member set with floor 0 resolves initial
    slots only (rss_gather semantics); min/max carry INT32_MAX/INT32_MIN
    sentinels for blocks where nothing matched (count disambiguates)."""
    P = data.shape[0]
    bp = min(block_pages, P)
    assert P % bp == 0, (P, bp)
    slot = rss_visible_slots_ref(ts, member_ts, floor)
    sel = jnp.take_along_axis(data, slot[:, None, None], axis=1)[:, 0]
    tag = sel[:, 0].reshape(P // bp, bp)
    x = sel[:, 1].reshape(P // bp, bp)
    valid = (tag == tag_main) | (tag == tag_alt)
    return jnp.stack([
        jnp.sum(jnp.where(valid, x, 0), axis=1),
        jnp.sum(valid.astype(jnp.int32), axis=1),
        jnp.sum((valid & (x < threshold)).astype(jnp.int32), axis=1),
        jnp.min(jnp.where(valid, x, _I32_MAX), axis=1),
        jnp.max(jnp.where(valid, x, _I32_MIN), axis=1),
    ], axis=1).astype(jnp.int32)


def rss_scan_agg_grouped_ref(data: jax.Array, ts: jax.Array, gid: jax.Array,
                             member_ts: jax.Array,
                             floor: jax.Array | int = 0,
                             tag_main: jax.Array | int = 1,
                             tag_alt: jax.Array | int = -2,
                             threshold: jax.Array | int = _I32_MAX,
                             *, n_groups: int = 1,
                             block_pages: int = 8) -> jax.Array:
    """GROUP BY twin of `rss_scan_agg_ref`: `gid` [P, 1] int32 group id
    per page (-1 = no group), `n_groups` accumulator rows -> [P/BP,
    n_groups, 5] per-block per-group partials with the kernel's exact
    blocking (bitwise comparable; fold the block axis per group on host —
    `ops.fold_group_partials`).  A group no page maps to folds to count 0
    with min/max sentinels (empty-group semantics)."""
    P = data.shape[0]
    bp = min(block_pages, P)
    assert P % bp == 0, (P, bp)
    assert gid.shape == (P, 1)
    slot = rss_visible_slots_ref(ts, member_ts, floor)
    sel = jnp.take_along_axis(data, slot[:, None, None], axis=1)[:, 0]
    tag = sel[:, 0]
    x = sel[:, 1]                                          # [P]
    valid = (tag == tag_main) | (tag == tag_alt)
    grp = (gid[:, 0][:, None] ==
           jnp.arange(n_groups, dtype=jnp.int32)[None, :]) & valid[:, None]
    grp = grp.reshape(P // bp, bp, n_groups)               # [NB, BP, G]
    xb = x.reshape(P // bp, bp)[:, :, None]
    return jnp.stack([
        jnp.sum(jnp.where(grp, xb, 0), axis=1),
        jnp.sum(grp.astype(jnp.int32), axis=1),
        jnp.sum((grp & (xb < threshold)).astype(jnp.int32), axis=1),
        jnp.min(jnp.where(grp, xb, _I32_MAX), axis=1),
        jnp.max(jnp.where(grp, xb, _I32_MIN), axis=1),
    ], axis=2).astype(jnp.int32)
