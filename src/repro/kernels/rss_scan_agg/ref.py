"""Pure-jnp oracle for the fused rss_scan_agg kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..rss_gather.ref import rss_visible_slots_ref
from .kernel import SELECT_BLOCK, _chunk_shape

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min


def rss_scan_agg_ref(data: jax.Array, ts: jax.Array, member_ts: jax.Array,
                     floor: jax.Array | int = 0,
                     tag_main: jax.Array | int = 1,
                     tag_alt: jax.Array | int = -2,
                     threshold: jax.Array | int = _I32_MAX,
                     *, block_pages: int = 8) -> jax.Array:
    """data [P,K,E] int32, ts [P,K], sorted member_ts [M], scalars ->
    [P/BP, 7] int32 per-block partials of [sum, count, count_below, min,
    max, count_above, sum_below] of payload element 1 over member-visible
    pages whose tag (element
    0) is tag_main or tag_alt — the kernel's exact blocking, so kernel and
    oracle are bitwise comparable; fold the block axis on host (lanes 0-2
    and 5-6 add, 3 min, 4 max; `ops.fold_partials`) in Python ints so
    whole-scan
    sums never wrap int32.  Empty member set with floor 0 resolves initial
    slots only (rss_gather semantics); min/max carry INT32_MAX/INT32_MIN
    sentinels for blocks where nothing matched (count disambiguates)."""
    P = data.shape[0]
    bp = min(block_pages, P)
    assert P % bp == 0, (P, bp)
    slot = rss_visible_slots_ref(ts, member_ts, floor)
    sel = jnp.take_along_axis(data, slot[:, None, None], axis=1)[:, 0]
    tag = sel[:, 0].reshape(P // bp, bp)
    x = sel[:, 1].reshape(P // bp, bp)
    valid = (tag == tag_main) | (tag == tag_alt)
    below = valid & (x < threshold)
    return jnp.stack([
        jnp.sum(jnp.where(valid, x, 0), axis=1),
        jnp.sum(valid.astype(jnp.int32), axis=1),
        jnp.sum(below.astype(jnp.int32), axis=1),
        jnp.min(jnp.where(valid, x, _I32_MAX), axis=1),
        jnp.max(jnp.where(valid, x, _I32_MIN), axis=1),
        jnp.sum((valid & (x > threshold)).astype(jnp.int32), axis=1),
        jnp.sum(jnp.where(below, x, 0), axis=1),
    ], axis=1).astype(jnp.int32)


def rss_delta_fold_ref(acc: jax.Array, delta: jax.Array) -> jax.Array:
    """Pure-jnp oracle for `rss_delta_fold`: acc [Lp, 128] lane rows,
    delta [Dp, 128] change rows (col 0 = target lane / -1 pad, 1 = old,
    2 = old-valid, 3 = new, 4 = new-valid, 5 = threshold) -> advanced
    [Lp, 128] tile.  Additive lanes retract old and apply new; min/max
    lanes only tighten with applied new values (supersession of an
    attained bound is the host's dirty-bit demotion, not the fold's)."""
    lp = acc.shape[0]
    tgt, thr = delta[:, 0], delta[:, 5]
    old, ov = delta[:, 1], delta[:, 2]
    new, nv = delta[:, 3], delta[:, 4]
    onehot = tgt[:, None] == jnp.arange(lp, dtype=jnp.int32)[None, :]
    oh = onehot.astype(jnp.int32)
    old_b = (old < thr).astype(jnp.int32)
    new_b = (new < thr).astype(jnp.int32)
    adds = jnp.stack([
        new * nv - old * ov,
        nv - ov,
        nv * new_b - ov * old_b,
        nv * (new > thr).astype(jnp.int32) - ov * (old > thr).astype(jnp.int32),
        new * nv * new_b - old * ov * old_b,
    ], axis=1)                                             # [Dp, 5]
    s = jnp.einsum("dl,ds->ls", oh, adds)                  # [Lp, 5]
    applied = onehot & (nv[:, None] == 1)
    s_min = jnp.min(jnp.where(applied, new[:, None], _I32_MAX), axis=0)
    s_max = jnp.max(jnp.where(applied, new[:, None], _I32_MIN), axis=0)
    lane = jnp.arange(128, dtype=jnp.int32)[None, :]
    out = jnp.where(lane == 0, acc + s[:, 0:1], acc)
    out = jnp.where(lane == 1, acc + s[:, 1:2], out)
    out = jnp.where(lane == 2, acc + s[:, 2:3], out)
    out = jnp.where(lane == 3, jnp.minimum(acc, s_min[:, None]), out)
    out = jnp.where(lane == 4, jnp.maximum(acc, s_max[:, None]), out)
    out = jnp.where(lane == 5, acc + s[:, 3:4], out)
    out = jnp.where(lane == 6, acc + s[:, 4:5], out)
    return out.astype(jnp.int32)


def _group_param_cols(n_groups, tag_main, tag_alt, threshold, group_params):
    """Per-group (tag_main, tag_alt, threshold) columns [G] — scalar args
    broadcast when group_params is None (same contract as the kernel's
    group-param tile)."""
    if group_params is None:
        return (jnp.full((n_groups,), jnp.asarray(tag_main, jnp.int32)),
                jnp.full((n_groups,), jnp.asarray(tag_alt, jnp.int32)),
                jnp.full((n_groups,), jnp.asarray(threshold, jnp.int32)))
    prm = jnp.asarray(group_params, jnp.int32)
    return prm[:, 0], prm[:, 1], prm[:, 2]


def rss_scan_agg_grouped_ref(data: jax.Array, ts: jax.Array, gid: jax.Array,
                             member_ts: jax.Array,
                             floor: jax.Array | int = 0,
                             tag_main: jax.Array | int = 1,
                             tag_alt: jax.Array | int = -2,
                             threshold: jax.Array | int = _I32_MAX,
                             *, n_groups: int = 1,
                             group_params: jax.Array | None = None,
                             block_pages: int = 8) -> jax.Array:
    """GROUP BY twin of `rss_scan_agg_ref` (flat-lane blocking): `gid`
    [P, 1] int32 group id per page (-1 = no group), `n_groups`
    accumulator rows -> [P/BP, n_groups, 7] per-block per-group partials
    with the kernel's exact blocking (bitwise comparable; fold the block
    axis per group on host — `ops.fold_group_partials`).  group_params
    [n_groups, 3] gives each lane its own (tag_main, tag_alt, threshold).
    A group no page maps to folds to count 0 with min/max sentinels
    (empty-group semantics)."""
    P = data.shape[0]
    bp = min(block_pages, P)
    assert P % bp == 0, (P, bp)
    assert gid.shape == (P, 1)
    slot = rss_visible_slots_ref(ts, member_ts, floor)
    sel = jnp.take_along_axis(data, slot[:, None, None], axis=1)[:, 0]
    tag = sel[:, 0]
    x = sel[:, 1]                                          # [P]
    tmain, talt, thr = _group_param_cols(n_groups, tag_main, tag_alt,
                                         threshold, group_params)
    tagm = ((tag[:, None] == tmain[None, :]) |
            (tag[:, None] == talt[None, :]))               # [P, G]
    grp = (gid[:, 0][:, None] ==
           jnp.arange(n_groups, dtype=jnp.int32)[None, :]) & tagm
    grp = grp.reshape(P // bp, bp, n_groups)               # [NB, BP, G]
    xb = x.reshape(P // bp, bp)[:, :, None]
    thr3 = thr[None, None, :]
    below = grp & (xb < thr3)
    return jnp.stack([
        jnp.sum(jnp.where(grp, xb, 0), axis=1),
        jnp.sum(grp.astype(jnp.int32), axis=1),
        jnp.sum(below.astype(jnp.int32), axis=1),
        jnp.min(jnp.where(grp, xb, _I32_MAX), axis=1),
        jnp.max(jnp.where(grp, xb, _I32_MIN), axis=1),
        jnp.sum((grp & (xb > thr3)).astype(jnp.int32), axis=1),
        jnp.sum(jnp.where(below, xb, 0), axis=1),
    ], axis=2).astype(jnp.int32)


def rss_scan_agg_chunked_ref(data: jax.Array, ts: jax.Array,
                             gid: jax.Array, member_ts: jax.Array,
                             floor: jax.Array | int = 0,
                             tag_main: jax.Array | int = 1,
                             tag_alt: jax.Array | int = -2,
                             threshold: jax.Array | int = _I32_MAX,
                             *, n_groups: int = 1,
                             group_params: jax.Array | None = None,
                             rows_per_step: int = 8,
                             fold_chunks: int = 8) -> jax.Array:
    """Oracle for `rss_scan_agg_chunked`: same chunk-aligned padding math
    (`_chunk_shape`), but each chunk reduces via `jax.ops.segment_*` —
    O(P) regardless of G, and bitwise equal to the kernel's one-hot sums
    (int32 addition is order-independent; segment_min/max identities are
    the kernel's sentinels).  Returns [chunks, n_groups, 7] int32."""
    P = data.shape[0]
    assert gid.shape == (P, 1)
    rows, _r, nc, Pp = _chunk_shape(P, rows_per_step, fold_chunks)
    del rows
    slot = rss_visible_slots_ref(ts, member_ts, floor)
    sel = jnp.take_along_axis(data, slot[:, None, None], axis=1)[:, 0]
    tag = sel[:, 0]
    x = sel[:, 1]
    g = gid[:, 0].astype(jnp.int32)
    if Pp != P:
        pad = Pp - P
        tag = jnp.concatenate([tag, jnp.full((pad,), -1, jnp.int32)])
        x = jnp.concatenate([x, jnp.zeros((pad,), jnp.int32)])
        g = jnp.concatenate([g, jnp.full((pad,), -1, jnp.int32)])
    tmain, talt, thr = _group_param_cols(n_groups, tag_main, tag_alt,
                                         threshold, group_params)
    gc = jnp.clip(g, 0, n_groups - 1)
    valid = (((tag == tmain[gc]) | (tag == talt[gc])) &
             (g >= 0) & (g < n_groups))
    seg = jnp.where(valid, g, n_groups)        # invalid -> spill segment
    belowm = valid & (x < thr[gc])
    below = belowm.astype(jnp.int32)
    above = (valid & (x > thr[gc])).astype(jnp.int32)
    sumb = jnp.where(belowm, x, 0)
    cp = Pp // nc                              # pages per chunk
    out = []
    for c in range(nc):
        sl = slice(c * cp, (c + 1) * cp)
        s, v, b = seg[sl], valid[sl], x[sl]
        args = dict(num_segments=n_groups + 1)
        out.append(jnp.stack([
            jax.ops.segment_sum(jnp.where(v, b, 0), s, **args),
            jax.ops.segment_sum(v.astype(jnp.int32), s, **args),
            jax.ops.segment_sum(below[sl], s, **args),
            jax.ops.segment_min(jnp.where(v, b, _I32_MAX), s, **args),
            jax.ops.segment_max(jnp.where(v, b, _I32_MIN), s, **args),
            jax.ops.segment_sum(above[sl], s, **args),
            jax.ops.segment_sum(sumb[sl], s, **args),
        ], axis=1)[:n_groups])
    return jnp.stack(out).astype(jnp.int32)
