"""Public ops: fused scan+aggregate (scalar, grouped flat-lane, grouped
chunked two-stage), kernel or jnp — plus the shape dispatcher that picks
the grouped strategy and the host-side int32 overflow guard.

Dispatch (`select_grouped_mode`, flash-linear-attention's chunk /
fused_recurrent idiom): small scans go "host" (launch overhead dominates
— the mirror decodes and aggregates in Python), few groups go "flat"
(all-G accumulator lanes per grid step), many groups go "chunked"
(two-stage tiled-group reduction).  Thresholds come from
`benchmarks.bench_kernels.group_agg_report` and are overridable — per
call, or globally via the REPRO_GROUPED_MODE env var.

Overflow guard: device partials are int32.  The flat path only needs one
BP-page block's partial to fit (|field| max * BP < 2**31) — when the
store's field magnitude violates that, the block size is SHRUNK until it
fits (BP=1 always does: a single int32 value cannot overflow), keeping
the host Python-int fold exact.  The chunked path folds ON DEVICE, so it
needs the whole-scan bound (|field| max * P < 2**31) and falls back to
flat-lane when violated.  `LAUNCH_STATS` counts dispatches, pallas
calls, chosen modes, shrinks and fallbacks — the driver and verify.sh
read it to assert one-launch-per-fused-batch."""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...obs import REGISTRY, StatsView
from ..config import resolve_interpret
from .kernel import (rss_delta_fold, rss_scan_agg, rss_scan_agg_chunked,
                     rss_scan_agg_grouped, tree_fold_partials)
from .ref import (rss_delta_fold_ref, rss_scan_agg_chunked_ref,
                  rss_scan_agg_grouped_ref, rss_scan_agg_ref)

# jitted ref entry points: the use_kernel=False paths serve fused
# dispatches too (benches, oracle runs), where eager per-op dispatch of
# the segment/scatter refs would swamp the fusion win
_scan_agg_ref = jax.jit(rss_scan_agg_ref, static_argnames=("block_pages",))
_grouped_ref = jax.jit(rss_scan_agg_grouped_ref,
                       static_argnames=("n_groups", "block_pages"))
_chunked_ref = jax.jit(rss_scan_agg_chunked_ref,
                       static_argnames=("n_groups", "rows_per_step",
                                        "fold_chunks"))

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min

BLOCK_PAGES = 8                   # default flat/scalar grid block

# --- shape dispatch ---------------------------------------------------------

GROUPED_MODE_ENV = "REPRO_GROUPED_MODE"
GROUPED_MODES = ("host", "flat", "chunked")
# sweep-derived thresholds (benchmarks.bench_kernels.group_agg_report):
# below HOST_MODE_MAX_PAGES the launch overhead beats any fusion win for a
# single plan; flat-lane wins while all-G lanes still fit useful VMEM —
# the measured flat/chunked crossover sits between G=32 and G=64 at
# P=1024..4096.
HOST_MODE_MAX_PAGES = 64
FLAT_MODE_MAX_GROUPS = 32

# process-wide launch accounting — a registry view (series
# kernel_launch_*), so snapshots/export/reset compose with every other
# layer's metrics; dict-shaped API preserved for existing readers
LAUNCH_STATS = StatsView(REGISTRY, "kernel_launch",
                         ("dispatches", "pallas_calls", "host", "flat",
                          "chunked", "block_shrinks", "overflow_fallbacks",
                          "delta_folds"))


def reset_launch_stats() -> dict:
    """Atomically zero LAUNCH_STATS and return the pre-reset snapshot."""
    return LAUNCH_STATS.reset()


def select_grouped_mode(n_pages: int, n_groups: int, n_plans: int = 1, *,
                        override: Optional[str] = None) -> str:
    """Pick the grouped execution strategy for a (P, G, n_plans) shape:
    "host" (decode + Python aggregate), "flat" (all-G accumulator lanes),
    or "chunked" (two-stage tiled-group reduction).  `override` (or the
    REPRO_GROUPED_MODE env var) forces a mode; "auto" defers to the
    shape heuristic.  Fused batches (n_plans > 1) never pick "host" —
    one device launch is the point of batching."""
    mode = override or os.environ.get(GROUPED_MODE_ENV) or "auto"
    if mode != "auto":
        assert mode in GROUPED_MODES, mode
        return mode
    if n_pages < HOST_MODE_MAX_PAGES and n_plans == 1:
        return "host"
    if n_groups <= FLAT_MODE_MAX_GROUPS:
        return "flat"
    return "chunked"


# --- overflow guard ---------------------------------------------------------

def field_maxabs(store: dict) -> int:
    """Largest |aggregable field| (payload element 1) across every slot of
    the store — the host-side input to the int32 partial bounds."""
    col = np.asarray(store["data"])[:, :, 1]
    return int(np.abs(col.astype(np.int64)).max()) if col.size else 0


def safe_block_pages(maxabs: int, n_pages: int,
                     preferred: int = BLOCK_PAGES) -> int:
    """Largest block size <= preferred whose per-block partial provably
    fits int32 (maxabs * BP < 2**31).  Halving keeps P % BP == 0 (stores
    are sublane-padded to multiples of 8); BP=1 always fits — a single
    int32 value cannot overflow its own sum."""
    bp = max(1, min(preferred, n_pages))
    while bp > 1 and maxabs > (2**31 - 1) // bp:
        bp //= 2
    return bp


def check_block_bound(maxabs: int, block_pages: int) -> None:
    """Raise OverflowError when a BP-page block partial could wrap int32
    — the guard for callers that pin an explicit block size."""
    if block_pages > 1 and maxabs > (2**31 - 1) // block_pages:
        raise OverflowError(
            f"int32 partial overflow: |field| max {maxabs} * "
            f"block_pages {block_pages} exceeds 2**31-1; shrink the "
            f"block (safe_block_pages) or aggregate on host")


def scan_bound_ok(maxabs: int, n_pages: int) -> bool:
    """True when a whole-scan int32 sum provably cannot wrap — the bound
    the chunked path's DEVICE fold needs (host folds are exact Python
    ints and only need the per-block bound)."""
    return n_pages == 0 or maxabs <= (2**31 - 1) // max(1, n_pages)


# --- scalar path ------------------------------------------------------------

def fold_partials(partials) -> list[int]:
    """Fold [n_blocks, 7] per-block device partials into the final [sum,
    count, count_below, min, max, count_above, sum_below] — exact past
    int32: partials are int32,
    so an int64 host accumulation cannot wrap below 2**32 blocks (a store
    that large doesn't fit an int32 page index anyway)."""
    rows = np.asarray(partials, dtype=np.int64)
    if not rows.shape[0]:
        return [0, 0, 0, int(_I32_MAX), int(_I32_MIN), 0, 0]
    return [int(rows[:, 0].sum()), int(rows[:, 1].sum()),
            int(rows[:, 2].sum()), int(rows[:, 3].min()),
            int(rows[:, 4].max()), int(rows[:, 5].sum()),
            int(rows[:, 6].sum())]


def snapshot_agg_members(store: dict, member_ts, floor=0, *,
                         tag_main: int, tag_alt: int = -2,
                         threshold: Optional[int] = None,
                         use_kernel: bool = True,
                         interpret: Optional[bool] = None) -> list[int]:
    """Fused RSS membership scan + aggregate over a paged store
    {'data': [P,K,E] int32, 'ts': [P,K]}: resolve visibility (ts <= floor
    or ts in the sorted member_ts array — `rss_gather` semantics; an empty
    member array with floor = watermark gives SI-V prefix visibility) and
    reduce payload element 1 over visible pages tagged tag_main/tag_alt,
    all in ONE device pass.

    Returns the folded [sum, count, count_below, min, max, count_above,
    sum_below] as Python ints
    (per-block int32 partials on device, exact fold on host);
    `tensorstore.version_store.finalize_agg` picks the requested statistic
    (min/max carry sentinels when count == 0).  The block size shrinks
    automatically when the store's field magnitude could wrap a block
    partial.  interpret defaults to the REPRO_INTERPRET switch
    (`repro.kernels.config`)."""
    thresh = _I32_MAX if threshold is None else int(threshold)
    P = int(store["ts"].shape[0])
    bp = safe_block_pages(field_maxabs(store), P)
    if bp != min(BLOCK_PAGES, P):
        LAUNCH_STATS["block_shrinks"] += 1
    if not use_kernel:
        partials = _scan_agg_ref(store["data"], store["ts"], member_ts,
                                 floor, tag_main, tag_alt, thresh,
                                 block_pages=bp)
    else:
        LAUNCH_STATS["pallas_calls"] += 1
        partials = rss_scan_agg(store["data"], store["ts"], member_ts,
                                floor, tag_main, tag_alt, thresh,
                                block_pages=bp,
                                interpret=resolve_interpret(interpret))
    return fold_partials(partials)


# --- grouped paths ----------------------------------------------------------

def fold_group_partials(partials) -> list[list[int]]:
    """Fold [n_blocks, G, 7] per-block per-group device partials into G
    final [sum, count, count_below, min, max, count_above, sum_below]
    rows — vectorized int64
    accumulation, same overflow discipline as `fold_partials`."""
    rows = np.asarray(partials, dtype=np.int64)
    n_groups = rows.shape[1]
    if not rows.shape[0]:
        return [[0, 0, 0, int(_I32_MAX), int(_I32_MIN), 0, 0]
                for _ in range(n_groups)]
    folded = np.concatenate([rows[:, :, :3].sum(axis=0),
                             rows[:, :, 3].min(axis=0)[:, None],
                             rows[:, :, 4].max(axis=0)[:, None],
                             rows[:, :, 5:7].sum(axis=0)], axis=1)
    return folded.tolist()


def snapshot_group_agg_members(store: dict, gid, n_groups: int,
                               member_ts, floor=0, *,
                               tag_main: int = 1, tag_alt: int = -2,
                               threshold: Optional[int] = None,
                               group_params=None,
                               use_kernel: bool = True,
                               interpret: Optional[bool] = None) \
        -> list[list[int]]:
    """GROUP BY variant of `snapshot_agg_members` (flat-lane strategy):
    `gid` maps each page of the store to an accumulator lane
    (0..n_groups-1; -1 = no group), and ONE fused device pass resolves
    visibility AND reduces every group — a small [n_groups, 5] tile back
    instead of one scalar per group.  group_params [n_groups, 3] int32
    rows of (tag_main, tag_alt, threshold) give each lane its own config
    (fused multi-plan batches); None broadcasts the scalar args.

    Returns n_groups folded [sum, count, count_below, min, max,
    count_above, sum_below] rows as
    Python ints; a group no visible page maps to is [0, 0, 0, INT32_MAX,
    INT32_MIN, 0, 0] (count disambiguates — `finalize_agg` folds the
    sentinels
    to 0).  Block size shrinks automatically under the overflow bound."""
    thresh = _I32_MAX if threshold is None else int(threshold)
    gid = jnp.asarray(np.asarray(gid, np.int32).reshape(-1, 1))
    P = int(store["ts"].shape[0])
    bp = safe_block_pages(field_maxabs(store), P)
    if bp != min(BLOCK_PAGES, P):
        LAUNCH_STATS["block_shrinks"] += 1
    if group_params is not None:
        group_params = jnp.asarray(np.asarray(group_params, np.int32))
    if not use_kernel:
        partials = _grouped_ref(
            store["data"], store["ts"], gid, member_ts, floor,
            tag_main, tag_alt, thresh, n_groups=n_groups,
            group_params=group_params, block_pages=bp)
    else:
        LAUNCH_STATS["pallas_calls"] += 1
        partials = rss_scan_agg_grouped(
            store["data"], store["ts"], gid, member_ts, floor,
            tag_main, tag_alt, thresh, n_groups=n_groups,
            block_pages=bp, group_params=group_params,
            interpret=resolve_interpret(interpret))
    return fold_group_partials(partials)


def snapshot_group_agg_chunked(store: dict, gid, n_groups: int,
                               member_ts, floor=0, *,
                               tag_main: int = 1, tag_alt: int = -2,
                               threshold: Optional[int] = None,
                               group_params=None,
                               group_tile: int = 8,
                               use_kernel: bool = True,
                               interpret: Optional[bool] = None) \
        -> list[list[int]]:
    """Chunked two-stage GROUP BY: select pass + tiled-group reduce +
    device tree fold (two pallas calls, [G, 7] back).  Same semantics as
    `snapshot_group_agg_members`; requires the whole-scan int32 bound —
    callers should go through `grouped_agg_auto`, which checks it and
    falls back to flat-lane."""
    thresh = _I32_MAX if threshold is None else int(threshold)
    gid = jnp.asarray(np.asarray(gid, np.int32).reshape(-1, 1))
    if group_params is not None:
        group_params = jnp.asarray(np.asarray(group_params, np.int32))
    if not use_kernel:
        partials = _chunked_ref(
            store["data"], store["ts"], gid, member_ts, floor,
            tag_main, tag_alt, thresh, n_groups=n_groups,
            group_params=group_params)
    else:
        LAUNCH_STATS["pallas_calls"] += 2      # select + reduce
        partials = rss_scan_agg_chunked(
            store["data"], store["ts"], gid, member_ts, floor,
            tag_main, tag_alt, thresh, n_groups=n_groups,
            group_params=group_params, group_tile=group_tile,
            interpret=resolve_interpret(interpret))
    return np.asarray(tree_fold_partials(partials)).tolist()


# --- incremental delta fold (materialized aggregates) -----------------------

_delta_fold_ref_j = jax.jit(rss_delta_fold_ref)


def delta_fold(acc, delta, *, use_kernel: bool = True,
               interpret: Optional[bool] = None) -> jax.Array:
    """Advance a materialized-aggregate accumulator tile by a dense delta
    buffer: acc [Lp, 128] int32 lane rows (lanes 0..6 = sum, count,
    count_below, min, max, count_above, sum_below), delta [Dp, 128] int32
    change rows — col 0 = target lane (-1 = padding), 1 = retracted old
    value, 2 = old-valid, 3 = applied new value, 4 = new-valid, 5 =
    threshold.  O(delta) regardless of table size — this is the commit-
    time fold behind `tensorstore.materialized.MaterializedView`.  The
    caller owns the int32 overflow ladder (bounded |contribution| and
    bounded pending-buffer length); min/max lanes only tighten here —
    retracting an attained bound is the host's dirty-bit demotion."""
    acc = jnp.asarray(acc, jnp.int32)
    delta = jnp.asarray(delta, jnp.int32)
    LAUNCH_STATS["delta_folds"] += 1
    if not use_kernel:
        return _delta_fold_ref_j(acc, delta)
    LAUNCH_STATS["pallas_calls"] += 1
    return rss_delta_fold(acc, delta,
                          interpret=resolve_interpret(interpret))


def grouped_agg_auto(store: dict, gid, n_groups: int, member_ts, floor=0,
                     *, group_params=None, n_plans: int = 1,
                     mode: Optional[str] = None,
                     use_kernel: bool = True,
                     interpret: Optional[bool] = None):
    """Shape-dispatched grouped aggregate: pick flat / chunked by
    (P, G, n_plans) — or honor `mode` / REPRO_GROUPED_MODE — run it, and
    return (rows, mode_used).  mode_used == "host" returns (None,
    "host"): the caller (the mirror) owns the decode-and-aggregate
    fallback, since it needs key-level values the kernel layer never
    sees.  A chunked pick that violates the whole-scan int32 bound
    silently demotes to flat (exact host fold) and counts an
    overflow_fallback."""
    P = int(store["ts"].shape[0])
    m = select_grouped_mode(P, n_groups, n_plans, override=mode)
    if m == "chunked" and not scan_bound_ok(field_maxabs(store), P):
        LAUNCH_STATS["overflow_fallbacks"] += 1
        m = "flat"
    LAUNCH_STATS["dispatches"] += 1
    LAUNCH_STATS[m] += 1
    if m == "host":
        return None, m
    if m == "chunked":
        rows = snapshot_group_agg_chunked(
            store, gid, n_groups, member_ts, floor,
            group_params=group_params, use_kernel=use_kernel,
            interpret=interpret)
    else:
        rows = snapshot_group_agg_members(
            store, gid, n_groups, member_ts, floor,
            group_params=group_params, use_kernel=use_kernel,
            interpret=interpret)
    return rows, m
