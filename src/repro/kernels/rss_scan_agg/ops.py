"""Public ops: snapshot_agg_members / snapshot_group_agg_members — fused
scan+aggregate (scalar and GROUP BY variants), kernel or jnp."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..config import resolve_interpret
from .kernel import rss_scan_agg, rss_scan_agg_grouped
from .ref import rss_scan_agg_grouped_ref, rss_scan_agg_ref

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min


def fold_partials(partials) -> list[int]:
    """Fold [n_blocks, 5] per-block device partials into the final [sum,
    count, count_below, min, max] — in arbitrary-precision Python ints, so
    whole-scan sums are exact even past int32 (only a single block's
    partial must fit int32 on device)."""
    rows = np.asarray(partials)
    return [int(sum(int(v) for v in rows[:, 0])),
            int(sum(int(v) for v in rows[:, 1])),
            int(sum(int(v) for v in rows[:, 2])),
            int(min((int(v) for v in rows[:, 3]), default=_I32_MAX)),
            int(max((int(v) for v in rows[:, 4]), default=_I32_MIN))]


def snapshot_agg_members(store: dict, member_ts, floor=0, *,
                         tag_main: int, tag_alt: int = -2,
                         threshold: Optional[int] = None,
                         use_kernel: bool = True,
                         interpret: Optional[bool] = None) -> list[int]:
    """Fused RSS membership scan + aggregate over a paged store
    {'data': [P,K,E] int32, 'ts': [P,K]}: resolve visibility (ts <= floor
    or ts in the sorted member_ts array — `rss_gather` semantics; an empty
    member array with floor = watermark gives SI-V prefix visibility) and
    reduce payload element 1 over visible pages tagged tag_main/tag_alt,
    all in ONE device pass.

    Returns the folded [sum, count, count_below, min, max] as Python ints
    (per-block int32 partials on device, exact fold on host);
    `tensorstore.version_store.finalize_agg` picks the requested statistic
    (min/max carry sentinels when count == 0).  interpret defaults to the
    REPRO_INTERPRET switch (`repro.kernels.config`)."""
    thresh = _I32_MAX if threshold is None else int(threshold)
    if not use_kernel:
        partials = rss_scan_agg_ref(store["data"], store["ts"], member_ts,
                                    floor, tag_main, tag_alt, thresh)
    else:
        partials = rss_scan_agg(store["data"], store["ts"], member_ts,
                                floor, tag_main, tag_alt, thresh,
                                interpret=resolve_interpret(interpret))
    return fold_partials(partials)


def fold_group_partials(partials) -> list[list[int]]:
    """Fold [n_blocks, G, 5] per-block per-group device partials into G
    final [sum, count, count_below, min, max] rows — exact Python-int
    arithmetic, same overflow discipline as `fold_partials`."""
    rows = np.asarray(partials)
    return [fold_partials(rows[:, g]) for g in range(rows.shape[1])]


def snapshot_group_agg_members(store: dict, gid, n_groups: int,
                               member_ts, floor=0, *,
                               tag_main: int, tag_alt: int = -2,
                               threshold: Optional[int] = None,
                               use_kernel: bool = True,
                               interpret: Optional[bool] = None) \
        -> list[list[int]]:
    """GROUP BY variant of `snapshot_agg_members`: `gid` maps each page of
    the store to an accumulator lane (0..n_groups-1; -1 = no group), and
    ONE fused device pass resolves visibility AND reduces every group —
    a small [n_groups, 5] tile back instead of one scalar per group.

    Returns n_groups folded [sum, count, count_below, min, max] rows as
    Python ints; a group no visible page maps to is [0, 0, 0, INT32_MAX,
    INT32_MIN] (count disambiguates — `finalize_agg` folds the sentinels
    to 0)."""
    thresh = _I32_MAX if threshold is None else int(threshold)
    gid = jnp.asarray(np.asarray(gid, np.int32).reshape(-1, 1))
    if not use_kernel:
        partials = rss_scan_agg_grouped_ref(
            store["data"], store["ts"], gid, member_ts, floor,
            tag_main, tag_alt, thresh, n_groups=n_groups)
    else:
        partials = rss_scan_agg_grouped(
            store["data"], store["ts"], gid, member_ts, floor,
            tag_main, tag_alt, thresh, n_groups=n_groups,
            interpret=resolve_interpret(interpret))
    return fold_group_partials(partials)
