"""Pallas TPU kernel: fused RSS visibility resolve + aggregate (scan+agg).

This is the device-resident OLAP executor's hot loop: one pass that resolves
RSS set-membership visibility for a key-range of pages per grid step (the
multi-page columnar extension of `rss_gather`'s one-slot-per-page resolve)
AND reduces the member-visible payloads on device — sum / count /
count-below-threshold / min / max / count-above-threshold /
sum-below-threshold over a tagged scalar field — so scan results never
leave the device.  The host receives seven scalars instead of P decoded
pages.

Contract (matches ref.py):
    data      [P, K, E] int32  page payloads; element 0 is the codec tag,
                               element 1 the aggregable field
                               (`tensorstore.mirror` codec)
    ts        [P, K]    int32  commit timestamp per slot (0 = initial)
    member_ts [M]       int32  sorted member commit timestamps ABOVE floor
    floor     scalar           compressed-snapshot watermark; with M == 0 it
                               degrades to prefix (SI-V) visibility, so the
                               same kernel serves watermark aggregates
    tag_main / tag_alt         payload tags that participate in the
                               aggregate (tag_alt = -2 to disable: real
                               tags are >= 0 and -1 marks sublane-padding
                               pages, so neither ever matches -2)
    threshold scalar           predicate bound shared by the thresholded
                               lanes (count_below / count_above /
                               sum_below)
    out       [P/BP, 128] int32  ONE PARTIAL ROW PER GRID BLOCK, lanes
                               0..6 = sum, count, count_below, min
                               (INT32_MAX when the block matched nothing),
                               max (INT32_MIN), count_above, sum_below

Visibility is the `rss_gather` protocol verbatim (ts <= floor OR ts in the
member array, newest wins, ties toward the lowest slot).  Each grid step
reduces its BP-page block to one partial row; `ops.snapshot_agg_members`
folds the rows ON HOST in arbitrary-precision Python ints.  Deliberate
overflow discipline: device arithmetic stays int32 (TPU-native), so a
whole-scan sum can exceed int32 without wrapping — only a single BP-page
block's partial must fit (|field| max < 2**31/BP per block; `ops` enforces
the bound host-side and shrinks BP when violated), keeping the fused
result bitwise equal to the per-key Python oracle.

Arithmetic intensity stays ~1 FLOP per K bytes read, but the fused path
writes P/BP partial rows instead of P·E gathered elements and skips the
host decode loop entirely — the win
`benchmarks.bench_kernels.scan_agg_report` measures.

Three grouped strategies (shape-dispatched by `ops.select_grouped_mode`):

`rss_scan_agg_grouped` — FLAT-LANE: every page carries a group id (`gid
[P, 1]`, -1 = no group), each grid step reduces its BP-page block into
PER-GROUP accumulator lanes — a [Gp, 128] tile whose row g holds group
g's [sum, count, count_below, min, max, count_above, sum_below] partial.
All G lanes stay live
every grid step, so VMEM pressure grows with G; fine for small group
counts, decays past G ~ 8-16.  Per-group kernel params (`group_params
[G, 3] = tag_main, tag_alt, threshold` rows) let ONE launch serve lanes
drawn from different plans/configs — the whole-batch fusion substrate.

`rss_select` + `rss_scan_agg_chunked` — CHUNKED TWO-STAGE: stage one
resolves visibility ONCE and packs (tag, field, gid) for 64 pages per
row into a [rows, 256] intermediate (lanes 0-63 tag, 64-127 field,
128-191 gid, 192-255 zero); stage two re-reduces that packed stream over
a TILED group axis — grid (G/G_tile, chunks, steps) where each step
accumulates `rows_per_step` rows into its chunk's [G_tile, 128] partial
tile via `@pl.when` revisits.  VMEM per step is bounded by G_tile, not
G, so G=64..256 no longer falls off the cliff, and the expensive member
compare runs once instead of once per group tile.  The [chunks, G, 7]
partials fold to [G, 7] with `tree_fold_partials` ON DEVICE (pairwise,
int32) — exactness now needs the whole-scan bound |field| max <
2**31/P, which `ops` checks host-side, falling back to flat-lane (exact
host fold) when violated.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min

# pages packed per select row: 64 tag + 64 field + 64 gid + 64 zero lanes
SELECT_BLOCK = 64


def _resolve_tag_x(mem_ref, scal_ref, ts_ref, data_ref):
    """Shared block body: RSS visibility resolve over one BP-page block.
    Returns (tag, x): the codec tag and aggregable field of each page's
    member-visible slot."""
    ts = ts_ref[...]                           # [BP, K] int32
    mem = mem_ref[...]                         # [1, Mp] int32 (-1 padded)
    floor = scal_ref[0, 0]
    # --- visibility resolve (rss_gather protocol) -----------------------
    is_member = (ts <= floor) | jnp.any(
        ts[:, :, None] == mem[0][None, None, :], axis=-1)
    masked = jnp.where(is_member, ts, -1)
    best = jnp.max(masked, axis=1, keepdims=True)          # [BP, 1]
    onehot = masked == best
    idx = jnp.arange(ts.shape[1], dtype=jnp.int32)[None, :]
    first = jnp.min(jnp.where(onehot, idx, ts.shape[1]), axis=1,
                    keepdims=True)
    onehot = idx == first                                  # [BP, K]
    data = data_ref[...]                                   # [BP, K, E]
    sel = jnp.sum(onehot.astype(data.dtype)[:, :, None] * data, axis=1)
    return sel[:, 0], sel[:, 1]                            # tag, x: [BP]


def _resolve_block(mem_ref, scal_ref, ts_ref, data_ref):
    """Resolve + scalar tag test: (x, valid, thresh) for the scalar
    kernel, tags/threshold from the scal tile."""
    tag, x = _resolve_tag_x(mem_ref, scal_ref, ts_ref, data_ref)
    tag_main = scal_ref[0, 1]
    tag_alt = scal_ref[0, 2]
    thresh = scal_ref[0, 3]
    valid = (tag == tag_main) | (tag == tag_alt)
    return x, valid, thresh


def _kernel(mem_ref, scal_ref, ts_ref, data_ref, out_ref):
    # --- fused aggregate over the visible payloads ----------------------
    x, valid, thresh = _resolve_block(mem_ref, scal_ref, ts_ref, data_ref)
    below = valid & (x < thresh)
    psum = jnp.sum(jnp.where(valid, x, 0))
    pcount = jnp.sum(valid.astype(jnp.int32))
    pbelow = jnp.sum(below.astype(jnp.int32))
    pmin = jnp.min(jnp.where(valid, x, _I32_MAX))
    pmax = jnp.max(jnp.where(valid, x, _I32_MIN))
    pabove = jnp.sum((valid & (x > thresh)).astype(jnp.int32))
    psumb = jnp.sum(jnp.where(below, x, 0))
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, 128), 1)
    tile = jnp.where(lane == 0, psum, 0)
    tile = jnp.where(lane == 1, pcount, tile)
    tile = jnp.where(lane == 2, pbelow, tile)
    tile = jnp.where(lane == 3, pmin, tile)
    tile = jnp.where(lane == 4, pmax, tile)
    tile = jnp.where(lane == 5, pabove, tile)
    tile = jnp.where(lane == 6, psumb, tile)
    out_ref[...] = tile                        # this block's partial row


def _scal_tile(floor, tag_main, tag_alt, threshold):
    # scalar params as one lane-aligned [1, 128] tile (same idiom as the
    # rss_gather floor tile): [0]=floor, [1]=tag_main, [2]=tag_alt,
    # [3]=threshold
    scal = jnp.zeros((1, 128), jnp.int32)
    scal = scal.at[0, 0].set(jnp.asarray(floor, jnp.int32))
    scal = scal.at[0, 1].set(jnp.asarray(tag_main, jnp.int32))
    scal = scal.at[0, 2].set(jnp.asarray(tag_alt, jnp.int32))
    scal = scal.at[0, 3].set(jnp.asarray(threshold, jnp.int32))
    return scal


def _mem_tile(member_ts):
    M = member_ts.shape[0]
    mp = max(128, -(-M // 128) * 128)          # lane-aligned, >= 1 tile
    mem = jnp.full((1, mp), -1, jnp.int32)
    if M:
        mem = mem.at[0, :M].set(member_ts.astype(jnp.int32))
    return mem, mp


def _group_param_tile(n_groups, gp, tag_main, tag_alt, threshold,
                      group_params):
    """[Gp, 128] per-group kernel params: lane 0 tag_main, 1 tag_alt,
    2 threshold.  group_params=None broadcasts the scalar args to every
    group (classic single-config launch); a [n_groups, 3] array gives
    each accumulator lane its own config — the batch-fusion substrate.
    Padded group rows keep zeros: no page's gid ever maps to them."""
    if group_params is None:
        prm = jnp.stack([
            jnp.full((n_groups,), jnp.asarray(tag_main, jnp.int32)),
            jnp.full((n_groups,), jnp.asarray(tag_alt, jnp.int32)),
            jnp.full((n_groups,), jnp.asarray(threshold, jnp.int32)),
        ], axis=1)
    else:
        prm = jnp.asarray(group_params, jnp.int32)
    gtile = jnp.zeros((gp, 128), jnp.int32)
    gtile = gtile.at[:n_groups, 0].set(prm[:, 0])
    gtile = gtile.at[:n_groups, 1].set(prm[:, 1])
    gtile = gtile.at[:n_groups, 2].set(prm[:, 2])
    return gtile


@functools.partial(jax.jit, static_argnames=("block_pages", "interpret"))
def rss_scan_agg(data: jax.Array, ts: jax.Array, member_ts: jax.Array,
                 floor: jax.Array | int = 0,
                 tag_main: jax.Array | int = 1,
                 tag_alt: jax.Array | int = -2,
                 threshold: jax.Array | int = _I32_MAX,
                 *, block_pages: int = 8,
                 interpret: bool = True) -> jax.Array:
    """Fused RSS membership scan + aggregate; returns [P/BP, 7] int32
    per-block partials of [sum, count, count_below, min, max,
    count_above, sum_below] over member-visible payloads whose tag is
    tag_main or tag_alt (fold the block axis on host — lanes 0-2 and 5-6
    add, 3 min, 4 max).  interpret=True executes on CPU (validation);
    interpret=False targets TPU."""
    P, K, E = data.shape
    assert ts.shape == (P, K)
    bp = min(block_pages, P)
    assert P % bp == 0, (P, bp)
    mem, mp = _mem_tile(member_ts)
    scal = _scal_tile(floor, tag_main, tag_alt, threshold)
    out = pl.pallas_call(
        _kernel,
        grid=(P // bp,),
        in_specs=[
            pl.BlockSpec((1, mp), lambda i: (0, 0)),        # members
            pl.BlockSpec((1, 128), lambda i: (0, 0)),       # scalar params
            pl.BlockSpec((bp, K), lambda i: (i, 0)),        # ts
            pl.BlockSpec((bp, K, E), lambda i: (i, 0, 0)),  # data
        ],
        out_specs=pl.BlockSpec((1, 128), lambda i: (i, 0)),  # partial rows
        out_shape=jax.ShapeDtypeStruct((P // bp, 128), jnp.int32),
        interpret=interpret,
    )(mem, scal, ts, data)
    return out[:, :7]


def _grouped_kernel(mem_ref, scal_ref, gprm_ref, gid_ref, ts_ref, data_ref,
                    out_ref):
    tag, x = _resolve_tag_x(mem_ref, scal_ref, ts_ref, data_ref)
    gid = gid_ref[...][:, 0]                               # [BP]
    prm = gprm_ref[...]                                    # [Gp, 128]
    gp = out_ref.shape[0]                                  # padded groups
    # page -> group one-hot; gid -1 (no group / padding) matches nothing,
    # and the tag test is PER GROUP LANE (lanes may carry distinct plan
    # configs in a fused batch launch)
    giota = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], gp), 1)
    tagm = ((tag[:, None] == prm[:, 0][None, :]) |
            (tag[:, None] == prm[:, 1][None, :]))
    grp = (gid[:, None] == giota) & tagm                   # [BP, Gp]
    thresh = prm[:, 2][None, :]                            # [1, Gp]
    xg = x[:, None]
    below = grp & (xg < thresh)
    psum = jnp.sum(jnp.where(grp, xg, 0), axis=0)          # [Gp]
    pcount = jnp.sum(grp.astype(jnp.int32), axis=0)
    pbelow = jnp.sum(below.astype(jnp.int32), axis=0)
    pmin = jnp.min(jnp.where(grp, xg, _I32_MAX), axis=0)
    pmax = jnp.max(jnp.where(grp, xg, _I32_MIN), axis=0)
    pabove = jnp.sum((grp & (xg > thresh)).astype(jnp.int32), axis=0)
    psumb = jnp.sum(jnp.where(below, xg, 0), axis=0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (gp, 128), 1)
    tile = jnp.where(lane == 0, psum[:, None], 0)
    tile = jnp.where(lane == 1, pcount[:, None], tile)
    tile = jnp.where(lane == 2, pbelow[:, None], tile)
    tile = jnp.where(lane == 3, pmin[:, None], tile)
    tile = jnp.where(lane == 4, pmax[:, None], tile)
    tile = jnp.where(lane == 5, pabove[:, None], tile)
    tile = jnp.where(lane == 6, psumb[:, None], tile)
    out_ref[...] = tile                        # this block's [Gp, 128] tile


@functools.partial(jax.jit, static_argnames=("n_groups", "block_pages",
                                             "interpret"))
def rss_scan_agg_grouped(data: jax.Array, ts: jax.Array, gid: jax.Array,
                         member_ts: jax.Array,
                         floor: jax.Array | int = 0,
                         tag_main: jax.Array | int = 1,
                         tag_alt: jax.Array | int = -2,
                         threshold: jax.Array | int = _I32_MAX,
                         *, n_groups: int = 1, block_pages: int = 8,
                         group_params: jax.Array | None = None,
                         interpret: bool = True) -> jax.Array:
    """Fused RSS membership scan + GROUPED aggregate (flat-lane): `gid` is
    a [P, 1] int32 group id per page (0..n_groups-1; -1 = no group,
    matching no accumulator lane — sublane padding).  Returns [P/BP,
    n_groups, 7] int32 per-block per-group partials of [sum, count,
    count_below, min, max, count_above, sum_below] over member-visible
    payloads whose tag matches the group's config (fold the block axis
    per group on host — lanes 0-2 and 5-6
    add, 3 min, 4 max).  group_params [n_groups, 3] int32 (tag_main,
    tag_alt, threshold per lane) overrides the scalar tag/threshold args
    per group, so one launch can serve lanes from different plans."""
    P, K, E = data.shape
    assert ts.shape == (P, K) and gid.shape == (P, 1)
    assert n_groups >= 1
    bp = min(block_pages, P)
    assert P % bp == 0, (P, bp)
    gp = -(-n_groups // 8) * 8                 # sublane-aligned group rows
    mem, mp = _mem_tile(member_ts)
    scal = _scal_tile(floor, tag_main, tag_alt, threshold)
    gtile = _group_param_tile(n_groups, gp, tag_main, tag_alt, threshold,
                              group_params)
    out = pl.pallas_call(
        _grouped_kernel,
        grid=(P // bp,),
        in_specs=[
            pl.BlockSpec((1, mp), lambda i: (0, 0)),        # members
            pl.BlockSpec((1, 128), lambda i: (0, 0)),       # scalar params
            pl.BlockSpec((gp, 128), lambda i: (0, 0)),      # group params
            pl.BlockSpec((bp, 1), lambda i: (i, 0)),        # group ids
            pl.BlockSpec((bp, K), lambda i: (i, 0)),        # ts
            pl.BlockSpec((bp, K, E), lambda i: (i, 0, 0)),  # data
        ],
        # one [Gp, 128] per-group partial tile per grid block, stacked
        # along rows: block i owns rows [i*Gp, (i+1)*Gp)
        out_specs=pl.BlockSpec((gp, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P // bp * gp, 128), jnp.int32),
        interpret=interpret,
    )(mem, scal, gtile, gid.astype(jnp.int32), ts, data)
    return out.reshape(P // bp, gp, 128)[:, :n_groups, :7]


# ---------------------------------------------------------------------------
# chunked two-stage grouped reduction
# ---------------------------------------------------------------------------

def _select_kernel(mem_ref, scal_ref, gid_ref, ts_ref, data_ref, out_ref):
    """Stage one: resolve visibility for SELECT_BLOCK pages and pack
    (tag, field, gid) into one [1, 4*SELECT_BLOCK] row — the expensive
    member compare runs exactly once per page, independent of G."""
    tag, x = _resolve_tag_x(mem_ref, scal_ref, ts_ref, data_ref)
    gid = gid_ref[...][:, 0]                               # [SB]
    row = jnp.concatenate([tag, x, gid, jnp.zeros_like(tag)])
    out_ref[...] = row[None, :]


def _chunk_reduce_kernel(gprm_ref, sel_ref, out_ref):
    """Stage two: re-reduce the packed select stream over a TILED group
    axis.  Grid (G/GT, chunks, steps); each step folds `rows_per_step`
    select rows into its (chunk, group-tile) partial via @pl.when
    revisits, so live VMEM is one [GT, 128] tile — bounded by the group
    tile, not by G."""
    i = pl.program_id(2)                                   # step in chunk
    j = pl.program_id(0)                                   # group tile
    sb = SELECT_BLOCK
    blk = sel_ref[...]                                     # [R, 4*SB]
    tag = blk[:, 0:sb].reshape(-1)                         # [R*SB]
    x = blk[:, sb:2 * sb].reshape(-1)
    gid = blk[:, 2 * sb:3 * sb].reshape(-1)
    prm = gprm_ref[...]                                    # [GT, 128]
    gt = prm.shape[0]
    # global group ids covered by this tile
    gl = j * gt + jax.lax.broadcasted_iota(jnp.int32, (1, gt), 1)[0]
    tagm = ((tag[:, None] == prm[:, 0][None, :]) |
            (tag[:, None] == prm[:, 1][None, :]))
    grp = (gid[:, None] == gl[None, :]) & tagm             # [R*SB, GT]
    thresh = prm[:, 2][None, :]
    xg = x[:, None]
    below = grp & (xg < thresh)
    psum = jnp.sum(jnp.where(grp, xg, 0), axis=0)          # [GT]
    pcount = jnp.sum(grp.astype(jnp.int32), axis=0)
    pbelow = jnp.sum(below.astype(jnp.int32), axis=0)
    pmin = jnp.min(jnp.where(grp, xg, _I32_MAX), axis=0)
    pmax = jnp.max(jnp.where(grp, xg, _I32_MIN), axis=0)
    pabove = jnp.sum((grp & (xg > thresh)).astype(jnp.int32), axis=0)
    psumb = jnp.sum(jnp.where(below, xg, 0), axis=0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, gt, 128), 2)
    tile = jnp.where(lane == 0, psum[None, :, None], 0)
    tile = jnp.where(lane == 1, pcount[None, :, None], tile)
    tile = jnp.where(lane == 2, pbelow[None, :, None], tile)
    tile = jnp.where(lane == 3, pmin[None, :, None], tile)
    tile = jnp.where(lane == 4, pmax[None, :, None], tile)
    tile = jnp.where(lane == 5, pabove[None, :, None], tile)
    tile = jnp.where(lane == 6, psumb[None, :, None], tile)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = tile

    @pl.when(i > 0)
    def _accumulate():
        prev = out_ref[...]
        out_ref[...] = jnp.where(
            (lane < 3) | (lane >= 5), prev + tile,
            jnp.where(lane == 3, jnp.minimum(prev, tile),
                      jnp.maximum(prev, tile)))


def _chunk_shape(P: int, rows_per_step: int, fold_chunks: int):
    """Static chunking math shared by kernel and ref: pad P to
    rows * SELECT_BLOCK pages where rows divides evenly into
    `fold_chunks`-or-fewer chunks of `rows_per_step`-row steps."""
    sb = SELECT_BLOCK
    rows0 = max(1, -(-P // sb))
    r = max(1, min(rows_per_step, rows0))
    nc = max(1, min(fold_chunks, rows0 // r))
    unit = r * nc
    rows = -(-rows0 // unit) * unit
    return rows, r, nc, rows * sb


def _pad_pages(data, ts, gid, P, Pp):
    """Pad to the chunk-aligned page count: tag -1 / ts 0 / gid -1 pages
    that match no group lane."""
    if Pp == P:
        return data, ts, gid.astype(jnp.int32)
    pad = Pp - P
    K, E = data.shape[1], data.shape[2]
    pad_data = jnp.zeros((pad, K, E), jnp.int32).at[:, :, 0].set(-1)
    data = jnp.concatenate([data, pad_data])
    ts = jnp.concatenate([ts, jnp.zeros((pad, K), jnp.int32)])
    gid = jnp.concatenate(
        [gid.astype(jnp.int32), jnp.full((pad, 1), -1, jnp.int32)])
    return data, ts, gid


@functools.partial(jax.jit, static_argnames=(
    "n_groups", "group_tile", "rows_per_step", "fold_chunks", "interpret"))
def rss_scan_agg_chunked(data: jax.Array, ts: jax.Array, gid: jax.Array,
                         member_ts: jax.Array,
                         floor: jax.Array | int = 0,
                         tag_main: jax.Array | int = 1,
                         tag_alt: jax.Array | int = -2,
                         threshold: jax.Array | int = _I32_MAX,
                         *, n_groups: int = 1,
                         group_params: jax.Array | None = None,
                         group_tile: int = 8,
                         rows_per_step: int = 8,
                         fold_chunks: int = 8,
                         interpret: bool = True) -> jax.Array:
    """Chunked two-stage grouped scan+agg: one select pass packs
    (tag, field, gid) per page, then a tiled-group reduce re-reads the
    packed stream — VMEM bounded by `group_tile`, visibility resolved
    once.  Returns [chunks, n_groups, 7] int32 per-chunk per-group
    partials (fold with `tree_fold_partials` on device, or
    `ops.fold_group_partials` on host).  Same lane semantics and
    group_params contract as `rss_scan_agg_grouped`; exact only when the
    whole-scan sum fits int32 (|field| max < 2**31/P — callers go through
    `ops`, which enforces the bound and falls back to flat-lane)."""
    P, K, E = data.shape
    assert ts.shape == (P, K) and gid.shape == (P, 1)
    assert n_groups >= 1
    assert group_tile >= 8 and group_tile % 8 == 0, group_tile
    sb = SELECT_BLOCK
    rows, r, nc, Pp = _chunk_shape(P, rows_per_step, fold_chunks)
    data, ts, gid = _pad_pages(data, ts, gid, P, Pp)
    gp = -(-n_groups // group_tile) * group_tile
    mem, mp = _mem_tile(member_ts)
    scal = _scal_tile(floor, tag_main, tag_alt, threshold)
    gtile = _group_param_tile(n_groups, gp, tag_main, tag_alt, threshold,
                              group_params)
    sel = pl.pallas_call(
        _select_kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, mp), lambda i: (0, 0)),        # members
            pl.BlockSpec((1, 128), lambda i: (0, 0)),       # scalar params
            pl.BlockSpec((sb, 1), lambda i: (i, 0)),        # group ids
            pl.BlockSpec((sb, K), lambda i: (i, 0)),        # ts
            pl.BlockSpec((sb, K, E), lambda i: (i, 0, 0)),  # data
        ],
        out_specs=pl.BlockSpec((1, 4 * sb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 4 * sb), jnp.int32),
        interpret=interpret,
    )(mem, scal, gid, ts, data)
    ngt = gp // group_tile
    bpc = rows // (r * nc)                     # steps per chunk
    out = pl.pallas_call(
        _chunk_reduce_kernel,
        grid=(ngt, nc, bpc),
        in_specs=[
            pl.BlockSpec((group_tile, 128), lambda j, c, i: (j, 0)),
            pl.BlockSpec((r, 4 * sb), lambda j, c, i: (c * bpc + i, 0)),
        ],
        out_specs=pl.BlockSpec((1, group_tile, 128),
                               lambda j, c, i: (c, j, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, gp, 128), jnp.int32),
        interpret=interpret,
    )(gtile, sel)
    return out[:, :n_groups, :7]


# ---------------------------------------------------------------------------
# incremental delta fold (materialized aggregates)
# ---------------------------------------------------------------------------

def _delta_fold_kernel(acc_ref, delta_ref, out_ref):
    """Fold a dense delta buffer of changed rows into a live accumulator
    tile.  acc [Lp, 128]: one row per accumulator lane, lanes 0..6 =
    [sum, count, count_below, min, max, count_above, sum_below].  delta
    [Dp, 128]: one row per (key, lane) change, cols 0 = target lane (-1 =
    padding, folds nowhere), 1 = retracted old value, 2 = old-valid, 3 =
    applied new value, 4 = new-valid, 5 = threshold.  Version supersession
    is retract-then-apply: every additive stat subtracts the old
    contribution and adds the new one; min/max only TIGHTEN (they are not
    subtractable — the host owns the dirty-bit demotion ladder when a
    retracted value was the attained bound)."""
    acc = acc_ref[...]                                     # [Lp, 128]
    blk = delta_ref[...]                                   # [Dp, 128]
    lp = acc.shape[0]
    tgt = blk[:, 0]
    old, ov = blk[:, 1], blk[:, 2]
    new, nv = blk[:, 3], blk[:, 4]
    thr = blk[:, 5]
    onehot = tgt[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (blk.shape[0], lp), 1)                  # [Dp, Lp]
    oh = onehot.astype(jnp.int32)
    old_b = (old < thr).astype(jnp.int32)
    new_b = (new < thr).astype(jnp.int32)
    d_sum = new * nv - old * ov
    d_count = nv - ov
    d_below = nv * new_b - ov * old_b
    d_above = (nv * (new > thr).astype(jnp.int32)
               - ov * (old > thr).astype(jnp.int32))
    d_sumb = new * nv * new_b - old * ov * old_b
    s_sum = jnp.sum(oh * d_sum[:, None], axis=0)           # [Lp]
    s_count = jnp.sum(oh * d_count[:, None], axis=0)
    s_below = jnp.sum(oh * d_below[:, None], axis=0)
    s_above = jnp.sum(oh * d_above[:, None], axis=0)
    s_sumb = jnp.sum(oh * d_sumb[:, None], axis=0)
    cand = jnp.where(nv == 1, new, 0)
    s_min = jnp.min(jnp.where(onehot & (nv[:, None] == 1),
                              cand[:, None], _I32_MAX), axis=0)
    s_max = jnp.max(jnp.where(onehot & (nv[:, None] == 1),
                              cand[:, None], _I32_MIN), axis=0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (lp, 128), 1)
    out = jnp.where(lane == 0, acc + s_sum[:, None], acc)
    out = jnp.where(lane == 1, acc + s_count[:, None], out)
    out = jnp.where(lane == 2, acc + s_below[:, None], out)
    out = jnp.where(lane == 3, jnp.minimum(acc, s_min[:, None]), out)
    out = jnp.where(lane == 4, jnp.maximum(acc, s_max[:, None]), out)
    out = jnp.where(lane == 5, acc + s_above[:, None], out)
    out = jnp.where(lane == 6, acc + s_sumb[:, None], out)
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("interpret",))
def rss_delta_fold(acc: jax.Array, delta: jax.Array, *,
                   interpret: bool = True) -> jax.Array:
    """Advance a materialized-aggregate accumulator tile by a dense delta
    buffer: acc [Lp, 128] int32 (lane rows, sublane-aligned), delta
    [Dp, 128] int32 change rows (see `_delta_fold_kernel` for the column
    layout; rows with col 0 == -1 are padding and fold nowhere).  Returns
    the advanced [Lp, 128] tile — O(delta) work, independent of table
    size.  int32 throughout: callers bound |contribution| and the pending
    buffer length so neither a row delta nor an additive accumulator lane
    can wrap (the `tensorstore.materialized` overflow ladder)."""
    lp, dp = acc.shape[0], delta.shape[0]
    assert acc.shape == (lp, 128) and delta.shape == (dp, 128)
    assert lp % 8 == 0 and dp % 8 == 0, (lp, dp)
    return pl.pallas_call(
        _delta_fold_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((lp, 128), lambda i: (0, 0)),     # accumulator
            pl.BlockSpec((dp, 128), lambda i: (0, 0)),     # delta rows
        ],
        out_specs=pl.BlockSpec((lp, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((lp, 128), jnp.int32),
        interpret=interpret,
    )(acc, delta)


@jax.jit
def tree_fold_partials(partials: jax.Array) -> jax.Array:
    """Device-side pairwise fold of [chunks, G, 7] chunked partials into
    the final [G, 7] rows (lanes 0-2 and 5-6 add, 3 min, 4 max).  int32
    throughout — exact only under the whole-scan bound the chunked path
    already requires."""
    ident = jnp.asarray([0, 0, 0, _I32_MAX, _I32_MIN, 0, 0], jnp.int32)
    lane = jnp.arange(7, dtype=jnp.int32)[None, None, :]
    while partials.shape[0] > 1:
        if partials.shape[0] % 2:
            pad = jnp.broadcast_to(ident, (1,) + partials.shape[1:])
            partials = jnp.concatenate([partials, pad])
        a, b = partials[0::2], partials[1::2]
        partials = jnp.where(
            (lane < 3) | (lane >= 5), a + b,
            jnp.where(lane == 3, jnp.minimum(a, b), jnp.maximum(a, b)))
    return partials[0]
