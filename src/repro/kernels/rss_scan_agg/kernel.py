"""Pallas TPU kernel: fused RSS visibility resolve + aggregate (scan+agg).

This is the device-resident OLAP executor's hot loop: one pass that resolves
RSS set-membership visibility for a key-range of pages per grid step (the
multi-page columnar extension of `rss_gather`'s one-slot-per-page resolve)
AND reduces the member-visible payloads on device — sum / count /
count-below-threshold / min / max over a tagged scalar field — so scan
results never leave the device.  The host receives five scalars instead of
P decoded pages.

Contract (matches ref.py):
    data      [P, K, E] int32  page payloads; element 0 is the codec tag,
                               element 1 the aggregable field
                               (`tensorstore.mirror` codec)
    ts        [P, K]    int32  commit timestamp per slot (0 = initial)
    member_ts [M]       int32  sorted member commit timestamps ABOVE floor
    floor     scalar           compressed-snapshot watermark; with M == 0 it
                               degrades to prefix (SI-V) visibility, so the
                               same kernel serves watermark aggregates
    tag_main / tag_alt         payload tags that participate in the
                               aggregate (tag_alt = -2 to disable: real
                               tags are >= 0 and -1 marks sublane-padding
                               pages, so neither ever matches -2)
    threshold scalar           count-below predicate bound
    out       [P/BP, 128] int32  ONE PARTIAL ROW PER GRID BLOCK, lanes
                               0..4 = sum, count, count_below, min
                               (INT32_MAX when the block matched nothing),
                               max (INT32_MIN)

Visibility is the `rss_gather` protocol verbatim (ts <= floor OR ts in the
member array, newest wins, ties toward the lowest slot).  Each grid step
reduces its BP-page block to one partial row; `ops.snapshot_agg_members`
folds the rows ON HOST in arbitrary-precision Python ints.  Deliberate
overflow discipline: device arithmetic stays int32 (TPU-native), so a
whole-scan sum can exceed int32 without wrapping — only a single BP-page
block's partial must fit (|field| avg < 2**31/BP per block, far beyond the
codec's realistic value domain), keeping the fused result bitwise equal to
the per-key Python oracle.

Arithmetic intensity stays ~1 FLOP per K bytes read, but the fused path
writes P/BP partial rows instead of P·E gathered elements and skips the
host decode loop entirely — the win
`benchmarks.bench_kernels.scan_agg_report` measures.

`rss_scan_agg_grouped` is the GROUP BY variant: every page additionally
carries a group id (`gid [P, 1]`, -1 = no group, e.g. sublane padding),
and each grid step reduces its BP-page block into PER-GROUP accumulator
lanes — a [Gp, 128] tile whose row g holds group g's [sum, count,
count_below, min, max] partial.  One fused visibility pass emits a small
[groups, 5] tile instead of one scalar; the host fold
(`ops.fold_group_partials`) is per-group, same overflow discipline as the
scalar fold.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min


def _resolve_block(mem_ref, scal_ref, ts_ref, data_ref):
    """Shared block body: RSS visibility resolve + tag test over one
    BP-page block.  Returns (x, valid, thresh): the aggregable field, the
    participates-in-the-aggregate mask, and the count-below bound."""
    ts = ts_ref[...]                           # [BP, K] int32
    mem = mem_ref[...]                         # [1, Mp] int32 (-1 padded)
    floor = scal_ref[0, 0]
    tag_main = scal_ref[0, 1]
    tag_alt = scal_ref[0, 2]
    thresh = scal_ref[0, 3]
    # --- visibility resolve (rss_gather protocol) -----------------------
    is_member = (ts <= floor) | jnp.any(
        ts[:, :, None] == mem[0][None, None, :], axis=-1)
    masked = jnp.where(is_member, ts, -1)
    best = jnp.max(masked, axis=1, keepdims=True)          # [BP, 1]
    onehot = masked == best
    idx = jnp.arange(ts.shape[1], dtype=jnp.int32)[None, :]
    first = jnp.min(jnp.where(onehot, idx, ts.shape[1]), axis=1,
                    keepdims=True)
    onehot = idx == first                                  # [BP, K]
    data = data_ref[...]                                   # [BP, K, E]
    sel = jnp.sum(onehot.astype(data.dtype)[:, :, None] * data, axis=1)
    tag = sel[:, 0]                                        # [BP]
    x = sel[:, 1]
    valid = (tag == tag_main) | (tag == tag_alt)
    return x, valid, thresh


def _kernel(mem_ref, scal_ref, ts_ref, data_ref, out_ref):
    # --- fused aggregate over the visible payloads ----------------------
    x, valid, thresh = _resolve_block(mem_ref, scal_ref, ts_ref, data_ref)
    psum = jnp.sum(jnp.where(valid, x, 0))
    pcount = jnp.sum(valid.astype(jnp.int32))
    pbelow = jnp.sum((valid & (x < thresh)).astype(jnp.int32))
    pmin = jnp.min(jnp.where(valid, x, _I32_MAX))
    pmax = jnp.max(jnp.where(valid, x, _I32_MIN))
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, 128), 1)
    tile = jnp.where(lane == 0, psum, 0)
    tile = jnp.where(lane == 1, pcount, tile)
    tile = jnp.where(lane == 2, pbelow, tile)
    tile = jnp.where(lane == 3, pmin, tile)
    tile = jnp.where(lane == 4, pmax, tile)
    out_ref[...] = tile                        # this block's partial row


@functools.partial(jax.jit, static_argnames=("block_pages", "interpret"))
def rss_scan_agg(data: jax.Array, ts: jax.Array, member_ts: jax.Array,
                 floor: jax.Array | int = 0,
                 tag_main: jax.Array | int = 1,
                 tag_alt: jax.Array | int = -2,
                 threshold: jax.Array | int = _I32_MAX,
                 *, block_pages: int = 8,
                 interpret: bool = True) -> jax.Array:
    """Fused RSS membership scan + aggregate; returns [P/BP, 5] int32
    per-block partials of [sum, count, count_below, min, max] over
    member-visible payloads whose tag is tag_main or tag_alt (fold the
    block axis on host — lanes 0-2 add, 3 min, 4 max).  interpret=True
    executes on CPU (validation); interpret=False targets TPU."""
    P, K, E = data.shape
    assert ts.shape == (P, K)
    bp = min(block_pages, P)
    assert P % bp == 0, (P, bp)
    M = member_ts.shape[0]
    mp = max(128, -(-M // 128) * 128)          # lane-aligned, >= 1 tile
    mem = jnp.full((1, mp), -1, jnp.int32)
    if M:
        mem = mem.at[0, :M].set(member_ts.astype(jnp.int32))
    # scalar params as one lane-aligned [1, 128] tile (same idiom as the
    # rss_gather floor tile): [0]=floor, [1]=tag_main, [2]=tag_alt,
    # [3]=threshold
    scal = jnp.zeros((1, 128), jnp.int32)
    scal = scal.at[0, 0].set(jnp.asarray(floor, jnp.int32))
    scal = scal.at[0, 1].set(jnp.asarray(tag_main, jnp.int32))
    scal = scal.at[0, 2].set(jnp.asarray(tag_alt, jnp.int32))
    scal = scal.at[0, 3].set(jnp.asarray(threshold, jnp.int32))
    out = pl.pallas_call(
        _kernel,
        grid=(P // bp,),
        in_specs=[
            pl.BlockSpec((1, mp), lambda i: (0, 0)),        # members
            pl.BlockSpec((1, 128), lambda i: (0, 0)),       # scalar params
            pl.BlockSpec((bp, K), lambda i: (i, 0)),        # ts
            pl.BlockSpec((bp, K, E), lambda i: (i, 0, 0)),  # data
        ],
        out_specs=pl.BlockSpec((1, 128), lambda i: (i, 0)),  # partial rows
        out_shape=jax.ShapeDtypeStruct((P // bp, 128), jnp.int32),
        interpret=interpret,
    )(mem, scal, ts, data)
    return out[:, :5]


def _grouped_kernel(mem_ref, scal_ref, gid_ref, ts_ref, data_ref, out_ref):
    x, valid, thresh = _resolve_block(mem_ref, scal_ref, ts_ref, data_ref)
    gid = gid_ref[...][:, 0]                               # [BP]
    gp = out_ref.shape[0]                                  # padded groups
    # page -> group one-hot; gid -1 (no group / padding) matches nothing
    giota = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], gp), 1)
    grp = (gid[:, None] == giota) & valid[:, None]         # [BP, Gp]
    xg = x[:, None]
    psum = jnp.sum(jnp.where(grp, xg, 0), axis=0)          # [Gp]
    pcount = jnp.sum(grp.astype(jnp.int32), axis=0)
    pbelow = jnp.sum((grp & (xg < thresh)).astype(jnp.int32), axis=0)
    pmin = jnp.min(jnp.where(grp, xg, _I32_MAX), axis=0)
    pmax = jnp.max(jnp.where(grp, xg, _I32_MIN), axis=0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (gp, 128), 1)
    tile = jnp.where(lane == 0, psum[:, None], 0)
    tile = jnp.where(lane == 1, pcount[:, None], tile)
    tile = jnp.where(lane == 2, pbelow[:, None], tile)
    tile = jnp.where(lane == 3, pmin[:, None], tile)
    tile = jnp.where(lane == 4, pmax[:, None], tile)
    out_ref[...] = tile                        # this block's [Gp, 128] tile


@functools.partial(jax.jit, static_argnames=("n_groups", "block_pages",
                                             "interpret"))
def rss_scan_agg_grouped(data: jax.Array, ts: jax.Array, gid: jax.Array,
                         member_ts: jax.Array,
                         floor: jax.Array | int = 0,
                         tag_main: jax.Array | int = 1,
                         tag_alt: jax.Array | int = -2,
                         threshold: jax.Array | int = _I32_MAX,
                         *, n_groups: int = 1, block_pages: int = 8,
                         interpret: bool = True) -> jax.Array:
    """Fused RSS membership scan + GROUPED aggregate: `gid` is a [P, 1]
    int32 group id per page (0..n_groups-1; -1 = no group, matching no
    accumulator lane — sublane padding).  Returns [P/BP, n_groups, 5]
    int32 per-block per-group partials of [sum, count, count_below, min,
    max] over member-visible payloads whose tag is tag_main/tag_alt (fold
    the block axis per group on host — lanes 0-2 add, 3 min, 4 max)."""
    P, K, E = data.shape
    assert ts.shape == (P, K) and gid.shape == (P, 1)
    assert n_groups >= 1
    bp = min(block_pages, P)
    assert P % bp == 0, (P, bp)
    gp = -(-n_groups // 8) * 8                 # sublane-aligned group rows
    M = member_ts.shape[0]
    mp = max(128, -(-M // 128) * 128)
    mem = jnp.full((1, mp), -1, jnp.int32)
    if M:
        mem = mem.at[0, :M].set(member_ts.astype(jnp.int32))
    scal = jnp.zeros((1, 128), jnp.int32)
    scal = scal.at[0, 0].set(jnp.asarray(floor, jnp.int32))
    scal = scal.at[0, 1].set(jnp.asarray(tag_main, jnp.int32))
    scal = scal.at[0, 2].set(jnp.asarray(tag_alt, jnp.int32))
    scal = scal.at[0, 3].set(jnp.asarray(threshold, jnp.int32))
    out = pl.pallas_call(
        _grouped_kernel,
        grid=(P // bp,),
        in_specs=[
            pl.BlockSpec((1, mp), lambda i: (0, 0)),        # members
            pl.BlockSpec((1, 128), lambda i: (0, 0)),       # scalar params
            pl.BlockSpec((bp, 1), lambda i: (i, 0)),        # group ids
            pl.BlockSpec((bp, K), lambda i: (i, 0)),        # ts
            pl.BlockSpec((bp, K, E), lambda i: (i, 0, 0)),  # data
        ],
        # one [Gp, 128] per-group partial tile per grid block, stacked
        # along rows: block i owns rows [i*Gp, (i+1)*Gp)
        out_specs=pl.BlockSpec((gp, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P // bp * gp, 128), jnp.int32),
        interpret=interpret,
    )(mem, scal, gid.astype(jnp.int32), ts, data)
    return out.reshape(P // bp, gp, 128)[:, :n_groups, :5]
