"""Jamba-1.5-Large 398B [arXiv:2403.19887]: Mamba+attention 1:7 interleave,
16-expert top-2 MoE on every other layer.  Period of 8: attention at
position 4, Mamba elsewhere; MoE on odd positions."""

from ..models.config import LayerSpec, ModelConfig


def _pattern():
    specs = []
    for i in range(8):
        mixer = "attn" if i == 4 else "mamba"
        mlp = "moe" if i % 2 == 1 else "dense"
        specs.append(LayerSpec(mixer=mixer, mlp=mlp))
    return tuple(specs)


CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    pattern=_pattern(),
    n_experts=16, top_k=2,
    mamba_expand=2, mamba_d_state=16, mamba_d_conv=4,
    mlp_act="swiglu", norm="rmsnorm",
    remat="dots", microbatches=8, fsdp=True, zero2=True, train_sharding="fsdp2d", moment_dtype="bfloat16",
)
