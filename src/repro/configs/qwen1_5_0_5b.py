"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B]: QKV bias, MHA, 152k vocab."""

from ..models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=2816, vocab_size=151936,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    qkv_bias=True,
    mlp_act="swiglu", norm="rmsnorm",
    remat="dots", microbatches=1, fsdp=False,
    train_sharding="fsdp2d",
)
