"""Nemotron-4 15B [arXiv:2402.16819]: squared-ReLU MLP, GQA kv=8,
partial rotary (50%), LayerNorm, 256k vocab."""

from ..models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=256000,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    mlp_act="relu2", norm="layernorm", rope_fraction=0.5,
    remat="dots", microbatches=2, fsdp=True, zero2=True, train_sharding="fsdp2d",
)
