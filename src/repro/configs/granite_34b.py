"""Granite-34B code [arXiv:2405.04324]: 88L deep, MQA (kv=1).

2-matrix GELU MLP (gpt_bigcode lineage) — with the assigned dims this lands
on the published 34B total; a gated MLP would overshoot to 47B."""

from ..models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    mlp_act="gelu", norm="layernorm",
    remat="dots", microbatches=2, fsdp=True, zero2=True, train_sharding="fsdp2d",
)
