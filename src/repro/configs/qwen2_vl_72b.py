"""Qwen2-VL 72B [arXiv:2409.12191]: M-RoPE, GQA kv=8, vision stub frontend."""

from ..models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    qkv_bias=True, rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    mlp_act="swiglu", norm="rmsnorm",
    remat="dots", microbatches=4, fsdp=True, zero2=True,
    train_sharding="fsdp2d", moment_dtype="bfloat16",
)
