"""Mixtral 8x7B [arXiv:2401.04088]: 32L, GQA kv=8, 8-expert top-2 MoE, SWA."""

from ..models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    pattern=(LayerSpec(mixer="attn", mlp="moe"),),
    n_experts=8, top_k=2,
    sliding_window=4096, rope_theta=1_000_000.0,
    mlp_act="swiglu", norm="rmsnorm",
    remat="dots", microbatches=2, fsdp=True, zero2=True, train_sharding="fsdp2d",
)
