"""Mixtral 8x22B [arXiv:2401.04088]: 56L, GQA kv=8, 8-expert top-2 MoE, SWA."""

from ..models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    pattern=(LayerSpec(mixer="attn", mlp="moe"),),
    n_experts=8, top_k=2,
    sliding_window=4096, rope_theta=1_000_000.0,
    mlp_act="swiglu", norm="rmsnorm",
    remat="dots", microbatches=4, fsdp=True, zero2=True, train_sharding="fsdp2d", moment_dtype="bfloat16",
)
