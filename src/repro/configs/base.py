"""Config helpers shared by the per-architecture files."""

from __future__ import annotations

import dataclasses

from ..models.config import LayerSpec, ModelConfig


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """A reduced same-family config for CPU smoke tests: same pattern/period,
    small width/depth/vocab.  One forward/train step must run on CPU."""
    d_model = 128
    head_dim = 32
    n_heads = 4
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    if cfg.n_kv_heads >= cfg.n_heads:      # MHA-style (qwen1.5, codeqwen)
        n_kv = n_heads
    elif cfg.n_kv_heads == 1:
        n_kv = 1
    else:
        n_kv = 2
    overrides = dict(
        n_layers=2 * cfg.period,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=256,
        vocab_size=512,
        rwkv_head_dim=32,
        mamba_d_state=8,
        mamba_dt_rank=8,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window
        else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        moe_capacity_factor=8.0,   # drop-free: decode/prefill == forward
        encoder_len=64,
        n_encoder_layers=2 if cfg.is_encoder_decoder else 0,
        remat="none",
        microbatches=1,
        fsdp=False,
        mrope_sections=(4, 6, 6) if cfg.mrope_sections else (),
    )
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **overrides)
