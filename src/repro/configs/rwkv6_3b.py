"""RWKV-6 Finch 3B [arXiv:2404.05892]: attention-free, data-dependent decay."""

from ..models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65536,
    pattern=(LayerSpec(mixer="rwkv", mlp="rwkv_cmix"),),
    rwkv_head_dim=64,
    norm="layernorm",
    remat="dots", microbatches=1, fsdp=True, zero2=True, train_sharding="fsdp2d",
)
