"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Every assigned architecture is selectable by its public id (``--arch``);
``smoke_variant`` derives the reduced same-family config used by CPU tests.
"""

from __future__ import annotations

from importlib import import_module

from ..models.config import ModelConfig, SHAPES, ShapeConfig
from .base import smoke_variant

_MODULES = {
    "mixtral-8x22b": ".mixtral_8x22b",
    "mixtral-8x7b": ".mixtral_8x7b",
    "rwkv6-3b": ".rwkv6_3b",
    "qwen2-vl-72b": ".qwen2_vl_72b",
    "nemotron-4-15b": ".nemotron_4_15b",
    "codeqwen1.5-7b": ".codeqwen1_5_7b",
    "qwen1.5-0.5b": ".qwen1_5_0_5b",
    "granite-34b": ".granite_34b",
    "whisper-tiny": ".whisper_tiny",
    "jamba-1.5-large-398b": ".jamba_1_5_large",
}


def list_archs() -> list[str]:
    return list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list_archs()}")
    mod = import_module(_MODULES[arch], __package__)
    return mod.CONFIG


def iter_cells():
    """All (arch, shape) dry-run cells, with skip markers.

    long_500k requires a sub-quadratic mixer (SSM/hybrid/SWA); pure
    full-attention archs skip it (recorded, per assignment)."""
    for arch in list_archs():
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            skip = None
            if shape_name == "long_500k" and not cfg.is_subquadratic:
                skip = "full-attention arch: long_500k needs sub-quadratic"
            yield arch, shape_name, skip


__all__ = ["get_config", "list_archs", "iter_cells", "smoke_variant",
           "SHAPES", "ShapeConfig", "ModelConfig"]
