"""Whisper-tiny [arXiv:2212.04356]: enc-dec, conv frontend stubbed
(input_specs provides precomputed frame embeddings).  RoPE replaces the
learned positional embeddings so parameters stay shape-independent
(deviation noted in DESIGN.md)."""

from ..models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab_size=51865,
    pattern=(LayerSpec(mixer="attn", mlp="dense", cross_attn=True),),
    is_encoder_decoder=True, n_encoder_layers=4, encoder_len=1500,
    mlp_act="gelu", norm="layernorm",
    remat="none", microbatches=1, fsdp=False,
)
