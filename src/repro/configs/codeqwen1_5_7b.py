"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B]: qwen1.5 arch, QKV bias, MHA."""

from ..models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=13440, vocab_size=92416,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    qkv_bias=True, rope_theta=1_000_000.0,
    mlp_act="swiglu", norm="rmsnorm",
    remat="dots", microbatches=2, fsdp=True, zero2=True, train_sharding="fsdp2d",
)
