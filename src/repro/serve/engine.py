"""Serving engine: batched prefill/decode over RSS-pinned snapshots.

The OLAP side of the HTAP boundary: every request batch pins a parameter
snapshot through the `VersionedParamStore` (wait-free — never blocks the
trainer, never aborts) and decodes against it.  Between request batches the
engine refreshes the RSS watermark by replaying the shipped WAL (Algorithm 1
runs on the replica, per the paper's multinode architecture).

KV caches are versioned at page granularity via `repro.tensorstore.paged`
when `kv_versioning=True` (demonstrates SI-V reads over interleaved state);
default serving uses plain ring caches.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import decode_step, init_cache, prefill
from ..tensorstore.versioned import VersionedParamStore


@dataclass
class GenerationResult:
    tokens: Any                 # [B, n_steps]
    snapshot_lsn: int           # WAL position of the pinned version
    freshness_lag: int          # LSNs behind the newest committed version


class ServingEngine:
    def __init__(self, cfg: ModelConfig, store: VersionedParamStore, *,
                 max_seq: int = 256):
        self.cfg = cfg
        self.store = store
        self.max_seq = max_seq
        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b, cache_len=max_seq))
        self._decode = jax.jit(
            lambda p, t, c, n: decode_step(p, cfg, t, c, n))

    def refresh(self):
        """Replay shipped WAL; rebuild RSS (replica-side, asynchronous)."""
        return self.store.refresh()

    def generate(self, batch: dict, n_steps: int,
                 *, refresh_between_steps: bool = False) -> GenerationResult:
        """Prefill the prompt then decode `n_steps` tokens against ONE pinned
        snapshot (a protected read-only transaction: all reads observe the
        same consistent version even while the trainer keeps publishing)."""
        pin, params = self.store.pin_snapshot()
        lsn = self.store.visible_lsn()
        try:
            logits, cache = self._prefill(params, batch)
            S = batch["tokens"].shape[1]
            toks = []
            tok = jnp.argmax(logits, axis=-1)[:, None]
            n = jnp.int32(S)
            for _ in range(n_steps):
                toks.append(tok)
                logits, cache = self._decode(params, tok, cache, n)
                tok = jnp.argmax(logits, axis=-1)[:, None]
                n = n + 1
                if refresh_between_steps:
                    # watermark may advance; THIS transaction stays pinned
                    self.refresh()
            out = jnp.concatenate(toks, axis=1)
        finally:
            self.store.release(pin)
        return GenerationResult(tokens=out, snapshot_lsn=lsn,
                                freshness_lag=self.store.freshness_lag())
