"""Serving launcher: prefill+decode against an RSS-pinned snapshot.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --prompt-len 16 --steps 8
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--train-steps", type=int, default=3,
                    help="concurrent trainer steps before serving")
    args = ap.parse_args(argv)

    from ..configs import get_config, smoke_variant
    from ..serve import ServingEngine
    from ..tensorstore import VersionedParamStore
    from ..train import Trainer

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    store = VersionedParamStore(slots=2)
    tr = Trainer(cfg, batch=2, seq_len=max(args.prompt_len, 16), store=store)
    tr.run(args.train_steps)
    eng = ServingEngine(cfg, store,
                        max_seq=args.prompt_len + args.steps + 8)
    eng.refresh()
    batch = {"tokens": jnp.ones((args.batch, args.prompt_len), jnp.int32)}
    if cfg.mrope_sections:
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(args.prompt_len), (3, args.batch, args.prompt_len))
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jnp.zeros(
            (args.batch, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
    res = eng.generate(batch, args.steps)
    print(f"arch={cfg.name} generated {res.tokens.shape} tokens "
          f"@snapshot lsn {res.snapshot_lsn} (lag {res.freshness_lag})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
