import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract memory / cost / collective statistics.

MUST be the process entry point (the XLA_FLAGS line above runs before any
other import so the 512 placeholder devices exist before JAX initializes).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import re
import sys
import traceback

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_config, iter_cells, list_archs
from ..models.sharding import with_mesh
from ..models.transformer import decode_step, loss_fn, prefill
from ..train.step import make_train_step
from .mesh import dp_axes, make_production_mesh, train_dp_axes
from .specs import input_specs

# ------------------------------------------------------- collective parsing
_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*?=?\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _group_size(line: str) -> int:
    """Participant count of a collective from its replica_groups attr."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:                      # iota format: [n_groups, group_size]
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_bytes(hlo_text: str) -> dict:
    """Wire-bytes per device per step for every collective in the compiled
    (SPMD-partitioned, local-shape) HLO, using ring-algorithm costs:

        all-gather          result r, group p:  r·(p-1)/p
        all-reduce          result r:           2·r·(p-1)/p
        reduce-scatter      local result r:     r·(p-1)      (input = r·p)
        all-to-all          result r:           r·(p-1)/p
        collective-permute  result r:           r
    """
    out = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(
            r".*?=\s*\(?([a-z0-9]+)\[([0-9,]*)\][^)]*\)?\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)", s)
        if not m:
            continue
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        r = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                r *= int(d)
        p = _group_size(s)
        if kind == "all-gather":
            wire = r * (p - 1) / p
        elif kind == "all-reduce":
            wire = 2 * r * (p - 1) / p
        elif kind == "reduce-scatter":
            wire = r * (p - 1)
        elif kind == "all-to-all":
            wire = r * (p - 1) / p
        else:
            wire = r
        out[kind] = out.get(kind, 0) + wire
        out["total"] = out.get("total", 0) + wire
    return out


def _analyze(compiled) -> dict:
    info = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        info["flops"] = float(ca.get("flops", -1))
        info["bytes_accessed"] = float(ca.get("bytes accessed", -1))
        info["transcendentals"] = float(ca.get("transcendentals", -1))
    except Exception as e:  # pragma: no cover
        info["cost_analysis_error"] = str(e)
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            info[k] = int(getattr(ma, k, -1))
    except Exception as e:  # pragma: no cover
        info["memory_analysis_error"] = str(e)
    return info


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             collectives: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.is_subquadratic:
        return {"arch": arch, "shape": shape_name, "skipped":
                "full-attention arch (long_500k requires sub-quadratic)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    axis_map = {"data": (train_dp_axes(mesh, cfg)
                         if shape.kind == "train" else dp_axes(mesh))}
    result = {"arch": arch, "shape": shape_name,
              "mesh": list(mesh.devices.shape),
              "axes": list(mesh.axis_names),
              "n_devices": mesh.devices.size}
    with with_mesh(mesh, axis_map):
        mode, specs = input_specs(cfg, shape, mesh)
        result["mode"] = mode
        if mode == "train":
            step = make_train_step(cfg, specs["opt_cfg"])
            lowered = jax.jit(step).lower(specs["state"], specs["batch"])
        elif mode == "prefill":
            cache_len = (min(cfg.sliding_window, shape.seq_len)
                         if cfg.sliding_window else shape.seq_len)
            fn = lambda p, b: prefill(p, cfg, b, cache_len=cache_len)
            lowered = jax.jit(fn).lower(specs["params"], specs["batch"])
        else:
            fn = lambda p, t, c, n: decode_step(p, cfg, t, c, n)
            lowered = jax.jit(fn).lower(specs["params"], specs["tokens"],
                                        specs["cache"], specs["cache_len"])
        compiled = lowered.compile()
        result.update(_analyze(compiled))
        if collectives:
            try:
                txt = compiled.as_text()
            except Exception:
                txt = lowered.as_text()
            result["collectives"] = collective_bytes(txt)
    return result


def run_cost_model(arch: str, shape_name: str, *, multi_pod: bool,
                   baseline: bool = False) -> dict:
    """Scan-corrected HLO cost extraction.

    XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count, so the full-config numbers undercount deep stacks.  We lower the
    same cell at n_layers = 1·period and 2·period (microbatches=1) and fit
    linearly:  per-period body = f(2)-f(1),  depth-independent base =
    f(1)-body,  total(n) = base + n·body.  This is exact because every
    per-period quantity (fwd, bwd, optimizer, cache traffic, collectives)
    is linear in the period count while embed/lm-head/loss are constant.
    """
    cfg0 = get_config(arch)
    if baseline:
        cfg0 = cfg0.with_overrides(zero2=False, train_sharding="tp",
                                   remat="full")
        from . import specs as _specs
        _specs.SERVE_RESIDENT_LIMIT = 0.0
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg0.is_subquadratic:
        return {"arch": arch, "shape": shape_name, "skipped": "full-attn"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    axis_map = {"data": (train_dp_axes(mesh, cfg0)
                         if shape.kind == "train" else dp_axes(mesh))}
    out = {"arch": arch, "shape": shape_name, "n_periods": cfg0.n_periods,
           "mode": shape.kind, "baseline": baseline}
    for k in (1, 2):
        cfg = cfg0.with_overrides(n_layers=k * cfg0.period, microbatches=1,
                                  unroll_layers=True, scan_chunk=-1)
        with with_mesh(mesh, axis_map):
            mode, specs = input_specs(cfg, shape, mesh)
            if mode == "train":
                step = make_train_step(cfg, specs["opt_cfg"])
                lowered = jax.jit(step).lower(specs["state"], specs["batch"])
            elif mode == "prefill":
                cache_len = (min(cfg.sliding_window, shape.seq_len)
                             if cfg.sliding_window else shape.seq_len)
                fn = lambda p, b: prefill(p, cfg, b, cache_len=cache_len)
                lowered = jax.jit(fn).lower(specs["params"], specs["batch"])
            else:
                fn = lambda p, t, c, n: decode_step(p, cfg, t, c, n)
                lowered = jax.jit(fn).lower(
                    specs["params"], specs["tokens"], specs["cache"],
                    specs["cache_len"])
            compiled = lowered.compile()
            info = _analyze(compiled)
            try:
                info["collective_bytes"] = collective_bytes(
                    compiled.as_text()).get("total", 0)
            except Exception:
                info["collective_bytes"] = 0
            out[f"k{k}"] = {kk: info.get(kk) for kk in
                            ("flops", "bytes_accessed", "collective_bytes")}
    # linear extrapolation to the real depth
    n = cfg0.n_periods
    for key in ("flops", "bytes_accessed", "collective_bytes"):
        f1 = out["k1"].get(key) or 0.0
        f2 = out["k2"].get(key) or 0.0
        # clamp: XLA layout nondeterminism can make f2 < f1 when the
        # per-period increment is negligible
        body = max(f2 - f1, 0.0)
        out[f"{key}_total"] = max(f1 - body, 0.0) + n * body
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--cost-model", action="store_true",
                    help="scan-corrected HLO cost extraction (single mesh)")
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful baseline: ZeRO-3 FSDP everywhere, "
                         "no serving-resident params")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        cells = [(a, s) for a, s, skip in iter_cells()]
    else:
        archs = [args.arch] if args.arch else list_archs()
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(a, s) for a in archs for s in shapes]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} × {shape} × {'multi' if mp else 'single'}-pod"
            try:
                if args.cost_model:
                    r = run_cost_model(arch, shape, multi_pod=mp,
                                       baseline=args.baseline)
                    if "skipped" not in r:
                        print(f"[OK]   cost {tag}: "
                              f"flops_total={r['flops_total']:.3e} "
                              f"coll_total={r['collective_bytes_total']:.3e}",
                              flush=True)
                    else:
                        print(f"[SKIP] cost {tag}", flush=True)
                    results.append(r)
                    continue
                r = run_cell(arch, shape, multi_pod=mp)
                if "skipped" in r:
                    print(f"[SKIP] {tag}: {r['skipped']}", flush=True)
                else:
                    print(f"[OK]   {tag}: flops={r.get('flops', -1):.3e} "
                          f"coll={r.get('collectives', {}).get('total', 0):.3e}B "
                          f"temp={r.get('temp_size_in_bytes', -1):.3e}B",
                          flush=True)
                results.append(r)
            except Exception as e:
                print(f"[FAIL] {tag}: {e}", flush=True)
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape,
                                "multi_pod": mp, "error": str(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    nfail = sum(1 for r in results if "error" in r)
    print(f"{len(results)} cells, {nfail} failures")
    return 1 if nfail else 0


if __name__ == "__main__":
    sys.exit(main())
