"""Launchers: mesh construction, sharding rules, dry-run, train/serve CLIs."""
from .mesh import make_production_mesh, make_host_mesh, dp_axes, dp_size
