"""Parameter/state/input sharding rules for the production meshes.

Strategy (per DESIGN.md §5):
  * "model"  — tensor parallel: attention heads / d_ff / vocab (lm_head),
  * "data"   — batch; doubles as the FSDP axis for params/opt of big archs,
  * "pod"    — outer data parallel (training) / replication boundary (HTAP).

Every spec is sanitized against the actual mesh: a dim that is not divisible
by its axis size falls back to replication for that dim (e.g. whisper's 6
heads on a 16-way model axis, granite's single KV head).
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from .mesh import dp_axes


# --------------------------------------------------------------- sanitation
def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def sanitize(mesh: Mesh, shape: tuple[int, ...], spec: P) -> P:
    """Drop axes whose size does not divide the dim; drop unknown axes."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, entries):
        if axis is None:
            out.append(None)
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        if not all(a in mesh.axis_names for a in axes):
            out.append(None)
            continue
        if dim % _axis_size(mesh, axis) != 0:
            out.append(None)
            continue
        out.append(axis)
    return P(*out)


def named(mesh: Mesh, shape: tuple[int, ...], spec: P) -> NamedSharding:
    return NamedSharding(mesh, sanitize(mesh, shape, spec))


# ------------------------------------------------------------- param rules
# matched against the "/"-joined tree path of each leaf; first match wins.
# L = leading stacked-period dim (present under blocks/enc_blocks).
def _param_rules(cfg: ModelConfig, fsdp: Optional[str]):
    F = fsdp  # alias; None disables FSDP for that dim
    return [
        (r"embed$",                 P("model", None)),
        (r"lm_head$",               P(None, "model")),
        # attention
        (r"(mixer|cross)/wq$",      P(None, F, "model")),
        (r"(mixer|cross)/wk$",      P(None, F, "model")),
        (r"(mixer|cross)/wv$",      P(None, F, "model")),
        (r"(mixer|cross)/wo$",      P(None, "model", F)),
        (r"(mixer|cross)/b[qkv]$",  P(None, None)),
        # dense mlp
        (r"mlp/w_(up|gate)$",       P(None, F, "model")),
        (r"mlp/w_down$",            P(None, "model", F)),
        # moe
        (r"mlp/router$",            P(None, None, None)),
        (r"mlp/w_(up|gate)$",       P(None, None, F, "model")),  # [L,E,D,F]
        (r"mlp/w_down$",            P(None, None, "model", F)),  # [L,E,F,D]
        # mamba
        (r"mixer/in_proj$",         P(None, F, "model")),
        (r"mixer/out_proj$",        P(None, "model", F)),
        (r"mixer/conv_[wb]$",       P(None, None, "model")),
        (r"mixer/x_proj$",          P(None, "model", None)),
        (r"mixer/dt_proj$",         P(None, None, "model")),
        (r"mixer/(A_log)$",         P(None, "model", None)),
        (r"mixer/(D|dt_bias)$",     P(None, "model")),
        # rwkv (heads often indivisible -> replicate outputs, FSDP inputs)
        (r"mixer/w[rkvgo]$",        P(None, F, None)),
        (r"mixer/(w_lora_a|mix_lora_a)$", P(None, F, None)),
        (r"mixer/.*$",              P(None,)),
        (r"mlp/w[kvr]$",            P(None, F, None)),
        # norms / everything else replicated
        (r".*",                     P()),
    ]


def _moe_aware(path: str, shape: tuple[int, ...], rules) -> P:
    """Pick the matching rule; disambiguate mlp w_up/w_down by rank (MoE
    weights are rank-4 with the stacked period dim)."""
    for pat, spec in rules:
        if re.search(pat, path):
            if re.search(r"mlp/w_(up|gate|down)$", path):
                want_rank4 = len(shape) == 4
                is_moe_rule = len(spec) == 4
                if want_rank4 != is_moe_rule:
                    continue
            return spec
    return P()


def _fsdp2d_spec(path: str, shape: tuple[int, ...]) -> P:
    """fsdp2d: every weight sharded over ("data","model") on its first
    big dim; embed/lm_head replicated (read once per step); no TP axis."""
    F = ("data", "model")
    if re.search(r"(embed|lm_head)$", path):
        return P()
    stacked = path.startswith(("blocks", "enc_blocks"))
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape
    if not body:
        return P()
    # put the FSDP axes on the largest dim of the body
    big = max(range(len(body)), key=lambda i: body[i])
    spec = [None] * len(body)
    spec[big] = F
    return P(*(list(lead) + spec))


def param_path_spec(cfg: ModelConfig, path: str,
                    shape: tuple[int, ...], *,
                    force_zero2: bool = False) -> P:
    """PartitionSpec for a parameter leaf given its tree path.

    ZeRO-2 (cfg.zero2 or force_zero2): parameters carry only the "model"
    axis — no per-layer all-gathers in fwd/bwd; the data axis shards the
    optimizer state instead (see opt_shardings).  The embedding table is
    fully replicated in ZeRO-2 (it is read once per step; replication
    removes the fp32 table-gather the partitioner otherwise emits)."""
    if cfg.train_sharding == "fsdp2d" and not force_zero2:
        return _fsdp2d_spec(path, shape)
    zero2 = force_zero2 or cfg.zero2
    fsdp = None if zero2 else ("data" if cfg.fsdp else None)
    if zero2 and re.search(r"embed$", path):
        return P()
    rules = _param_rules(cfg, fsdp)
    spec = _moe_aware(path, shape, rules)
    stacked = path.startswith(("blocks", "enc_blocks"))
    if not stacked:
        # drop the leading placeholder for unstacked leaves
        entries = list(spec)
        if entries and entries[0] is None and len(entries) > len(shape):
            spec = P(*entries[1:])
    return spec


def tree_paths(tree) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, x: "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                              for q in p), tree)


def param_shardings(mesh: Mesh, cfg: ModelConfig, params_shape, *,
                    force_zero2: bool = False) -> Any:
    """Pytree of NamedShardings matching a params(-shaped) pytree."""
    def one(path, leaf):
        pstr = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                        for q in path)
        return named(mesh, leaf.shape,
                     param_path_spec(cfg, pstr, leaf.shape,
                                     force_zero2=force_zero2))
    return jax.tree_util.tree_map_with_path(one, params_shape)


# --------------------------------------------------------------- opt state
def opt_shardings(mesh: Mesh, cfg: ModelConfig, opt_shape,
                  params_shape) -> Any:
    """Adam moments follow their parameters — except under ZeRO-2, where
    moments keep the data-axis (FSDP) sharding while params do not: the
    optimizer state is the thing worth sharding, and its traffic is one
    reduce-scatter + one all-gather per step instead of per layer."""
    if cfg.zero2 and cfg.train_sharding != "fsdp2d":
        z3 = cfg.with_overrides(zero2=False, fsdp=True)
        pshard = param_shardings(mesh, z3, params_shape)
    else:
        pshard = param_shardings(mesh, cfg, params_shape)
    out = {"m": pshard, "v": pshard,
           "count": NamedSharding(mesh, P())}
    if "ef" in opt_shape:
        out["ef"] = pshard
    return out


# -------------------------------------------------------------- batch/cache
def batch_shardings(mesh: Mesh, cfg: ModelConfig, batch_shape) -> Any:
    dp = dp_axes(mesh)

    def one(path, leaf):
        pstr = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                        for q in path)
        if pstr == "mrope_positions":            # [3,B,S]
            return named(mesh, leaf.shape, P(None, dp, None))
        spec = [dp] + [None] * (len(leaf.shape) - 1)
        return named(mesh, leaf.shape, P(*spec))
    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_shardings(mesh: Mesh, cfg: ModelConfig, cache_shape) -> Any:
    """KV: [L,B,T,K,hd] — batch over dp; heads over model when divisible,
    else sequence over model (flash-decoding split-KV).  SSM/RWKV states:
    batch over dp, inner dim over model when divisible."""
    dp = dp_axes(mesh)
    model_n = mesh.shape["model"]

    def one(path, leaf):
        pstr = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                        for q in path)
        shp = leaf.shape
        if pstr.endswith(("/k", "/v", "/xk", "/xv")):
            K = shp[3]
            if K % model_n == 0:
                return named(mesh, shp, P(None, dp, None, "model", None))
            return named(mesh, shp, P(None, dp, "model", None, None))
        if pstr.endswith("/ssm"):                 # [L,B,Di,N]
            return named(mesh, shp, P(None, dp, "model", None))
        if pstr.endswith("/conv"):                # [L,B,k-1,Di]
            return named(mesh, shp, P(None, dp, None, "model"))
        if pstr.endswith("/wkv"):                 # [L,B,H,N,N]
            return named(mesh, shp, P(None, dp, None, None, None))
        if pstr.endswith(("/shift", "/cmix_shift")):   # [L,B,D]
            return named(mesh, shp, P(None, dp, None))
        spec = [None] + [dp] + [None] * (len(shp) - 2)
        return named(mesh, shp, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache_shape)
