"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is the HTAP boundary (OLTP/training pod 0 ships its WAL to the
OLAP/serving pod 1 asynchronously); for training dry-runs it acts as an
outer data-parallel axis so the full 512-chip lowering is exercised.

Functions, not module constants: importing this module never touches JAX
device state (the dry-run must set XLA_FLAGS before any device query).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the locally available devices (CPU smoke tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Axes batch is sharded over (pod absorbs into data-parallel)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def train_dp_axes(mesh, cfg) -> tuple:
    """Batch axes for training: fsdp2d folds the model axis into data
    parallelism when the global batch divides the full chip count."""
    base = dp_axes(mesh)
    if getattr(cfg, "train_sharding", "tp") == "fsdp2d" \
            and "pod" not in mesh.axis_names:
        return base + ("model",)
    return base


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n
