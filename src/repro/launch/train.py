"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 20 --batch 4 --seq 64 --ckpt-dir /tmp/ckpt

Runs the host training loop (checkpointing, straggler monitor, RSS
publication) on the local devices; ``--mesh production`` instead lowers
against the 16×16 pod mesh (requires the 512-device XLA flag, see dryrun).
"""

from __future__ import annotations

import argparse
import json


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--publish", action="store_true",
                    help="publish versions to an RSS store (HTAP mode)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from ..configs import get_config, smoke_variant
    from ..optim import AdamWConfig
    from ..tensorstore import VersionedParamStore
    from ..train import Trainer

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    if args.microbatches:
        cfg = cfg.with_overrides(microbatches=args.microbatches)
    store = VersionedParamStore(slots=2) if args.publish else None
    tr = Trainer(cfg, batch=args.batch, seq_len=args.seq,
                 opt=AdamWConfig(lr=args.lr, moment_dtype=cfg.moment_dtype),
                 seed=args.seed, ckpt_dir=args.ckpt_dir,
                 ckpt_every=args.ckpt_every, store=store)
    logs = tr.run(args.steps)
    for m in logs[:3] + logs[-3:]:
        print(json.dumps(m))
    print(f"final loss: {logs[-1]['loss']:.4f}  "
          f"stragglers flagged: {len(tr.monitor.flagged)}")
    if store is not None:
        print(f"published versions: {store.stats['publishes']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
