"""ShapeDtypeStruct input specs for every (arch × shape × mode) cell.

`input_specs()` returns sharding-annotated ShapeDtypeStructs — weak-type
correct, shardable, zero allocation — for:
  * train  : (state, batch)  for `train_step`
  * prefill: (params, batch) for `prefill`
  * decode : (params, tokens, cache, cache_len) for `decode_step`
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig, ShapeConfig
from ..models.transformer import cache_spec, init_params
from ..optim import adamw
from .mesh import dp_axes, train_dp_axes
from .shardings import (batch_shardings, cache_shardings, named,
                        opt_shardings, param_shardings)


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(shape_tree, shard_tree):
    return jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), shape_tree, shard_tree)


SERVE_RESIDENT_LIMIT = 12e9   # bytes/chip of resident params for serving


def params_specs(mesh: Mesh, cfg: ModelConfig, *, serving: bool = False):
    shapes = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    force_zero2 = False
    if serving:
        # serving wants params RESIDENT (model-sharded, no per-step
        # gathers); fall back to FSDP only when a model shard exceeds HBM
        # (jamba-398B, mixtral-8x22B on a 16-way model axis).
        per_chip = 2 * cfg.param_count() / mesh.shape["model"]
        force_zero2 = per_chip <= SERVE_RESIDENT_LIMIT
    return _with_shardings(
        shapes, param_shardings(mesh, cfg, shapes,
                                force_zero2=force_zero2))


def state_specs(mesh: Mesh, cfg: ModelConfig,
                opt_cfg: Optional[adamw.AdamWConfig] = None):
    opt_cfg = opt_cfg or adamw.AdamWConfig(moment_dtype=cfg.moment_dtype)
    pspec = params_specs(mesh, cfg)
    oshapes = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), pspec)
    oshard = opt_shardings(mesh, cfg, oshapes, pspec)
    return {
        "params": pspec,
        "opt": _with_shardings(oshapes, oshard),
        "step": _sds((), jnp.int32, NamedSharding(mesh, P())),
    }, opt_cfg


def batch_specs(mesh: Mesh, cfg: ModelConfig, batch: int, seq_len: int,
                *, labels: bool = True, train: bool = False):
    shapes = {"tokens": _sds((batch, seq_len), jnp.int32)}
    if labels:
        shapes["labels"] = _sds((batch, seq_len), jnp.int32)
    if cfg.mrope_sections:
        shapes["mrope_positions"] = _sds((3, batch, seq_len), jnp.int32)
        shapes["vision_embeds"] = _sds(
            (batch, max(seq_len // 4, 1), cfg.d_model), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        shapes["enc_embeds"] = _sds(
            (batch, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
    if train:
        from .shardings import batch_shardings as _bs
        import repro.launch.shardings as _sh
        dp = train_dp_axes(mesh, cfg)
        def one(path, leaf):
            pstr = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                            for q in path)
            if pstr == "mrope_positions":
                return _sh.named(mesh, leaf.shape, P(None, dp, None))
            spec = [dp] + [None] * (len(leaf.shape) - 1)
            return _sh.named(mesh, leaf.shape, P(*spec))
        shard = jax.tree_util.tree_map_with_path(one, shapes)
        return _with_shardings(shapes, shard)
    return _with_shardings(shapes, batch_shardings(mesh, cfg, shapes))


def cache_specs(mesh: Mesh, cfg: ModelConfig, batch: int, seq_len: int):
    spec = cache_spec(cfg, batch, seq_len)
    is_sd = lambda x: (isinstance(x, tuple) and len(x) == 2
                       and isinstance(x[0], tuple))
    shapes = jax.tree.map(lambda sd: _sds(*sd), spec, is_leaf=is_sd)
    return _with_shardings(shapes, cache_shardings(mesh, cfg, shapes))


def input_specs(arch_cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Returns (mode, specs dict) for the cell."""
    cfg = arch_cfg
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        state, opt_cfg = state_specs(mesh, cfg)
        return "train", {"state": state,
                         "batch": batch_specs(mesh, cfg, B, S, train=True),
                         "opt_cfg": opt_cfg}
    if shape.kind == "prefill":
        return "prefill", {"params": params_specs(mesh, cfg, serving=True),
                           "batch": batch_specs(mesh, cfg, B, S,
                                                labels=False)}
    # decode: one new token against a seq_len-deep cache
    dp = dp_axes(mesh)
    return "decode", {
        "params": params_specs(mesh, cfg, serving=True),
        "tokens": _sds((B, 1), jnp.int32,
                       named(mesh, (B, 1), P(dp, None))),
        "cache": cache_specs(mesh, cfg, B, S),
        "cache_len": _sds((), jnp.int32, NamedSharding(mesh, P())),
    }
