"""Lightweight span tracing of the two hot paths.

A *span* is a named, labeled, timed tree node: the OLAP serve path opens
`olap_serve` with children for route -> resolve -> kernel dispatch ->
finalize, and the OLTP commit path opens `oltp_commit` with certify/WAL
children — so a trace dump answers "where did this serve spend its
time?" per replica / policy / plan kind / kernel mode.

Capture is OFF by default and costs one cached boolean check per
`span()` call (a shared no-op context manager is returned, nothing
allocated).  Enable with ``REPRO_TRACE=1`` — resolved once at import,
mirroring ``REPRO_INTERPRET`` in `repro.kernels.config` — or at runtime
via `TRACER.set_enabled(True)`.  Even when enabled, spans are plain
perf_counter pairs and small dicts: no I/O, no thread handoff.

The tracer also keeps always-on `spans_opened` / `spans_closed`
registry counters (balance is a verify.sh invariant: an unbalanced tree
means an instrumented path raised past its finally or a span leaked).
"""

from __future__ import annotations

import os
import time
from collections import deque
from typing import Optional

from .registry import REGISTRY

_FALSE = ("0", "false", "no", "off")


def _env_trace_default() -> bool:
    return os.environ.get("REPRO_TRACE", "0").strip().lower() not in _FALSE


class Span:
    """One timed node of a trace tree."""

    __slots__ = ("name", "labels", "t0", "dt", "children")

    def __init__(self, name: str, labels: dict) -> None:
        self.name = name
        self.labels = labels
        self.t0 = time.perf_counter()
        self.dt = 0.0
        self.children: list[Span] = []

    def close(self) -> None:
        self.dt = time.perf_counter() - self.t0

    def render(self, indent: int = 0) -> str:
        lbl = " ".join(f"{k}={v}" for k, v in self.labels.items())
        line = (f"{'  ' * indent}{self.name:<{max(1, 24 - 2 * indent)}} "
                f"{self.dt * 1e6:9.1f}us" + (f"  [{lbl}]" if lbl else ""))
        return "\n".join([line] + [c.render(indent + 1)
                                   for c in self.children])


class _NullSpan:
    """Shared do-nothing context manager handed out when tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _SpanCtx:
    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span) -> None:
        self._tracer = tracer
        self._span = span

    def __enter__(self):
        return self._span

    def __exit__(self, *exc):
        self._tracer._close(self._span)
        return False


class Tracer:
    """Per-process span collector: root spans land in a bounded deque."""

    def __init__(self, max_traces: int = 256) -> None:
        self._enabled: Optional[bool] = None       # None -> env default
        self._stack: list[Span] = []
        self.traces: deque[Span] = deque(maxlen=max_traces)
        self._opened = REGISTRY.counter("trace_spans_opened")
        self._closed = REGISTRY.counter("trace_spans_closed")

    # ----------------------------------------------------------- switch
    @property
    def enabled(self) -> bool:
        return _env_trace_default() if self._enabled is None \
            else self._enabled

    def set_enabled(self, on: Optional[bool]) -> None:
        """True/False to force; None to fall back to REPRO_TRACE."""
        self._enabled = on

    # ---------------------------------------------------------- capture
    def span(self, name: str, **labels):
        """Context manager opening a child of the current span (or a new
        root).  Returns a shared no-op object when capture is off."""
        if not self.enabled:
            return _NULL_SPAN
        s = Span(name, labels)
        if self._stack:
            self._stack[-1].children.append(s)
        self._stack.append(s)
        self._opened.inc()
        return _SpanCtx(self, s)

    def _close(self, span: Span) -> None:
        span.close()
        self._closed.inc()
        # tolerate a corrupted stack (an instrumented frame that escaped
        # its with-block) rather than cascading: drop back to the span
        if self._stack and self._stack[-1] is span:
            self._stack.pop()
        elif span in self._stack:
            del self._stack[self._stack.index(span):]
        if not self._stack:
            self.traces.append(span)

    def annotate(self, **labels) -> None:
        """Attach labels to the innermost open span (no-op when off or at
        top level) — used where the value is only known mid-span, e.g.
        the routed replica index or the selected kernel mode."""
        if self._stack:
            self._stack[-1].labels.update(labels)

    # ------------------------------------------------------------ query
    @property
    def depth(self) -> int:
        return len(self._stack)

    @property
    def opened(self) -> int:
        return self._opened.value

    @property
    def closed(self) -> int:
        return self._closed.value

    def render(self, limit: int = 5) -> str:
        """Human-readable dump of the most recent `limit` trace trees."""
        roots = list(self.traces)[-limit:]
        if not roots:
            return "(no traces captured; set REPRO_TRACE=1)"
        return "\n".join(r.render() for r in roots)

    def clear(self) -> None:
        """Drop captured trees and any dangling stack (counters are reset
        by the registry-wide reset, not here)."""
        self._stack.clear()
        self.traces.clear()


# the process-wide default tracer
TRACER = Tracer()
