"""Process-wide metric registry: counters, gauges, and fixed-bucket latency
histograms that yield p50/p95/p99 without storing samples.

One `MetricRegistry` (`repro.obs.REGISTRY`) is the single source of truth
for every operational statistic in the repo.  A *metric* is a named series
with a frozen label set — `registry.counter("engine_commits", engine="e3",
certifier="ssn")` returns the same `Counter` object on every call with the
same (name, labels) pair, so components hold direct references and
increments are one attribute add (no lookup on the hot path).

The pre-registry ad-hoc stats dicts (`Engine.stats`,
`PagedMirror.range_stats`/`exec_stats`, `ReplicaCluster.stats`, the kernel
layer's `LAUNCH_STATS`) survive as *views* over registry series:

  * `StatsView`        — dict-shaped view, one counter per fixed key
                         (`stats["commits"] += 1` still works)
  * `LabeledCounterMap` — open-keyed dict view, one labeled series per key
                         seen (`stats["by_reason"]["pivot"] += 1`)
  * `CounterList`      — list-shaped view over an indexed family
                         (`stats["served"][idx] += 1`, per-replica labels)

so no caller churns, but `snapshot()` / `to_json()` /
`render_prometheus()` see everything, and `reset()` is one atomic
zero-everything with a pre-reset snapshot returned (the cross-run-leakage
fix for process-global stats).

Latency histograms use fixed log-spaced bucket boundaries (1 µs .. 10 s,
4 per decade): `observe()` is a bisect + two adds, percentiles come from
linear interpolation inside the covering bucket — bounded memory at any
sample count.

Timing is cheap-by-default and stubbable: instrument with
``t0 = tick()`` ... ``tock(hist, t0)``; `set_timing(False)` turns both
into no-ops (no `perf_counter` calls), which is how the observability
bench measures its own overhead bound.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from bisect import bisect_left
from collections import abc
from typing import Optional, Sequence

# latency bucket boundaries in SECONDS: 1 µs .. 10 s, 4 per decade, plus an
# implicit overflow bucket.  Fixed across every histogram so merged
# summaries (e.g. per-stage across replicas) stay exact bucket sums.
DEFAULT_BOUNDS = tuple(1e-6 * 10 ** (i / 4) for i in range(29))


class Counter:
    """Monotonic (by convention) integer series."""

    __slots__ = ("name", "labels", "value")
    kind = "counter"

    def __init__(self, name: str, labels: tuple) -> None:
        self.name, self.labels, self.value = name, labels, 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def set(self, v) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0

    def snap(self):
        return self.value


class Gauge(Counter):
    """Point-in-time value (peaks tracked via `track_max`)."""

    __slots__ = ()
    kind = "gauge"

    def track_max(self, v) -> None:
        if v > self.value:
            self.value = v


class Histogram:
    """Fixed-bucket latency histogram: p50/p95/p99 from bucket counts, no
    samples stored.  Values are seconds; summaries report microseconds."""

    __slots__ = ("name", "labels", "bounds", "counts", "total", "count")
    kind = "histogram"

    def __init__(self, name: str, labels: tuple,
                 bounds: Sequence[float] = DEFAULT_BOUNDS) -> None:
        self.name, self.labels = name, labels
        self.bounds = tuple(bounds)
        self.counts = [0] * (len(self.bounds) + 1)   # +1: overflow bucket
        self.total = 0.0
        self.count = 0

    def observe(self, seconds: float) -> None:
        self.counts[bisect_left(self.bounds, seconds)] += 1
        self.total += seconds
        self.count += 1

    def reset(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0.0
        self.count = 0

    def percentile(self, q: float) -> float:
        """q-th percentile (q in [0, 1]) in seconds, linearly interpolated
        inside the covering bucket; 0.0 when empty."""
        return percentile_of(self.bounds, self.counts, self.count, q)

    def snap(self) -> dict:
        return summarize(self.bounds, self.counts, self.count, self.total)


def percentile_of(bounds: Sequence[float], counts: Sequence[int],
                  total_count: int, q: float) -> float:
    if not total_count:
        return 0.0
    target = q * total_count
    cum, lo = 0, 0.0
    for bound, c in zip(bounds, counts):
        if c and cum + c >= target:
            return lo + (target - cum) / c * (bound - lo)
        cum += c
        lo = bound
    return bounds[-1]        # overflow bucket: clamp to the last boundary


def summarize(bounds, counts, count, total) -> dict:
    """The standard latency summary: count + p50/p95/p99 in µs (rounded)."""
    return {
        "count": count,
        "sum_us": round(total * 1e6, 1),
        "p50_us": round(percentile_of(bounds, counts, count, 0.50) * 1e6, 1),
        "p95_us": round(percentile_of(bounds, counts, count, 0.95) * 1e6, 1),
        "p99_us": round(percentile_of(bounds, counts, count, 0.99) * 1e6, 1),
    }


def _fmt_series(name: str, labels: tuple) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class MetricRegistry:
    """Process-wide named-series registry with atomic reset/snapshot."""

    def __init__(self) -> None:
        self._metrics: dict[tuple, object] = {}
        self._lock = threading.RLock()
        self._scopes = itertools.count(1)

    # ------------------------------------------------------------ creation
    def scope(self, prefix: str) -> str:
        """A unique per-instance label value (e.g. "engine3"): component
        instances scope their series so per-instance views never alias."""
        return f"{prefix}{next(self._scopes)}"

    def _get(self, cls, name: str, labels: dict, **kw):
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(name, key[1], **kw)
            assert isinstance(m, cls), \
                f"metric {name} already registered as {m.kind}"
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, *, bounds: Sequence[float] = DEFAULT_BOUNDS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, bounds=bounds)

    # ----------------------------------------------------------- queries
    def series(self, name: str) -> list:
        with self._lock:
            return [m for m in self._metrics.values() if m.name == name]

    def total(self, name: str, **label_filter) -> int:
        """Sum a counter/gauge family over every label set matching the
        filter (aggregation across instances/replicas comes free)."""
        out = 0
        for m in self.series(name):
            lbl = dict(m.labels)
            if all(lbl.get(k) == str(v) for k, v in label_filter.items()):
                out += m.value
        return out

    def hist_summary(self, name: str, **label_filter) -> dict:
        """Merged latency summary of a histogram family: exact bucket sums
        across every matching label set (shared fixed bounds)."""
        counts, count, total, bounds = None, 0, 0.0, DEFAULT_BOUNDS
        for m in self.series(name):
            lbl = dict(m.labels)
            if not all(lbl.get(k) == str(v) for k, v in label_filter.items()):
                continue
            bounds = m.bounds
            if counts is None:
                counts = [0] * (len(m.bounds) + 1)
            for i, c in enumerate(m.counts):
                counts[i] += c
            count += m.count
            total += m.total
        return summarize(bounds, counts or [0] * (len(bounds) + 1),
                         count, total)

    def hist_group(self, name: str, by: str, **label_filter) -> dict:
        """Per-label-value merged summaries of a histogram family, e.g.
        hist_group("olap_serve_seconds", "plan") -> {plan kind: summary}."""
        values = sorted({dict(m.labels).get(by) for m in self.series(name)
                         if dict(m.labels).get(by) is not None})
        out = {v: self.hist_summary(name, **{by: v}, **label_filter)
               for v in values}
        # registrations survive reset; groups that saw nothing in this
        # measurement window are noise, not data
        return {v: s for v, s in out.items() if s["count"]}

    # ----------------------------------------------------------- export
    def snapshot(self) -> dict:
        """Plain-data snapshot: {"counters": {series: value}, "gauges":
        {...}, "histograms": {series: summary}}."""
        with self._lock:
            out = {"counters": {}, "gauges": {}, "histograms": {}}
            for m in self._metrics.values():
                out[m.kind + "s"][_fmt_series(m.name, m.labels)] = m.snap()
            return out

    def totals(self) -> dict:
        """Counter/gauge families aggregated over all label sets — the
        compact cross-instance view driver metrics snapshot from."""
        with self._lock:
            out: dict[str, int] = {}
            for m in self._metrics.values():
                if m.kind in ("counter", "gauge"):
                    out[m.name] = out.get(m.name, 0) + m.value
            return out

    def to_json(self, *, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def render_prometheus(self) -> str:
        """Prometheus text exposition (cumulative histogram buckets)."""
        with self._lock:
            lines: list[str] = []
            seen_type: set[str] = set()
            for m in sorted(self._metrics.values(),
                            key=lambda m: (m.name, m.labels)):
                if m.name not in seen_type:
                    seen_type.add(m.name)
                    lines.append(f"# TYPE {m.name} {m.kind}")
                if m.kind != "histogram":
                    lines.append(f"{_fmt_series(m.name, m.labels)} {m.value}")
                    continue
                cum = 0
                for bound, c in zip(m.bounds, m.counts):
                    cum += c
                    lbl = m.labels + (("le", f"{bound:.6g}"),)
                    lines.append(
                        f"{_fmt_series(m.name + '_bucket', lbl)} {cum}")
                lbl = m.labels + (("le", "+Inf"),)
                lines.append(
                    f"{_fmt_series(m.name + '_bucket', lbl)} {m.count}")
                lines.append(
                    f"{_fmt_series(m.name + '_sum', m.labels)} "
                    f"{m.total:.9f}")
                lines.append(
                    f"{_fmt_series(m.name + '_count', m.labels)} {m.count}")
            return "\n".join(lines) + "\n"

    # ------------------------------------------------------------- reset
    def reset(self) -> dict:
        """Atomically zero EVERY registered series (registrations — and the
        object identities views hold — survive) and return the pre-reset
        snapshot.  The driver calls this at run start so two back-to-back
        runs both start from zero."""
        with self._lock:
            snap = self.snapshot()
            for m in self._metrics.values():
                m.reset()
            return snap

    def reset_metrics(self, metrics) -> None:
        """Atomically zero a subset of series (e.g. one view's counters)."""
        with self._lock:
            for m in metrics:
                m.reset()


# ---------------------------------------------------------------- views
class StatsView(abc.MutableMapping):
    """Dict-shaped thin view over registry counters: preserves the
    pre-registry stats-attribute API (`stats["k"] += 1`, `dict(stats)`,
    `==`), one fixed-key series each; `sub` mounts nested views (e.g. a
    `LabeledCounterMap` under "by_reason")."""

    __slots__ = ("_reg", "_c", "_sub")

    def __init__(self, registry: MetricRegistry, prefix: str,
                 keys: Sequence[str], *, labels: Optional[dict] = None,
                 sub: Optional[dict] = None) -> None:
        self._reg = registry
        self._c = {k: registry.counter(f"{prefix}_{k}", **(labels or {}))
                   for k in keys}
        self._sub = dict(sub or {})

    def __getitem__(self, k):
        if k in self._sub:
            return self._sub[k]
        return self._c[k].value

    def __setitem__(self, k, v) -> None:
        if k in self._sub:
            raise TypeError(f"nested stats view {k!r} cannot be assigned")
        self._c[k].set(v)

    def __delitem__(self, k) -> None:
        raise TypeError("stats views have a fixed key set")

    def __iter__(self):
        yield from self._c
        yield from self._sub

    def __len__(self) -> int:
        return len(self._c) + len(self._sub)

    def __eq__(self, other):
        if isinstance(other, abc.Mapping):
            return dict(self) == dict(other)
        return NotImplemented

    __hash__ = None

    def __repr__(self) -> str:
        return f"StatsView({dict(self)!r})"

    def reset(self) -> dict:
        """Atomic zero of this view's series; returns the pre-reset dict."""
        with self._reg._lock:
            snap = {k: c.value for k, c in self._c.items()}
            self._reg.reset_metrics(self._c.values())
            return snap

    def detach(self) -> dict:
        """Deep plain-dict copy, severed from the registry: what a run
        hands back to callers that outlive the measurement window (a
        later `REGISTRY.reset()` must not zero their copy)."""
        return {k: dict(v) if isinstance(v, abc.Mapping) else v
                for k, v in self.items()}


class LabeledCounterMap(abc.MutableMapping):
    """Open-keyed dict view: each key materializes one labeled series of a
    family (e.g. engine_aborts_by_reason{reason=...}).  Iteration skips
    zero-valued keys, matching the ad-hoc-dict semantics where an unseen
    reason was simply absent."""

    __slots__ = ("_reg", "_name", "_lk", "_labels", "_c")

    def __init__(self, registry: MetricRegistry, name: str, label_key: str,
                 *, labels: Optional[dict] = None) -> None:
        self._reg, self._name, self._lk = registry, name, label_key
        self._labels = dict(labels or {})
        self._c: dict = {}

    def _counter(self, k) -> Counter:
        c = self._c.get(k)
        if c is None:
            c = self._c[k] = self._reg.counter(
                self._name, **self._labels, **{self._lk: k})
        return c

    def __getitem__(self, k):
        if k not in self._c:
            raise KeyError(k)
        return self._c[k].value

    def __setitem__(self, k, v) -> None:
        self._counter(k).set(v)

    def __delitem__(self, k) -> None:
        raise TypeError("labeled counter maps cannot drop series")

    def __iter__(self):
        return (k for k, c in self._c.items() if c.value)

    def __len__(self) -> int:
        return sum(1 for _ in self)

    def __eq__(self, other):
        if isinstance(other, abc.Mapping):
            return dict(self) == dict(other)
        return NotImplemented

    __hash__ = None

    def __repr__(self) -> str:
        return f"LabeledCounterMap({dict(self)!r})"


class CounterList(abc.Sequence):
    """List-shaped view over an indexed counter family (e.g. per-replica
    serve counts: cluster_served{replica="0"} ...)."""

    __slots__ = ("_c",)

    def __init__(self, registry: MetricRegistry, name: str, n: int,
                 label_key: str = "replica", *,
                 labels: Optional[dict] = None) -> None:
        self._c = [registry.counter(name, **(labels or {}),
                                    **{label_key: str(i)})
                   for i in range(n)]

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [c.value for c in self._c[i]]
        return self._c[i].value

    def __setitem__(self, i: int, v) -> None:
        self._c[i].set(v)

    def __len__(self) -> int:
        return len(self._c)

    def __eq__(self, other):
        return list(self) == other if isinstance(other, (list, tuple)) \
            else NotImplemented

    __hash__ = None

    def __repr__(self) -> str:
        return f"CounterList({list(self)!r})"


# ------------------------------------------------------- timing switch
# Counters stay on unconditionally (one add each); timing instrumentation
# (perf_counter pairs feeding latency histograms) flows through tick/tock
# so the whole layer can be stubbed — the overhead bound in
# benchmarks.bench_serve_latency compares default vs stubbed runs.
_TIMING = [True]


def set_timing(enabled: bool) -> None:
    """Enable/disable latency timing (histogram observes) process-wide."""
    _TIMING[0] = bool(enabled)


def timing_enabled() -> bool:
    return _TIMING[0]


def tick() -> float:
    """Start a latency measurement (0.0 when timing is stubbed)."""
    return time.perf_counter() if _TIMING[0] else 0.0


def tock(hist: Histogram, t0: float) -> None:
    """Finish a latency measurement into `hist` (no-op when stubbed)."""
    if t0:
        hist.observe(time.perf_counter() - t0)


# the process-wide default registry
REGISTRY = MetricRegistry()
