"""Unified observability layer: process-wide metric registry + hot-path
span tracing.

Everything operational in the repo reports here: counters/gauges are
always on (one attribute add each), latency histograms are on by default
and stubbable via `set_timing(False)`, span capture is off by default
and enabled with REPRO_TRACE=1 (or `TRACER.set_enabled(True)`).

`reset_run()` is the one atomic "start a fresh measurement window"
entry point the driver calls per run.
"""

from .registry import (DEFAULT_BOUNDS, REGISTRY, Counter, CounterList, Gauge,
                       Histogram, LabeledCounterMap, MetricRegistry,
                       StatsView, set_timing, summarize, tick,
                       timing_enabled, tock)
from .trace import TRACER, Span, Tracer

__all__ = [
    "Counter", "CounterList", "DEFAULT_BOUNDS", "Gauge", "Histogram",
    "LabeledCounterMap", "MetricRegistry", "REGISTRY", "Span", "StatsView",
    "TRACER", "Tracer", "reset_run", "set_timing", "summarize", "tick",
    "timing_enabled", "tock",
]


def reset_run() -> dict:
    """Start a fresh measurement window: atomically zero every registered
    series and drop captured traces.  Returns the pre-reset snapshot."""
    snap = REGISTRY.reset()
    TRACER.clear()
    return snap
