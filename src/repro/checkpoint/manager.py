"""Fault-tolerant checkpointing: atomic sharded save, elastic restore.

Layout:  <dir>/step_<N>/  arrays.npz + manifest.json ;  <dir>/LATEST
Writes go to a temp dir then `os.replace` (atomic on POSIX), so a crash
mid-save never corrupts the latest checkpoint.  `restore` re-shards every
leaf onto the *current* mesh (elastic resume: the saved mesh layout does not
need to match).  Optional async save runs in a daemon thread off a host copy.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8)}


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(state, step: int, ckpt_dir: str, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    stored = {}
    for k, v in flat.items():
        name = str(v.dtype)
        if name in _EXOTIC:                    # npz can't hold ml_dtypes
            v = v.view(_EXOTIC[name][1])
        stored[k] = v
    np.savez(os.path.join(tmp, "arrays.npz"), **stored)
    manifest = {"step": step,
                "leaves": {k: {"shape": list(v.shape),
                               "dtype": str(v.dtype)}
                           for k, v in flat.items()}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                    # atomic publish
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep)
    return final


def save_async(state, step: int, ckpt_dir: str, *, keep: int = 3) -> threading.Thread:
    """Snapshot to host memory synchronously, write to disk in background."""
    host_state = jax.tree.map(np.asarray, state)
    t = threading.Thread(target=save, args=(host_state, step, ckpt_dir),
                         kwargs={"keep": keep}, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, template, *, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of `template` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of shardings —
    enables elastic resume onto a different mesh (each leaf is device_put
    with the new sharding)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    arrays = np.load(os.path.join(d, "arrays.npz"))
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(flat_t))
    out = []
    for (path, leaf), sh in zip(flat_t, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = arrays[key]
        saved_dtype = manifest["leaves"][key]["dtype"]
        if saved_dtype in _EXOTIC:
            arr = arr.view(_EXOTIC[saved_dtype][0])
        want = np.dtype(leaf.dtype)
        if arr.dtype != want:
            arr = arr.astype(want)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
