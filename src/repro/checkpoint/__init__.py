from . import manager
__all__ = ["manager"]
