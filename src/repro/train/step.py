"""Training step builder: microbatched grad accumulation, AdamW, metrics.

`make_train_step(cfg, opt_cfg)` returns a pure `train_step(state, batch)`
suitable for jit/pjit.  Gradient accumulation runs as a `lax.scan` over
microbatches (activation memory / accum trade-off; the per-microbatch
reduce-scatter overlaps the next microbatch's compute under XLA latency
hiding).  The accumulator dtype follows `opt_cfg.moment_dtype` so 398B-class
configs fit HBM.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..models.config import ModelConfig
from ..models.transformer import loss_fn
from ..optim import adamw


def init_state(key, cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
               params=None) -> dict:
    from ..models.transformer import init_params
    if params is None:
        params = init_params(key, cfg)
    return {"params": params,
            "opt": adamw.init(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}


def _split_microbatches(batch: dict, A: int) -> dict:
    """[B, ...] -> [A, B/A, ...]; mrope_positions has its batch at dim 1."""
    out = {}
    for k, x in batch.items():
        if k == "mrope_positions":            # [3, B, S]
            B = x.shape[1]
            out[k] = x.reshape(3, A, B // A, *x.shape[2:]).swapaxes(0, 1)
        else:
            B = x.shape[0]
            out[k] = x.reshape(A, B // A, *x.shape[1:])
    return out


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig):
    A = max(cfg.microbatches, 1)
    acc_dt = jnp.dtype(opt_cfg.moment_dtype)

    def loss_of(params, mb):
        return loss_fn(params, cfg, mb)

    def train_step(state, batch):
        params = state["params"]
        if A == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            mbs = _split_microbatches(batch, A)

            def mb_body(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (gsum, lsum), _ = lax.scan(mb_body, (g0, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: (g / A).astype(jnp.float32), gsum)
            loss = lsum / A
        new_params, new_opt, om = adamw.update(grads, state["opt"], params,
                                               opt_cfg)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, **om}
        return new_state, metrics

    return train_step
