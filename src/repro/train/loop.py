"""Host training loop: checkpoint/restart fault tolerance, straggler
monitoring, and RSS publication (the OLTP side of the HTAP boundary).

Every training step is a write transaction: the loop begins a txn, runs the
jitted step, and publishes the new parameter version to the
`VersionedParamStore` (which appends begin/commit records to the WAL that the
serving pod replays).  Auxiliary writers (e.g. an embedding-tuning task in
the examples) share the same WAL and may carry rw-dependency records —
exactly the paper's Sec 5.1 "OLTP side collects dependencies".
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

from ..checkpoint import manager as ckpt
from ..data.pipeline import SyntheticPipeline
from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig
from ..tensorstore.versioned import VersionedParamStore
from .step import init_state, make_train_step


@dataclass
class StragglerMonitor:
    """EMA step-time tracker; flags steps slower than `factor`× the EMA.

    On a real fleet the callback triggers mitigation (hot spare swap /
    within-step timeout); here it records and reports."""
    alpha: float = 0.1
    factor: float = 3.0
    ema: Optional[float] = None
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        straggler = self.ema is not None and dt > self.factor * self.ema
        self.ema = dt if self.ema is None else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        if straggler:
            self.flagged.append((step, dt))
        return straggler


class Trainer:
    def __init__(self, cfg: ModelConfig, *, batch: int, seq_len: int,
                 opt: Optional[AdamWConfig] = None, seed: int = 0,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 publish_every: int = 1,
                 store: Optional[VersionedParamStore] = None):
        self.cfg = cfg
        self.opt_cfg = opt or AdamWConfig(moment_dtype=cfg.moment_dtype)
        self.pipeline = SyntheticPipeline(cfg, batch=batch, seq_len=seq_len,
                                          seed=seed)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.publish_every = publish_every
        self.store = store
        self.monitor = StragglerMonitor()
        self.step_fn = jax.jit(make_train_step(cfg, self.opt_cfg))
        self.state = init_state(jax.random.PRNGKey(seed), cfg, self.opt_cfg)
        self.metrics_log: list[dict] = []
        if store is not None:
            store.publish(self.state["params"])   # version 1 = init
            store.refresh()

    # ------------------------------------------------------------- recovery
    def try_restore(self) -> bool:
        if self.ckpt_dir is None:
            return False
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return False
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.state)
        self.state = ckpt.restore(self.ckpt_dir, template, step=step)
        self.pipeline.restore_state({"step": int(self.state["step"])})
        return True

    # ----------------------------------------------------------------- train
    def run(self, n_steps: int, *, inject_failure_at: Optional[int] = None
            ) -> list[dict]:
        done = 0
        while done < n_steps:
            try:
                done = self._run_inner(done, n_steps, inject_failure_at)
            except RuntimeError as e:
                if "injected-failure" not in str(e):
                    raise
                # fault tolerance path: restore from latest checkpoint
                restored = self.try_restore()
                done = int(self.state["step"]) if restored else 0
            finally:
                inject_failure_at = None      # injections are one-shot
        return self.metrics_log

    def _run_inner(self, done: int, n_steps: int,
                   inject_failure_at: Optional[int]) -> int:
        for i in range(done, n_steps):
            if inject_failure_at is not None and i == inject_failure_at:
                raise RuntimeError("injected-failure")
            t0 = time.perf_counter()
            batch = self.pipeline.batch_at(i)
            self.state, metrics = self.step_fn(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            metrics.update(step=i, dt=dt,
                           straggler=self.monitor.observe(i, dt))
            self.metrics_log.append(metrics)
            if self.store is not None and (i + 1) % self.publish_every == 0:
                self.store.publish(self.state["params"])
            if self.ckpt_dir and (i + 1) % self.ckpt_every == 0:
                ckpt.save(self.state, i + 1, self.ckpt_dir)
        return n_steps
