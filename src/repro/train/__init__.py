from .step import make_train_step, init_state
from .loop import Trainer, StragglerMonitor
__all__ = ["make_train_step", "init_state", "Trainer", "StragglerMonitor"]
