"""N-way WAL fan-out: one primary, N log-shipping replicas (paper Sec 5.1).

`ReplicaCluster` is the unit of decoupled-storage HTAP design at N > 1:

  * **Fan-out** — every replica is registered as a named WAL consumer
    (replication slot) on the primary's log; `ship(i)` replays the tail
    into replica i (its own RSSManager, paged mirror, and PRoT pin table)
    and acks the applied LSN back to the slot.
  * **Bounded log** — after every ship round the primary WAL is recycled
    up to `min_acked_lsn()`: the minimum applied LSN across ALL consumers.
    A lagging replica holds the log; it can never be handed a recycled
    prefix (the single-consumer truncation bug this subsystem replaces).
  * **Routing** — snapshot acquisition goes through a `RoutingPolicy`
    (freshest / round_robin / bounded_staleness); when no replica meets
    the staleness bound the cluster *ships-then-serves*: one synchronous
    replication round on the freshest replica, then serve it.
  * **Cluster-wide GC floor** — `gc_floor_seq()` is the min over replicas
    of min(replication horizon, oldest pinned snapshot); `gc_versions()`
    prunes every replica's version chains under its own floor, and the
    facade (`mvcc.htap.MultiNodeHTAP`) additionally prunes the primary
    under min(cluster floor, active-transaction horizon).

Snapshot handles are `(kind, replica_idx, reader_id, snapshot)` tuples —
kind is "rss" (an `RssSnapshot`, PRoT-pinned) or "si" (a commit-seq
horizon, pinned in the replica's SI pin table); `release(handle)` drops
the pin on the replica that served it.
"""

from __future__ import annotations

from typing import Any, Iterable, Optional, Sequence, Union

from .routing import Freshest, RoutingPolicy, make_policy

# handle: (kind, replica_idx, reader_id, snapshot)
SnapshotHandle = tuple


class ReplicaCluster:
    def __init__(self, primary, replicas: Iterable,
                 *, policy: Union[str, RoutingPolicy] = "freshest",
                 max_lag: int = 100) -> None:
        """`primary` is the OLTP engine (only its `.wal` and `.seq` are
        touched here); `replicas` are `mvcc.htap.Replica` instances (or
        anything with the same catch_up/snapshot/release surface)."""
        self.primary = primary
        self.replicas = list(replicas)
        assert self.replicas, "a cluster needs at least one replica"
        self.policy = make_policy(policy, max_lag=max_lag)
        self._slots: list[str] = []
        for i, rep in enumerate(self.replicas):
            name = primary.wal.register_consumer(f"replica{i}",
                                                 start_lsn=rep.applied_lsn)
            self._slots.append(name)
        self.stats: dict[str, Any] = {
            "served": [0] * len(self.replicas),
            "acquires": 0,
            "ship_then_serve": 0,
            "lag_records_sum": 0,       # summed over served snapshots
            "truncated_records": 0,
        }

    def __len__(self) -> int:
        return len(self.replicas)

    # ------------------------------------------------------------ lag state
    def lag_records(self, i: int) -> int:
        """Replication lag of replica i, in unapplied WAL records."""
        return self.primary.wal.head_lsn - self.replicas[i].applied_lsn

    def min_applied_lsn(self) -> int:
        return min(rep.applied_lsn for rep in self.replicas)

    def freshest_idx(self) -> int:
        return Freshest().choose(self)

    # -------------------------------------------------------------- fan-out
    def ship(self, replica: Optional[int] = None, *,
             max_records: int = 0) -> int:
        """One replication round: replay the WAL tail into one replica
        (or all, when `replica` is None), ack the applied LSNs, then
        recycle the primary WAL prefix EVERY consumer has applied."""
        idxs = range(len(self.replicas)) if replica is None else [replica]
        n = 0
        for i in idxs:
            rep = self.replicas[i]
            n += rep.catch_up(self.primary, max_records=max_records)
            self.primary.wal.ack(self._slots[i], rep.applied_lsn)
        self.stats["truncated_records"] += self.primary.wal.truncate()
        return n

    # -------------------------------------------------------------- routing
    def acquire(self, *, max_lag: Optional[int] = None) -> SnapshotHandle:
        """Route a snapshot acquisition through the policy.  When no
        replica satisfies the staleness bound, ship-then-serve: catch the
        freshest replica up synchronously, then serve it."""
        idx = self.policy.choose(self, max_lag=max_lag)
        if idx is None:
            idx = self.freshest_idx()
            self.ship(idx)
            self.stats["ship_then_serve"] += 1
        self.stats["acquires"] += 1
        self.stats["served"][idx] += 1
        self.stats["lag_records_sum"] += self.lag_records(idx)
        rep = self.replicas[idx]
        if rep.with_rss:
            rid, snap = rep.rss_snapshot()
            return ("rss", idx, rid, snap)
        rid, seq = rep.si_snapshot_pinned()
        return ("si", idx, rid, seq)

    def avg_served_lag(self) -> float:
        """Mean replication lag (WAL records) of served snapshots — the
        cluster's freshness metric per routing policy."""
        return self.stats["lag_records_sum"] / max(self.stats["acquires"], 1)

    # ---------------------------------------------------------------- reads
    def read(self, handle: SnapshotHandle, key: str) -> Any:
        kind, idx, _, s = handle
        rep = self.replicas[idx]
        return rep.read_si(s, key) if kind == "si" else rep.read_rss(s, key)

    def scan(self, handle: SnapshotHandle, keys: Sequence[str]) -> list[Any]:
        kind, idx, _, s = handle
        rep = self.replicas[idx]
        return rep.scan_si(s, keys) if kind == "si" else rep.scan_rss(s, keys)

    def release(self, handle: SnapshotHandle) -> None:
        _, idx, rid, _ = handle
        self.replicas[idx].release(rid)

    # ------------------------------------------------------------------- GC
    def gc_floor_seq(self) -> int:
        """The cluster-wide version-GC floor (commit-seq units): the min
        over replicas of min(replication horizon, oldest pinned
        snapshot)."""
        return min(rep.gc_floor_seq() for rep in self.replicas)

    def gc_versions(self) -> int:
        """Prune every replica's chain versions under its own pinned floor;
        returns total versions dropped."""
        return sum(rep.gc_versions() for rep in self.replicas)
