"""N-way WAL fan-out: one primary, N log-shipping replicas (paper Sec 5.1).

`ReplicaCluster` is the unit of decoupled-storage HTAP design at N > 1:

  * **Fan-out** — every replica is registered as a named WAL consumer
    (replication slot) on the primary's log; `ship(i)` replays the tail
    into replica i (its own RSSManager, paged mirror, and PRoT pin table)
    and acks the applied LSN back to the slot.
  * **Bounded log** — after every ship round the primary WAL is recycled
    up to `min_acked_lsn()`: the minimum applied LSN across ALL consumers.
    A lagging replica holds the log; it can never be handed a recycled
    prefix (the single-consumer truncation bug this subsystem replaces).
  * **Routing** — snapshot acquisition goes through a `RoutingPolicy`
    (freshest / round_robin / bounded_staleness); when no replica meets
    the staleness bound the cluster *ships-then-serves*: one synchronous
    replication round on the freshest replica, then serve it.
  * **Cluster-wide GC floor** — `gc_floor_seq()` is the min over replicas
    of min(replication horizon, oldest pinned snapshot); `gc_versions()`
    prunes every replica's version chains under its own floor, and the
    facade (`mvcc.htap.MultiNodeHTAP`) additionally prunes the primary
    under min(cluster floor, active-transaction horizon).

Snapshot handles are `(kind, replica_idx, reader_id, snapshot)` tuples —
kind is "rss" (an `RssSnapshot`, PRoT-pinned) or "si" (a commit-seq
horizon, pinned in the replica's SI pin table); `release(handle)` drops
the pin on the replica that served it.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Iterable, Optional, Sequence, Union

from ..obs import (REGISTRY, TRACER, CounterList, StatsView, tick, tock)
from ..tensorstore.version_store import Plan
from .routing import Freshest, RoutingPolicy, make_policy
from .session import Session

# handle: (kind, replica_idx, reader_id, snapshot)
SnapshotHandle = tuple

# the serve path's route stage: policy choice + cadence/ship decision +
# snapshot pin (the resolve/dispatch/finalize stages live in the mirror)
_ROUTE_H = REGISTRY.histogram("olap_stage_seconds", stage="route")


class ReplicaCluster:
    def __init__(self, primary, replicas: Iterable,
                 *, policy: Union[str, RoutingPolicy] = "freshest",
                 max_lag: int = 100) -> None:
        """`primary` is the OLTP engine (only its `.wal` and `.seq` are
        touched here); `replicas` are `mvcc.htap.Replica` instances (or
        anything with the same catch_up/snapshot/release surface)."""
        self.primary = primary
        self.replicas = list(replicas)
        assert self.replicas, "a cluster needs at least one replica"
        self.policy = make_policy(policy, max_lag=max_lag)
        self._slots: list[str] = []
        for i, rep in enumerate(self.replicas):
            name = primary.wal.register_consumer(f"replica{i}",
                                                 start_lsn=rep.applied_lsn)
            self._slots.append(name)
        # per-replica cadence history: head LSN at each EXTERNALLY-driven
        # ship (the replication schedule).  Serve-time ships (scheduled /
        # ship-then-serve) are excluded — recording them would shrink the
        # learned cadence, fire ship_due earlier, and trigger yet more
        # serve-time ships (a self-reinforcing collapse toward shipping on
        # every acquire).  `_last_ship_lsn` tracks ships of ANY kind so
        # due-ness still throttles to one serve-time ship per interval.
        self._ship_lsns: list[deque] = [deque(maxlen=8)
                                        for _ in self.replicas]
        self._last_ship_lsn: list[int] = [primary.wal.head_lsn
                                          for _ in self.replicas]
        # registry-backed accounting (series cluster_*), dict-shaped view;
        # "served" is a per-replica counter family (cluster_served{replica=i})
        lbl = {"cluster": REGISTRY.scope("cluster"),
               "policy": self.policy.name}
        self.stats = StatsView(
            REGISTRY, "cluster",
            ("acquires",
             "ship_then_serve",
             "scheduled_ships",         # cadence-due ships run at serve
             "lag_records_sum",         # observed, summed over served snaps
             "predicted_lag_sum",       # predicted at routing time, ditto
             "truncated_records",
             "token_acquires",          # acquires routed through a session
             "token_ships",             # delta ships run to cover a token
             "token_violations"),       # served below the token (must stay 0)
            labels=lbl,
            sub={"served": CounterList(REGISTRY, "cluster_served",
                                       len(self.replicas), labels=lbl)})
        self._next_sid = 0

    def __len__(self) -> int:
        return len(self.replicas)

    # ------------------------------------------------------------ lag state
    def lag_records(self, i: int) -> int:
        """Replication lag of replica i, in unapplied WAL records."""
        return self.primary.wal.head_lsn - self.replicas[i].applied_lsn

    def min_applied_lsn(self) -> int:
        return min(rep.applied_lsn for rep in self.replicas)

    def freshest_idx(self) -> int:
        return Freshest().choose(self)

    # -------------------------------------------------------- predicted lag
    def ship_cadence(self, i: int) -> Optional[float]:
        """Replica i's learned ship cadence in WAL records (mean head-LSN
        gap between its recent ships), or None before two ships."""
        h = self._ship_lsns[i]
        if len(h) < 2:
            return None
        return max((h[-1] - h[0]) / (len(h) - 1), 1.0)

    # a replica's next ship counts as imminent once this fraction of its
    # cadence interval has elapsed: running it early at serve replays the
    # same delta the schedule was about to replay (delta shipping makes
    # total replication work invariant — only the per-ship overhead is
    # pulled forward), at most once per window (`_last_ship_lsn` resets)
    DUE_FRACTION = 0.5

    def ship_due(self, i: int) -> bool:
        """Has the primary appended most of a cadence interval since
        replica i's last ship — of any kind, so a serve-time ship consumes
        the owed interval?  (Its next scheduled ship is imminent.)"""
        cadence = self.ship_cadence(i)
        return cadence is not None and \
            self.primary.wal.head_lsn - self._last_ship_lsn[i] >= \
            self.DUE_FRACTION * cadence

    def predicted_lag(self, i: int) -> int:
        """The lag replica i would serve with at THIS moment: observed lag,
        except ~0 when its cadence says a scheduled ship is due now (the
        serve path runs the due ship before serving — `acquire` with a
        predictive policy)."""
        return 0 if self.ship_due(i) else self.lag_records(i)

    # -------------------------------------------------------------- fan-out
    def ship(self, replica: Optional[int] = None, *,
             max_records: int = 0, record_cadence: bool = True) -> int:
        """One replication round: replay the WAL tail into one replica
        (or all, when `replica` is None), ack the applied LSNs, then
        recycle the primary WAL prefix EVERY consumer has applied.

        `record_cadence=False` marks a serve-time ship (scheduled /
        ship-then-serve): it advances `_last_ship_lsn` but stays out of
        the cadence history, so the learned cadence keeps reflecting the
        external replication schedule only."""
        idxs = range(len(self.replicas)) if replica is None else [replica]
        n = 0
        for i in idxs:
            rep = self.replicas[i]
            n += rep.catch_up(self.primary, max_records=max_records)
            self.primary.wal.ack(self._slots[i], rep.applied_lsn)
            self._last_ship_lsn[i] = self.primary.wal.head_lsn
            h = self._ship_lsns[i]
            # cadence points only when the head actually advanced: two
            # ships at the same LSN (e.g. back-to-back warm-up ships)
            # would otherwise teach a degenerate ~0-record cadence and
            # make every acquire look ship-due
            if record_cadence and (not h or self.primary.wal.head_lsn >
                                   h[-1]):
                h.append(self.primary.wal.head_lsn)
        self.stats["truncated_records"] += self.primary.wal.truncate()
        return n

    # ------------------------------------------------------------- sessions
    def session(self, *, keep_history: bool = False) -> Session:
        """Open a client session: a token carrying the LSN horizon this
        client has observed.  Pass it to `acquire(session=...)` for
        read-your-writes / monotonic reads across the fleet; call
        `session.note_commit(primary.wal.head_lsn)` after each of the
        client's OLTP commits."""
        sid, self._next_sid = self._next_sid, self._next_sid + 1
        return Session(sid, keep_history=keep_history)

    # -------------------------------------------------------------- routing
    def acquire(self, *, max_lag: Optional[int] = None,
                session: Optional[Session] = None) -> SnapshotHandle:
        """Route a snapshot acquisition through the policy.  A predictive
        policy may pick a replica on predicted lag (its scheduled ship is
        due): run that due ship before serving — cadence-owed work, not an
        emergency round.  When no replica satisfies the staleness bound,
        ship-then-serve: catch the freshest replica up synchronously, then
        serve it.

        With a `session`, only replicas whose applied LSN covers the
        session token (read-your-writes + monotonic reads) are eligible;
        when none does, the freshest replica gets a cadence-owed DELTA
        ship (`token_ships`) — never a synchronous stall, since delta
        shipping replays exactly what the replication schedule owed — and
        the token's floor is ratcheted forward after the serve."""
        min_lsn = session.min_required_lsn() if session is not None else 0
        t0 = tick()
        with TRACER.span("route", policy=self.policy.name):
            idx = self.policy.choose(self, max_lag=max_lag, min_lsn=min_lsn)
            predicted = self.predicted_lag(idx) if idx is not None else 0
            if idx is None:
                idx = self.freshest_idx()
                predicted = 0                  # served post-ship: lag ~0
                if min_lsn and \
                        self.policy.choose(self, max_lag=max_lag) is not None:
                    # staleness was satisfiable — only the session token
                    # wasn't: the freshest replica's delta ship covers it
                    # (cadence-owed records, not an emergency round)
                    with TRACER.span("token_ship", replica=idx):
                        self.ship(idx, record_cadence=False)
                    self.stats["token_ships"] += 1
                else:
                    with TRACER.span("ship_then_serve", replica=idx):
                        self.ship(idx, record_cadence=False)
                    self.stats["ship_then_serve"] += 1
            elif getattr(self.policy, "predictive", False) and \
                    (predicted < self.lag_records(idx) or
                     self.replicas[idx].applied_lsn < min_lsn):
                # the prediction was load-bearing: this replica only met
                # the staleness bound (or the session token) because its
                # imminent ship counts as run — run it (cadence-owed work
                # pulled forward, not an emergency round).  A replica
                # whose OBSERVED lag already satisfies the bound is
                # served as-is: no ship, no extra work.
                bound = self.policy.effective_bound(max_lag)
                if self.replicas[idx].applied_lsn < min_lsn:
                    with TRACER.span("token_ship", replica=idx):
                        self.ship(idx, record_cadence=False)
                    self.stats["token_ships"] += 1
                elif bound is not None and self.lag_records(idx) > bound:
                    with TRACER.span("scheduled_ship", replica=idx):
                        self.ship(idx, record_cadence=False)
                    self.stats["scheduled_ships"] += 1
                else:
                    predicted = self.lag_records(idx)   # served unshipped
            self.stats["acquires"] += 1
            self.stats["served"][idx] += 1
            self.stats["predicted_lag_sum"] += predicted
            self.stats["lag_records_sum"] += self.lag_records(idx)
            rep = self.replicas[idx]
            TRACER.annotate(replica=idx)
            if rep.with_rss:
                rid, snap = rep.rss_snapshot()
                handle = ("rss", idx, rid, snap)
            else:
                rid, seq = rep.si_snapshot_pinned()
                handle = ("si", idx, rid, seq)
            if session is not None:
                self.stats["token_acquires"] += 1
                if rep.applied_lsn < min_lsn:      # must never happen
                    self.stats["token_violations"] += 1
                session.note_read(rep.applied_lsn, idx)
        tock(_ROUTE_H, t0)
        return handle

    def avg_served_lag(self) -> float:
        """Mean observed replication lag (WAL records) of served snapshots —
        the cluster's freshness metric per routing policy."""
        return self.stats["lag_records_sum"] / max(self.stats["acquires"], 1)

    def avg_predicted_lag(self) -> float:
        """Mean lag predicted at routing time for served snapshots; compare
        with `avg_served_lag` to see what the cadence model promised vs
        what the replicas delivered."""
        return self.stats["predicted_lag_sum"] / max(self.stats["acquires"],
                                                     1)

    # ---------------------------------------------------------------- reads
    def read(self, handle: SnapshotHandle, key: str) -> Any:
        kind, idx, _, s = handle
        rep = self.replicas[idx]
        return rep.read_si(s, key) if kind == "si" else rep.read_rss(s, key)

    def execute(self, handle: SnapshotHandle, plan: Plan) -> Any:
        """The cluster's ONE plan-execution seam: serve any plan on the
        replica that served the handle's snapshot (same routing/freshness
        decision as the acquisition), under the handle's snapshot kind."""
        kind, idx, _, s = handle
        rep = self.replicas[idx]
        return rep.execute_si(s, plan) if kind == "si" \
            else rep.execute_rss(s, plan)

    def release(self, handle: SnapshotHandle) -> None:
        _, idx, rid, _ = handle
        self.replicas[idx].release(rid)

    # ------------------------------------------------------------------- GC
    def gc_floor_seq(self) -> int:
        """The cluster-wide version-GC floor (commit-seq units): the min
        over replicas of min(replication horizon, oldest pinned
        snapshot)."""
        return min(rep.gc_floor_seq() for rep in self.replicas)

    def gc_versions(self) -> int:
        """Prune every replica's chain versions under its own pinned floor;
        returns total versions dropped."""
        return sum(rep.gc_versions() for rep in self.replicas)
