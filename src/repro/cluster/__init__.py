"""Decoupled-storage replica cluster: N-way WAL fan-out with lag-aware
RSS snapshot routing (paper Sec 5.1 generalized to N replicas).

  cluster.py  ReplicaCluster — fan-out, min-LSN WAL recycling, routing
              (+ ship-cadence tracking for predicted-lag serves),
              session-token enforcement, cluster-wide GC floor
  routing.py  Freshest / RoundRobin / BoundedStaleness /
              PredictedStaleness / LatencySLO policies (+ ship-then-serve
              fallback when every replica is too stale, token-aware
              eligibility from below)
  session.py  Session — per-client token (last-commit LSN + last-read
              horizon) for read-your-writes / monotonic reads across the
              fleet
"""

from .cluster import ReplicaCluster, SnapshotHandle
from .routing import (BoundedStaleness, Freshest, LatencySLO,
                      PredictedStaleness, RoundRobin, RoutingPolicy,
                      make_policy)
from .session import Session

__all__ = [
    "ReplicaCluster", "SnapshotHandle", "Session",
    "RoutingPolicy", "Freshest", "RoundRobin", "BoundedStaleness",
    "PredictedStaleness", "LatencySLO", "make_policy",
]
