"""Decoupled-storage replica cluster: N-way WAL fan-out with lag-aware
RSS snapshot routing (paper Sec 5.1 generalized to N replicas).

  cluster.py  ReplicaCluster — fan-out, min-LSN WAL recycling, routing
              (+ ship-cadence tracking for predicted-lag serves),
              cluster-wide GC floor
  routing.py  Freshest / RoundRobin / BoundedStaleness /
              PredictedStaleness policies (+ ship-then-serve fallback when
              every replica is too stale)
"""

from .cluster import ReplicaCluster, SnapshotHandle
from .routing import (BoundedStaleness, Freshest, PredictedStaleness,
                      RoundRobin, RoutingPolicy, make_policy)

__all__ = [
    "ReplicaCluster", "SnapshotHandle",
    "RoutingPolicy", "Freshest", "RoundRobin", "BoundedStaleness",
    "PredictedStaleness", "make_policy",
]
