"""Session tokens: per-client consistency guarantees across the fleet.

A `Session` is the unit of client-visible consistency in a replicated
HTAP deployment (million-user serving): each client carries a small
token recording the LSN horizon it has *observed* — the WAL position of
its last OLTP commit (`last_commit_lsn`) and the applied LSN of the
replica that served its last read (`last_read_lsn`).  Routing honours
the token (`ReplicaCluster.acquire(session=...)`):

  * **read-your-writes** — only replicas whose applied LSN covers
    `last_commit_lsn` may serve the session, so a client never misses
    the WAL prefix containing its own committed writes;
  * **monotonic reads**   — only replicas at or above `last_read_lsn`
    may serve, so a session's observed horizon never regresses even as
    round-robin / bounded-staleness routing hops it across a lag-skewed
    fleet.

Both collapse into one predicate: serve from any replica with
`applied_lsn >= session.min_required_lsn()`.  When no replica covers
the token the cluster runs a cadence-owed *delta* ship on the freshest
replica (`token_ships` in the cluster stats) — never a synchronous
stall: delta shipping replays exactly the records the replication
schedule was about to replay anyway.

The guarantee is LSN-prefix-level (PostgreSQL hot-standby style).
Under RSS a committed-but-Obscure transaction may be held out of
snapshot *membership* until its dependencies resolve — on every replica
identically, because membership is a deterministic function of the
applied WAL prefix — so prefix coverage is the strongest portable
token; SI-mode sessions additionally get value-level read-your-writes.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class Session:
    """A client session token.  Mutable by design: the cluster advances
    `last_read_lsn` on every serve and the client (facade) advances
    `last_commit_lsn` on every OLTP commit."""

    sid: int
    last_commit_lsn: int = 0
    last_read_lsn: int = 0
    serves: int = 0
    # recorded (replica_idx, served_applied_lsn, required_lsn) per serve
    # when keep_history — the property tests replay these to check both
    # guarantees offline against the token floor that held at serve time
    history: list = field(default_factory=list)
    keep_history: bool = False

    def min_required_lsn(self) -> int:
        """The LSN any serving replica must have applied: read-your-writes
        (last_commit_lsn) and monotonic reads (last_read_lsn) combined."""
        return max(self.last_commit_lsn, self.last_read_lsn)

    def note_commit(self, lsn: int) -> None:
        """The client committed an OLTP transaction whose record sits at
        WAL position `lsn` (primary head after commit)."""
        if lsn > self.last_commit_lsn:
            self.last_commit_lsn = lsn

    def note_read(self, applied_lsn: int, replica: int = -1) -> None:
        """A replica at `applied_lsn` served this session; ratchets the
        monotonic-reads floor (never decreases)."""
        self.serves += 1
        if self.keep_history:
            self.history.append((replica, applied_lsn,
                                 self.min_required_lsn()))
        if applied_lsn > self.last_read_lsn:
            self.last_read_lsn = applied_lsn

    def violations(self) -> int:
        """Offline check over a kept history: serves whose replica had not
        applied the token floor in force at serve time — read-your-writes
        and monotonic reads both (0 when the guarantees held)."""
        return sum(1 for _, lsn, req in self.history if lsn < req)
