"""Snapshot routing policies over a lag-skewed replica fleet.

A decoupled-storage HTAP cluster (paper Sec 5.1 at N > 1) serves OLAP
readers from whichever replica a *routing policy* picks.  Replicas lag the
primary by different amounts (each ships the WAL on its own cadence), so the
policy is where the freshness/throughput trade-off lives:

  * `Freshest`          — route to the replica with the maximum applied
                          commit horizon (minimum replication lag).  Best
                          staleness, but concentrates the read load on one
                          node.
  * `RoundRobin`        — spread readers uniformly across the fleet.  Best
                          load balance, worst-case staleness is the slowest
                          replica's lag.
  * `BoundedStaleness`  — serve from any replica within `max_lag` WAL
                          records of the primary (round-robin among the
                          eligible set, so load still spreads).  When EVERY
                          replica is too stale the policy abstains
                          (`choose` returns None) and the cluster falls
                          back to ship-then-serve: synchronously catch one
                          replica up, then serve it — freshness bought with
                          one synchronous replication round.
  * `PredictedStaleness` — bounded staleness on PREDICTED lag at serve
                          time: the cluster knows each replica's ship
                          cadence (`ReplicaCluster.ship_cadence`, learned
                          from the slot-ack history), so a replica whose
                          scheduled ship is due predicts lag ~0 and stays
                          eligible even when its observed lag exceeds the
                          bound.  The cluster then runs that due ship at
                          serve (a *scheduled* ship the replication cadence
                          owed anyway) instead of an emergency
                          ship-then-serve round on the freshest replica —
                          cutting sync fallbacks on cadence-skewed fleets.

Policies see the cluster read-only through `lag_records(i)` /
`replicas[i].applied_lsn`; a per-call `max_lag` (e.g. a query-class
freshness hint from the workload) narrows ANY policy's eligible set the
same way, so `Freshest` and `RoundRobin` also degrade to ship-then-serve
when a hint is unsatisfiable.
"""

from __future__ import annotations

from typing import Optional, Union


class RoutingPolicy:
    """Pick a replica index for the next snapshot acquisition, or None when
    no replica satisfies the staleness bound (caller ships-then-serves)."""

    name = "policy"

    def choose(self, cluster, *, max_lag: Optional[int] = None) \
            -> Optional[int]:
        raise NotImplementedError

    def _lag(self, cluster, i: int) -> float:
        """The staleness measure eligibility filters on; predictive
        policies override (observed lag by default)."""
        return cluster.lag_records(i)

    def effective_bound(self, max_lag: Optional[int]) -> Optional[int]:
        """The staleness bound this policy actually enforced for a choice
        made with `max_lag` (the per-query hint; bounded-staleness
        policies tighten it with their default)."""
        return max_lag

    def _eligible(self, cluster, max_lag: Optional[int]) -> list[int]:
        idxs = range(len(cluster.replicas))
        if max_lag is None:
            return list(idxs)
        return [i for i in idxs if self._lag(cluster, i) <= max_lag]


class Freshest(RoutingPolicy):
    """Max applied commit horizon == min replication lag; ties break toward
    the lowest replica index (deterministic)."""

    name = "freshest"

    def choose(self, cluster, *, max_lag: Optional[int] = None) \
            -> Optional[int]:
        elig = self._eligible(cluster, max_lag)
        if not elig:
            return None
        return min(elig, key=lambda i: (cluster.lag_records(i), i))


class RoundRobin(RoutingPolicy):
    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def choose(self, cluster, *, max_lag: Optional[int] = None) \
            -> Optional[int]:
        elig = self._eligible(cluster, max_lag)
        if not elig:
            return None
        idx = elig[self._next % len(elig)]
        self._next += 1
        return idx


class BoundedStaleness(RoundRobin):
    """Any replica within `max_lag` WAL records of the primary may serve;
    round-robin among the eligible set spreads load.  A per-call `max_lag`
    (query freshness hint) overrides the policy default when tighter."""

    name = "bounded_staleness"

    def __init__(self, max_lag: int = 100) -> None:
        super().__init__()
        self.max_lag = max_lag

    def choose(self, cluster, *, max_lag: Optional[int] = None) \
            -> Optional[int]:
        return super().choose(cluster, max_lag=self.effective_bound(max_lag))

    def effective_bound(self, max_lag: Optional[int]) -> Optional[int]:
        return self.max_lag if max_lag is None else min(self.max_lag,
                                                        max_lag)


class PredictedStaleness(BoundedStaleness):
    """Bounded staleness evaluated on `cluster.predicted_lag(i)` — the lag
    replica i will serve with once its cadence-due scheduled ship runs —
    instead of last-observed lag.  The `predictive` marker tells the
    cluster to actually run that due ship before serving, so the served
    snapshot honours the bound; clusters without cadence tracking degrade
    to observed lag."""

    name = "predicted_staleness"
    predictive = True

    def _lag(self, cluster, i: int) -> float:
        return getattr(cluster, "predicted_lag", cluster.lag_records)(i)


def make_policy(spec: Union[str, RoutingPolicy], *,
                max_lag: int = 100) -> RoutingPolicy:
    """Resolve a policy spec: an instance passes through; a name constructs
    one ('bounded_staleness' / 'predicted_staleness' take `max_lag` as
    their default bound)."""
    if isinstance(spec, RoutingPolicy):
        return spec
    if spec == "freshest":
        return Freshest()
    if spec == "round_robin":
        return RoundRobin()
    if spec == "bounded_staleness":
        return BoundedStaleness(max_lag)
    if spec == "predicted_staleness":
        return PredictedStaleness(max_lag)
    raise ValueError(f"unknown routing policy {spec!r}")
