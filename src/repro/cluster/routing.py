"""Snapshot routing policies over a lag-skewed replica fleet.

A decoupled-storage HTAP cluster (paper Sec 5.1 at N > 1) serves OLAP
readers from whichever replica a *routing policy* picks.  Replicas lag the
primary by different amounts (each ships the WAL on its own cadence), so the
policy is where the freshness/throughput trade-off lives:

  * `Freshest`          — route to the replica with the maximum applied
                          commit horizon (minimum replication lag).  Best
                          staleness, but concentrates the read load on one
                          node.
  * `RoundRobin`        — spread readers uniformly across the fleet.  Best
                          load balance, worst-case staleness is the slowest
                          replica's lag.
  * `BoundedStaleness`  — serve from any replica within `max_lag` WAL
                          records of the primary (round-robin among the
                          eligible set, so load still spreads).  When EVERY
                          replica is too stale the policy abstains
                          (`choose` returns None) and the cluster falls
                          back to ship-then-serve: synchronously catch one
                          replica up, then serve it — freshness bought with
                          one synchronous replication round.
  * `PredictedStaleness` — bounded staleness on PREDICTED lag at serve
                          time: the cluster knows each replica's ship
                          cadence (`ReplicaCluster.ship_cadence`, learned
                          from the slot-ack history), so a replica whose
                          scheduled ship is due predicts lag ~0 and stays
                          eligible even when its observed lag exceeds the
                          bound.  The cluster then runs that due ship at
                          serve (a *scheduled* ship the replication cadence
                          owed anyway) instead of an emergency
                          ship-then-serve round on the freshest replica —
                          cutting sync fallbacks on cadence-skewed fleets.
  * `LatencySLO`         — bounded staleness PLUS a serve-latency SLO:
                          replicas whose `olap_serve_seconds{replica=i}`
                          p99 (from the `repro.obs` histograms) degrades
                          past `slo_factor` x the fleet median drop out of
                          the eligible set, so a slow replica sheds read
                          load instead of dragging tail latency — unless
                          EVERY replica is slow, in which case the SLO
                          filter stands down (staleness still binds).

Policies see the cluster read-only through `lag_records(i)` /
`replicas[i].applied_lsn`; a per-call `max_lag` (e.g. a query-class
freshness hint from the workload) narrows ANY policy's eligible set the
same way, so `Freshest` and `RoundRobin` also degrade to ship-then-serve
when a hint is unsatisfiable.  A per-call `min_lsn` (a session token's
required horizon — read-your-writes / monotonic reads) filters the same
way from below: only replicas whose applied LSN covers the token are
eligible; predictive policies additionally keep ship-due replicas
eligible (their serve-time delta ship applies the full tail, covering
any token the primary has issued).
"""

from __future__ import annotations

from typing import Optional, Union

from ..obs import REGISTRY


class RoutingPolicy:
    """Pick a replica index for the next snapshot acquisition, or None when
    no replica satisfies the staleness bound / session token (caller
    ships-then-serves, or delta-ships for a token)."""

    name = "policy"

    def choose(self, cluster, *, max_lag: Optional[int] = None,
               min_lsn: int = 0) -> Optional[int]:
        raise NotImplementedError

    def _lag(self, cluster, i: int) -> float:
        """The staleness measure eligibility filters on; predictive
        policies override (observed lag by default)."""
        return cluster.lag_records(i)

    def _covers(self, cluster, i: int, min_lsn: int) -> bool:
        """Does replica i satisfy a session token requiring `min_lsn`?
        Predictive policies also accept ship-due replicas (the serve-time
        delta ship catches them fully up before the pin)."""
        return cluster.replicas[i].applied_lsn >= min_lsn or \
            (self.predictive and cluster.ship_due(i))

    predictive = False

    def effective_bound(self, max_lag: Optional[int]) -> Optional[int]:
        """The staleness bound this policy actually enforced for a choice
        made with `max_lag` (the per-query hint; bounded-staleness
        policies tighten it with their default)."""
        return max_lag

    def _eligible(self, cluster, max_lag: Optional[int],
                  min_lsn: int = 0) -> list[int]:
        idxs = range(len(cluster.replicas))
        return [i for i in idxs
                if (max_lag is None or self._lag(cluster, i) <= max_lag)
                and (min_lsn <= 0 or self._covers(cluster, i, min_lsn))]


class Freshest(RoutingPolicy):
    """Max applied commit horizon == min replication lag; ties break toward
    the lowest replica index (deterministic)."""

    name = "freshest"

    def choose(self, cluster, *, max_lag: Optional[int] = None,
               min_lsn: int = 0) -> Optional[int]:
        elig = self._eligible(cluster, max_lag, min_lsn)
        if not elig:
            return None
        return min(elig, key=lambda i: (cluster.lag_records(i), i))


class RoundRobin(RoutingPolicy):
    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def choose(self, cluster, *, max_lag: Optional[int] = None,
               min_lsn: int = 0) -> Optional[int]:
        elig = self._eligible(cluster, max_lag, min_lsn)
        if not elig:
            return None
        idx = elig[self._next % len(elig)]
        self._next += 1
        return idx


class BoundedStaleness(RoundRobin):
    """Any replica within `max_lag` WAL records of the primary may serve;
    round-robin among the eligible set spreads load.  A per-call `max_lag`
    (query freshness hint) overrides the policy default when tighter."""

    name = "bounded_staleness"

    def __init__(self, max_lag: int = 100) -> None:
        super().__init__()
        self.max_lag = max_lag

    def choose(self, cluster, *, max_lag: Optional[int] = None,
               min_lsn: int = 0) -> Optional[int]:
        return super().choose(cluster, max_lag=self.effective_bound(max_lag),
                              min_lsn=min_lsn)

    def effective_bound(self, max_lag: Optional[int]) -> Optional[int]:
        return self.max_lag if max_lag is None else min(self.max_lag,
                                                        max_lag)


class PredictedStaleness(BoundedStaleness):
    """Bounded staleness evaluated on `cluster.predicted_lag(i)` — the lag
    replica i will serve with once its cadence-due scheduled ship runs —
    instead of last-observed lag.  The `predictive` marker tells the
    cluster to actually run that due ship before serving, so the served
    snapshot honours the bound; clusters without cadence tracking degrade
    to observed lag."""

    name = "predicted_staleness"
    predictive = True

    def _lag(self, cluster, i: int) -> float:
        return getattr(cluster, "predicted_lag", cluster.lag_records)(i)


class LatencySLO(PredictedStaleness):
    """Predicted-staleness routing with a serve-latency SLO on top: a
    replica whose merged `olap_serve_seconds{replica=i}` p99 exceeds
    `slo_factor` x the fleet median (with at least `min_count` serves
    observed, so cold replicas aren't judged on noise) is steered around.

    The p99s come straight from the `repro.obs` histograms the serve path
    already populates — no new instrumentation — and are refreshed every
    `refresh` choices (histogram merging walks bucket arrays; per-choice
    recomputation would put O(replicas x buckets) on the route stage).
    The filter NEVER empties the eligible set: when every replica busts
    the SLO there is no better replica to steer to, so staleness alone
    decides."""

    name = "latency_slo"
    predictive = True

    def __init__(self, max_lag: int = 100, *, slo_factor: float = 3.0,
                 min_count: int = 20, refresh: int = 64) -> None:
        super().__init__(max_lag)
        self.slo_factor = slo_factor
        self.min_count = min_count
        self.refresh = refresh
        self._slow: set[int] = set()
        self._choices = 0

    def _refresh_slow(self, cluster) -> None:
        p99s = {}
        for i in range(len(cluster.replicas)):
            s = REGISTRY.hist_summary("olap_serve_seconds", replica=i)
            if s["count"] >= self.min_count:
                p99s[i] = s["p99_us"]
        self._slow = set()
        if len(p99s) >= 2:
            med = sorted(p99s.values())[len(p99s) // 2]
            if med > 0:
                self._slow = {i for i, p in p99s.items()
                              if p > self.slo_factor * med}

    def _eligible(self, cluster, max_lag: Optional[int],
                  min_lsn: int = 0) -> list[int]:
        if self._choices % self.refresh == 0:
            self._refresh_slow(cluster)
        self._choices += 1
        base = super()._eligible(cluster, max_lag, min_lsn)
        healthy = [i for i in base if i not in self._slow]
        return healthy or base


def make_policy(spec: Union[str, RoutingPolicy], *,
                max_lag: int = 100) -> RoutingPolicy:
    """Resolve a policy spec: an instance passes through; a name constructs
    one ('bounded_staleness' / 'predicted_staleness' / 'latency_slo' take
    `max_lag` as their default bound)."""
    if isinstance(spec, RoutingPolicy):
        return spec
    if spec == "freshest":
        return Freshest()
    if spec == "round_robin":
        return RoundRobin()
    if spec == "bounded_staleness":
        return BoundedStaleness(max_lag)
    if spec == "predicted_staleness":
        return PredictedStaleness(max_lag)
    if spec == "latency_slo":
        return LatencySLO(max_lag)
    raise ValueError(f"unknown routing policy {spec!r}")
