"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step, shard) — the pipeline is
resumable by construction (its checkpoint state is a single step counter) and
shardable (each data-parallel shard derives its own stream).  The token
stream has a Zipf-ish marginal so losses move during smoke training runs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig, ShapeConfig


@dataclass
class PipelineState:
    step: int = 0


class SyntheticPipeline:
    def __init__(self, cfg: ModelConfig, *, batch: int, seq_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.state = PipelineState()

    # ------------------------------------------------------------------ state
    def checkpoint_state(self) -> dict:
        return {"step": self.state.step, "seed": self.seed}

    def restore_state(self, st: dict) -> None:
        self.state.step = int(st["step"])
        self.seed = int(st.get("seed", self.seed))

    # ------------------------------------------------------------------ batch
    def _tokens(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        # Zipf-ish marginal over the vocab, cheap and deterministic
        v = self.cfg.vocab_size
        u = rng.random((self.batch, self.seq_len + 1))
        toks = np.minimum((u ** 3 * v).astype(np.int64), v - 1)
        return toks

    def next_batch(self) -> dict:
        step = self.state.step
        self.state.step += 1
        return self.batch_at(step)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        toks = self._tokens(step)
        batch = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        B, S = self.batch, self.seq_len
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(jnp.arange(S), (3, B, S))
            batch["mrope_positions"] = pos
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, 7]))
            batch["vision_embeds"] = jnp.asarray(
                rng.standard_normal((B, max(S // 4, 1), cfg.d_model),
                                    dtype=np.float32) * 0.02)
        if cfg.is_encoder_decoder:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, 11]))
            batch["enc_embeds"] = jnp.asarray(
                rng.standard_normal((B, cfg.encoder_len, cfg.d_model),
                                    dtype=np.float32) * 0.02)
        return batch
