from .pipeline import SyntheticPipeline, PipelineState
__all__ = ["SyntheticPipeline", "PipelineState"]
