#!/usr/bin/env bash
# Repo verification: tier-1 tests + interpret-mode kernel parity checks.
#
#   bash scripts/verify.sh          # tier-1 + kernel parity (fast-ish)
#   bash scripts/verify.sh --bench  # also run the full benchmark suite
#                                   # (writes BENCH_kernels.json)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo
echo "== interpret-mode kernel parity (version_gather / rss_gather / rss_scan_agg[+grouped]) =="
python - <<'EOF'
import numpy as np, jax, jax.numpy as jnp
from repro.kernels.version_gather.kernel import version_gather
from repro.kernels.version_gather.ref import version_gather_ref
from repro.kernels.rss_gather.kernel import rss_gather
from repro.kernels.rss_gather.ref import rss_gather_ref
from repro.kernels.rss_scan_agg.kernel import rss_scan_agg, rss_scan_agg_grouped
from repro.kernels.rss_scan_agg.ref import (rss_scan_agg_grouped_ref,
                                            rss_scan_agg_ref)

rng = np.random.default_rng(0)
for P, K, E in [(16, 4, 256), (32, 3, 128)]:
    data = jnp.asarray(rng.standard_normal((P, K, E)), jnp.float32)
    ts = jnp.asarray(rng.integers(0, 50, (P, K)), jnp.int32)
    for wm in (0, 13, 49):
        np.testing.assert_array_equal(
            np.asarray(version_gather(data, ts, wm)),
            np.asarray(version_gather_ref(data, ts, wm)))
    for M in (0, 5, 130):
        mem = jnp.asarray(np.sort(rng.choice(np.arange(1, 50), size=min(M, 49),
                                             replace=False)), jnp.int32)
        for floor in (0, 17):   # compressed-snapshot watermark
            np.testing.assert_array_equal(
                np.asarray(rss_gather(data, ts, mem, floor)),
                np.asarray(rss_gather_ref(data, ts, mem, floor)))
for P, K, E in [(16, 4, 32), (32, 3, 16)]:
    idata = np.zeros((P, K, E), np.int32)
    idata[:, :, 0] = rng.integers(-1, 4, (P, K))     # tags incl. TAG_PAD
    idata[:, :, 1] = rng.integers(-99, 99, (P, K))
    its = jnp.asarray(rng.integers(0, 50, (P, K)), np.int32)
    idata = jnp.asarray(idata)
    gid = jnp.asarray(rng.integers(-1, 5, (P, 1)), jnp.int32)
    for M in (0, 7):
        mem = jnp.asarray(np.sort(rng.choice(np.arange(1, 50), size=M,
                                             replace=False)), jnp.int32)
        for floor in (0, 21):
            for tags in [(1, 0, 50), (3, -2, 0)]:
                np.testing.assert_array_equal(
                    np.asarray(rss_scan_agg(idata, its, mem, floor, *tags)),
                    np.asarray(rss_scan_agg_ref(idata, its, mem, floor,
                                                *tags)))
                # grouped variant: per-group accumulator lanes, incl. an
                # empty group (gid never reaches n_groups-1=5) and gid -1
                np.testing.assert_array_equal(
                    np.asarray(rss_scan_agg_grouped(
                        idata, its, gid, mem, floor, *tags, n_groups=6)),
                    np.asarray(rss_scan_agg_grouped_ref(
                        idata, its, gid, mem, floor, *tags, n_groups=6)))
print("kernel parity OK (version_gather, rss_gather+floor, rss_scan_agg "
      "+ grouped; interpret mode)")
EOF

echo
echo "== chunked two-stage parity + whole-batch launch accounting =="
python - <<'EOF'
import numpy as np, jax.numpy as jnp, random
from repro.kernels.rss_scan_agg import ops as kops
from repro.kernels.rss_scan_agg.kernel import (rss_scan_agg_chunked,
                                               rss_scan_agg_grouped,
                                               tree_fold_partials)
from repro.kernels.rss_scan_agg.ops import fold_group_partials
from repro.kernels.rss_scan_agg.ref import rss_scan_agg_chunked_ref

# chunked kernel == segment-sum oracle per chunk; device tree fold ==
# flat-lane host fold (non-divisible G, TAG_PAD, gid -1, empty groups)
rng = np.random.default_rng(1)
for P, K, E in [(24, 3, 16), (72, 4, 8)]:
    data = np.zeros((P, K, E), np.int32)
    data[:, :, 0] = rng.integers(-1, 4, (P, K))
    data[:, :, 1] = rng.integers(-99, 99, (P, K))
    ts = jnp.asarray(rng.integers(0, 50, (P, K)), np.int32)
    data = jnp.asarray(data)
    for G in (3, 13):
        gid = jnp.asarray(rng.integers(-1, G, (P, 1)), jnp.int32)
        mem = jnp.asarray(np.sort(rng.choice(np.arange(1, 50), size=7,
                                             replace=False)), jnp.int32)
        args = (data, ts, gid, mem, 21, 1, 0, 50)
        chunks = rss_scan_agg_chunked(*args, n_groups=G, rows_per_step=2,
                                      fold_chunks=2)
        np.testing.assert_array_equal(
            np.asarray(chunks),
            np.asarray(rss_scan_agg_chunked_ref(
                *args, n_groups=G, rows_per_step=2, fold_chunks=2)))
        flat = rss_scan_agg_grouped(*args, n_groups=G)
        assert fold_group_partials(chunks) == fold_group_partials(flat)
        np.testing.assert_array_equal(np.asarray(tree_fold_partials(chunks)),
                                      np.asarray(fold_group_partials(chunks)))
print("chunked parity OK (kernel == ref == flat fold; device tree fold)")

# whole-batch plan fusion: N>=4 same-horizon plans -> ONE fused aggregate
# dispatch (and one pallas launch in flat mode, two in chunked)
from repro.mvcc import Engine
from repro.tensorstore import (AggOp, AggPlan, BatchPlan, ChainVersionStore,
                               PagedMirror, PagedVersionStore)
eng = Engine("ssi")
t = eng.begin()
for i in range(32):
    eng.write(t, f"k:{i}", random.Random(i).randrange(-50, 90))
eng.commit(t)
plans = tuple(AggPlan(tuple(f"k:{i + 8 * j}" for i in range(8)),
                      AggOp("sum", "int")) for j in range(4))
oracle = [ChainVersionStore(eng.store).execute(p, eng.seq) for p in plans]
for mode, calls in (("flat", 1), ("chunked", 2)):
    mirror = PagedMirror()
    mirror.catch_up(eng.wal)
    mirror.grouped_mode = mode
    before = dict(mirror.exec_stats)
    kops.reset_launch_stats()
    got = list(PagedVersionStore(mirror).execute(BatchPlan(plans), eng.seq))
    assert got == oracle, (mode, got, oracle)
    assert mirror.exec_stats["agg_dispatches"] - before["agg_dispatches"] \
        == 1, mode
    assert kops.LAUNCH_STATS["dispatches"] == 1, mode
    assert kops.LAUNCH_STATS["pallas_calls"] == calls, \
        (mode, kops.LAUNCH_STATS)
print("plan fusion OK (4-plan batch == oracle; 1 dispatch; "
      "1 launch flat / 2 chunked)")
EOF

echo
echo "== certifier matrix (driver under each policy; fused == oracle) =="
python - <<'EOF'
from repro.core import is_serializable, is_si_history, ssi_accepts
from repro.mvcc import run_multi_node, run_single_node, run_write_skew

for cert in ("conservative-ssi", "commit-order-ssi", "ssn"):
    # HTAP drivers with check_scans=True: every fused plan result is
    # asserted equal to the per-key engine read path (the oracle), and
    # the RSS readers must stay abort-free under every certifier.
    ms = run_single_node(olap_mode="ssi+rss", oltp_clients=4,
                         olap_clients=2, rounds=600, seed=7,
                         olap_scan=True, check_scans=True, certifier=cert)
    assert ms.certifier == cert and ms.oltp_commits > 0
    assert ms.olap_aborts == 0 and ms.olap_wait_rounds == 0, cert
    mm = run_multi_node(olap_mode="ssi+rss", oltp_clients=4,
                        olap_clients=2, rounds=500, seed=7,
                        olap_scan=True, check_scans=True, certifier=cert)
    assert mm.certifier == cert and mm.olap_aborts == 0, cert

    # contended write skew, recorded: zero serializability violations
    m, e = run_write_skew(certifier=cert, contention=0.6, rounds=800,
                          seed=7, record=True)
    assert is_serializable(e.history) and is_si_history(e.history), cert
    if cert != "ssn":   # SSN admits serializable non-SSI histories
        assert ssi_accepts(e.history), cert
    reasons = ";".join(f"{k}={v}" for k, v in
                       sorted(m.by_abort_reason.items())) or "none"
    print(f"certifier OK: {cert:17s} write_skew commits={m.oltp_commits} "
          f"aborts={m.oltp_aborts} [{reasons}]")
print("certifier matrix OK (fused == oracle; RSS abort-/wait-free; "
      "0 serializability violations)")
EOF

echo
echo "== observability (both facades traced; invariants; p50/p99 table) =="
REPRO_TRACE=1 python - <<'EOF'
from repro.mvcc import run_multi_node, run_single_node
from repro.obs import REGISTRY, TRACER

assert TRACER.enabled            # REPRO_TRACE=1 reached the tracer


def table(tag, m):
    print(f"  {tag:28s} {'n':>5s} {'p50_us':>9s} {'p99_us':>10s}")
    rows = [("serve (all plans)", m.serve_latency)]
    rows += sorted(m.serve_latency_by_plan.items())
    rows += [(f"stage:{k}", v) for k, v in
             sorted(m.serve_stage_latency.items())]
    rows.append(("oltp_commit", m.oltp_commit_latency))
    for name, s in rows:
        print(f"  {name:28s} {s['count']:5d} {s['p50_us']:9.1f} "
              f"{s['p99_us']:10.1f}")


def check(m, *, engine_commits):
    steps = (m.olap_scan_steps + m.olap_agg_steps +
             m.olap_multi_agg_steps + m.olap_group_steps)
    by_plan = m.serve_latency_by_plan
    unbatched = sum(v["count"] for k, v in by_plan.items()
                    if k != "BatchPlan")
    fused = by_plan.get("BatchPlan", {"count": 0})["count"]
    # every counted plan step served exactly once (solo or fused)
    assert unbatched == steps - m.olap_batched_plans
    assert fused == m.olap_batch_dispatches
    assert m.serve_latency["count"] == unbatched + fused > 0
    # mirror-layer dispatch accounting == kernel-layer launch accounting
    assert m.olap_agg_dispatches == m.olap_kernel_dispatches > 0
    # engine-layer commits == driver-observed commits; the commit
    # histogram observes successes only
    assert REGISTRY.total("engine_commits") == engine_commits
    assert m.oltp_commit_latency["count"] == engine_commits
    # span trees balanced: opened == closed, stack drained
    assert TRACER.opened == TRACER.closed and TRACER.depth == 0


args = dict(olap_mode="ssi+rss", oltp_clients=3, olap_clients=3,
            rounds=600, seed=13, olap_scan=True, paged_olap=True,
            batch_plans=True)
ms = run_single_node(**args)
check(ms, engine_commits=ms.oltp_commits + ms.olap_commits)
table("single-node (batched)", ms)
mm = run_multi_node(**args, n_replicas=2, route_policy="bounded_staleness")
check(mm, engine_commits=mm.oltp_commits)   # OLAP never hits the primary
table("multi-node N=2 (batched)", mm)
print("  most recent trace tree:")
print("\n".join(f"    {l}" for l in TRACER.render(limit=1).splitlines()))
print("observability OK (latency recorded on both facades; cross-layer "
      "counters consistent; span trees balanced)")
EOF

echo
echo "== materialized aggregates (delta-fold views == oracle on both facades) =="
python - <<'EOF'
import numpy as np
from repro.core.wal import WalRecord
from repro.kernels.rss_scan_agg import ops as kops
from repro.kernels.rss_scan_agg.ref import rss_delta_fold_ref
from repro.mvcc import run_multi_node, run_single_node
from repro.tensorstore import AggOp, MultiAggPlan, PagedMirror

# delta-fold kernel == ref over random dense delta buffers (interpret)
rng = np.random.default_rng(4)
for lp, dp in [(8, 8), (16, 32)]:
    acc = np.zeros((lp, 128), np.int32)
    acc[:, :7] = [0, 0, 0, np.iinfo(np.int32).max,
                  np.iinfo(np.int32).min, 0, 0]
    delta = np.zeros((dp, 128), np.int32)
    delta[:, 0] = rng.integers(-1, lp, dp)         # incl. -1 padding rows
    delta[:, 1] = rng.integers(-99, 99, dp)
    delta[:, 2] = rng.integers(0, 2, dp)
    delta[:, 3] = rng.integers(-99, 99, dp)
    delta[:, 4] = rng.integers(0, 2, dp)
    delta[:, 5] = rng.integers(-50, 50, dp)
    np.testing.assert_array_equal(
        np.asarray(kops.delta_fold(acc, delta, use_kernel=True)),
        np.asarray(rss_delta_fold_ref(acc, delta)))
print("delta_fold parity OK (kernel == ref; interpret mode)")

# registry seam: >=1 view hit AND >=1 clean (gate-miss) fallback, both
# equal to the fused scan
mirror = PagedMirror()
plan = MultiAggPlan(("a", "b", "c"),
                    (AggOp("sum", "int"), AggOp("min", "int")))
mirror.apply(WalRecord(lsn=1, type="commit", txn=1,
                       writes=(("a", 5), ("b", 9), ("c", 2)), seq=1))
mirror.register_view(plan)
mirror.apply(WalRecord(lsn=2, type="commit", txn=2,
                       writes=(("c", 11),), seq=2))
stale = mirror.watermark - 1                 # excludes the queued commit
hit, _ = mirror.execute_with_writers(plan, mirror.watermark,
                                     need_writers=False)
fb, _ = mirror.execute_with_writers(plan, stale, need_writers=False)
assert hit == (25, 5) and fb == (16, 2), (hit, fb)
s = mirror.exec_stats
assert s["view_hits"] >= 1 and s["view_fallbacks"] >= 1, dict(s)

# both facades thread the registry: driver runs with materialize=True
# and check_scans=True assert tile == fused scan == per-key oracle at
# EVERY serve, and the Metrics surface exposes the olap_view_* counters
args = dict(olap_mode="ssi+rss", oltp_clients=3, olap_clients=2,
            rounds=600, seed=5, olap_scan=True, paged_olap=True,
            check_scans=True, materialize=True)
for tag, m in (("single", run_single_node(**args)),
               ("multi", run_multi_node(**args))):
    assert m.olap_view_hits >= 1, (tag, m.olap_view_hits)
    print(f"  {tag:6s} hits={m.olap_view_hits} "
          f"fallbacks={m.olap_view_fallbacks} "
          f"demotions={m.olap_view_demotions}")
print("materialized OK (kernel parity; hit+fallback == fused; both "
      "facades oracle-checked with views on)")
EOF

echo
echo "== session serving (token guarantees; cache == uncached; hit rates) =="
python - <<'EOF'
from repro.mvcc import run_sessions

# Zipf-skewed sticky sessions over a cadence-skewed 2-replica fleet:
# every serve must cover the session's token (read-your-writes +
# monotonic reads) — run_sessions asserts zero violations internally,
# and check_scans asserts every (cached, fused) result == the per-key
# chain oracle.  Cache on vs off must be bit-identical.
args = dict(n_sessions=48, rounds=5, seed=17, n_replicas=2,
            ship_every=2, ship_skew=1, write_fraction=0.2,
            check_scans=True, keep_history=True)
m_off, s_off = run_sessions(resolve_cache=False, batch_plans=False, **args)
m_on, s_on = run_sessions(resolve_cache=True, batch_plans=True, **args)
assert [s.pending for s in s_on] == [s.pending for s in s_off]
for tag, m, ss in (("cache+batch=off", m_off, s_off),
                   ("cache+batch=on", m_on, s_on)):
    assert m.session_token_violations == 0
    assert all(s.session.violations() == 0 for s in ss)
    hits = ";".join(f"{k}={v:.2f}" for k, v in m.cache_hit_rates().items())
    print(f"  {tag:16s} serves={m.session_serves} "
          f"token_ships={m.session_token_ships} "
          f"dispatches={m.olap_batch_dispatches} [{hits}]")
assert m_on.cache_hit_rates()["member"] > 0
assert 0 < m_on.olap_batch_dispatches < m_on.session_serves
print("session serving OK (0 token violations on both runs; cached+"
      "batched == uncached == oracle; caches hit; plans folded)")
EOF

echo
echo "== examples (smoke mode: demos must not rot) =="
for ex in quickstart anomaly_demo paged_snapshot_reads cluster_fanout \
          observability_demo; do
    python "examples/$ex.py" > /dev/null
    echo "example OK: $ex"
done
python examples/htap_train_serve.py --smoke > /dev/null
echo "example OK: htap_train_serve (--smoke)"

echo
echo "== benchmark entry points (--smoke: tiny scale, no BENCH_kernels.json) =="
python -m benchmarks.run --smoke > /dev/null
echo "bench smoke OK (all entry points, incl. scan-vs-fused-agg sweep)"

if [[ "${1:-}" == "--bench" ]]; then
    echo
    echo "== benchmarks (writes BENCH_kernels.json) =="
    python -m benchmarks.run
    echo
    echo "== perf regression gate (fresh run vs committed baseline) =="
    python -m benchmarks.check_regression
fi

echo
echo "verify: all green"
