"""Grouped + compound plans: fused kernels == the per-key chain oracle.

The PR-5 contract of the plan-first executor: `GroupByPlan` (per-group
accumulator lanes, one fused pass -> [groups, 5] tile) and `MultiAggPlan`
(several statistics from one visibility pass) must produce exactly the
per-key chain-walk results at every seam — under randomized replication
lag (batched shipping), RSS state GC, PRoT pins, legacy (unstamped) WAL
records, missing keys, empty groups, duplicate keys across groups, and
both snapshot kinds (compressed RSS snapshots and SI-V watermarks).

Seeded-random stream tests always run; hypothesis widens the search when
available (same harness style as tests/test_rss_scan_agg.py).
"""

import random

import numpy as np
import pytest

from repro.core import PRoTManager, RSSManager, Wal
from repro.core.wal import effective_commit_seq
from repro.mvcc import Engine
from repro.mvcc.store import Store
from repro.tensorstore import (AggOp, ChainVersionStore, GroupByPlan,
                               MultiAggPlan, PagedMirror, PagedVersionStore,
                               ScanPlan, apply_plan, group_by, plan_keys)

KEYS = [f"stock:{i}" for i in range(8)] + ["warehouse:0", "district:0:0",
                                           "order:0:0:0", "order:0:0:1"]
OPS = [AggOp("sum", "int"), AggOp("count", "int"),
       AggOp("count_below", "int", 50), AggOp("count_below", "int", 0),
       AggOp("min", "int"), AggOp("max", "int"),
       AggOp("sum", "total"), AggOp("count", "total"),
       AggOp("min", "total"), AggOp("max", "total")]


def _rand_value(rng, key):
    if key.startswith("district"):
        return {"next_o_id": rng.randrange(40), "ytd": rng.randrange(99)}
    if key.startswith("order"):
        return {"items": [rng.randrange(9) for _ in range(rng.randrange(4))],
                "total": rng.randrange(500)}
    return rng.randrange(-100, 200)


def random_writes_wal(rng, steps=250, *, legacy_prob=0.0):
    """Engine-shaped WAL with committed writesets attached (workload-shaped
    values), deps after reader commits, optional legacy (seq=0) commits."""
    wal = Wal()
    active = []
    tid = 0
    for _ in range(steps):
        act = rng.random()
        if act < 0.35 or not active:
            tid += 1
            wal.log_begin(tid)
            active.append(tid)
        elif act < 0.8:
            t = active.pop(rng.randrange(len(active)))
            seq = 0 if rng.random() < legacy_prob else wal.head_lsn + 1
            writes = [(k, _rand_value(rng, k))
                      for k in rng.sample(KEYS, rng.randint(1, 3))]
            wal.log_commit(t, writes, seq=seq)
            if active and rng.random() < 0.5:
                wal.log_deps(t, sorted(rng.sample(
                    active, rng.randint(1, min(2, len(active))))))
        else:
            t = active.pop(rng.randrange(len(active)))
            wal.log_abort(t)
    return wal


def _rand_plan(rng):
    """A random grouped or compound plan: key groups may be empty, repeat
    keys across groups, and include missing keys."""
    pool = KEYS + ["missing:key"]
    ops = tuple(rng.sample(OPS, rng.randint(1, 4)))
    if rng.random() < 0.5:
        groups = []
        for _ in range(rng.randint(1, 5)):
            groups.append(tuple(rng.sample(pool, rng.randint(0, len(pool)))))
        return GroupByPlan(tuple(groups), ops)
    return MultiAggPlan(tuple(rng.sample(pool, rng.randint(1, len(pool)))),
                        ops)


def check_group_stream(seed, *, gc_prob=0.0, legacy_prob=0.0, pin_prob=0.0,
                       grouped_mode=None):
    """Replay a random stream into RSSManager + paged mirror + chain store
    in randomized batches; at every round, every live snapshot must
    execute random grouped/compound plans identically through the fused
    kernels and the chain oracle (results AND writers).  `grouped_mode`
    pins the mirror's kernel-strategy override (host / flat / chunked) so
    every strategy faces the same stream."""
    rng = random.Random(seed)
    wal = random_writes_wal(rng, legacy_prob=legacy_prob)
    man = RSSManager()
    prot = PRoTManager(man)
    mirror = PagedMirror(slots=64)            # retain everything: parity
    mirror.grouped_mode = grouped_mode
    store = Store()                           # under K-slot pressure is the
    chain = ChainVersionStore(store)          # driver tests' job
    paged = PagedVersionStore(mirror)
    applied_seq = 0
    pruned_floor = 0          # chain reads below this are invalid post-prune
    pins = []
    while man.applied_lsn < wal.head_lsn:
        batch = rng.randint(1, 15)            # lagged, split shipping
        for rec in wal.tail(man.applied_lsn):
            man.apply(rec)
            mirror.apply(rec, gc_floor=prot.gc_floor_seq())
            if rec.type == "commit":
                seq = effective_commit_seq(applied_seq, rec.seq)
                for k, v in rec.writes:
                    store.chain(k).install(seq, rec.txn, v)
                applied_seq = seq
            batch -= 1
            if batch <= 0:
                break
        snap = man.construct()
        for s in [snap, applied_seq,
                  max(applied_seq - 3, pruned_floor)] \
                + [p[1] for p in pins]:
            for _ in range(3):
                plan = _rand_plan(rng)
                want, ww = chain.execute_with_writers(plan, s)
                got, gw = paged.execute_with_writers(plan, s)
                assert want == got, (seed, plan, s, want, got)
                assert ww == gw, (seed, plan, s)
                # ... and both equal the host apply of the scanned values
                keys = plan_keys(plan)
                scanned = chain.execute(ScanPlan(keys), s)
                assert want == apply_plan(scanned, plan), (seed, plan)
        if pin_prob and rng.random() < pin_prob:
            pins.append(prot.acquire())
        if pins and rng.random() < 0.3:
            prot.release(pins.pop(rng.randrange(len(pins)))[0])
        if gc_prob and rng.random() < gc_prob:
            man.gc(keep_lsn=prot.gc_floor(), keep_seq=prot.gc_floor_seq())
            store.prune(prot.gc_floor_seq())
            pruned_floor = max(pruned_floor, prot.gc_floor_seq())


# ------------------------------------------------------------ always-run
@pytest.mark.parametrize("seed", range(6))
def test_grouped_and_compound_equal_chain_oracle(seed):
    check_group_stream(seed)


@pytest.mark.parametrize("seed", range(6))
def test_grouped_equal_oracle_with_gc_and_pins(seed):
    check_group_stream(seed, gc_prob=0.5, pin_prob=0.3)


@pytest.mark.parametrize("seed", range(4))
def test_grouped_equal_oracle_with_legacy_records(seed):
    check_group_stream(seed, legacy_prob=0.3, gc_prob=0.3, pin_prob=0.2)


@pytest.mark.parametrize("mode", ["host", "flat", "chunked"])
@pytest.mark.parametrize("seed", range(2))
def test_grouped_equal_oracle_every_forced_mode(seed, mode):
    """Every kernel strategy — host decode, flat-lane, chunked two-stage —
    must match the chain oracle on the same randomized stream (shape
    dispatch must never be load-bearing for correctness)."""
    check_group_stream(seed, gc_prob=0.3, pin_prob=0.2, grouped_mode=mode)


# ------------------------------------------------------ kernel-level parity
@pytest.mark.parametrize("seed", range(4))
def test_grouped_kernel_matches_ref(seed):
    """Pallas grouped kernel == jnp oracle over random stores, tags,
    floors, members, thresholds, group counts — including TAG_PAD pages,
    gid -1 (no group), group counts that are not sublane multiples, empty
    member sets, and groups no page maps to."""
    import jax.numpy as jnp
    from repro.kernels.rss_scan_agg.kernel import rss_scan_agg_grouped
    from repro.kernels.rss_scan_agg.ref import rss_scan_agg_grouped_ref

    rng = np.random.default_rng(seed)
    for P, K, E in [(8, 3, 8), (16, 4, 32), (64, 4, 16)]:
        data = np.zeros((P, K, E), np.int32)
        data[:, :, 0] = rng.integers(-1, 4, (P, K))     # tags incl. TAG_PAD
        data[:, :, 1] = rng.integers(-100, 100, (P, K))
        ts = rng.integers(0, 60, (P, K)).astype(np.int32)
        for G in (1, 3, 8, 13):
            # gid -1 = no group; G-1 may map to no page (empty group)
            gid = rng.integers(-1, max(G - 1, 1), (P, 1)).astype(np.int32)
            for M in (0, 7, 140):
                mem = np.sort(rng.choice(np.arange(1, 60), size=min(M, 59),
                                         replace=False)).astype(np.int32)
                for floor in (0, 23):
                    for tag_main, tag_alt, thr in [(1, 0, 50), (3, -2, 10)]:
                        args = (jnp.asarray(data), jnp.asarray(ts),
                                jnp.asarray(gid), jnp.asarray(mem), floor,
                                tag_main, tag_alt, thr)
                        np.testing.assert_array_equal(
                            np.asarray(rss_scan_agg_grouped(*args,
                                                            n_groups=G)),
                            np.asarray(rss_scan_agg_grouped_ref(
                                *args, n_groups=G)),
                            err_msg=f"{seed},{P},{G},{M},{floor}")


@pytest.mark.parametrize("seed", range(3))
def test_chunked_kernel_matches_ref_and_flat(seed):
    """Chunked two-stage kernel == its segment-sum oracle per chunk, and
    after the device tree fold == the flat-lane kernel's host fold —
    across TAG_PAD pages, gid -1, empty groups, group counts that don't
    divide the group tile, page counts that don't divide the select
    block, empty/large member sets, and per-group param tiles."""
    import jax.numpy as jnp
    from repro.kernels.rss_scan_agg.kernel import (rss_scan_agg_chunked,
                                                   rss_scan_agg_grouped,
                                                   tree_fold_partials)
    from repro.kernels.rss_scan_agg.ops import fold_group_partials
    from repro.kernels.rss_scan_agg.ref import rss_scan_agg_chunked_ref

    rng = np.random.default_rng(seed)
    for P, K, E in [(8, 3, 8), (72, 4, 16), (256, 4, 8)]:
        data = np.zeros((P, K, E), np.int32)
        data[:, :, 0] = rng.integers(-1, 4, (P, K))     # tags incl. TAG_PAD
        data[:, :, 1] = rng.integers(-100, 100, (P, K))
        ts = rng.integers(0, 60, (P, K)).astype(np.int32)
        for G in (1, 13, 40):
            gid = rng.integers(-1, max(G - 1, 1), (P, 1)).astype(np.int32)
            gprm = np.stack([rng.choice([1, 3], G),
                             rng.choice([0, -2], G),
                             rng.integers(-50, 50, G)], 1).astype(np.int32)
            for M in (0, 7, 140):
                mem = np.sort(rng.choice(np.arange(1, 60), size=min(M, 59),
                                         replace=False)).astype(np.int32)
                for params in ({"tag_main": 1, "tag_alt": 0,
                                "threshold": 50},
                               {"group_params": jnp.asarray(gprm)}):
                    args = (jnp.asarray(data), jnp.asarray(ts),
                            jnp.asarray(gid), jnp.asarray(mem), 23)
                    chunks = rss_scan_agg_chunked(
                        *args, n_groups=G, rows_per_step=2, fold_chunks=2,
                        **params)
                    ref = rss_scan_agg_chunked_ref(
                        *args, n_groups=G, rows_per_step=2, fold_chunks=2,
                        **params)
                    np.testing.assert_array_equal(
                        np.asarray(chunks), np.asarray(ref),
                        err_msg=f"{seed},{P},{G},{M}")
                    # device tree fold == host fold == flat-lane kernel
                    flat = rss_scan_agg_grouped(*args, n_groups=G, **params)
                    assert fold_group_partials(chunks) == \
                        fold_group_partials(flat), (seed, P, G, M)
                    np.testing.assert_array_equal(
                        np.asarray(tree_fold_partials(chunks)),
                        np.asarray(fold_group_partials(chunks)),
                        err_msg=f"{seed},{P},{G},{M}")


def test_grouped_op_empty_groups_and_sentinels():
    """ops-level: a group with no pages folds to count 0 and the fused
    result finalizes min/max to 0 — matching the per-key oracle exactly."""
    eng = Engine("ssi")
    t = eng.begin()
    for i in range(4):
        eng.write(t, f"s:{i}", 10 * (i + 1))
    eng.commit(t)
    mirror = PagedMirror()
    mirror.catch_up(eng.wal)
    plan = GroupByPlan(
        (("s:0", "s:1"), (), ("s:2", "s:3", "missing:x")),
        (AggOp("sum", "int"), AggOp("count", "int"), AggOp("min", "int"),
         AggOp("max", "int")))
    chain = ChainVersionStore(eng.store).execute(plan, eng.seq)
    fused = PagedVersionStore(mirror).execute(plan, eng.seq)
    assert chain == fused
    assert fused[1] == (0, 0, 0, 0)             # empty group
    assert fused[0] == (30, 2, 10, 20)
    assert fused[2] == (70, 3, 0, 40)           # missing key reads as int 0


def test_grouped_duplicate_keys_across_groups():
    """A key in two groups participates in BOTH accumulator lanes (its
    page is gathered once per occurrence, each with its own gid)."""
    eng = Engine("ssi")
    t = eng.begin()
    eng.write(t, "a", 5)
    eng.write(t, "b", 7)
    eng.commit(t)
    mirror = PagedMirror()
    mirror.catch_up(eng.wal)
    plan = GroupByPlan((("a", "b"), ("b",)), (AggOp("sum", "int"),))
    chain = ChainVersionStore(eng.store).execute(plan, eng.seq)
    fused = PagedVersionStore(mirror).execute(plan, eng.seq)
    assert chain == fused == ((12,), (7,))


def test_multi_agg_one_config_per_field_threshold():
    """A compound of ops sharing one (field, threshold) config costs ONE
    fused device pass; distinct thresholds/fields add passes — asserted by
    counting sub-store exports (`jnp_store_for` calls via range_stats)."""
    eng = Engine("ssi")
    t = eng.begin()
    for i in range(6):
        eng.write(t, f"s:{i}", i * 10)
    eng.commit(t)
    mirror = PagedMirror()
    mirror.catch_up(eng.wal)
    paged = PagedVersionStore(mirror)
    keys = tuple(f"s:{i}" for i in range(6))

    def passes(plan):
        # jnp_store_for is called once per execute; kernel passes share it,
        # so count kernel configs through _scalar_raws' config dedup
        from repro.tensorstore.mirror import _op_config
        return len(dict.fromkeys(_op_config(op) for op in plan.ops))

    one = MultiAggPlan(keys, (AggOp("sum", "int"), AggOp("count", "int"),
                              AggOp("min", "int"), AggOp("max", "int")))
    assert passes(one) == 1
    two = MultiAggPlan(keys, (AggOp("count_below", "int", 10),
                              AggOp("count_below", "int", 30)))
    assert passes(two) == 2
    # results still match the oracle either way
    for plan in (one, two):
        assert paged.execute(plan, eng.seq) == \
            ChainVersionStore(eng.store).execute(plan, eng.seq)


def test_group_by_key_fn_builder():
    """`group_by` builds a GroupByPlan from a key-classifier in
    first-appearance order and returns the labels."""
    keys = ["customer:0:0:0", "customer:0:1:0", "customer:0:0:1",
            "customer:1:0:0"]
    labels, plan = group_by(keys, lambda k: k.split(":")[1],
                            [AggOp("sum", "int")])
    assert labels == ("0", "1")
    assert plan.key_groups == (
        ("customer:0:0:0", "customer:0:1:0", "customer:0:0:1"),
        ("customer:1:0:0",))
    assert plan_keys(plan) == tuple(keys[:3] + keys[3:])


# ------------------------------------------------------------ engine seams
class TestEnginePlanSeam:
    def test_group_plan_records_flat_read_set(self):
        eng = Engine("ssi", record=True)
        t0 = eng.begin()
        eng.write(t0, "a", 7)
        eng.write(t0, "b", 3)
        eng.commit(t0)
        t = eng.begin(read_only=True, skip_siread=True)
        plan = GroupByPlan((("a",), ("b", "c")), (AggOp("sum", "int"),))
        got = eng.execute(t, plan)
        assert got == ((7,), (3,))
        assert t.reads == {"a": t0.tid, "b": t0.tid, "c": 0}
        reads = [op for op in eng.history.ops
                 if op.kind == "r" and op.txn == t.tid]
        assert len(reads) == 3

    def test_ssi_tracked_group_plan_falls_back_to_per_key_reads(self):
        eng = Engine("ssi")
        t = eng.begin(read_only=True)
        eng.execute(t, MultiAggPlan(("a", "b"), (AggOp("count", "int"),)))
        assert t.tid in eng.siread.get("a", set())
        assert t.tid in eng.siread.get("b", set())

    def test_group_plan_sees_own_writes(self):
        eng = Engine("si")
        t = eng.begin()
        eng.write(t, "k1", 42)
        plan = GroupByPlan((("k0", "k1"), ("k1",)),
                           (AggOp("sum", "int"), AggOp("max", "int")))
        assert eng.execute(t, plan) == ((42, 42), (42, 42))


# ------------------------------------------------------------ facade seams
class TestFacadePlanSeam:
    def test_driver_serves_group_and_multi_plans_checked(self):
        from repro.mvcc.driver import run_single_node
        m = run_single_node(olap_mode="ssi+rss", oltp_clients=4,
                            olap_clients=2, rounds=1500, seed=3,
                            olap_scan=True, paged_olap=True,
                            check_scans=True)
        assert m.olap_group_steps > 0       # GroupByPlan served + checked
        assert m.olap_multi_agg_steps > 0   # MultiAggPlan served + checked
        assert m.olap_agg_steps > 0 and m.olap_scan_steps > 0

    def test_multi_node_serves_group_and_multi_plans_checked(self):
        from repro.mvcc.driver import run_multi_node
        m = run_multi_node(olap_mode="ssi+rss", oltp_clients=4,
                           olap_clients=2, rounds=1500, seed=3,
                           olap_scan=True, paged_olap=True,
                           check_scans=True, n_replicas=2)
        assert m.olap_group_steps > 0
        assert m.olap_multi_agg_steps > 0

    def test_reserved_key_families_raise_dense_hit_rate(self):
        """Page-range locality: with key families reserved contiguously
        (the driver default), dense plans slice instead of gather — the
        fast-path hit rate is recorded and high."""
        from repro.mvcc.driver import run_single_node
        m = run_single_node(olap_mode="ssi+rss", oltp_clients=4,
                            olap_clients=2, rounds=1500, seed=3,
                            olap_scan=True, paged_olap=True)
        assert m.olap_dense_range_hits > 0
        # stock/customer family plans all slice; only order-key plans
        # (dynamic allocation) may gather
        assert m.dense_range_hit_rate() > 0.5

    def test_unreserved_mirror_mostly_gathers(self):
        """Counter-check: WAL-order page allocation scatters key families,
        so the same workload shape without reservation mostly gathers."""
        from repro.mvcc.htap import SingleNodeHTAP
        from repro.mvcc.workload import Scale, load_initial
        from repro.tensorstore import AggPlan

        sc = Scale()
        htap = SingleNodeHTAP("ssi+rss", paged=True)   # no reserve_keys
        rng = random.Random(0)
        keys = sc.all_stock_keys()
        shuffled = list(keys)
        rng.shuffle(shuffled)
        t = htap.engine.begin()
        for k in shuffled:                  # commit in shuffled order
            htap.engine.write(t, k, rng.randrange(100))
        htap.engine.commit(t)
        htap.refresh_rss()
        r = htap.olap_begin()
        htap.olap_execute(r, AggPlan(tuple(keys), AggOp("sum", "int")))
        assert htap.mirror.range_stats["gather"] > 0
        assert htap.mirror.range_stats["dense"] == 0


# ------------------------------------------------------------- hypothesis
try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), gc=st.booleans(), legacy=st.booleans())
    def test_grouped_equal_oracle_hypothesis(seed, gc, legacy):
        check_group_stream(seed, gc_prob=0.5 if gc else 0.0,
                           legacy_prob=0.3 if legacy else 0.0, pin_prob=0.2)
except ImportError:                      # pragma: no cover
    pass
