"""Incremental RSS construction == the batch oracle, under lag and GC.

The tentpole contract: `RSSManager.construct()` (incremental: begin-LSN
heap Done/Clear tracking + `core.rss.IncrementalRss` delta application +
compressed floor/above-floor snapshots) must produce exactly the same
membership, floor and member-seq export as the O(history) batch path
(`construct_batch`, i.e. Algorithm 1 via `construct_rss_ssi` over the full
prefix) at EVERY replication round — including batched/lagged shipping
(rounds that split commit/deps pairs) and resumption after state GC.

Seeded-random stream tests always run; hypothesis widens the search when
available (same pattern as tests/test_gc_pins.py).
"""

import random

import pytest

from repro.core import (IncrementalRss, PRoTManager, RSSManager, Wal,
                        advance, construct_rss_ssi)
from repro.mvcc import Engine, SerializationFailure, Status


# --------------------------------------------------------------- generators
def random_wal_stream(rng, steps=300, *, legacy_prob=0.0):
    """Engine-shaped random WAL: begins/commits/aborts with deps logged
    immediately after the reader's commit, listing only writers that were
    concurrent with it and not yet aborted (the invariants `Engine.commit`
    guarantees)."""
    wal = Wal()
    active = []
    tid = 0
    for _ in range(steps):
        act = rng.random()
        if act < 0.35 or not active:
            tid += 1
            wal.log_begin(tid)
            active.append(tid)
        elif act < 0.75:
            t = active.pop(rng.randrange(len(active)))
            seq = 0 if rng.random() < legacy_prob else wal.head_lsn + 1
            wal.log_commit(t, seq=seq)
            if active and rng.random() < 0.6:
                k = rng.randint(1, min(3, len(active)))
                wal.log_deps(t, sorted(rng.sample(active, k)))
        else:
            t = active.pop(rng.randrange(len(active)))
            wal.log_abort(t)
    return wal


def full_members(manager, snap):
    """Explicit membership of a compressed snapshot, resolved through an
    un-GC'd manager's commit-seq bookkeeping."""
    return {t for t, s in manager.commit_seq.items()
            if s <= snap.floor_seq} | set(snap.txns)


def check_stream(seed, *, gc_prob=0.0, legacy_prob=0.0, pin_prob=0.0):
    rng = random.Random(seed)
    wal = random_wal_stream(rng, legacy_prob=legacy_prob)
    inc = RSSManager()               # incremental, possibly GC'd
    ora = RSSManager()               # oracle: full state, batch construct
    prot = PRoTManager(inc)
    pins = []
    prev_floor = 0
    while inc.applied_lsn < wal.head_lsn:
        batch = rng.randint(1, 12)   # lagged shipping, splits commit/deps
        for rec in wal.tail(inc.applied_lsn):
            inc.apply(rec)
            ora.apply(rec)
            batch -= 1
            if batch <= 0:
                break
        s_inc = inc.construct()
        s_ora = ora.construct_batch()
        assert s_inc.floor_seq == s_ora.floor_seq, seed
        assert s_inc.member_seqs == s_ora.member_seqs, seed
        assert s_inc.floor_seq >= prev_floor, "floor_seq must be monotone"
        prev_floor = s_inc.floor_seq
        want = full_members(ora, s_ora)
        for t in list(ora.committed):
            assert inc.is_member(t, s_inc) == (t in want), (seed, t)
        if pin_prob and rng.random() < pin_prob:
            pins.append(prot.acquire()[0])
        if pins and rng.random() < 0.3:
            prot.release(pins.pop(rng.randrange(len(pins))))
        if gc_prob and rng.random() < gc_prob:
            inc.gc(keep_lsn=prot.gc_floor(), keep_seq=prot.gc_floor_seq())
    # post-GC resumption reached the same final state
    s_inc, s_ora = inc.construct(), ora.construct_batch()
    assert s_inc.floor_seq == s_ora.floor_seq
    assert s_inc.member_seqs == s_ora.member_seqs


# ------------------------------------------------------------ always-run
@pytest.mark.parametrize("seed", range(12))
def test_incremental_equals_batch_oracle(seed):
    check_stream(seed)


@pytest.mark.parametrize("seed", range(12))
def test_incremental_equals_oracle_with_gc_and_pins(seed):
    check_stream(seed, gc_prob=0.5, pin_prob=0.3)


@pytest.mark.parametrize("seed", range(8))
def test_incremental_equals_oracle_with_legacy_records(seed):
    check_stream(seed, legacy_prob=0.3, gc_prob=0.3)


@pytest.mark.parametrize("seed", range(6))
def test_state_bounded_and_drains(seed):
    """After sustained load + GC, retained per-txn state is bounded by the
    window concurrent with the oldest active transaction — and drains to
    zero once every transaction settles."""
    rng = random.Random(seed)
    wal = Wal()
    active = []
    tid = 0
    m = RSSManager()
    peak = 0
    for _ in range(2000):
        act = rng.random()
        if act < 0.4 or not active:
            tid += 1
            wal.log_begin(tid); active.append(tid)
        elif act < 0.85:
            t = active.pop(rng.randrange(len(active)))
            wal.log_commit(t, seq=wal.head_lsn + 1)
            if active and rng.random() < 0.5:
                wal.log_deps(t, sorted(rng.sample(active, 1)))
        else:
            t = active.pop(rng.randrange(len(active)))
            wal.log_abort(t)
        if rng.random() < 0.2:
            m.catch_up(wal); m.construct(); m.gc()
            peak = max(peak, m.tracked_txns())
    for t in active:
        wal.log_abort(t)
    m.catch_up(wal); m.construct(); m.gc()
    assert m.tracked_txns() == 0
    assert len(m.commit_order) == 0 and not m.rw_out
    assert peak < 2000 // 4          # far below total history


def test_incremental_from_engine_wal_matches_oracle():
    """End-to-end: the incremental manager replaying a real SSI engine's WAL
    agrees with the batch oracle at every replication round."""
    rng = random.Random(11)
    eng = Engine("ssi")
    sessions = [None] * 4
    inc, ora = RSSManager(), RSSManager()
    prev_floor = 0
    for step in range(400):
        i = rng.randrange(4)
        t = sessions[i]
        try:
            if t is None or t.status != Status.ACTIVE:
                sessions[i] = eng.begin()
            elif rng.random() < 0.5:
                eng.read(t, rng.choice("abcde"))
            elif rng.random() < 0.7:
                eng.write(t, rng.choice("abcde"), rng.randrange(100))
            else:
                eng.commit(t)
                sessions[i] = None
        except SerializationFailure:
            sessions[i] = None
        if step % 17 == 0:
            inc.catch_up(eng.wal); ora.catch_up(eng.wal)
            s_inc, s_ora = inc.construct(), ora.construct_batch()
            assert s_inc.floor_seq == s_ora.floor_seq
            assert s_inc.member_seqs == s_ora.member_seqs
            assert s_inc.floor_seq >= prev_floor
            prev_floor = s_inc.floor_seq
            inc.gc()


def test_deps_after_reader_gc_is_dropped_without_leak():
    """Lag-split shipping: a reader's commit lands in one batch, state GC
    runs, then its deps record arrives.  The reader is already a
    floor-covered member; the record must be dropped, not stashed forever
    in IncrementalRss._pending_pull (bounded-state leak)."""
    wal = Wal()
    wal.log_begin(1); wal.log_commit(1, seq=1)
    wal.log_begin(2); wal.log_commit(2, seq=2)     # the reader
    m = RSSManager()
    m.catch_up(wal)
    m.construct()
    m.gc()                                         # both pruned (all Clear)
    assert m.tracked_txns() == 0
    wal.log_deps(2, [1])                           # arrives after the GC
    m.catch_up(wal)
    snap = m.construct()
    assert m.is_member(1, snap) and m.is_member(2, snap)
    assert not m._inc._pending_pull                # nothing stashed
    assert m.tracked_txns() == 0


# --------------------------------------------------- IncrementalRss direct
@pytest.mark.parametrize("seed", range(10))
def test_advance_matches_construct_rss_ssi(seed):
    """`advance` deltas reproduce Algorithm 1's batch result regardless of
    event interleaving (edges before/after commits, late clears)."""
    rng = random.Random(seed)
    txns = list(range(1, 30))
    committed = set(rng.sample(txns, 18))
    clear = set(rng.sample(sorted(committed), 9))
    edges = [(rng.choice(txns), rng.choice(txns)) for _ in range(25)]
    events = ([("c", t) for t in committed] + [("k", t) for t in clear]
              + [("e", e) for e in edges])
    rng.shuffle(events)
    state = IncrementalRss()
    added = set()
    for kind, payload in events:
        added |= advance(state,
                         committed=[payload] if kind == "c" else (),
                         clear=[payload] if kind == "k" else (),
                         edges=[payload] if kind == "e" else ())
    want = construct_rss_ssi(clear, committed, edges)
    assert state.rss == want == added


# ------------------------------------------------------------- hypothesis
try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 10_000), gc=st.booleans(),
           legacy=st.booleans())
    def test_incremental_equals_oracle_hypothesis(seed, gc, legacy):
        check_stream(seed, gc_prob=0.5 if gc else 0.0,
                     legacy_prob=0.3 if legacy else 0.0, pin_prob=0.2)
except ImportError:                      # pragma: no cover
    pass
