"""Replica-cluster fan-out: multi-consumer WAL truncation, lag-aware
routing, RSS-vs-oracle parity under skewed per-replica ship schedules, and
cluster-wide GC (state drains to the bounded window when the fleet catches
up).

Oracle strategy: a shadow copy of every WAL record (taken before the
primary recycles its prefix) feeds one un-GC'd `RSSManager` per replica to
the replica's applied LSN; its `construct_batch` (Algorithm 1 over the full
prefix) must agree with the replica's incrementally-maintained snapshot,
and the replica's batched RSS scans must equal per-key protected reads on
the primary engine at that snapshot.

Seeded-random schedules always run; hypothesis widens the search when
available (same pattern as tests/test_rss_incremental.py).
"""

import random

import pytest

from repro.cluster import (BoundedStaleness, Freshest, ReplicaCluster,
                           RoundRobin, make_policy)
from repro.core import RSSManager, Wal
from repro.mvcc import (MultiNodeHTAP, SerializationFailure, Status,
                        run_multi_node)
from repro.tensorstore import ScanPlan

KEYS = [f"k{i}" for i in range(8)]


# ------------------------------------------------------- WAL consumer slots
class TestWalConsumers:
    def test_truncate_clamps_to_min_acked(self):
        wal = Wal()
        for i in range(1, 7):
            wal.log_begin(i)
        wal.register_consumer("a")
        wal.register_consumer("b")
        wal.ack("a", 5)
        wal.ack("b", 3)
        assert wal.min_acked_lsn() == 3
        assert wal.truncate(6) == 3          # clamped at min acked, not 6
        assert wal.base_lsn == 3
        assert wal.truncate() == 0           # nothing below the horizon left
        wal.ack("b", 6)
        assert wal.truncate() == 2           # up to min(5, 6)
        assert wal.base_lsn == 5

    def test_ack_is_monotone_and_requires_registration(self):
        wal = Wal()
        wal.log_begin(1)
        wal.register_consumer("a")
        wal.ack("a", 1)
        wal.ack("a", 0)                      # stale ack: no regression
        assert wal.consumers["a"] == 1
        with pytest.raises(KeyError):
            wal.ack("ghost", 1)

    def test_register_below_base_is_an_error(self):
        wal = Wal()
        wal.log_begin(1); wal.log_begin(2)
        wal.truncate(2)
        with pytest.raises(LookupError):
            wal.register_consumer("late", start_lsn=0)
        wal.register_consumer("ok")          # defaults to base_lsn
        assert wal.consumers["ok"] == 2

    def test_unregistered_wal_keeps_legacy_truncation(self):
        """Regression for the old single-consumer path: with no registered
        slots, `truncate(lsn)` is taken at face value."""
        wal = Wal()
        for i in range(1, 5):
            wal.log_begin(i)
        assert wal.truncate(3) == 3
        assert wal.base_lsn == 3

    def test_consumers_survive_dump_load(self, tmp_path):
        wal = Wal()
        wal.log_begin(1); wal.log_begin(2)
        wal.register_consumer("replica0")
        wal.ack("replica0", 1)
        wal.truncate()
        p = str(tmp_path / "wal.jsonl")
        wal.dump(p)
        wal2 = Wal.load(p)
        assert wal2.consumers == {"replica0": 1}
        assert wal2.base_lsn == 1
        assert wal2.truncate(2) == 0         # still held by the slot


# ------------------------------------------------------- single-replica path
class TestSingleReplicaRegression:
    def test_ship_log_truncates_at_the_replica_lsn(self):
        """The old MultiNodeHTAP observable: with one replica, shipping
        recycles exactly the applied prefix."""
        htap = MultiNodeHTAP("ssi+rss")
        e = htap.primary
        t = e.begin(); e.write(t, "x", 1); e.commit(t)
        htap.ship_log()
        assert htap.primary.wal.base_lsn == htap.replica.applied_lsn
        assert not htap.primary.wal.records

    def test_second_consumer_no_longer_reads_a_recycled_prefix(self):
        """THE bug this subsystem fixes: previously `ship_log` truncated at
        the single replica's LSN, so a second, laggier consumer tailing the
        WAL hit a recycled prefix (LookupError).  Now truncation is held at
        the minimum applied LSN across registered consumers."""
        htap = MultiNodeHTAP("ssi+rss", n_replicas=2)
        e = htap.primary
        t = e.begin(); e.write(t, "x", 1); e.commit(t)
        htap.ship_log(replica=0)             # replica 1 has applied nothing
        assert htap.primary.wal.base_lsn == 0
        assert htap.ship_log(replica=1) > 0  # no LookupError: prefix intact
        assert htap.primary.wal.base_lsn == \
            min(r.applied_lsn for r in htap.cluster.replicas)


# ------------------------------------------------------------ routing logic
def _mini_cluster(n=3, *, policy="freshest", max_lag=100):
    htap = MultiNodeHTAP("ssi+rss", n_replicas=n, route_policy=policy,
                         max_staleness=max_lag)
    e = htap.primary
    for i in range(6):
        t = e.begin(); e.write(t, f"k{i}", i); e.commit(t)
    return htap


class TestRouting:
    def test_freshest_picks_min_lag(self):
        htap = _mini_cluster(policy="freshest")
        htap.ship_log(replica=1)             # replica 1 fully caught up
        assert htap.cluster.policy.choose(htap.cluster) == 1
        kind, idx, rid, snap = htap.olap_snapshot()
        assert (kind, idx) == ("rss", 1)
        htap.olap_release((kind, idx, rid, snap))

    def test_round_robin_cycles(self):
        htap = _mini_cluster(policy="round_robin")
        htap.ship_log()
        picked = [htap.cluster.policy.choose(htap.cluster) for _ in range(6)]
        assert picked == [0, 1, 2, 0, 1, 2]

    def test_bounded_staleness_ship_then_serve(self):
        """When every replica exceeds the bound, acquisition synchronously
        catches the freshest replica up before serving (freshness bought
        with one replication round)."""
        htap = _mini_cluster(policy="bounded_staleness", max_lag=3)
        cl = htap.cluster
        assert all(cl.lag_records(i) > 3 for i in range(3))
        assert cl.policy.choose(cl) is None
        handle = cl.acquire()
        assert cl.stats["ship_then_serve"] == 1
        assert cl.lag_records(handle[1]) == 0   # served fresh
        cl.release(handle)

    def test_per_query_hint_narrows_any_policy(self):
        htap = _mini_cluster(policy="freshest")
        cl = htap.cluster
        assert cl.policy.choose(cl, max_lag=0) is None   # all too stale
        handle = cl.acquire(max_lag=0)                   # ship-then-serve
        assert cl.stats["ship_then_serve"] == 1
        cl.release(handle)

    def test_make_policy_specs(self):
        assert isinstance(make_policy("freshest"), Freshest)
        assert isinstance(make_policy("round_robin"), RoundRobin)
        p = make_policy("bounded_staleness", max_lag=7)
        assert isinstance(p, BoundedStaleness) and p.max_lag == 7
        assert make_policy(p) is p
        with pytest.raises(ValueError):
            make_policy("nope")


# --------------------------------------- RSS vs oracle under skewed shipping
def _random_oltp_step(eng, sessions, rng):
    i = rng.randrange(len(sessions))
    t = sessions[i]
    try:
        if t is None or t.status != Status.ACTIVE:
            sessions[i] = eng.begin()
        elif rng.random() < 0.45:
            eng.read(t, rng.choice(KEYS))
        elif rng.random() < 0.75:
            eng.write(t, rng.choice(KEYS), rng.randrange(1000))
        else:
            eng.commit(t)
            sessions[i] = None
    except SerializationFailure:
        sessions[i] = None


def check_cluster_vs_oracle(seed, *, n_replicas=3, steps=250):
    """Randomized per-replica ship schedule: every replica's compressed RSS
    snapshot equals the batch oracle at its applied LSN, batched replica
    scans equal per-key protected reads on the primary, and the WAL only
    ever recycles below min(applied LSN) across consumers."""
    rng = random.Random(seed)
    htap = MultiNodeHTAP("ssi+rss", n_replicas=n_replicas)
    eng = htap.primary
    wal = eng.wal
    cluster = htap.cluster
    sessions = [None] * 4
    shadow = []                      # full record stream (never truncated)
    oracles = [RSSManager() for _ in range(n_replicas)]

    def sync_shadow():
        have = shadow[-1].lsn if shadow else 0
        shadow.extend(wal.tail(have))

    for _ in range(steps):
        _random_oltp_step(eng, sessions, rng)
        sync_shadow()
        if rng.random() < 0.4:
            i = rng.randrange(n_replicas)
            base_before = wal.base_lsn
            htap.ship_log(replica=i,
                          max_records=rng.choice((0, 1, 3, 7)))
            rep = cluster.replicas[i]
            # truncation invariant: never beyond any consumer's applied LSN
            assert wal.base_lsn <= cluster.min_applied_lsn()
            assert wal.base_lsn >= base_before
            # oracle replay to the same LSN
            ora = oracles[i]
            for rec in shadow[ora.applied_lsn:rep.applied_lsn]:
                ora.apply(rec)
            assert ora.applied_lsn == rep.applied_lsn
            s_ora = ora.construct_batch()
            rid, s_rep = rep.rss_snapshot()
            assert s_rep.floor_seq == s_ora.floor_seq, seed
            assert s_rep.member_seqs == s_ora.member_seqs, seed
            # replica batched scan == primary per-key protected reads
            vals = rep.execute_rss(s_rep, ScanPlan(tuple(KEYS)))
            r = eng.begin(read_only=True, rss=s_rep)
            assert vals == [eng.read(r, k) for k in KEYS], seed
            rep.release(rid)
    return htap, shadow, oracles


@pytest.mark.parametrize("seed", range(8))
def test_cluster_rss_matches_batch_oracle(seed):
    check_cluster_vs_oracle(seed)


@pytest.mark.parametrize("seed", range(4))
def test_cluster_state_drains_when_fleet_catches_up(seed):
    """Once every transaction settles, every replica ships to head, and all
    pins are released: the WAL drains to empty, every RSSManager's per-txn
    bookkeeping GCs to zero, and engine state is bounded."""
    htap, _, _ = check_cluster_vs_oracle(seed, steps=200)
    eng = htap.primary
    for t in list(eng.active.values()):
        try:
            eng.commit(t)
        except SerializationFailure:
            pass
    htap.ship_log()                          # all replicas to head
    assert not eng.wal.records               # min acked == head: drained
    assert eng.wal.base_lsn == eng.wal.head_lsn
    for rep in htap.cluster.replicas:
        assert rep.applied_lsn == eng.wal.head_lsn
        rep.rss_manager.gc(keep_lsn=rep.prot.gc_floor(),
                           keep_seq=rep.prot.gc_floor_seq())
        assert rep.rss_manager.tracked_txns() == 0
    assert htap.gc_versions() >= 0           # cluster-wide floor well-formed


def test_mixed_si_and_prot_pins_on_one_replica():
    """SI and PRoT pins on the same (with_rss) replica: disjoint reader-id
    namespaces (releasing an SI handle never drops a PRoT pin) and the GC
    floor honours BOTH kinds — an old SI pin holds version pruning even
    while the RSS floor advances."""
    htap = MultiNodeHTAP("ssi+rss")
    e, rep = htap.primary, htap.replica
    t = e.begin(); e.write(t, "x", 1); e.commit(t)
    htap.ship_log()
    si_rid, si_seq = rep.si_snapshot_pinned()
    prot_rid, snap = rep.rss_snapshot()
    assert si_rid < 0 < prot_rid                  # disjoint id spaces
    for i in range(5):                            # floor moves past si_seq
        t = e.begin(); e.write(t, "x", 10 + i); e.commit(t)
    htap.ship_log()
    assert rep.gc_floor_seq() <= si_seq           # SI pin holds the floor
    rep.gc_versions()
    assert rep.read_si(si_seq, "x") == 1          # pinned version survived
    rep.release(si_rid)                           # must not drop the PRoT pin
    assert rep.prot.pinned == 1
    assert rep.gc_floor_seq() <= snap.floor_seq   # PRoT pin still in force
    rep.release(prot_rid)
    assert rep.prot.pinned == 0 and not rep._si_pins


def test_cluster_gc_floor_and_version_pruning():
    """The cluster-wide GC floor is the min over replicas of min(horizon,
    oldest pin); a lagging replica (or an old pin) holds version pruning
    everywhere below it."""
    htap = MultiNodeHTAP("ssi+rss", n_replicas=2)
    e = htap.primary
    for i in range(10):
        t = e.begin(); e.write(t, "x", i); e.commit(t)
    htap.ship_log(replica=0)
    # replica 1 never shipped: floor pinned at its (empty) horizon
    assert htap.cluster.gc_floor_seq() == 0
    assert len(e.store.chain("x").versions) == 11
    pruned_held = htap.gc_versions()
    assert len(e.store.chain("x").versions) == 11   # primary held at floor 0
    htap.ship_log(replica=1)
    pruned = htap.gc_versions()
    assert pruned > pruned_held
    assert len(e.store.chain("x").versions) == 1    # newest survives


def test_driver_multi_replica_end_to_end():
    """Skewed-lag driver run with scan checking: wait-free OLAP across a
    3-replica fleet, load spread per policy, snapshots scan-verified
    against the per-key oracle in-run."""
    m = run_multi_node(olap_mode="ssi+rss", oltp_clients=4, olap_clients=3,
                       rounds=600, seed=5, olap_scan=True, check_scans=True,
                       n_replicas=3, route_policy="round_robin", ship_skew=2)
    assert m.olap_commits > 0 and m.olap_aborts == 0
    assert len(m.olap_served_by) == 3
    assert all(c > 0 for c in m.olap_served_by)
    # skewed cadence => replica 0 is fresher than replica 2 on average
    m_fresh = run_multi_node(olap_mode="ssi+rss", oltp_clients=4,
                             olap_clients=3, rounds=600, seed=5,
                             olap_scan=True, n_replicas=3,
                             route_policy="freshest", ship_skew=2)
    assert m_fresh.olap_avg_lag_records <= m.olap_avg_lag_records


# ------------------------------------------------------------- hypothesis
try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000),
           n_replicas=st.integers(3, 5))
    def test_cluster_rss_matches_oracle_hypothesis(seed, n_replicas):
        check_cluster_vs_oracle(seed, n_replicas=n_replicas, steps=150)
except ImportError:                      # pragma: no cover
    pass
