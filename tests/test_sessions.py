"""Session-token serving: read-your-writes / monotonic reads across a
lag-skewed replica fleet, horizon-keyed resolve caching, dedup plan
batching, and latency-SLO routing.

The guarantees are LSN-prefix-level (PostgreSQL hot-standby style): a
session is never served by a replica whose applied WAL position is below
max(the session's last observed commit LSN, its last served horizon) —
asserted both by the cluster's own `token_violations` counter and by
replaying each session's kept serve history.  Cached serving must be
bit-identical to uncached serving and to the per-key chain oracle
(`check_scans=True` asserts the latter at every serve)."""

import random

import pytest

from repro.cluster import LatencySLO, Session, make_policy
from repro.mvcc import MultiNodeHTAP, run_multi_node, run_sessions
from repro.mvcc.workload import (Scale, load_initial, session_plan_families,
                                 zipf_assign)
from repro.obs import REGISTRY, reset_run

SMALL = Scale(warehouses=2, districts=2, customers=3, items=6)


# ------------------------------------------------------------ token object
class TestSessionToken:
    def test_required_lsn_is_max_of_commit_and_read_horizons(self):
        s = Session(0)
        assert s.min_required_lsn() == 0
        s.note_commit(7)
        assert s.min_required_lsn() == 7
        s.note_read(12)
        assert s.min_required_lsn() == 12
        s.note_commit(5)            # stale stamp: never regresses
        assert s.last_commit_lsn == 7 and s.min_required_lsn() == 12

    def test_read_horizon_is_monotone(self):
        s = Session(1)
        s.note_read(10)
        s.note_read(4)              # a lower serve records, never regresses
        assert s.last_read_lsn == 10 and s.serves == 2

    def test_history_audit_counts_violations(self):
        s = Session(2, keep_history=True)
        s.note_commit(5)
        s.note_read(6, replica=0)   # ok: 6 >= required 5
        s.note_read(3, replica=1)   # violation: 3 < required 6
        assert s.violations() == 1
        assert [r for r, _, _ in s.history] == [0, 1]


# ------------------------------------------------- cluster-level guarantees
def _commit_n(htap, n, start=0):
    eng = htap.primary
    for i in range(n):
        t = eng.begin()
        eng.write(t, f"warehouse:{i % 2}", start + i)
        eng.commit(t)


def test_read_your_writes_forces_delta_ship():
    """Non-predictive policy, whole fleet stale below the token: the
    cluster must delta-ship (a token ship, not a staleness fallback)
    rather than serve a stale replica or stall."""
    htap = MultiNodeHTAP("ssi+rss", n_replicas=2, route_policy="freshest")
    load_initial(htap.primary, SMALL)
    htap.ship_log()
    sess = htap.session()
    _commit_n(htap, 3)              # unshipped tail
    htap.note_commit(sess)
    handle = htap.olap_snapshot(session=sess)
    idx = handle[1]
    assert htap.cluster.replicas[idx].applied_lsn >= sess.last_commit_lsn
    st = htap.cluster.stats
    assert st["token_ships"] == 1 and st["ship_then_serve"] == 0
    assert st["token_violations"] == 0
    htap.olap_release(handle)


def test_session_value_level_read_your_writes_under_si():
    """ssi+si replicas serve plain SI snapshots at the applied horizon,
    so an LSN-covered serve also covers the session's writes at the
    VALUE level: the committed value must come back."""
    htap = MultiNodeHTAP("ssi+si", n_replicas=2, route_policy="round_robin")
    load_initial(htap.primary, SMALL)
    htap.ship_log()
    sess = htap.session()
    eng = htap.primary
    t = eng.begin()
    eng.write(t, "warehouse:0", 4242)
    eng.commit(t)
    htap.note_commit(sess)
    handle = htap.olap_snapshot(session=sess)
    assert htap.olap_read(handle, "warehouse:0") == 4242
    htap.olap_release(handle)
    assert htap.cluster.stats["token_violations"] == 0


@pytest.mark.parametrize("seed", range(4))
def test_session_guarantees_randomized(seed):
    """Randomized ship schedules / fleet sizes / policies / cache+batch
    settings: every session's kept history must show zero serves below
    its required LSN, and the cluster's own violation counter agrees.
    `check_scans=True` additionally asserts every (possibly cached,
    possibly fused) plan result against the per-key chain oracle."""
    rng = random.Random(seed)
    m, sessions = run_sessions(
        n_sessions=rng.randint(8, 20), rounds=rng.randint(3, 6),
        seed=seed, scale=SMALL,
        n_replicas=rng.randint(2, 3),
        route_policy=rng.choice(["freshest", "round_robin",
                                 "predicted_staleness", "latency_slo"]),
        ship_every=rng.randint(1, 4), ship_skew=rng.randint(0, 2),
        zipf_s=rng.uniform(0.8, 1.6),
        resolve_cache=rng.random() < 0.5,
        batch_plans=rng.random() < 0.5,
        write_fraction=0.3, check_scans=True, keep_history=True)
    assert m.session_token_violations == 0
    assert all(s.session.violations() == 0 for s in sessions)
    assert m.session_serves == m.session_token_acquires > 0
    assert m.oltp_commits > 0


def test_run_multi_node_session_tokens():
    """The general driver grows the same guarantee: sticky per-client
    sessions thread through `olap_snapshot`, violation-free."""
    m = run_multi_node(olap_mode="ssi+rss", oltp_clients=2, olap_clients=3,
                       rounds=300, seed=11, scale=SMALL, olap_scan=True,
                       n_replicas=2, route_policy="round_robin",
                       ship_every=20, ship_skew=2, session_tokens=True)
    assert m.session_token_acquires > 0
    assert m.session_token_violations == 0


# ------------------------------------------------------- cache == uncached
def test_resolve_cache_matches_uncached_run():
    """Same seed, cache on vs off: identical final results per session
    (and the cached run actually hit its caches)."""
    outs, hit_rates = [], None
    for cache in (False, True):
        m, sessions = run_sessions(n_sessions=12, rounds=4, seed=3,
                                   scale=SMALL, resolve_cache=cache,
                                   batch_plans=False, check_scans=True,
                                   write_fraction=0.25)
        outs.append([s.pending for s in sessions])
        if cache:
            hit_rates = m.cache_hit_rates()
    assert outs[0] == outs[1]
    assert hit_rates["member"] > 0 and hit_rates["pindex"] > 0


def test_mirror_cache_precise_invalidation():
    """Mirror-level: repeated execution hits the store cache; an applied
    commit invalidates precisely (the new value shows up); an explicit
    `invalidate_caches` changes nothing observable."""
    from repro.core.wal import WalRecord
    from repro.tensorstore import AggOp, AggPlan, PagedMirror, \
        PagedVersionStore

    mirror = PagedMirror()
    mirror.apply(WalRecord(lsn=1, type="commit", txn=1,
                           writes=(("a", 5), ("b", 9)), seq=1))
    plan = AggPlan(("a", "b"), AggOp("sum", "int"))
    store = PagedVersionStore(mirror)
    before = mirror.cache_stats["store_hits"]
    assert store.execute(plan, mirror.watermark) == 14
    assert store.execute(plan, mirror.watermark) == 14   # cached resolve
    assert mirror.cache_stats["store_hits"] > before
    mirror.invalidate_caches()
    assert store.execute(plan, mirror.watermark) == 14   # cold == warm
    mirror.apply(WalRecord(lsn=2, type="commit", txn=2,
                           writes=(("b", 1),), seq=2))
    assert store.execute(plan, mirror.watermark) == 6    # no stale serve


def test_batching_dedup_matches_unbatched():
    """Dedup batching folds a skewed fleet's same-horizon serves into few
    dispatches without changing any session's result."""
    outs, dispatches = [], 0
    for batch in (False, True):
        m, sessions = run_sessions(n_sessions=20, rounds=3, seed=5,
                                   scale=SMALL, resolve_cache=True,
                                   batch_plans=batch, write_fraction=0.2)
        outs.append([s.pending for s in sessions])
        if batch:
            dispatches = m.olap_batch_dispatches
            assert m.olap_batched_plans > m.olap_batch_dispatches
    assert outs[0] == outs[1]
    assert 0 < dispatches < 20 * 3


# ------------------------------------------------------- latency_slo policy
def test_make_policy_resolves_latency_slo():
    p = make_policy("latency_slo", max_lag=17)
    assert isinstance(p, LatencySLO)
    assert p.max_lag == 17 and p.predictive


def test_latency_slo_steers_around_slow_replica():
    reset_run()
    pol = LatencySLO(1000, min_count=5, refresh=1)
    htap = MultiNodeHTAP("ssi+rss", n_replicas=3, route_policy=pol)
    load_initial(htap.primary, SMALL)
    htap.ship_log()
    for i in range(3):              # replica 2 serves 100x slower
        h = REGISTRY.histogram("olap_serve_seconds", replica=i)
        for _ in range(10):
            h.observe(1e-1 if i == 2 else 1e-3)
    chosen = {pol.choose(htap.cluster) for _ in range(9)}
    assert chosen and 2 not in chosen


def test_latency_slo_never_empties_eligible_set():
    pol = LatencySLO(1000, refresh=10_000)
    htap = MultiNodeHTAP("ssi+rss", n_replicas=2, route_policy=pol)
    load_initial(htap.primary, SMALL)
    htap.ship_log()
    pol._choices = 1                # hold the fabricated slow set
    pol._slow = {0, 1}              # whole fleet busts the SLO
    assert pol.choose(htap.cluster) is not None


def test_latency_slo_ignores_cold_replicas():
    reset_run()
    pol = LatencySLO(1000, min_count=5, refresh=1)
    htap = MultiNodeHTAP("ssi+rss", n_replicas=2, route_policy=pol)
    load_initial(htap.primary, SMALL)
    htap.ship_log()
    # only replica 0 has data, and few observations: no SLO judgement
    REGISTRY.histogram("olap_serve_seconds", replica=0).observe(1e-1)
    assert pol.choose(htap.cluster) is not None
    assert not pol._slow


# ------------------------------------------------------------ zipf workload
def test_session_plan_families_are_stable_fingerprints():
    fams = session_plan_families(SMALL)
    assert len(fams) == 4 + 2 * SMALL.warehouses
    # frozen plans: identical fingerprints call to call (dedup + resolve
    # caching both key on this)
    assert fams == session_plan_families(SMALL)
    assert len({plan for _n, plan in fams}) == len(fams)


def test_zipf_assign_is_skewed_and_deterministic():
    a = zipf_assign(random.Random(7), 2000, 8, s=1.2)
    b = zipf_assign(random.Random(7), 2000, 8, s=1.2)
    assert a == b and len(a) == 2000
    assert set(a) <= set(range(8))
    counts = [a.count(i) for i in range(8)]
    assert counts[0] == max(counts)          # rank-0 family dominates
    assert counts[0] > 3 * max(counts[-1], 1)
