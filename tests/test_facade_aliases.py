"""Facade-alias removal regression: the per-op aliases are GONE.

PR 5 collapsed the per-op OLAP facade seams into one `execute(plan)` seam
per layer and kept the old names as deprecated thin aliases.  This PR
deletes them: `olap_scan`/`olap_agg` on both HTAP facades,
`scan_si`/`scan_rss`/`agg_si`/`agg_rss` on `Replica`, and `scan`/`agg`
on `Engine` and `ReplicaCluster`.  These tests pin the removal — an
alias that sneaks back in is facade drift waiting to happen — and
re-verify that the surviving plan seam serves the same results the
aliases used to.
"""

import random

import pytest

from repro.cluster import ReplicaCluster
from repro.mvcc import Engine
from repro.mvcc.htap import MultiNodeHTAP, Replica, SingleNodeHTAP
from repro.mvcc.workload import Scale, load_initial
from repro.tensorstore import AggOp, AggPlan, ScanPlan, apply_plan

OP = AggOp("count_below", "int", 60)

REMOVED = {
    Engine: ("scan", "agg"),
    SingleNodeHTAP: ("olap_scan", "olap_agg"),
    MultiNodeHTAP: ("olap_scan", "olap_agg"),
    Replica: ("scan_si", "scan_rss", "agg_si", "agg_rss"),
    ReplicaCluster: ("scan", "agg"),
}


def _loaded_single(paged):
    htap = SingleNodeHTAP("ssi+rss", paged=paged)
    load_initial(htap.engine, Scale())
    rng = random.Random(1)
    for _ in range(30):
        t = htap.engine.begin()
        htap.engine.write(t, f"stock:0:{rng.randrange(50)}",
                          rng.randrange(120))
        htap.engine.commit(t)
    htap.refresh_rss()
    return htap


class TestAliasesRemoved:
    @pytest.mark.parametrize("cls,names", sorted(
        REMOVED.items(), key=lambda kv: kv[0].__name__),
        ids=lambda v: v.__name__ if isinstance(v, type) else None)
    def test_class_has_no_alias(self, cls, names):
        for name in names:
            assert not hasattr(cls, name), \
                f"deprecated alias {cls.__name__}.{name} is back"

    def test_instances_have_no_alias(self):
        eng = Engine("ssi")
        for name in REMOVED[Engine]:
            assert not hasattr(eng, name)
        htap = _loaded_single(paged=True)
        for name in REMOVED[SingleNodeHTAP]:
            assert not hasattr(htap, name)
        mh = MultiNodeHTAP("ssi+rss", paged_olap=True)
        for name in REMOVED[MultiNodeHTAP]:
            assert not hasattr(mh, name)
        for name in REMOVED[Replica]:
            assert not hasattr(mh.replica, name)
        for name in REMOVED[ReplicaCluster]:
            assert not hasattr(mh.cluster, name)


class TestPlanSeamStillServes:
    """The one surviving seam serves what the aliases used to serve."""

    def test_single_node_execute(self):
        for paged in (False, True):
            htap = _loaded_single(paged)
            keys = Scale().all_stock_keys()
            t = htap.olap_begin()
            vals = htap.olap_execute(t, ScanPlan(tuple(keys)))
            assert vals == [htap.engine.read(t, k) for k in keys]
            assert htap.olap_execute(t, AggPlan(tuple(keys), OP)) == \
                apply_plan(vals, AggPlan(tuple(keys), OP))
            htap.olap_commit(t)

    def test_engine_execute(self):
        eng = Engine("ssi")
        t0 = eng.begin()
        for i in range(8):
            eng.write(t0, f"k:{i}", i * 9)
        eng.commit(t0)
        keys = tuple(f"k:{i}" for i in range(8))
        t = eng.begin(read_only=True, skip_siread=True)
        vals = eng.execute(t, ScanPlan(keys))
        assert vals == [eng.read(t, k) for k in keys]
        assert eng.execute(t, AggPlan(keys, OP)) == \
            apply_plan(vals, AggPlan(keys, OP))

    def test_multi_node_execute(self):
        for paged in (False, True):
            htap = MultiNodeHTAP("ssi+rss", paged_olap=paged, n_replicas=2)
            load_initial(htap.primary, Scale())
            htap.ship_log()
            keys = tuple(Scale().all_stock_keys())
            snap = htap.olap_snapshot()
            vals = htap.olap_execute(snap, ScanPlan(keys))
            assert vals == [htap.olap_read(snap, k) for k in keys]
            assert htap.olap_execute(snap, AggPlan(keys, OP)) == \
                apply_plan(vals, AggPlan(keys, OP))
            htap.olap_release(snap)

    def test_si_replica_execute(self):
        htap = MultiNodeHTAP("ssi+si", paged_olap=True)
        load_initial(htap.primary, Scale())
        htap.ship_log()
        rep = htap.replica
        keys = tuple(Scale().all_stock_keys())
        seq = rep.si_snapshot()
        vals = rep.execute_si(seq, ScanPlan(keys))
        assert rep.execute_si(seq, AggPlan(keys, OP)) == \
            apply_plan(vals, AggPlan(keys, OP))
