"""Facade-drift regression: deprecated per-op aliases == the plan path.

PR 5 collapsed the per-op OLAP facade seams (`olap_scan`/`olap_agg`,
`scan_si`/`scan_rss`/`agg_si`/`agg_rss`, cluster `scan`/`agg`, engine
`scan`/`agg`) into one `execute(plan)` seam per layer, keeping the old
names as thin aliases.  The drift hazard: an alias that re-implements its
op can silently diverge from the plan path.  These tests assert (a) alias
results == plan-path results at every facade, and (b) the aliases really
ROUTE through the plan seam (counted via monkeypatching), so logic cannot
be duplicated without failing here.
"""

import random

from repro.mvcc import Engine
from repro.mvcc.htap import MultiNodeHTAP, Replica, SingleNodeHTAP
from repro.mvcc.workload import Scale, load_initial
from repro.tensorstore import AggOp, AggPlan, ScanPlan

OP = AggOp("count_below", "int", 60)


def _loaded_single(paged):
    htap = SingleNodeHTAP("ssi+rss", paged=paged)
    load_initial(htap.engine, Scale())
    rng = random.Random(1)
    for _ in range(30):
        t = htap.engine.begin()
        htap.engine.write(t, f"stock:0:{rng.randrange(50)}",
                          rng.randrange(120))
        htap.engine.commit(t)
    htap.refresh_rss()
    return htap


class TestSingleNodeAliases:
    def test_alias_equals_plan_path(self):
        for paged in (False, True):
            htap = _loaded_single(paged)
            keys = Scale().all_stock_keys()
            t = htap.olap_begin()
            assert htap.olap_scan(t, keys) == \
                htap.olap_execute(t, ScanPlan(tuple(keys)))
            assert htap.olap_agg(t, keys, OP) == \
                htap.olap_execute(t, AggPlan(tuple(keys), OP))
            htap.olap_commit(t)

    def test_alias_routes_through_execute(self, monkeypatch):
        htap = _loaded_single(paged=True)
        calls = []
        orig = SingleNodeHTAP.olap_execute
        monkeypatch.setattr(
            SingleNodeHTAP, "olap_execute",
            lambda self, t, plan: calls.append(type(plan).__name__)
            or orig(self, t, plan))
        t = htap.olap_begin()
        htap.olap_scan(t, ["stock:0:0"])
        htap.olap_agg(t, ["stock:0:0"], OP)
        assert calls == ["ScanPlan", "AggPlan"]


class TestEngineAliases:
    def test_alias_equals_plan_path_and_routes(self, monkeypatch):
        eng = Engine("ssi")
        t0 = eng.begin()
        for i in range(8):
            eng.write(t0, f"k:{i}", i * 9)
        eng.commit(t0)
        keys = [f"k:{i}" for i in range(8)]
        t = eng.begin(read_only=True, skip_siread=True)
        assert eng.scan(t, keys) == eng.execute(t, ScanPlan(tuple(keys)))
        assert eng.agg(t, keys, OP) == \
            eng.execute(t, AggPlan(tuple(keys), OP))
        calls = []
        orig = Engine.execute
        monkeypatch.setattr(
            Engine, "execute",
            lambda self, txn, plan: calls.append(type(plan).__name__)
            or orig(self, txn, plan))
        eng.scan(t, keys)
        eng.agg(t, keys, OP)
        assert calls == ["ScanPlan", "AggPlan"]


class TestMultiNodeAliases:
    def test_alias_equals_plan_path(self):
        for paged in (False, True):
            htap = MultiNodeHTAP("ssi+rss", paged_olap=paged, n_replicas=2)
            load_initial(htap.primary, Scale())
            htap.ship_log()
            keys = Scale().all_stock_keys()
            snap = htap.olap_snapshot()
            assert htap.olap_scan(snap, keys) == \
                htap.olap_execute(snap, ScanPlan(tuple(keys)))
            assert htap.olap_agg(snap, keys, OP) == \
                htap.olap_execute(snap, AggPlan(tuple(keys), OP))
            htap.olap_release(snap)

    def test_cluster_and_replica_aliases_route_through_execute(
            self, monkeypatch):
        htap = MultiNodeHTAP("ssi+rss", paged_olap=True)
        load_initial(htap.primary, Scale())
        htap.ship_log()
        keys = ["stock:0:0", "stock:0:1"]
        snap = htap.olap_snapshot()
        calls = []
        orig = Replica._execute
        monkeypatch.setattr(
            Replica, "_execute",
            lambda self, s, plan: calls.append(type(plan).__name__)
            or orig(self, s, plan))
        htap.olap_scan(snap, keys)        # facade -> cluster -> replica
        htap.olap_agg(snap, keys, OP)
        rep = htap.replica
        rep.scan_si(rep.si_snapshot(), keys)
        rep.agg_si(rep.si_snapshot(), keys, OP)
        assert calls == ["ScanPlan", "AggPlan", "ScanPlan", "AggPlan"]
        htap.olap_release(snap)

    def test_si_replica_aliases_equal_plan_path(self):
        htap = MultiNodeHTAP("ssi+si", paged_olap=True)
        load_initial(htap.primary, Scale())
        htap.ship_log()
        rep = htap.replica
        keys = Scale().all_stock_keys()
        seq = rep.si_snapshot()
        assert rep.scan_si(seq, keys) == \
            rep.execute_si(seq, ScanPlan(tuple(keys)))
        assert rep.agg_si(seq, keys, OP) == \
            rep.execute_si(seq, AggPlan(tuple(keys), OP))
