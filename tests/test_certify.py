"""Certifier matrix: extraction pin, soundness oracles, monotone admission.

Three layers of evidence that the `Certifier` seam is a refactor and the
refined certifiers are sound:

  * ConservativeSSI reproduces the SEED engine's abort decisions exactly —
    a verbatim copy of the pre-extraction inlined logic lives here as a
    shadow certifier, and randomized schedules must produce identical Adya
    histories, WAL streams, and stats under both.
  * Every committed history passes the `repro.core` serializability
    oracles: `ssi_accepts` for the SSI-family certifiers (conservative /
    commit-order), `is_serializable` + SI validity for SSN (which by
    design admits serializable schedules no SSI scheduler accepts).
  * Admitted-schedule sets are monotone: a schedule ConservativeSSI runs
    abort-free is abort-free under CommitOrderSSI, and likewise
    CommitOrderSSI under SSN.
"""

import random

import pytest

from repro.core import is_serializable, ssi_accepts
from repro.core.ssi import is_si_history
from repro.mvcc import (AbortReason, Certifier, CommitOrderSSI,
                        ConservativeSSI, Engine, MultiNodeHTAP, SSN,
                        SerializationFailure, Status, make_certifier,
                        run_write_skew)

KEYS = ["a", "b", "c", "d", "e", "f", "g", "h"]
CERTS = ("conservative", "commit-order", "ssn")


# ----------------------------------------------------------- schedule harness
def gen_schedule(seed, n_rounds=None):
    """Pre-draw every client decision (the INTENDED schedule) so the same
    workload can be replayed under different certifiers; executions only
    diverge after the first diverging abort decision.  Variable length
    keeps the pool mixed: short schedules every certifier admits, long
    contended ones only the refined certifiers survive."""
    rng = random.Random(seed)
    n = n_rounds if n_rounds is not None else 30 + seed % 40
    return [(rng.randrange(4), rng.random(), rng.random() < 0.25,
             rng.choice(KEYS), rng.randrange(100))
            for _ in range(n)]


def run_schedule(sched, certifier):
    eng = Engine("ssi", record=True, certifier=certifier)
    sessions = [None] * 4
    for (i, act, ro, key, val) in sched:
        t = sessions[i]
        if t is None or t.status != Status.ACTIVE:
            sessions[i] = eng.begin(read_only=ro)
            continue
        try:
            if act < 0.4:
                eng.read(t, key)
            elif act < 0.7 and not t.read_only:
                eng.write(t, key, val)
            else:
                eng.commit(t)
                sessions[i] = None
        except SerializationFailure:
            sessions[i] = None
    for t in sessions:                       # settle stragglers
        if t is not None and t.status == Status.ACTIVE:
            try:
                eng.commit(t)
            except SerializationFailure:
                pass
    return eng


class SeedPivotCertifier(Certifier):
    """VERBATIM copy of the seed engine's inlined `_maybe_abort_pivot` /
    `_precommit_ssi_check` logic, kept here as the behaviour pin for the
    extracted `ConservativeSSI`.  Do not "fix" this class — it IS the
    reference."""

    name = "seed-pivot"

    def on_rw_edge(self, reader, writer):
        for cand in (writer, reader):
            if cand.is_pivot:
                if cand.status == Status.ACTIVE:
                    self.abort(cand, AbortReason.PIVOT)
                    return
                for nid in list(cand.in_rw) + list(cand.out_rw):
                    n = self.engine.txns.get(nid)
                    if n is not None and n.status == Status.ACTIVE:
                        self.abort(n, AbortReason.INCOMING_PIVOT)
                        return

    def on_precommit(self, t):
        if t.is_pivot and t.status == Status.ACTIVE:
            raise SerializationFailure(AbortReason.PIVOT)


# ------------------------------------------------------------- extraction pin
class TestConservativeIsTheSeed:
    def test_identical_histories_wal_and_stats(self):
        for seed in range(40):
            sched = gen_schedule(seed)
            a = run_schedule(sched, ConservativeSSI())
            b = run_schedule(sched, SeedPivotCertifier())
            assert a.history.ops == b.history.ops, seed
            assert [r.to_json() for r in a.wal.records] == \
                   [r.to_json() for r in b.wal.records], seed
            assert a.stats == b.stats, seed

    def test_default_certifier_is_conservative(self):
        assert isinstance(Engine("ssi").certifier, ConservativeSSI)
        assert isinstance(make_certifier(None), ConservativeSSI)

    def test_certifier_instances_are_per_engine(self):
        c = CommitOrderSSI()
        Engine("ssi", certifier=c)
        with pytest.raises(AssertionError):
            Engine("ssi", certifier=c)


# ------------------------------------------------------------------ soundness
class TestSoundness:
    @pytest.mark.parametrize("cert", CERTS)
    def test_committed_histories_pass_oracles(self, cert):
        for seed in range(40):
            eng = run_schedule(gen_schedule(seed), cert)
            h = eng.history
            assert is_serializable(h), (cert, seed)
            assert is_si_history(h), (cert, seed)
            if cert != "ssn":        # SSN admits beyond any SSI scheduler
                assert ssi_accepts(h), (cert, seed)

    @pytest.mark.parametrize("cert", CERTS)
    def test_write_skew_sweep_histories_serializable(self, cert):
        m, eng = run_write_skew(certifier=cert, n_clients=6,
                                contention=0.8, rounds=600, record=True)
        assert is_serializable(eng.history), cert
        # the workload's serial invariant: every on-call group keeps at
        # least one doctor (write skew would drop a group to zero)
        groups = {}
        for key, ch in eng.store.chains.items():
            g = key.split(":")[1]
            groups[g] = groups.get(g, 0) + ch.newest().value
        assert all(v >= 1 for v in groups.values()), (cert, groups)


# ----------------------------------------------------------------- admissions
class TestMonotoneAdmission:
    def test_admitted_sets_are_ordered(self):
        """admits(Conservative) => admits(CommitOrder) => admits(SSN),
        where a certifier admits a schedule iff it runs it abort-free
        (then executions are identical, so the implication is exactly
        set containment of admitted schedules)."""
        admitted = {c: 0 for c in CERTS}
        contended = 0
        for seed in range(120):
            sched = gen_schedule(seed)
            stats = {c: run_schedule(sched, c).stats for c in CERTS}
            ok = {c: stats[c]["aborts"] == 0 for c in CERTS}
            if ok["conservative"]:
                assert ok["commit-order"], seed
            if ok["commit-order"]:
                assert ok["ssn"], seed
            for c in CERTS:
                admitted[c] += ok[c]
            contended += not ok["conservative"]
        # the seed pool must exercise both branches, and the refined
        # certifiers must admit strictly more schedules overall
        assert contended and admitted["conservative"] > 0
        assert admitted["conservative"] < admitted["commit-order"] \
            < admitted["ssn"]

    def test_benign_structure_tc_last_admitted_by_refined(self):
        """U -rw-> T -rw-> V with V (the pivot's out-neighbour) committing
        LAST is provably benign (Fekete): Conservative kills the pivot
        anyway; CommitOrder and SSN must admit all three."""
        def run(cert):
            e = Engine("ssi", record=True, certifier=cert)
            u, t, v = e.begin(), e.begin(), e.begin()
            e.read(u, "a")
            e.read(t, "b")
            e.write(t, "a", 1)        # u -rw-> t
            e.write(v, "b", 1)        # t -rw-> v
            e.write(u, "z", 1)
            out = {}
            for name, x in (("u", u), ("t", t), ("v", v)):
                if x.status == Status.ABORTED:
                    out[name] = "aborted"
                    continue
                try:
                    e.commit(x)
                    out[name] = "committed"
                except SerializationFailure:
                    out[name] = "aborted"
            assert is_serializable(e.history), cert
            return out

        assert run("conservative")["t"] == "aborted"
        assert set(run("commit-order").values()) == {"committed"}
        assert set(run("ssn").values()) == {"committed"}

    def test_ssn_admits_structure_commit_order_aborts(self):
        """U -rw-> T -rw-> V with commit order V, T, U and no edge back
        into U: a fatal dangerous structure (V first) but NO cycle.  Every
        SSI certifier aborts (CommitOrder via the committed-pivot Ta
        case); SSN proves the serial order U < T < V is intact and admits
        — the strict SSN > CommitOrderSSI separation."""
        def run(cert):
            e = Engine("ssi", record=True, certifier=cert)
            u, t, v = e.begin(), e.begin(), e.begin()
            e.read(t, "x")
            e.write(v, "x", 1)        # t -rw-> v
            e.read(u, "y")
            e.write(t, "y", 1)        # u -rw-> t
            e.write(u, "z", 1)
            out = {}
            for name, x in (("v", v), ("t", t), ("u", u)):
                if x.status == Status.ABORTED:
                    out[name] = "aborted"
                    continue
                try:
                    e.commit(x)
                    out[name] = "committed"
                except SerializationFailure:
                    out[name] = "aborted"
            assert is_serializable(e.history), cert
            return out

        assert run("conservative")["t"] == "aborted"
        assert run("commit-order")["u"] == "aborted"
        assert set(run("ssn").values()) == {"committed"}

    def test_all_certifiers_abort_write_skew(self):
        for cert in CERTS:
            e = Engine("ssi", record=True, certifier=cert)
            t1, t2 = e.begin(), e.begin()
            e.read(t1, "a"), e.read(t1, "b")
            e.read(t2, "a"), e.read(t2, "b")
            e.write(t1, "a", 1)
            e.write(t2, "b", 1)
            survivors = 0
            for t in (t1, t2):
                if t.status == Status.ABORTED:
                    continue
                try:
                    e.commit(t)
                    survivors += 1
                except SerializationFailure:
                    pass
            assert survivors == 1, cert
            assert is_serializable(e.history), cert

    def test_refined_certifiers_fewer_aborts_on_contended_sweep(self):
        """The acceptance criterion at test scale: on the contended
        write-skew sweep the refined certifiers abort strictly fewer
        writers while committing at least as many transactions."""
        res = {c: run_write_skew(certifier=c, n_clients=8, contention=0.7,
                                 rounds=1200) for c in CERTS}
        cons = res["conservative"]
        for c in ("commit-order", "ssn"):
            m, e = res[c]
            assert e.stats["writer_aborts"] < cons[1].stats["writer_aborts"]
            assert m.oltp_commits >= cons[0].oltp_commits
            assert m.certifier == make_certifier(c).name


# ------------------------------------------------ WAL / RSS certifier-freedom
class TestWalInvariance:
    def _drive(self, eng):
        """A concurrent schedule with rw edges (so deps records are
        logged) but no dangerous structure — admitted abort-free by every
        certifier, hence byte-identical WAL output."""
        r1 = eng.begin()
        eng.read(r1, "x")
        w1 = eng.begin()
        eng.write(w1, "x", 1)
        eng.commit(w1)                 # r1 -rw-> w1 (vulnerable)
        eng.write(r1, "y", 2)
        eng.commit(r1)                 # logs deps: out_rw of r1
        t = eng.begin()
        eng.read(t, "y")
        eng.write(t, "z", 3)
        eng.commit(t)

    def test_wal_streams_byte_identical_across_certifiers(self):
        streams = {}
        for cert in CERTS:
            eng = Engine("ssi", certifier=cert)
            self._drive(eng)
            streams[cert] = [r.to_json() for r in eng.wal.records]
            assert eng.stats["aborts"] == 0, cert
            assert any('"deps"' in s or "deps" in s for s in streams[cert])
        assert streams["conservative"] == streams["commit-order"] \
            == streams["ssn"]

    def test_replica_rss_construction_identical_across_certifiers(self):
        """Replica-side RSS is built from begin/commit/abort + deps
        records only; under an abort-free schedule every certifier ships
        the same records, so replica state is bit-for-bit identical."""
        snaps = {}
        for cert in CERTS:
            htap = MultiNodeHTAP("ssi+rss", certifier=cert)
            self._drive(htap.primary)
            htap.ship_log()
            rep = htap.replica
            snap = rep.rss_manager.construct()
            snaps[cert] = (snap.txns, rep.applied_seq,
                           {k: [(v.commit_seq, v.writer, v.value)
                                for v in ch.versions]
                            for k, ch in rep.store.chains.items()})
        assert snaps["conservative"] == snaps["commit-order"] \
            == snaps["ssn"]


# --------------------------------------------------------- bookkeeping bounds
class TestStateDrains:
    @pytest.mark.parametrize("cert", CERTS)
    def test_certifier_state_is_gc_bounded(self, cert):
        rng = random.Random(7)
        eng = Engine("ssi", certifier=cert)
        for i in range(1200):
            t = eng.begin(read_only=rng.random() < 0.3)
            try:
                for key in rng.sample(KEYS, 2):
                    if t.read_only or rng.random() < 0.5:
                        eng.read(t, key)
                    else:
                        eng.write(t, key, i)
                eng.commit(t)
            except SerializationFailure:
                pass
            state = getattr(eng.certifier, "state", None)
            if state is not None:
                assert len(state) < 60, (cert, i, len(state))
        assert len(eng.txns) < 60


# ----------------------------------------------------------- hypothesis widen
# the deterministic seed loops above must run even without hypothesis, so
# the widened variants are defined conditionally rather than via a
# module-level importorskip
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 100_000))
    def test_property_conservative_matches_seed(seed):
        sched = gen_schedule(seed)
        a = run_schedule(sched, ConservativeSSI())
        b = run_schedule(sched, SeedPivotCertifier())
        assert a.history.ops == b.history.ops
        assert a.stats == b.stats

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 100_000), cert=st.sampled_from(CERTS))
    def test_property_all_certified_histories_serializable(seed, cert):
        eng = run_schedule(gen_schedule(seed), cert)
        assert is_serializable(eng.history)
        assert is_si_history(eng.history)
        if cert != "ssn":
            assert ssi_accepts(eng.history)

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 100_000))
    def test_property_monotone_admission(seed):
        sched = gen_schedule(seed)
        ok = {c: run_schedule(sched, c).stats["aborts"] == 0 for c in CERTS}
        assert not ok["conservative"] or ok["commit-order"]
        assert not ok["commit-order"] or ok["ssn"]
