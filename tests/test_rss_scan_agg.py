"""Fused rss_scan_agg == the per-key chain oracle, at every seam.

The tentpole contract of the device-resident OLAP executor: the fused
Pallas pass (visibility resolve + on-device reduction, `rss_scan_agg`)
must produce exactly the per-key chain-walk aggregate for every plan —
under randomized replication lag (batched shipping), RSS state GC, PRoT
pins, legacy (unstamped) WAL records, missing keys, and both snapshot
kinds (compressed RSS snapshots and SI-V watermarks).

Seeded-random stream tests always run; hypothesis widens the search when
available (same harness style as tests/test_rss_incremental.py).
"""

import random

import numpy as np
import pytest

from repro.core import PRoTManager, RSSManager, Wal
from repro.core.wal import effective_commit_seq
from repro.mvcc import Engine
from repro.mvcc.store import Store
from repro.tensorstore import (AggOp, AggPlan, ChainVersionStore, PagedMirror,
                               PagedVersionStore, ScanPlan, apply_agg,
                               finalize_agg)

KEYS = [f"stock:{i}" for i in range(8)] + ["warehouse:0", "district:0:0",
                                           "order:0:0:0", "order:0:0:1"]
OPS = [AggOp("sum", "int"), AggOp("count", "int"),
       AggOp("count_below", "int", 50), AggOp("count_below", "int", 0),
       AggOp("min", "int"), AggOp("max", "int"),
       AggOp("sum", "total"), AggOp("count", "total"),
       AggOp("min", "total"), AggOp("max", "total")]


def _rand_value(rng, key):
    if key.startswith("district"):
        return {"next_o_id": rng.randrange(40), "ytd": rng.randrange(99)}
    if key.startswith("order"):
        return {"items": [rng.randrange(9) for _ in range(rng.randrange(4))],
                "total": rng.randrange(500)}
    return rng.randrange(-100, 200)


def random_writes_wal(rng, steps=250, *, legacy_prob=0.0):
    """Engine-shaped WAL with committed writesets attached (workload-shaped
    values), deps after reader commits, optional legacy (seq=0) commits."""
    wal = Wal()
    active = []
    tid = 0
    for _ in range(steps):
        act = rng.random()
        if act < 0.35 or not active:
            tid += 1
            wal.log_begin(tid)
            active.append(tid)
        elif act < 0.8:
            t = active.pop(rng.randrange(len(active)))
            seq = 0 if rng.random() < legacy_prob else wal.head_lsn + 1
            writes = [(k, _rand_value(rng, k))
                      for k in rng.sample(KEYS, rng.randint(1, 3))]
            wal.log_commit(t, writes, seq=seq)
            if active and rng.random() < 0.5:
                wal.log_deps(t, sorted(rng.sample(
                    active, rng.randint(1, min(2, len(active))))))
        else:
            t = active.pop(rng.randrange(len(active)))
            wal.log_abort(t)
    return wal


def check_agg_stream(seed, *, gc_prob=0.0, legacy_prob=0.0, pin_prob=0.0):
    """Replay a random stream into RSSManager + paged mirror + chain store
    in randomized batches; at every round, every live snapshot must
    aggregate identically through the fused kernel and the chain oracle."""
    rng = random.Random(seed)
    wal = random_writes_wal(rng, legacy_prob=legacy_prob)
    man = RSSManager()
    prot = PRoTManager(man)
    mirror = PagedMirror(slots=64)            # retain everything: parity
    store = Store()                           # under K-slot pressure is the
    chain = ChainVersionStore(store)          # driver tests' job
    paged = PagedVersionStore(mirror)
    applied_seq = 0
    pruned_floor = 0          # chain reads below this are invalid post-prune
    pins = []
    while man.applied_lsn < wal.head_lsn:
        batch = rng.randint(1, 15)            # lagged, split shipping
        for rec in wal.tail(man.applied_lsn):
            man.apply(rec)
            mirror.apply(rec, gc_floor=prot.gc_floor_seq())
            if rec.type == "commit":
                seq = effective_commit_seq(applied_seq, rec.seq)
                for k, v in rec.writes:
                    store.chain(k).install(seq, rec.txn, v)
                applied_seq = seq
            batch -= 1
            if batch <= 0:
                break
        snap = man.construct()
        qkeys = tuple(rng.sample(KEYS, rng.randint(1, len(KEYS)))
                      + ["missing:key"])
        for s in [snap, applied_seq,
                  max(applied_seq - 3, pruned_floor)] \
                + [p[1] for p in pins]:
            for op in rng.sample(OPS, 4):
                plan = AggPlan(qkeys, op)
                want, ww = chain.execute_with_writers(plan, s)
                got, gw = paged.execute_with_writers(plan, s)
                assert want == got, (seed, op, s, want, got)
                assert ww == gw, (seed, op, s)
                # ... and both equal the host reduce of the scanned values
                assert want == apply_agg(chain.execute(ScanPlan(qkeys), s),
                                         op), (seed, op)
        if pin_prob and rng.random() < pin_prob:
            pins.append(prot.acquire())
        if pins and rng.random() < 0.3:
            prot.release(pins.pop(rng.randrange(len(pins)))[0])
        if gc_prob and rng.random() < gc_prob:
            man.gc(keep_lsn=prot.gc_floor(), keep_seq=prot.gc_floor_seq())
            store.prune(prot.gc_floor_seq())
            pruned_floor = max(pruned_floor, prot.gc_floor_seq())


# ------------------------------------------------------------ always-run
@pytest.mark.parametrize("seed", range(8))
def test_fused_agg_equals_chain_oracle(seed):
    check_agg_stream(seed)


@pytest.mark.parametrize("seed", range(8))
def test_fused_agg_equals_oracle_with_gc_and_pins(seed):
    check_agg_stream(seed, gc_prob=0.5, pin_prob=0.3)


@pytest.mark.parametrize("seed", range(6))
def test_fused_agg_equals_oracle_with_legacy_records(seed):
    check_agg_stream(seed, legacy_prob=0.3, gc_prob=0.3, pin_prob=0.2)


# ------------------------------------------------------ kernel-level parity
@pytest.mark.parametrize("seed", range(4))
def test_kernel_matches_ref(seed):
    """Pallas kernel == jnp oracle over random stores, tags, floors,
    members, thresholds — including TAG_PAD pages and empty member sets."""
    import jax.numpy as jnp
    from repro.kernels.rss_scan_agg.kernel import rss_scan_agg
    from repro.kernels.rss_scan_agg.ref import rss_scan_agg_ref

    rng = np.random.default_rng(seed)
    for P, K, E in [(8, 3, 8), (16, 4, 32), (64, 4, 16)]:
        data = np.zeros((P, K, E), np.int32)
        data[:, :, 0] = rng.integers(-1, 4, (P, K))     # tags incl. TAG_PAD
        data[:, :, 1] = rng.integers(-100, 100, (P, K))
        ts = rng.integers(0, 60, (P, K)).astype(np.int32)
        for M in (0, 7, 140):
            mem = np.sort(rng.choice(np.arange(1, 60), size=min(M, 59),
                                     replace=False)).astype(np.int32)
            for floor in (0, 23):
                for tag_main, tag_alt, thr in [(1, 0, 50), (3, -2, 10),
                                               (1, -2, 0)]:
                    args = (jnp.asarray(data), jnp.asarray(ts),
                            jnp.asarray(mem), floor, tag_main, tag_alt, thr)
                    np.testing.assert_array_equal(
                        np.asarray(rss_scan_agg(*args)),
                        np.asarray(rss_scan_agg_ref(*args)),
                        err_msg=f"{seed},{P},{M},{floor}")


def test_sum_exact_past_int32_whole_scan():
    """Device partials are int32 per block, but the host fold is exact
    Python-int arithmetic: a whole-scan sum past 2**31 must NOT wrap and
    must equal the per-key chain oracle bitwise."""
    eng = Engine("ssi")
    big = 200_000_000                      # 16 pages * 2e8 = 3.2e9 > 2**31
    t = eng.begin()
    for i in range(16):
        eng.write(t, f"big:{i:02d}", big)
    eng.commit(t)
    mirror = PagedMirror()
    mirror.catch_up(eng.wal)
    keys = tuple(f"big:{i:02d}" for i in range(16))
    plan = AggPlan(keys, AggOp("sum", "int"))
    chain = ChainVersionStore(eng.store).execute(plan, eng.seq)
    fused = PagedVersionStore(mirror).execute(plan, eng.seq)
    assert chain == fused == 16 * big      # 3_200_000_000, no int32 wrap


def test_finalize_agg_empty_set_sentinels():
    raw = [0, 0, 0, 2 ** 31 - 1, -(2 ** 31)]    # kernel out, nothing valid
    assert finalize_agg(raw, AggOp("min", "int")) == 0
    assert finalize_agg(raw, AggOp("max", "int")) == 0
    assert finalize_agg(raw, AggOp("sum", "int")) == 0


def test_mirror_dense_page_range_fast_path():
    """A contiguous key run hits the slice path of jnp_store_for; a
    shuffled/holey run takes the gather — same aggregate either way."""
    from repro.tensorstore.paged import as_page_range

    eng = Engine("ssi")
    rng = random.Random(3)
    keys = [f"s:{i:02d}" for i in range(16)]   # lex order == page order
    t = eng.begin()
    for k in keys:
        eng.write(t, k, rng.randrange(100))
    eng.commit(t)
    mirror = PagedMirror()
    mirror.catch_up(eng.wal)
    dense = mirror.page_index(keys)
    assert as_page_range(dense) == (0, 16)
    shuffled = list(keys)
    rng.shuffle(shuffled)
    assert as_page_range(mirror.page_index(shuffled + ["nope"])) is None
    paged = PagedVersionStore(mirror)
    chain = ChainVersionStore(eng.store)
    for qkeys in (keys, shuffled + ["nope"]):
        plan = AggPlan(tuple(qkeys), AggOp("sum", "int"))
        assert paged.execute(plan, eng.seq) == chain.execute(plan, eng.seq)


# ------------------------------------------------------------ engine seams
class TestEngineAgg:
    def test_agg_records_read_set_like_scan(self):
        eng = Engine("ssi", record=True)
        t0 = eng.begin()
        eng.write(t0, "a", 7)
        eng.write(t0, "b", {"items": [], "total": 3})
        eng.commit(t0)
        t = eng.begin(read_only=True, skip_siread=True)
        got = eng.execute(t, AggPlan(("a", "b", "c"), AggOp("sum", "int")))
        assert got == 7                      # 7 + initial c=0; b is a dict
        assert t.reads == {"a": t0.tid, "b": t0.tid, "c": 0}
        reads = [op for op in eng.history.ops
                 if op.kind == "r" and op.txn == t.tid]
        assert len(reads) == 3

    def test_ssi_tracked_agg_falls_back_to_per_key_reads(self):
        eng = Engine("ssi")
        t = eng.begin(read_only=True)
        eng.execute(t, AggPlan(("a", "b"), AggOp("count", "int")))
        assert t.tid in eng.siread.get("a", set())
        assert t.tid in eng.siread.get("b", set())

    def test_agg_sees_own_writes(self):
        eng = Engine("si")
        t = eng.begin()
        eng.write(t, "k1", 42)
        assert eng.execute(
            t, AggPlan(("k0", "k1"), AggOp("sum", "int"))) == 42
        assert eng.execute(
            t, AggPlan(("k0", "k1"), AggOp("count_below", "int", 10))) == 1

    def test_rss_agg_has_no_siread_side_effects(self):
        from repro.core.replica import RssSnapshot
        eng = Engine("ssi")
        t = eng.begin(read_only=True, rss=RssSnapshot(0, frozenset()))
        eng.execute(t, AggPlan(("a", "b"), AggOp("sum", "int")))
        assert "a" not in eng.siread and "b" not in eng.siread


# ------------------------------------------------------------- hypothesis
try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), gc=st.booleans(), legacy=st.booleans())
    def test_fused_agg_equals_oracle_hypothesis(seed, gc, legacy):
        check_agg_stream(seed, gc_prob=0.5 if gc else 0.0,
                         legacy_prob=0.3 if legacy else 0.0, pin_prob=0.2)
except ImportError:                      # pragma: no cover
    pass
