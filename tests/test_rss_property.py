"""Property-based validation of the paper's central claims.

Strategy: drive random concurrent workloads through the executable SSI
engine (which emits Adya histories), then check at EVERY prefix that
Algorithm 1's RSS — constructed from only the information the WAL carries at
that prefix — satisfies Definition 4.1 against the FINAL history's
dependency graph (i.e. it is safe against all dependencies that appear
later: the "prophetic" guarantee that makes reads wait-free), and that
adding a PRoT reader keeps the history serializable (Theorem 4.4).
"""

import random

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (construct_rss, construct_rss_ssi, clear_set,
                        is_rss, is_serializable, ssi_accepts,
                        vulnerable_edges, with_protected_reader)
from repro.mvcc import Engine, SerializationFailure, Status

KEYS = ["a", "b", "c", "d", "e"]


def run_random_workload(seed: int, n_clients: int = 4, n_rounds: int = 60,
                        read_only_prob: float = 0.3):
    """Interleaved random transactions through the SSI engine; returns the
    engine (history recorded)."""
    rng = random.Random(seed)
    eng = Engine("ssi", record=True)
    sessions = [None] * n_clients
    for _ in range(n_rounds):
        i = rng.randrange(n_clients)
        t = sessions[i]
        if t is None or t.status != Status.ACTIVE:
            sessions[i] = eng.begin(read_only=rng.random() < read_only_prob)
            continue
        try:
            act = rng.random()
            if act < 0.4:
                eng.read(t, rng.choice(KEYS))
            elif act < 0.7 and not t.read_only:
                eng.write(t, rng.choice(KEYS), rng.randrange(100))
            else:
                eng.commit(t)
                sessions[i] = None
        except SerializationFailure:
            sessions[i] = None
    for t in sessions:       # settle stragglers
        if t is not None and t.status == Status.ACTIVE:
            try:
                eng.commit(t)
            except SerializationFailure:
                pass
    return eng


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_ssi_engine_histories_are_serializable(seed):
    eng = run_random_workload(seed)
    h = eng.history
    assert is_serializable(h), h
    assert ssi_accepts(h), h


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_algorithm1_is_rss_against_the_future(seed):
    """RSS built at any prefix (from prefix-local info only) must satisfy
    Def 4.1 versus the FINAL dependency graph."""
    eng = run_random_workload(seed)
    h = eng.history
    final_committed = h.committed
    for n in range(0, len(h.ops) + 1, 3):
        p = h.prefix(n)
        P = construct_rss(p)
        assert P <= final_committed
        assert is_rss(h, P), (n, P)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), prefix_frac=st.floats(0.2, 1.0))
def test_prot_reader_keeps_serializability(seed, prefix_frac):
    """Theorem 4.4 end-to-end: a protected reader over Algorithm 1's RSS
    never creates a cycle, at any construction point."""
    eng = run_random_workload(seed)
    h = eng.history
    n = int(len(h.ops) * prefix_frac)
    P = construct_rss(h.prefix(n))
    h2 = with_protected_reader(h, P, KEYS, txn_id=9_999)
    assert is_serializable(h2), (n, P)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_algorithm1_uses_only_wal_information(seed):
    """construct_rss (from the history) must agree with construct_rss_ssi
    fed only begin/commit events + concurrent-rw edges — what the WAL ships."""
    eng = run_random_workload(seed)
    h = eng.history
    for n in range(0, len(h.ops) + 1, 5):
        p = h.prefix(n)
        edges = [(v.src, v.dst) for v in vulnerable_edges(p)]
        P_wal = construct_rss_ssi(clear_set(p), p.committed, edges)
        assert P_wal == construct_rss(p)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_rss_contains_clear(seed):
    eng = run_random_workload(seed)
    h = eng.history
    for n in range(0, len(h.ops) + 1, 7):
        p = h.prefix(n)
        assert clear_set(p) <= construct_rss(p)
