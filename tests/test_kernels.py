"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.version_gather.kernel import version_gather
from repro.kernels.version_gather.ref import version_gather_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.wkv_scan.kernel import wkv_scan
from repro.kernels.wkv_scan.ref import wkv_scan_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


class TestVersionGather:
    @pytest.mark.parametrize("P,K,E", [(8, 2, 256), (32, 4, 512),
                                       (16, 8, 128), (64, 3, 1024)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, P, K, E, dtype):
        key = jax.random.PRNGKey(P * K)
        data = jax.random.normal(key, (P, K, E)).astype(dtype)
        ts = jax.random.randint(key, (P, K), 0, 50)
        for wm in (0, 13, 49):
            out = version_gather(data, ts, wm,
                                 block_pages=min(8, P),
                                 block_elems=min(256, E))
            ref = version_gather_ref(data, ts, wm)
            np.testing.assert_allclose(np.asarray(out, np.float32),
                                       np.asarray(ref, np.float32))

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 1000), wm=st.integers(0, 60))
    def test_property_matches_per_page_scan(self, seed, wm):
        """Against an independent per-page python oracle."""
        key = jax.random.PRNGKey(seed)
        P, K, E = 16, 4, 128
        data = jax.random.normal(key, (P, K, E), jnp.float32)
        ts = jax.random.randint(jax.random.fold_in(key, 1), (P, K), 0, 50)
        out = np.asarray(version_gather(data, ts, wm))
        tsn, datan = np.asarray(ts), np.asarray(data)
        for p in range(P):
            vis = [k for k in range(K) if tsn[p, k] <= wm]
            best = max(vis, key=lambda k: (tsn[p, k], -k)) if vis else \
                int(np.argmax(np.where(tsn[p] <= wm, tsn[p], -1)))
            np.testing.assert_allclose(out[p], datan[p, best])


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,K,S,hd", [(1, 4, 4, 128, 64),
                                            (2, 8, 2, 256, 64),
                                            (1, 6, 6, 192, 32),
                                            (2, 4, 1, 128, 128)])
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                               (False, 0)])
    def test_shapes(self, B, H, K, S, hd, causal, window):
        key = jax.random.PRNGKey(B * S)
        q = jax.random.normal(key, (B, H, S, hd), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, K, S, hd),
                              jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, K, S, hd),
                              jnp.float32)
        o = flash_attention(q, k, v, causal=causal, window=window,
                            block_q=64, block_k=64)
        r = attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(o, r, **TOL[jnp.float32])

    def test_bf16(self):
        key = jax.random.PRNGKey(7)
        q = jax.random.normal(key, (1, 4, 128, 64)).astype(jnp.bfloat16)
        k = jax.random.normal(key, (1, 2, 128, 64)).astype(jnp.bfloat16)
        v = jax.random.normal(key, (1, 2, 128, 64)).astype(jnp.bfloat16)
        o = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
        r = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32),
                                   **TOL[jnp.bfloat16])

    def test_matches_model_attention_path(self):
        """The kernel agrees with the model's chunked-flash XLA path."""
        from repro.models.layers import flash_attention_xla
        key = jax.random.PRNGKey(3)
        q = jax.random.normal(key, (2, 128, 8, 64), jnp.float32)   # BSHD
        k = jax.random.normal(jax.random.fold_in(key, 1),
                              (2, 128, 2, 64), jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2),
                              (2, 128, 2, 64), jnp.float32)
        xla = flash_attention_xla(q, k, v, causal=True, chunk=64)
        pal = flash_attention(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              causal=True, block_q=64, block_k=64)
        np.testing.assert_allclose(xla.transpose(0, 2, 1, 3), pal,
                                   rtol=2e-5, atol=2e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("B,H,K,T,hd", [(2, 8, 2, 512, 64),
                                            (1, 4, 4, 256, 128),
                                            (4, 4, 1, 1024, 64)])
    def test_shapes(self, B, H, K, T, hd):
        key = jax.random.PRNGKey(T)
        q = jax.random.normal(key, (B, H, hd), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, K, T, hd),
                              jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, K, T, hd),
                              jnp.float32)
        for vl in (1, T // 3, T):
            o = decode_attention(q, k, v, vl, block_t=128)
            r = decode_attention_ref(q, k, v, vl)
            np.testing.assert_allclose(o, r, **TOL[jnp.float32])


class TestWkvScan:
    @pytest.mark.parametrize("BH,T,N,chunk", [(2, 128, 64, 32),
                                              (4, 256, 64, 128),
                                              (1, 64, 32, 64)])
    def test_shapes(self, BH, T, N, chunk):
        key = jax.random.PRNGKey(T + N)
        r = jax.random.normal(key, (BH, T, N), jnp.float32) * 0.5
        k = jax.random.normal(jax.random.fold_in(key, 1), (BH, T, N)) * 0.5
        v = jax.random.normal(jax.random.fold_in(key, 2), (BH, T, N))
        w_log = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3),
                                           (BH, T, N)) - 2)
        u = jax.random.normal(jax.random.fold_in(key, 4), (BH, N)) * 0.1
        o, S = wkv_scan(r, k, v, w_log, u, chunk=chunk)
        orf, Srf = wkv_scan_ref(r, k, v, w_log, u)
        np.testing.assert_allclose(o, orf, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(S, Srf, rtol=1e-4, atol=1e-4)

    def test_matches_model_rwkv_layer_scan(self):
        """Kernel recurrence == the model's associative-scan WKV."""
        from repro.models.layers import _wkv_chunked
        key = jax.random.PRNGKey(9)
        B, T, H, N = 2, 64, 2, 32
        shp = (B, T, H, N)
        r = jax.random.normal(key, shp) * 0.5
        k = jax.random.normal(jax.random.fold_in(key, 1), shp) * 0.5
        v = jax.random.normal(jax.random.fold_in(key, 2), shp)
        w_log = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), shp)
                         - 2)
        u = jax.random.normal(jax.random.fold_in(key, 4), (H, N)) * 0.1
        o_model, S_model = _wkv_chunked(r, k, v, w_log, u, chunk=16)
        flat = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, T, N)
        uf = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, N)
        o_k, S_k = wkv_scan(flat(r), flat(k), flat(v), flat(w_log), uf,
                            chunk=32)
        np.testing.assert_allclose(
            o_k.reshape(B, H, T, N).transpose(0, 2, 1, 3), o_model,
            rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(S_k.reshape(B, H, N, N), S_model,
                                   rtol=1e-4, atol=1e-4)


class TestSsmScan:
    @pytest.mark.parametrize("Bb,T,Di,N,chunk", [(2, 64, 128, 8, 32),
                                                 (1, 128, 256, 16, 128)])
    def test_matches_oracle(self, Bb, T, Di, N, chunk):
        from repro.kernels.ssm_scan.kernel import ssm_scan
        from repro.kernels.ssm_scan.ref import ssm_scan_ref
        key = jax.random.PRNGKey(T + Di)
        u = jax.random.normal(key, (Bb, T, Di), jnp.float32)
        dt = jax.nn.softplus(
            jax.random.normal(jax.random.fold_in(key, 1), (Bb, T, Di)) - 1)
        B = jax.random.normal(jax.random.fold_in(key, 2), (Bb, T, N))
        C = jax.random.normal(jax.random.fold_in(key, 3), (Bb, T, N))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 4), (Di, N)))
        D = jax.random.normal(jax.random.fold_in(key, 5), (Di,))
        y, h = ssm_scan(u, dt, B, C, A, D, chunk=chunk, block_di=64)
        yr, hr = ssm_scan_ref(u, dt, B, C, A, D)
        np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(h, hr, rtol=2e-4, atol=2e-4)

    def test_matches_model_mamba_chunked(self):
        """Kernel == the model's associative-scan formulation."""
        from repro.kernels.ssm_scan.kernel import ssm_scan
        from repro.models.layers import _mamba_scan_chunked
        key = jax.random.PRNGKey(11)
        Bb, T, Di, N = 2, 64, 64, 8
        u = jax.random.normal(key, (Bb, T, Di), jnp.float32)
        dt = jax.nn.softplus(
            jax.random.normal(jax.random.fold_in(key, 1), (Bb, T, Di)) - 1)
        B = jax.random.normal(jax.random.fold_in(key, 2), (Bb, T, N))
        C = jax.random.normal(jax.random.fold_in(key, 3), (Bb, T, N))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 4), (Di, N)))
        y_model, h_model = _mamba_scan_chunked(u, dt, B, C, A, chunk=32)
        y_k, h_k = ssm_scan(u, dt, B, C, A, jnp.zeros((Di,)), chunk=32,
                            block_di=64)
        np.testing.assert_allclose(y_k, y_model, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(h_k, h_model, rtol=2e-4, atol=2e-4)


class TestInterpretSwitch:
    """REPRO_INTERPRET is the ONE switch between interpret-mode validation
    and TPU-compiled execution for every kernel op (`repro.kernels.config`)."""

    def test_default_is_interpret(self, monkeypatch):
        from repro.kernels.config import default_interpret, resolve_interpret
        monkeypatch.delenv("REPRO_INTERPRET", raising=False)
        assert default_interpret() is True
        assert resolve_interpret(None) is True

    @pytest.mark.parametrize("val,want", [
        ("1", True), ("true", True), ("yes", True), ("", True),
        ("0", False), ("false", False), ("No", False), ("OFF", False),
    ])
    def test_env_values(self, monkeypatch, val, want):
        from repro.kernels.config import default_interpret
        monkeypatch.setenv("REPRO_INTERPRET", val)
        assert default_interpret() is want

    def test_explicit_argument_wins_over_env(self, monkeypatch):
        from repro.kernels.config import resolve_interpret
        monkeypatch.setenv("REPRO_INTERPRET", "0")
        assert resolve_interpret(True) is True
        assert resolve_interpret(None) is False

    def test_ops_run_through_the_switch(self, monkeypatch):
        """An op called with interpret=None resolves through the env switch
        and still matches its oracle (interpret mode on this CPU)."""
        from repro.kernels.rss_scan_agg.ops import (fold_partials,
                                                    snapshot_agg_members)
        from repro.kernels.rss_scan_agg.ref import rss_scan_agg_ref
        monkeypatch.setenv("REPRO_INTERPRET", "1")
        rng = np.random.default_rng(0)
        data = np.zeros((8, 2, 8), np.int32)
        data[:, :, 0] = 1
        data[:, :, 1] = rng.integers(0, 50, (8, 2))
        ts = rng.integers(0, 9, (8, 2)).astype(np.int32)
        store = {"data": jnp.asarray(data), "ts": jnp.asarray(ts)}
        mem = jnp.asarray([], jnp.int32)
        out = snapshot_agg_members(store, mem, 5, tag_main=1, tag_alt=0)
        ref = fold_partials(
            rss_scan_agg_ref(store["data"], store["ts"], mem, 5, 1, 0))
        assert out == ref
