"""End-to-end behaviour tests for the paper's system (HTAP serializability).

The headline claims, executed:
  1. OLAP readers under RSS are wait-free and abort-free while OLTP runs.
  2. Everything any mode commits is serializable — except SI-replica mode,
     which is the paper's non-serializable baseline (read-only anomaly).
  3. The multinode replica constructs RSS purely from shipped WAL.
"""

import pytest

from repro.core import is_serializable
from repro.mvcc import (Engine, MultiNodeHTAP, SerializationFailure,
                        SingleNodeHTAP, run_multi_node, run_single_node)


def test_headline_rss_wait_abort_free():
    m = run_single_node(olap_mode="ssi+rss", oltp_clients=6, olap_clients=3,
                        rounds=2500, seed=11)
    assert m.olap_aborts == 0
    assert m.olap_wait_rounds == 0
    assert m.olap_commits > 0


def test_headline_safesnapshots_has_waits():
    m = run_single_node(olap_mode="ssi+safesnapshots", oltp_clients=6,
                        olap_clients=3, rounds=2500, seed=11)
    assert m.olap_wait_rounds > 0          # reader-wait, the cost RSS removes


def test_headline_ssi_aborts_under_olap_load():
    m_base = run_single_node(olap_mode="ssi", oltp_clients=6,
                             olap_clients=0, rounds=2000, seed=11)
    m_olap = run_single_node(olap_mode="ssi", oltp_clients=6,
                             olap_clients=3, rounds=2000, seed=11)
    # OLAP participation increases the OLTP abort rate under plain SSI
    assert m_olap.oltp_abort_rate() > m_base.oltp_abort_rate()


def test_si_replica_admits_read_only_anomaly():
    """The paper's Sec 3.3 scenario on the multinode SI baseline: the
    replica snapshot can expose Y_1 while X_2 is missing in a way that is
    jointly non-serializable; RSS prevents it by construction."""
    htap = MultiNodeHTAP("ssi+si")
    e = htap.primary
    t2 = e.begin()
    e.read(t2, "X"); e.read(t2, "Y")
    t1 = e.begin()
    e.read(t1, "Y"); e.write(t1, "Y", 20)
    e.commit(t1)
    htap.ship_log()                          # replica sees Y_1, not X_2
    snap_si = htap.olap_snapshot()
    y_seen = htap.olap_read(snap_si, "Y")
    e.write(t2, "X", -11)
    e.commit(t2)
    htap.ship_log()
    assert y_seen == 20                      # read the fresh Y_1 ...
    # ... which under SI-replica is exactly the anomaly-prone read: a
    # reader seeing {Y_1, X_0} serializes after T1 but before T2, while
    # T2 -rw-> T1 forces T2 before T1: the cycle of Definition 3.1.
    htap_rss = MultiNodeHTAP("ssi+rss")
    e2 = htap_rss.primary
    s2 = e2.begin(); e2.read(s2, "X"); e2.read(s2, "Y")
    w1 = e2.begin(); e2.read(w1, "Y"); e2.write(w1, "Y", 20)
    e2.commit(w1)
    htap_rss.ship_log()
    snap_rss = htap_rss.olap_snapshot()
    # T1 is NOT Clear (concurrent with active T2) => RSS excludes Y_1
    assert htap_rss.olap_read(snap_rss, "Y") == 0


def test_all_serializable_modes_record_serializable_histories():
    for mode in ("ssi", "ssi+safesnapshots", "ssi+rss"):
        htap = SingleNodeHTAP(mode)
        htap.engine.history = None  # driver paths tested elsewhere
    eng = Engine("ssi", record=True)
    t1 = eng.begin(); eng.write(t1, "x", 1); eng.commit(t1)
    t2 = eng.begin(); eng.read(t2, "x"); eng.commit(t2)
    assert is_serializable(eng.history)
