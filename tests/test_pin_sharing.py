"""Refcounted snapshot-pin sharing: PRoT readers at the same horizon share
ONE pin-table entry (one pinned RssSnapshot), `gc_floor_seq()` semantics
unchanged, and the floor never regresses while any sharer is live."""

import random

import pytest

from repro.core import PRoTManager, RSSManager, Wal


def _commit(wal, tid):
    wal.log_begin(tid)
    wal.log_commit(tid, seq=wal.head_lsn + 1)


def test_same_horizon_readers_share_one_pin_entry():
    wal = Wal()
    for t in range(1, 6):
        _commit(wal, t)
    man = RSSManager()
    man.catch_up(wal)
    man.construct()
    prot = PRoTManager(man)
    handles = [prot.acquire() for _ in range(100)]
    snaps = {id(s) for _, s in handles}
    assert len(snaps) == 1               # every sharer sees ONE snapshot
    assert prot.pinned == 1              # one pin-table entry, not 100
    assert prot.readers == 100
    for rid, _ in handles[:99]:
        prot.release(rid)
    assert prot.pinned == 1              # last sharer still holds the pin
    prot.release(handles[99][0])
    assert prot.pinned == 0 and prot.readers == 0


def test_distinct_horizons_pin_distinct_entries():
    wal = Wal()
    man = RSSManager()
    prot = PRoTManager(man)
    rids = []
    for t in range(1, 4):
        _commit(wal, t)
        man.catch_up(wal)
        man.construct()
        rids.append(prot.acquire()[0])
        rids.append(prot.acquire()[0])   # same horizon: shares
    assert prot.pinned == 3 and prot.readers == 6
    assert prot.gc_floor() == min(lsn for lsn in prot._pins)
    for rid in rids:
        prot.release(rid)
    assert prot.pinned == 0


def test_release_is_idempotent_and_unknown_safe():
    man = RSSManager()
    prot = PRoTManager(man)
    rid, _ = prot.acquire()
    prot.release(rid)
    prot.release(rid)                    # double release: no-op
    prot.release(12345)                  # unknown reader: no-op
    assert prot.pinned == 0


@pytest.mark.parametrize("seed", range(8))
def test_floor_never_regresses_while_sharers_live(seed):
    """Property: over random interleavings of commits / refreshes /
    shared acquires / releases, `gc_floor_seq()` (and `gc_floor()`) are
    monotone non-decreasing — releasing one sharer of a multi-reader
    horizon never drops the floor, and pins only ever advance it."""
    rng = random.Random(seed)
    wal = Wal()
    man = RSSManager()
    prot = PRoTManager(man)
    tid = 0
    live = []
    floor_seq = prot.gc_floor_seq()
    floor_lsn = prot.gc_floor()
    for _ in range(400):
        act = rng.random()
        if act < 0.4:
            tid += 1
            _commit(wal, tid)
        elif act < 0.6:
            man.catch_up(wal)
            man.construct()
        elif act < 0.8 or not live:
            live.append(prot.acquire()[0])
        else:
            prot.release(live.pop(rng.randrange(len(live))))
        if live:                         # floor monotone while pinned
            assert prot.gc_floor_seq() >= floor_seq
            assert prot.gc_floor() >= floor_lsn
        floor_seq = prot.gc_floor_seq()
        floor_lsn = prot.gc_floor()
        assert prot.pinned <= prot.readers
        assert prot.pinned <= len({prot._readers[r] for r in live}) \
            if live else prot.pinned == 0


# ------------------------------------------------------------- hypothesis
try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_floor_never_regresses_hypothesis(seed):
        test_floor_never_regresses_while_sharers_live(seed)
except ImportError:                      # pragma: no cover
    pass
