"""Whole-batch plan fusion + shape-dispatched kernel selection.

The tentpole contracts of this PR:

  * a `BatchPlan` of N same-horizon aggregate plans produces EXACTLY the
    N unbatched results and the chain oracle's — at the mirror, at both
    HTAP facades, and through the driver's round-level batcher — while
    costing ONE fused aggregate dispatch (and one/two pallas calls,
    depending on the strategy the shape dispatcher picks);
  * `select_grouped_mode` routes (P, G, n_plans) shapes between host /
    flat / chunked, overridable per call or via REPRO_GROUPED_MODE;
  * the int32 overflow guards hold: pinned blocks raise, auto blocks
    shrink, chunked demotes to flat when the whole-scan bound fails —
    results stay exact throughout.
"""

import random

import pytest

from repro.kernels.rss_scan_agg import ops as kops
from repro.mvcc import Engine, MultiNodeHTAP, SingleNodeHTAP
from repro.mvcc.driver import run_multi_node, run_single_node
from repro.mvcc.workload import Scale, load_initial
from repro.tensorstore import (AggOp, AggPlan, BatchPlan, ChainVersionStore,
                               GroupByPlan, MultiAggPlan, PagedMirror,
                               PagedVersionStore, ScanPlan, apply_plan,
                               plan_keys)

OPS = (AggOp("sum", "int"), AggOp("count", "int"),
       AggOp("count_below", "int", 40), AggOp("min", "int"),
       AggOp("max", "int"), AggOp("sum", "total"))


def _loaded_engine(n=24, seed=0):
    eng = Engine("ssi")
    rng = random.Random(seed)
    t = eng.begin()
    for i in range(n):
        eng.write(t, f"k:{i}", rng.randrange(-80, 120))
    for i in range(4):
        eng.write(t, f"o:{i}", {"items": [], "total": rng.randrange(200)})
    eng.commit(t)
    return eng


def _mirror_for(eng):
    mirror = PagedMirror()
    mirror.catch_up(eng.wal)
    return mirror


def _plans(rng, n, pool):
    out = []
    for _ in range(n):
        kind = rng.randrange(3)
        ops = tuple(rng.sample(OPS, rng.randint(1, 3)))
        if kind == 0:
            out.append(AggPlan(tuple(rng.sample(pool, 5)), ops[0]))
        elif kind == 1:
            out.append(MultiAggPlan(tuple(rng.sample(pool, 6)), ops))
        else:
            groups = tuple(tuple(rng.sample(pool, rng.randint(0, 4)))
                           for _ in range(rng.randint(1, 4)))
            out.append(GroupByPlan(groups, ops))
    return out


# --------------------------------------------------------- mirror-level fusion
class TestMirrorBatchFusion:
    @pytest.mark.parametrize("mode", [None, "flat", "chunked"])
    @pytest.mark.parametrize("seed", range(3))
    def test_batch_equals_unbatched_and_oracle(self, seed, mode):
        eng = _loaded_engine(seed=seed)
        mirror = _mirror_for(eng)
        mirror.grouped_mode = mode
        paged = PagedVersionStore(mirror)
        chain = ChainVersionStore(eng.store)
        rng = random.Random(seed)
        pool = [f"k:{i}" for i in range(24)] + [f"o:{i}" for i in range(4)] \
            + ["missing:x"]
        plans = _plans(rng, 4, pool)
        batch = BatchPlan(tuple(plans))
        got, gw = paged.execute_with_writers(batch, eng.seq)
        want, ww = chain.execute_with_writers(batch, eng.seq)
        assert tuple(got) == tuple(want)
        assert gw == ww
        # exactly the per-plan unbatched results, in order
        for plan, r in zip(plans, got):
            assert r == paged.execute(plan, eng.seq)
            assert r == chain.execute(plan, eng.seq)

    def test_single_plan_batch_equals_unbatched(self):
        eng = _loaded_engine()
        paged = PagedVersionStore(_mirror_for(eng))
        plan = MultiAggPlan(tuple(f"k:{i}" for i in range(10)), OPS[:3])
        (only,), writers = paged.execute_with_writers(
            BatchPlan((plan,)), eng.seq)
        assert only == paged.execute(plan, eng.seq)
        assert writers == paged.execute_with_writers(plan, eng.seq)[1]

    def test_batch_costs_one_fused_dispatch(self):
        eng = _loaded_engine()
        mirror = _mirror_for(eng)
        paged = PagedVersionStore(mirror)
        plans = tuple(AggPlan(tuple(f"k:{i + 4 * j}" for i in range(4)),
                              AggOp("sum", "int")) for j in range(4))
        before = dict(mirror.exec_stats)
        paged.execute(BatchPlan(plans), eng.seq)
        assert mirror.exec_stats["agg_dispatches"] - \
            before["agg_dispatches"] == 1
        assert mirror.exec_stats["batches"] - before["batches"] == 1
        assert mirror.exec_stats["batched_plans"] - \
            before["batched_plans"] == 4

    @pytest.mark.parametrize("mode,calls", [("flat", 1), ("chunked", 2)])
    def test_batch_pallas_call_count_per_mode(self, mode, calls):
        """Flat = one fused launch for the whole batch; chunked = two
        (select + tiled reduce), never one per plan."""
        eng = _loaded_engine()
        mirror = _mirror_for(eng)
        mirror.grouped_mode = mode
        paged = PagedVersionStore(mirror)
        plans = tuple(MultiAggPlan(tuple(f"k:{i + 6 * j}" for i in range(6)),
                                   (AggOp("sum", "int"),
                                    AggOp("count", "int")))
                      for j in range(4))
        kops.reset_launch_stats()
        paged.execute(BatchPlan(plans), eng.seq)
        assert kops.LAUNCH_STATS["pallas_calls"] == calls
        assert kops.LAUNCH_STATS["dispatches"] == 1
        assert kops.LAUNCH_STATS[mode] == 1

    def test_batch_rejects_scan_plans(self):
        with pytest.raises(AssertionError):
            BatchPlan((ScanPlan(("a",)),))
        with pytest.raises(AssertionError):
            BatchPlan(())


# --------------------------------------------------------------- facade level
class TestFacadeBatch:
    def _single(self):
        htap = SingleNodeHTAP("ssi+rss", paged=True, check_scans=True,
                              reserve_keys=Scale().key_families())
        load_initial(htap.engine, Scale())
        htap.refresh_rss()
        return htap

    def test_single_node_batch_equals_unbatched_and_records_reads(self):
        htap = self._single()
        keys = Scale().all_stock_keys()
        txns = [htap.olap_begin() for _ in range(4)]
        assert len({t.rss.lsn for t in txns}) == 1    # PRoT pin sharing
        plans = [MultiAggPlan(tuple(keys[8 * i:8 * i + 8]), OPS[:3])
                 for i in range(4)]
        results = htap.olap_execute_batch(list(zip(txns, plans)))
        for t, p, r in zip(txns, plans, results):
            t2 = htap.olap_begin()
            assert r == htap.olap_execute(t2, p)
            assert set(t.reads) == set(plan_keys(p))  # read set recorded
            htap.olap_commit(t2)
        for t in txns:
            htap.olap_commit(t)

    def test_single_node_mixed_horizons_fall_back(self):
        htap = self._single()
        t1 = htap.olap_begin()
        t2 = htap.engine.begin()
        htap.engine.write(t2, "stock:0:0", 999)
        htap.engine.commit(t2)
        htap.refresh_rss()
        t3 = htap.olap_begin()
        if t1.rss.lsn == t3.rss.lsn:        # horizons happened to match
            pytest.skip("no horizon split to exercise")
        plan = AggPlan(("stock:0:0", "stock:0:1"), AggOp("sum", "int"))
        before = htap.mirror.exec_stats["batches"]
        r1, r3 = htap.olap_execute_batch([(t1, plan), (t3, plan)])
        assert htap.mirror.exec_stats["batches"] == before  # no fused batch
        assert r1 == htap.olap_execute(t1, plan)
        assert r3 == htap.olap_execute(t3, plan)

    def test_multi_node_batch_equals_unbatched(self):
        htap = MultiNodeHTAP("ssi+rss", paged_olap=True, check_scans=True,
                             n_replicas=2,
                             reserve_keys=Scale().key_families())
        load_initial(htap.primary, Scale())
        htap.ship_log()
        keys = Scale().all_stock_keys()
        snaps = [htap.olap_snapshot() for _ in range(3)]
        plans = [GroupByPlan((tuple(keys[:6]), tuple(keys[6:12])),
                             (AggOp("sum", "int"), AggOp("max", "int")))
                 for _ in range(3)]
        entries = list(zip(snaps, plans))
        results = htap.olap_execute_batch(entries)
        for (h, p), r in zip(entries, results):
            assert r == htap.olap_execute(h, p)
        for h in snaps:
            htap.olap_release(h)


# --------------------------------------------------------------- driver level
class TestDriverBatching:
    def test_single_node_run_batches_and_stays_correct(self):
        m = run_single_node(olap_mode="ssi+rss", oltp_clients=4,
                            olap_clients=4, rounds=800, seed=11,
                            olap_scan=True, paged_olap=True,
                            check_scans=True, batch_plans=True)
        assert m.olap_batch_dispatches > 0
        assert m.plans_per_dispatch() > 1.0
        assert m.olap_agg_dispatches > 0
        assert m.olap_mode_flat + m.olap_mode_chunked + m.olap_mode_host > 0

    def test_multi_node_run_batches_and_stays_correct(self):
        m = run_multi_node(olap_mode="ssi+rss", oltp_clients=4,
                           olap_clients=4, rounds=600, seed=11,
                           olap_scan=True, paged_olap=True,
                           check_scans=True, n_replicas=2,
                           batch_plans=True)
        assert m.olap_batch_dispatches > 0
        assert m.plans_per_dispatch() > 1.0

    def test_batched_run_matches_unbatched_outputs(self):
        kw = dict(olap_mode="ssi+rss", oltp_clients=3, olap_clients=2,
                  rounds=600, seed=5, olap_scan=True, paged_olap=True)
        a = run_single_node(**kw, batch_plans=False)
        b = run_single_node(**kw, batch_plans=True)
        assert a.olap_outputs == b.olap_outputs   # same results, fewer
        assert a.oltp_commits == b.oltp_commits   # launches


# ----------------------------------------------------------- shape dispatcher
class TestSelectGroupedMode:
    def test_shape_heuristic(self):
        assert kops.select_grouped_mode(32, 4, 1) == "host"
        assert kops.select_grouped_mode(32, 4, 2) == "flat"   # batches fuse
        assert kops.select_grouped_mode(
            4096, kops.FLAT_MODE_MAX_GROUPS, 1) == "flat"
        assert kops.select_grouped_mode(
            4096, kops.FLAT_MODE_MAX_GROUPS + 1, 1) == "chunked"
        assert kops.select_grouped_mode(4096, 256, 4) == "chunked"

    def test_override_wins(self):
        assert kops.select_grouped_mode(32, 4, 1,
                                        override="chunked") == "chunked"
        with pytest.raises(AssertionError):
            kops.select_grouped_mode(32, 4, 1, override="nope")

    def test_env_override(self, monkeypatch):
        monkeypatch.setenv(kops.GROUPED_MODE_ENV, "flat")
        assert kops.select_grouped_mode(32, 4, 1) == "flat"
        monkeypatch.setenv(kops.GROUPED_MODE_ENV, "auto")
        assert kops.select_grouped_mode(32, 4, 1) == "host"

    def test_mirror_honors_env_override(self, monkeypatch):
        eng = _loaded_engine()
        monkeypatch.setenv(kops.GROUPED_MODE_ENV, "chunked")
        mirror = _mirror_for(eng)
        paged = PagedVersionStore(mirror)
        plan = GroupByPlan((("k:0", "k:1"), ("k:2",)),
                           (AggOp("sum", "int"),))
        before = mirror.exec_stats["mode_chunked"]
        got = paged.execute(plan, eng.seq)
        assert mirror.exec_stats["mode_chunked"] == before + 1
        assert got == ChainVersionStore(eng.store).execute(plan, eng.seq)


# ------------------------------------------------------------ overflow guards
class TestOverflowGuards:
    def test_check_block_bound_raises(self):
        kops.check_block_bound(2**27, 8)                 # fits
        with pytest.raises(OverflowError):
            kops.check_block_bound(2**28 + 1, 8)
        kops.check_block_bound(2**31 - 1, 1)             # BP=1 always safe

    def test_safe_block_pages_halves(self):
        assert kops.safe_block_pages(100, 4096) == 8
        assert kops.safe_block_pages(2**28 + 1, 4096) == 4
        assert kops.safe_block_pages(2**29, 4096) == 2
        assert kops.safe_block_pages(2**31 - 1, 4096) == 1

    def test_scan_bound(self):
        assert kops.scan_bound_ok(100, 4096)
        assert not kops.scan_bound_ok(2**28, 16)
        assert kops.scan_bound_ok(0, 0)

    def test_chunked_demotes_to_flat_on_scan_bound(self):
        """Huge field values violate the whole-scan device-fold bound:
        a chunked pick silently demotes to flat (exact host fold) and the
        result still equals the arbitrary-precision oracle."""
        eng = Engine("ssi")
        t = eng.begin()
        big = 2**28 + 7
        for i in range(24):
            eng.write(t, f"k:{i}", big if i % 2 else -big)
        eng.commit(t)
        mirror = _mirror_for(eng)
        mirror.grouped_mode = "chunked"
        paged = PagedVersionStore(mirror)
        plan = GroupByPlan((tuple(f"k:{i}" for i in range(12)),
                            tuple(f"k:{i}" for i in range(12, 24))),
                           (AggOp("sum", "int"), AggOp("min", "int")))
        kops.reset_launch_stats()
        got = paged.execute(plan, eng.seq)
        assert kops.LAUNCH_STATS["overflow_fallbacks"] == 1
        assert kops.LAUNCH_STATS["flat"] == 1          # demoted
        assert kops.LAUNCH_STATS["block_shrinks"] == 1  # BP shrank too
        assert got == ChainVersionStore(eng.store).execute(plan, eng.seq)
