"""Predicted-lag routing: the cluster learns each replica's ship cadence
and `predicted_staleness` routes on the lag a replica WILL serve with once
its due scheduled ship runs — cutting ship-then-serve sync fallbacks
versus observed-lag bounded staleness, with both predicted and observed
lag recorded in the routing metrics."""

from repro.cluster import PredictedStaleness, make_policy
from repro.mvcc import Engine, MultiNodeHTAP, run_multi_node
from repro.mvcc.workload import Scale, load_initial


def _cluster(n=2, policy="predicted_staleness", max_staleness=10):
    htap = MultiNodeHTAP("ssi+rss", n_replicas=n, route_policy=policy,
                         max_staleness=max_staleness)
    load_initial(htap.primary, Scale(warehouses=1, districts=1, customers=2,
                                     items=4))
    htap.ship_log()
    return htap


def _commit_n(eng: Engine, n: int, start: int = 0) -> None:
    for i in range(n):
        t = eng.begin()
        eng.write(t, f"x{(start + i) % 7}", start + i)
        eng.commit(t)


def test_make_policy_resolves_predicted():
    p = make_policy("predicted_staleness", max_lag=17)
    assert isinstance(p, PredictedStaleness)
    assert p.max_lag == 17 and p.predictive


def test_ship_cadence_learned_from_ship_history():
    htap = _cluster()
    cl = htap.cluster
    assert cl.ship_cadence(0) is None       # one ship: no cadence yet
    for r in range(3):
        _commit_n(htap.primary, 5, start=10 * r)
        htap.ship_log(replica=0)
    cadence = cl.ship_cadence(0)
    assert cadence is not None and 10 <= cadence <= 20  # ~15 records/ship
    # replica 1 never shipped again: still cadence-less, predicted falls
    # back to observed lag
    assert cl.ship_cadence(1) is None
    assert cl.predicted_lag(1) == cl.lag_records(1)


def test_predicted_lag_zero_when_ship_due():
    htap = _cluster()
    cl = htap.cluster
    for r in range(3):
        _commit_n(htap.primary, 4, start=10 * r)
        htap.ship_log(replica=0)
    _commit_n(htap.primary, 40, start=100)  # way past one cadence interval
    assert cl.ship_due(0)
    assert cl.predicted_lag(0) == 0
    assert cl.lag_records(0) > 0            # observed disagrees


def test_acquire_runs_due_scheduled_ship_and_records_both_lags():
    htap = _cluster(n=1, max_staleness=5)
    cl = htap.cluster
    for r in range(3):
        _commit_n(htap.primary, 4, start=10 * r)
        htap.ship_log(replica=0)
    _commit_n(htap.primary, 30, start=100)
    before = cl.stats["ship_then_serve"]
    handle = cl.acquire()
    cl.release(handle)
    assert cl.stats["scheduled_ships"] == 1     # due ship ran at serve
    assert cl.stats["ship_then_serve"] == before  # NOT an emergency round
    assert cl.lag_records(0) == 0               # served fresh
    assert cl.stats["predicted_lag_sum"] == 0
    assert cl.avg_predicted_lag() <= cl.avg_served_lag() + 1e-9


def test_predicted_cuts_sync_fallbacks_vs_bounded_on_skewed_fleet():
    common = dict(olap_mode="ssi+rss", oltp_clients=4, olap_clients=2,
                  rounds=800, seed=9, olap_scan=True, ship_every=100,
                  n_replicas=4, max_staleness=40, ship_skew=1,
                  freshness_hints=True, check_scans=True)
    mb = run_multi_node(route_policy="bounded_staleness", **common)
    mp = run_multi_node(route_policy="predicted_staleness", **common)
    assert mb.olap_ship_then_serve > 0          # the skew forces fallbacks
    assert mp.olap_ship_then_serve < mb.olap_ship_then_serve
    assert mp.olap_scheduled_ships > 0
    # identical logical results regardless of routing (serializability is
    # not a function of the serving replica)
    assert mp.olap_avg_predicted_lag <= mp.olap_avg_lag_records + 1e-9
    assert mp.olap_commits > 0 and mp.olap_agg_steps > 0
