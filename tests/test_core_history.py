"""Core theory tests: the paper's own examples and definitions."""

import pytest

from repro.core import (History, T0, b, c, r, w, a, build_dsg, clear_set,
                        construct_rss, dangerous_structures, done_set,
                        find_cycle, is_rss, is_serializable, is_si_history,
                        latest_versions_in, obscure_set, protected_read,
                        read_only_anomaly_example, rss_violations,
                        ssi_accepts, vulnerable_edges, with_protected_reader)


class TestReadOnlyAnomaly:
    """Section 3.3: the h_s example, verbatim."""

    def test_hs_is_not_serializable(self):
        h = read_only_anomaly_example()
        assert not is_serializable(h)
        cyc = find_cycle(h)
        assert cyc is not None and set(cyc) == {1, 2, 3}

    def test_hs_without_t3_is_serializable(self):
        # "the history over T1 and T2 is serializable under SI"
        h = read_only_anomaly_example().without_txn(3)
        assert is_serializable(h)
        assert ssi_accepts(h)

    def test_hs_is_si(self):
        # SI accepts h_s — that's the anomaly
        assert is_si_history(read_only_anomaly_example())

    def test_hs_has_dangerous_structure(self):
        # T3 -rw-> T2 -rw-> T1 (paper: "would be aborted under SSI")
        ds = dangerous_structures(read_only_anomaly_example())
        assert (3, 2, 1) in ds

    def test_vulnerable_edges(self):
        vul = {(v.src, v.dst) for v in
               vulnerable_edges(read_only_anomaly_example())}
        assert vul == {(2, 1), (3, 2)}

    def test_previous_version_read_avoids_anomaly(self):
        """Section 3.3: 'if the read protocol of T3 chooses the previous
        version Y_0, the scheduler cannot have led to the read-only
        anomaly'."""
        h = read_only_anomaly_example().without_txn(3)
        h2 = History(h.ops)
        h2.extend([b(3), r(3, "X", T0), r(3, "Y", T0), c(3)])
        assert is_serializable(h2)


class TestFatalStructures:
    """The full Fekete condition, including the Ta == Tc coincidence."""

    def test_two_txn_write_skew_is_fatal(self):
        """Plain write skew is the structure T2 -rw-> T1 -rw-> T2 (Ta and
        Tc coincide): non-serializable, so `ssi_accepts` must reject it —
        the commit-order filter may only compare Tc against Tb."""
        h = History([b(1), b(2),
                     r(1, "x", T0), r(1, "y", T0),
                     r(2, "x", T0), r(2, "y", T0),
                     w(1, "x"), w(2, "y"), c(1), c(2)])
        assert is_si_history(h)
        assert not is_serializable(h)
        assert not ssi_accepts(h)

    def test_hs_fatal_pivot_rejected(self):
        assert not ssi_accepts(read_only_anomaly_example())

    def test_structure_with_tc_last_is_benign(self):
        """Ta -rw-> Tb -rw-> Tc with Tc committing LAST of the three:
        dangerous structurally but provably benign — accepted."""
        h = History([b(1), b(2), b(3),
                     r(1, "a", T0),                  # T1 -rw-> T2 (w a)
                     r(2, "b", T0),                  # T2 -rw-> T3 (w b)
                     w(2, "a"), w(3, "b"), w(1, "z"),
                     c(1), c(2), c(3)])
        assert dangerous_structures(h)
        assert is_serializable(h)
        assert ssi_accepts(h)


class TestDefinitions:
    def test_clear_done_obscure(self):
        h = History([b(1), w(1, "x"), c(1),          # ends before T2 begins
                     b(2), w(2, "y"),                # active
                     b(3), w(3, "z"), c(3)])         # concurrent with T2
        assert done_set(h) == {1, 3}
        assert clear_set(h) == {1}
        assert obscure_set(h) == {3}

    def test_clear_requires_end_before_every_active_begin(self):
        h = History([b(2), b(1), w(1, "x"), c(1)])   # T1 concurrent w/ T2
        assert clear_set(h) == set()

    def test_rss_definition_4_1(self):
        # T1 -> T2 (wr): {T2} is not an RSS ({T1} reaches in); {T1} is.
        h = History([b(1), w(1, "x"), c(1), b(2), r(2, "x", 1), w(2, "y"),
                     c(2)])
        assert is_rss(h, {1})
        assert is_rss(h, {1, 2})
        assert not is_rss(h, {2})
        assert rss_violations(h, {2}) == [(1, 2)]

    def test_latest_versions_in(self):
        h = History([b(1), w(1, "x"), c(1), b(2), w(2, "x"), c(2)])
        assert latest_versions_in(h, {1})["x"] == 1
        assert latest_versions_in(h, {1, 2})["x"] == 2
        assert latest_versions_in(h, set())["x"] == T0


class TestAlgorithm1:
    def test_clear_plus_incoming_edges(self):
        """Algorithm 1 step (3): a committed txn OUTSIDE Clear joins RSS via
        a direct (vulnerable rw) edge into a Clear member."""
        # T1 ends before T3 begins -> T1 is Clear.  T2 (concurrent with the
        # still-active T3) commits with T2 -rw-> T1 (it read x_T0, T1 wrote
        # the next version).  T2 is Obscure but joins RSS through the edge.
        h = History([
            b(2), r(2, "x", T0),
            b(1), w(1, "x"), c(1),
            b(3), w(3, "q"),           # active: horizon = Begin(3)
            c(2),
        ])
        assert clear_set(h) == {1}
        assert obscure_set(h) == {2}
        assert construct_rss(h) == {1, 2}
        # and the result is a valid RSS w.r.t. Definition 4.1
        assert is_rss(h, construct_rss(h))

    def test_rss_grows_to_clear_when_quiescent(self):
        h = History([b(1), w(1, "x"), c(1), b(2), r(2, "x", 1), c(2)])
        assert clear_set(h) == {1, 2}
        assert construct_rss(h) == {1, 2}

    def test_theorem_4_4_prot_keeps_serializability(self):
        h = read_only_anomaly_example().without_txn(3)
        for n in range(len(h.ops) + 1):
            p = h.prefix(n)
            P = construct_rss(p)
            h2 = with_protected_reader(h, P, ["X", "Y"], txn_id=50)
            assert is_serializable(h2), (n, P)

    def test_aborted_txns_never_join_rss(self):
        h = History([b(1), w(1, "x"), a(1), b(2), w(2, "y"), c(2)])
        assert 1 not in construct_rss(h)
        assert construct_rss(h) == {2}


class TestSafeSnapshots:
    """Ports & Grittner baseline semantics (the cost RSS removes)."""

    def test_unsafe_while_writer_active(self):
        from repro.core import snapshot_is_safe, reader_wait
        h = History([b(1), w(1, "x")])          # active writer
        assert not snapshot_is_safe(h)
        h.extend([c(1)])
        assert snapshot_is_safe(h)

    def test_reader_wait_measures_positions(self):
        from repro.core import reader_wait
        h = History([b(1), w(1, "x"), c(1), b(2), w(2, "y"), c(2)])
        # requesting at position 1 (T1 active): must wait until C1 (pos 3)
        assert reader_wait(h, 1) == 2
        # requesting when quiescent: no wait
        assert reader_wait(h, 3) == 0

    def test_unbounded_wait_when_writers_never_drain(self):
        from repro.core import earliest_safe_point
        h = History([b(1), w(1, "x"), b(2), w(2, "y"), c(1)])  # T2 open
        assert earliest_safe_point(h, 4) is None
