"""Engine-level CC semantics: SI-V/SI-W, SSI aborts, HTAP mode invariants."""

import random

import pytest

from repro.core import is_serializable, dangerous_structures
from repro.core.history import READ
from repro.core.replica import RssSnapshot
from repro.mvcc import (Engine, SerializationFailure, Status,
                        SingleNodeHTAP, MultiNodeHTAP,
                        run_single_node, run_multi_node)
from repro.tensorstore import ScanPlan


class TestSIBasics:
    def test_snapshot_read_ignores_later_commits(self):
        e = Engine("si")
        t1 = e.begin()
        e.write(t1, "x", 1)
        e.commit(t1)
        t2 = e.begin()            # snapshot includes x=1
        t3 = e.begin()
        e.write(t3, "x", 2)
        e.commit(t3)
        assert e.read(t2, "x") == 1      # SI-V: version at Begin(T2)
        e.commit(t2)

    def test_first_committer_wins(self):
        e = Engine("si")
        t1, t2 = e.begin(), e.begin()
        e.write(t1, "x", 1)
        e.write(t2, "x", 2)
        e.commit(t1)
        with pytest.raises(SerializationFailure):
            e.commit(t2)
        assert e.stats["ww_aborts"] == 1

    def test_read_your_own_writes(self):
        e = Engine("si")
        t = e.begin()
        e.write(t, "x", 42)
        assert e.read(t, "x") == 42

    def test_si_allows_write_skew(self):
        """SI accepts write skew (non-serializable) — the baseline anomaly."""
        e = Engine("si", record=True)
        t1, t2 = e.begin(), e.begin()
        e.read(t1, "a"), e.read(t1, "b")
        e.read(t2, "a"), e.read(t2, "b")
        e.write(t1, "a", 1)
        e.write(t2, "b", 1)
        e.commit(t1)
        e.commit(t2)              # no abort under plain SI
        assert not is_serializable(e.history)


class TestSSI:
    def test_ssi_aborts_write_skew(self):
        e = Engine("ssi", record=True)
        t1, t2 = e.begin(), e.begin()
        e.read(t1, "a"), e.read(t1, "b")
        e.read(t2, "a"), e.read(t2, "b")
        e.write(t1, "a", 1)
        e.write(t2, "b", 1)
        aborted = (t1.status == Status.ABORTED or
                   t2.status == Status.ABORTED)
        if not aborted:
            try:
                e.commit(t1)
                e.commit(t2)
            except SerializationFailure:
                aborted = True
        assert aborted
        assert is_serializable(e.history)

    def test_read_only_anomaly_prevented(self):
        """The paper's h_s under the engine: someone gets aborted, and the
        committed history stays serializable."""
        e = Engine("ssi", record=True)
        t2 = e.begin()
        e.read(t2, "X"), e.read(t2, "Y")
        t1 = e.begin()
        e.read(t1, "Y")
        e.write(t1, "Y", 20)
        e.commit(t1)
        t3 = e.begin(read_only=True)
        try:
            e.read(t3, "X")
            e.read(t3, "Y")
            e.commit(t3)
            e.write(t2, "X", -11)
            e.commit(t2)
        except SerializationFailure:
            pass
        assert e.stats["aborts"] >= 1 or is_serializable(e.history)
        assert is_serializable(e.history)
        assert not dangerous_structures(e.history)


class TestRssMode:
    def test_rss_reader_never_waits_or_aborts(self):
        htap = SingleNodeHTAP("ssi+rss")
        t = htap.oltp_begin()
        htap.engine.write(t, "x", 1)
        htap.engine.commit(t)
        htap.refresh_rss()
        # writer mid-flight while reader works: no interference either way
        w = htap.oltp_begin()
        htap.engine.write(w, "x", 2)
        r = htap.olap_begin()
        assert r is not None                  # wait-free
        assert htap.olap_read(r, "x") == 1    # snapshot, not dirty
        htap.olap_commit(r)                   # commit never fails
        htap.engine.commit(w)
        assert htap.engine.stats["reader_aborts"] == 0

    def test_rss_reader_sees_consistent_prefix(self):
        htap = SingleNodeHTAP("ssi+rss")
        for i in range(5):
            t = htap.oltp_begin()
            htap.engine.write(t, "x", i)
            htap.engine.write(t, "y", i)
            htap.engine.commit(t)
        htap.refresh_rss()
        r = htap.olap_begin()
        assert htap.olap_read(r, "x") == htap.olap_read(r, "y")
        htap.olap_commit(r)


class TestEngineGC:
    def test_committed_rw_partners_are_collected(self):
        """Committed transactions joined by an rw edge must not pin each
        other in `engine.txns` forever: once both end below the concurrency
        horizon their edge is released and both are reaped."""
        e = Engine("ssi")
        for i in range(200):
            reader = e.begin(read_only=True)
            e.read(reader, "k")                 # SIRead lock
            writer = e.begin()
            e.write(writer, "k", i)             # reader -rw-> writer edge
            e.commit(writer)
            try:
                e.commit(reader)
            except SerializationFailure:
                pass
            # both committed with a mutual rw edge; a later txn advances
            # the horizon past them
            assert len(e.txns) < 20, (i, len(e.txns))
        assert e.stats["commits"] > 300

    def test_long_run_state_stays_bounded(self):
        rng = random.Random(0)
        e = Engine("ssi")
        keys = [f"k{i}" for i in range(6)]
        peak = 0
        for i in range(1500):
            t = e.begin(read_only=rng.random() < 0.3)
            try:
                for key in rng.sample(keys, 2):
                    if t.read_only or rng.random() < 0.5:
                        e.read(t, key)
                    else:
                        e.write(t, key, i)
                e.commit(t)
            except SerializationFailure:
                pass
            peak = max(peak, len(e.txns))
        assert peak < 60, peak                  # bounded, not O(history)
        assert sum(len(s) for s in e.siread.values()) < 60

    def test_aborted_txn_edges_drop_and_drain(self):
        """Aborting drops the txn from its neighbours' edge sets via its
        OWN in_rw/out_rw (not a scan of all tracked txns), and the edge
        state still drains under GC afterwards."""
        e = Engine("ssi")
        for i in range(150):
            r = e.begin(read_only=True)
            e.read(r, "k")
            w = e.begin()
            e.write(w, "k", i)                  # r -rw-> w edge
            e.commit(w)
            e.abort(r)                          # user abort, edge intact
            assert not r.in_rw and not r.out_rw
            assert all(r.tid not in (x.in_rw | x.out_rw)
                       for x in e.txns.values()), i
            assert len(e.txns) < 20, (i, len(e.txns))
        assert e.stats["aborts"] == 150
        assert e.stats["by_reason"] == {"user abort": 150}

    def test_gc_keeps_edges_spanning_the_horizon(self):
        """Only edges between two ended-below-horizon txns are released:
        an edge whose writer ends above the horizon (a long-running reader
        keeps it there) pins both endpoints."""
        e = Engine("ssi")
        r = e.begin()
        e.read(r, "k")
        w = e.begin()
        e.write(w, "k", 1)                       # r -rw-> w (concurrent)
        e.commit(r)
        long_running = e.begin()                 # horizon anchor
        e.read(long_running, "z")
        e.commit(w)                              # w ends above the horizon
        filler = e.begin()
        e.write(filler, "f", 1)
        e.commit(filler)                         # triggers _gc
        assert r.tid in e.txns and w.tid in e.txns
        assert r.out_rw == {w.tid} and w.in_rw == {r.tid}   # edge intact
        e.commit(long_running)


class TestScanRecording:
    def test_si_scan_records_reads_and_history(self):
        e = Engine("si", record=True)
        t0 = e.begin()
        e.write(t0, "a", 7)
        e.commit(t0)
        t = e.begin(read_only=True)
        e.execute(t, ScanPlan(("a", "b")))
        assert t.reads == {"a": t0.tid, "b": 0}
        scan_reads = [(op.key, op.version) for op in e.history.ops
                      if op.kind == READ and op.txn == t.tid]
        assert scan_reads == [("a", t0.tid), ("b", 0)]

    def test_rss_scan_records_member_resolved_writers(self):
        e = Engine("ssi", record=True)
        t1 = e.begin(); e.write(t1, "x", 1); e.commit(t1)
        t2 = e.begin(); e.write(t2, "x", 2); e.commit(t2)
        snap = RssSnapshot(lsn=0, txns=frozenset({t1.tid}))
        t = e.begin(read_only=True, rss=snap)
        vals = e.execute(t, ScanPlan(("x", "y")))
        assert vals == [1, 0]                   # member-visible version
        assert t.reads == {"x": t1.tid, "y": 0}
        recorded = [(op.key, op.version) for op in e.history.ops
                    if op.kind == READ and op.txn == t.tid]
        assert recorded == [("x", t1.tid), ("y", 0)]

    def test_scan_skips_own_writes_in_recording(self):
        e = Engine("si", record=True)
        t = e.begin()
        e.write(t, "k1", 42)
        assert e.execute(t, ScanPlan(("k0", "k1"))) == [0, 42]
        assert "k1" not in t.reads              # never hit the store
        assert t.reads == {"k0": 0}

    def test_recorded_scan_history_passes_oracle_checks(self):
        """Histories including batched scan reads stay valid inputs for the
        specification-level checkers."""
        from repro.core import ssi_accepts
        e = Engine("ssi", record=True)
        t0 = e.begin()
        e.write(t0, "a", 1); e.write(t0, "b", 2)
        e.commit(t0)
        r1 = e.begin(read_only=True, skip_siread=True)
        e.execute(r1, ScanPlan(("a", "b")))
        e.commit(r1)
        assert is_serializable(e.history)
        assert ssi_accepts(e.history)


class TestMultiNode:
    def test_replica_lags_then_catches_up(self):
        htap = MultiNodeHTAP("ssi+rss")
        t = htap.oltp_begin()
        htap.primary.write(t, "x", 7)
        htap.primary.commit(t)
        snap0 = htap.olap_snapshot()
        assert htap.olap_read(snap0, "x") == 0     # not shipped yet
        htap.ship_log()
        snap1 = htap.olap_snapshot()
        assert htap.olap_read(snap1, "x") == 7

    def test_si_replica_vs_rss_replica_visibility(self):
        for mode in ("ssi+si", "ssi+rss"):
            htap = MultiNodeHTAP(mode)
            t = htap.oltp_begin()
            htap.primary.write(t, "k", 1)
            htap.primary.commit(t)
            htap.ship_log()
            snap = htap.olap_snapshot()
            assert htap.olap_read(snap, "k") == 1


class TestDrivers:
    def test_driver_modes_run_and_rss_is_wait_and_abort_free(self):
        for mode in ("ssi", "ssi+safesnapshots", "ssi+rss"):
            m = run_single_node(olap_mode=mode, oltp_clients=4,
                                olap_clients=2, rounds=1500, seed=3)
            assert m.oltp_commits > 0 and m.olap_commits > 0, mode
            if mode == "ssi+rss":
                assert m.olap_aborts == 0
                assert m.olap_wait_rounds == 0
            if mode == "ssi+safesnapshots":
                assert m.olap_aborts == 0

    def test_multinode_driver(self):
        for mode in ("ssi+si", "ssi+rss"):
            m = run_multi_node(olap_mode=mode, oltp_clients=4,
                               olap_clients=2, rounds=1200, seed=3)
            assert m.oltp_commits > 0 and m.olap_commits > 0
            assert m.olap_aborts == 0
