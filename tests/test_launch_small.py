"""Launch-layer integration on a small forced-device mesh (subprocess:
XLA device count must be set before JAX init, so these run out-of-process).

Covers: mesh construction, sharding rules (sanitization on non-divisible
dims), input_specs, an actual lower+compile of a smoke cell on a 4×2 mesh,
and elastic checkpoint restore across different meshes.
"""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_smoke_cell_compiles_on_4x2_mesh():
    out = run_sub("""
import jax, jax.numpy as jnp
from repro.configs import get_config, smoke_variant
from repro.models.sharding import with_mesh
from repro.launch.shardings import param_shardings, batch_shardings
from repro.train.step import make_train_step, init_state
from repro.optim import AdamWConfig
from jax.sharding import NamedSharding

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = smoke_variant(get_config("qwen1.5-0.5b")).with_overrides(fsdp=True)
opt = AdamWConfig()
with with_mesh(mesh, {"data": ("data",)}):
    state = init_state(jax.random.PRNGKey(0), cfg, opt)
    pshard = param_shardings(mesh, cfg, state["params"])
    state["params"] = jax.device_put(state["params"], pshard)
    batch = {"tokens": jnp.ones((8, 16), jnp.int32),
             "labels": jnp.ones((8, 16), jnp.int32)}
    step = jax.jit(make_train_step(cfg, opt))
    state2, m = step(state, batch)
    print("LOSS", float(m["loss"]))
    # a sharded leaf really is distributed
    leaf = jax.tree.leaves(state2["params"])[3]
    print("NSHARDS", len(leaf.sharding.device_set))
""")
    assert "LOSS" in out
    nshards = int(out.strip().split("NSHARDS")[-1])
    assert nshards >= 1


def test_elastic_restore_across_meshes(tmp_path):
    """Save on a 4×2 mesh, restore onto 2×4 — elastic resume."""
    out = run_sub(f"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, smoke_variant
from repro.models.sharding import with_mesh
from repro.launch.shardings import param_shardings
from repro.checkpoint import manager as ckpt
from repro.train.step import init_state
from repro.optim import AdamWConfig

cfg = smoke_variant(get_config("qwen1.5-0.5b")).with_overrides(fsdp=True)
opt = AdamWConfig()
state = init_state(jax.random.PRNGKey(0), cfg, opt)

mesh1 = jax.make_mesh((4, 2), ("data", "model"))
p1 = jax.device_put(state["params"], param_shardings(mesh1, cfg,
                                                     state["params"]))
ckpt.save({{"params": p1}}, 1, r"{tmp_path}")

mesh2 = jax.make_mesh((2, 4), ("data", "model"))
template = {{"params": jax.tree.map(
    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state["params"])}}
shard2 = {{"params": param_shardings(mesh2, cfg, state["params"])}}
restored = ckpt.restore(r"{tmp_path}", template, shardings=shard2)
a = np.asarray(jax.tree.leaves(p1)[0], np.float32)
b = np.asarray(jax.tree.leaves(restored["params"])[0], np.float32)
np.testing.assert_allclose(a, b)
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


def test_dryrun_collective_parser():
    """Wire-cost parser handles iota and explicit replica groups."""
    sys.path.insert(0, SRC)
    from repro.launch.dryrun import collective_bytes, _group_size
    hlo = """
  %ag = bf16[16,128] all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[4,4] all-reduce(%y), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 128 * 2 * 15 / 16
    assert out["all-reduce"] == 2 * 4 * 4 * 4 * 3 / 4
    assert _group_size("replica_groups=[8,32]<=[256]") == 32
