"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
each family runs one forward + one train step on CPU, asserting output shapes
and the absence of NaNs; plus prefill/decode consistency with the training
forward (teacher forcing)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, smoke_variant
from repro.models import (decode_step, forward, init_params, loss_fn,
                          prefill)
from repro.optim import AdamWConfig
from repro.train import init_state, make_train_step


def make_batch(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.mrope_sections:
        batch["mrope_positions"] = jnp.broadcast_to(jnp.arange(S), (3, B, S))
        batch["vision_embeds"] = jax.random.normal(
            key, (B, max(S // 4, 1), cfg.d_model)) * 0.02
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_len, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    params = init_params(jax.random.PRNGKey(0), cfg)
    logits = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    opt_cfg = AdamWConfig(lr=1e-3)
    state = init_state(jax.random.PRNGKey(0), cfg, opt_cfg, params=params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state2["step"]) == 1
    # loss decreases over a few steps on a repeated batch (learning works)
    for _ in range(3):
        state2, m2 = step(state2, batch)
    assert float(m2["loss"]) < float(metrics["loss"])


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_forward(arch):
    """Teacher forcing: decode logits at position t must match the training
    forward's logits at t (same params, same prefix).  fp32 so the check
    isolates cache/state-handoff logic from bf16 accumulation noise."""
    cfg = smoke_variant(get_config(arch)).with_overrides(
        param_dtype="float32", compute_dtype="float32")
    B, S = 2, 12
    batch = make_batch(cfg, B, S)
    params = init_params(jax.random.PRNGKey(1), cfg)
    full = forward(params, cfg, batch).astype(jnp.float32)

    pre_batch = {k: (v[:, :S - 2] if k in ("tokens",) else v)
                 for k, v in batch.items() if k != "labels"}
    if "mrope_positions" in pre_batch:
        pre_batch["mrope_positions"] = batch["mrope_positions"][:, :, :S - 2]
    if "vision_embeds" in pre_batch:
        del pre_batch["vision_embeds"]       # keep text-only for exactness
        if "vision_embeds" in batch:
            full = forward(params, cfg,
                           {k: v for k, v in batch.items()
                            if k != "vision_embeds"}).astype(jnp.float32)
    logits_p, cache = prefill(params, cfg, pre_batch, cache_len=S)
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32), np.asarray(full[:, S - 3]),
        rtol=2e-2, atol=2e-2)
    # decode the next token position
    tok = batch["tokens"][:, S - 2:S - 1]
    logits_d, cache = decode_step(params, cfg, tok, cache,
                                  jnp.int32(S - 2))
    np.testing.assert_allclose(
        np.asarray(logits_d, np.float32), np.asarray(full[:, S - 2]),
        rtol=2e-2, atol=2e-2)


def test_moe_routing_is_selective():
    """Top-k weights differ across tokens (the router actually routes)."""
    cfg = smoke_variant(get_config("mixtral-8x7b"))
    params = init_params(jax.random.PRNGKey(2), cfg)
    batch = make_batch(cfg)
    logits = forward(params, cfg, batch)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_param_counts_match_published():
    expected = {
        "mixtral-8x22b": 140.6e9, "mixtral-8x7b": 46.7e9,
        "rwkv6-3b": 3.1e9, "qwen2-vl-72b": 72.7e9,
        "nemotron-4-15b": 15.6e9, "codeqwen1.5-7b": 8.2e9,
        "qwen1.5-0.5b": 0.62e9, "granite-34b": 34.0e9,
        "whisper-tiny": 0.0564e9, "jamba-1.5-large-398b": 398.5e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.05, (arch, got, want)
