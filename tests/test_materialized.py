"""Materialized aggregates: incremental tiles == the chain oracle.

The tentpole contract: a registered plan's live accumulator tile —
advanced by commit-delta folds, demoted per-lane when a min/max bound
retracts, gated on snapshot membership — must be indistinguishable from
the fused-scan path and the per-key chain walk at EVERY serve, under
randomized replication lag, RSS state GC, PRoT pins, WAL truncation
below the watermark, legacy (unstamped) records, late registration, and
full reseeds.  Views may fall back (gate miss) or degrade (overflow,
fold-order violation) — they may never serve a stale or wrong result.

Harness style follows tests/test_group_agg.py: seeded-random streams
against RSSManager + PagedMirror + ChainVersionStore.
"""

import random

import numpy as np
import pytest

from repro.core import PRoTManager, RSSManager, Wal
from repro.core.wal import WalRecord, effective_commit_seq
from repro.mvcc.store import Store
from repro.tensorstore import (AggOp, ChainVersionStore, GroupByPlan,
                               MultiAggPlan, PagedMirror, PagedVersionStore)
from repro.tensorstore.materialized import MAX_CONTRIB

STOCK = [f"stock:{i}" for i in range(8)]
ORDERS = ["order:0:0:0", "order:0:0:1"]
KEYS = STOCK + ["warehouse:0", "district:0:0"] + ORDERS

# statically-fingerprinted plans a session would register (all seven
# fold lanes exercised: additive, thresholded, and min/max demotion)
PLAN_MULTI = MultiAggPlan(
    tuple(STOCK), (AggOp("sum", "int"), AggOp("count", "int"),
                   AggOp("min", "int"), AggOp("max", "int"),
                   AggOp("count_below", "int", 50),
                   AggOp("count_above", "int", 90),
                   AggOp("sum_below", "int", 100)))
PLAN_GROUP = GroupByPlan(
    (tuple(STOCK[:4]), tuple(STOCK[4:])),
    (AggOp("sum", "int"), AggOp("max", "int")))
PLAN_TOTAL = MultiAggPlan(
    tuple(ORDERS), (AggOp("sum", "total"), AggOp("count", "total")))
PLANS = (PLAN_MULTI, PLAN_GROUP, PLAN_TOTAL)


def _rand_value(rng, key):
    if key.startswith("district"):
        return {"next_o_id": rng.randrange(40), "ytd": rng.randrange(99)}
    if key.startswith("order"):
        return {"items": [rng.randrange(9) for _ in range(rng.randrange(4))],
                "total": rng.randrange(500)}
    return rng.randrange(-100, 200)


def random_writes_wal(rng, steps=220, *, legacy_prob=0.0):
    wal = Wal()
    active = []
    tid = 0
    for _ in range(steps):
        act = rng.random()
        if act < 0.35 or not active:
            tid += 1
            wal.log_begin(tid)
            active.append(tid)
        elif act < 0.8:
            t = active.pop(rng.randrange(len(active)))
            seq = 0 if rng.random() < legacy_prob else wal.head_lsn + 1
            writes = [(k, _rand_value(rng, k))
                      for k in rng.sample(KEYS, rng.randint(1, 3))]
            wal.log_commit(t, writes, seq=seq)
            if active and rng.random() < 0.5:
                wal.log_deps(t, sorted(rng.sample(
                    active, rng.randint(1, min(2, len(active))))))
        else:
            wal.log_abort(active.pop(rng.randrange(len(active))))
    return wal


def _check_tile_matches_shadow(view):
    """Device tile == int64 host shadow, lane for lane (post flush and
    demotion) — the kernel-fold vs host-fold parity seam."""
    if view.degraded:
        return
    rows = view.serve_rows()
    assert rows == [[int(x) for x in r] for r in view.shadow], \
        (rows, view.shadow)


def check_view_stream(seed, *, gc_prob=0.0, pin_prob=0.0,
                      truncate_prob=0.0, legacy_prob=0.0,
                      reseed_prob=0.0, late_register=False,
                      use_kernel=False):
    """Replay a random commit stream; every live snapshot must execute
    the registered plans identically through the materialized registry
    (hit, fallback, or degraded) and the chain oracle.  Returns the
    mirror's exec stats for hit/fallback assertions."""
    rng = random.Random(seed)
    wal = random_writes_wal(rng, legacy_prob=legacy_prob)
    man = RSSManager()
    prot = PRoTManager(man)
    mirror = PagedMirror(slots=64)
    store = Store()
    chain = ChainVersionStore(store)
    paged = PagedVersionStore(mirror)
    if not late_register:
        for p in PLANS:
            mirror.register_view(p, use_kernel=use_kernel)
    applied_seq = 0
    pruned_floor = 0
    registered = not late_register
    pins = []
    rounds = 0
    while man.applied_lsn < wal.head_lsn:
        batch = rng.randint(1, 15)
        for rec in wal.tail(man.applied_lsn):
            man.apply(rec)
            mirror.apply(rec, gc_floor=prot.gc_floor_seq())
            if rec.type == "commit":
                seq = effective_commit_seq(applied_seq, rec.seq)
                for k, v in rec.writes:
                    store.chain(k).install(seq, rec.txn, v)
                applied_seq = seq
            batch -= 1
            if batch <= 0:
                break
        rounds += 1
        if late_register and not registered and rounds >= 4:
            for p in PLANS:
                mirror.register_view(p, use_kernel=use_kernel)
            registered = True
        snap = man.construct()
        mirror.advance_views(snap)            # the facade's refresh step
        # fresh snapshot first (the hit path), stale/pinned after (the
        # fallback path) — every serve must equal the chain oracle
        stale = [applied_seq, max(applied_seq - 3, pruned_floor)] \
            + [p[1] for p in pins]
        for s in [snap] + stale:
            for plan in PLANS:
                want = chain.execute(plan, s)
                got = paged.execute(plan, s)
                assert want == got, (seed, plan, s, want, got)
        if registered:
            for view in mirror.views.values():
                _check_tile_matches_shadow(view)
        if pin_prob and rng.random() < pin_prob:
            pins.append(prot.acquire())
        if pins and rng.random() < 0.3:
            prot.release(pins.pop(rng.randrange(len(pins)))[0])
        if gc_prob and rng.random() < gc_prob:
            man.gc(keep_lsn=prot.gc_floor(), keep_seq=prot.gc_floor_seq())
            mirror.gc_views(prot.gc_floor_seq())
            store.prune(prot.gc_floor_seq())
            pruned_floor = max(pruned_floor, prot.gc_floor_seq())
        if truncate_prob and rng.random() < truncate_prob:
            # recycle the fully-applied WAL prefix (below the watermark);
            # views must keep serving from incremental state
            wal.truncate(min(man.applied_lsn, mirror.applied_lsn))
        if reseed_prob and rng.random() < reseed_prob:
            mirror.reseed_views()
    return dict(mirror.exec_stats)


# ------------------------------------------------------------ always-run
@pytest.mark.parametrize("seed", range(3))
def test_views_equal_chain_oracle_stream(seed):
    stats = check_view_stream(seed)
    assert stats["view_hits"] > 0, stats


@pytest.mark.parametrize("seed", range(3))
def test_views_survive_gc_pins_and_truncation(seed):
    stats = check_view_stream(seed, gc_prob=0.5, pin_prob=0.3,
                              truncate_prob=0.4)
    assert stats["view_hits"] > 0, stats
    assert stats["view_fallbacks"] > 0, stats     # stale serves fell back


@pytest.mark.parametrize("seed", range(2))
def test_views_with_legacy_records(seed):
    check_view_stream(seed, legacy_prob=0.3, gc_prob=0.3, pin_prob=0.2,
                      truncate_prob=0.3)


@pytest.mark.parametrize("seed", range(2))
def test_views_late_registration_and_reseed(seed):
    stats = check_view_stream(seed, late_register=True, reseed_prob=0.2,
                              gc_prob=0.3)
    assert stats["view_hits"] > 0, stats


def test_views_kernel_fold_parity_stream():
    """One full stream through the REAL delta-fold kernel (interpret on
    CPU): tile rows must match the int64 host shadow at every round —
    covered inline by _check_tile_matches_shadow."""
    stats = check_view_stream(0, use_kernel=True)
    assert stats["view_hits"] > 0, stats


# ------------------------------------------------------------ unit seams
def _mirror_with_view(values, *, plan=None):
    mirror = PagedMirror(slots=64)
    plan = plan or MultiAggPlan(tuple(sorted(values)),
                                (AggOp("sum", "int"), AggOp("min", "int")))
    mirror.apply(WalRecord(lsn=1, type="commit", txn=1,
                           writes=tuple(values.items()), seq=1))
    view = mirror.register_view(plan, use_kernel=False)
    return mirror, view, plan


def test_overflow_degrades_to_clean_fallback():
    vals = {"a": 1, "b": 2}
    mirror, view, plan = _mirror_with_view(vals)
    mirror.apply(WalRecord(lsn=2, type="commit", txn=2,
                           writes=(("a", MAX_CONTRIB + 1),), seq=2))
    mirror.advance_views(mirror.watermark)
    assert view.degraded
    # the degraded view falls back to the fused scan — still exact
    got, _ = mirror.execute_with_writers(plan, mirror.watermark,
                                         need_writers=False)
    assert got == (MAX_CONTRIB + 1 + 2, 2)
    assert mirror.exec_stats["view_fallbacks"] > 0


def test_out_of_order_same_key_fold_degrades():
    """A same-key fold below an already-folded seq would retract the
    newer version — the view must refuse (degrade), never serve it."""
    _, view, _ = _mirror_with_view({"a": 1, "b": 2})
    view.on_commit(WalRecord(lsn=2, type="commit", txn=2,
                             writes=(("a", 10),), seq=5), 5)
    assert not view.degraded
    view.on_commit(WalRecord(lsn=3, type="commit", txn=3,
                             writes=(("a", 7),), seq=4), 4)
    assert view.degraded


def test_demotion_recomputes_min_after_bound_retraction():
    vals = {"a": 3, "b": 8, "c": 5}
    mirror, view, plan = _mirror_with_view(vals)
    # overwrite the attained min: the min lane goes dirty and must be
    # recomputed by a partial rescan at serve time
    mirror.apply(WalRecord(lsn=2, type="commit", txn=2,
                           writes=(("a", 9),), seq=2))
    got, _ = mirror.execute_with_writers(plan, mirror.watermark,
                                         need_writers=False)
    assert got == (9 + 8 + 5, 5)
    assert mirror.exec_stats["view_hits"] == 1
    assert mirror.exec_stats["view_demotions"] >= 1


def test_duplicate_keys_in_group_rejected():
    mirror = PagedMirror(slots=64)
    with pytest.raises(ValueError):
        mirror.register_view(MultiAggPlan(("a", "a"),
                                          (AggOp("sum", "int"),)))


def test_registry_is_idempotent_by_fingerprint():
    vals = {"a": 1}
    mirror, view, plan = _mirror_with_view(vals)
    twin = MultiAggPlan(tuple(sorted(vals)),
                        (AggOp("sum", "int"), AggOp("min", "int")))
    assert mirror.register_view(twin) is view     # equal plan, same view
    assert len(mirror.views) == 1


# ------------------------------------------------------- facade threading
def test_single_node_facade_serves_and_counts():
    from repro.mvcc.driver import run_single_node
    m = run_single_node(olap_mode="ssi+rss", oltp_clients=3, olap_clients=2,
                        rounds=600, olap_scan=True, paged_olap=True,
                        check_scans=True, materialize=True, seed=5)
    assert m.olap_view_hits > 0, m
    assert m.olap_view_fallbacks >= 0


def test_replica_delta_ship_advances_views():
    from repro.mvcc.driver import run_multi_node
    m = run_multi_node(olap_mode="ssi+rss", oltp_clients=3, olap_clients=2,
                       rounds=600, olap_scan=True, paged_olap=True,
                       check_scans=True, materialize=True, seed=5)
    assert m.olap_view_hits > 0, m
