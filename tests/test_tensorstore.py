"""Paged-store (device SI-V) property tests + integration with kernels."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.tensorstore import (init_store, publish_page, snapshot_read_ref,
                               snapshot_read_members, visible_slots,
                               visible_slots_members)
from repro.kernels.version_gather.ops import snapshot_read


class TestPagedStore:
    def test_initial_visibility(self):
        store = init_store(4, 3, 8, jnp.float32,
                           initial=jnp.arange(32.0).reshape(4, 8))
        out = snapshot_read_ref(store, jnp.int32(0))
        np.testing.assert_allclose(out, np.arange(32.0).reshape(4, 8))

    def test_publish_then_read_at_watermarks(self):
        store = init_store(2, 3, 4, jnp.float32)
        store = publish_page(store, 0, jnp.full((4,), 1.0), jnp.int32(10))
        store = publish_page(store, 0, jnp.full((4,), 2.0), jnp.int32(20))
        assert float(snapshot_read_ref(store, jnp.int32(5))[0][0]) == 0.0
        assert float(snapshot_read_ref(store, jnp.int32(15))[0][0]) == 1.0
        assert float(snapshot_read_ref(store, jnp.int32(25))[0][0]) == 2.0

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 999), n_pub=st.integers(1, 12),
           slots=st.integers(2, 4))
    def test_property_matches_python_mvcc(self, seed, n_pub, slots):
        """publish_page + snapshot_read == a python dict-of-versions oracle,
        for every watermark, as long as the watermark is within the K-1
        retained versions (GC contract)."""
        rng = np.random.default_rng(seed)
        P, E = 4, 8
        store = init_store(P, slots, E, jnp.float32)
        oracle = {p: [(0, np.zeros(E))] for p in range(P)}
        ts = 0
        for _ in range(n_pub):
            ts += int(rng.integers(1, 5))
            p = int(rng.integers(P))
            payload = rng.standard_normal(E).astype(np.float32)
            store = publish_page(store, p, jnp.asarray(payload),
                                 jnp.int32(ts))
            oracle[p].append((ts, payload))
        # read at the newest watermark (always retained)
        out = np.asarray(snapshot_read_ref(store, jnp.int32(ts)))
        kout = np.asarray(snapshot_read(
            {"data": store["data"], "ts": store["ts"]}, jnp.int32(ts)))
        for p in range(P):
            want = max(oracle[p], key=lambda kv: kv[0])[1]
            np.testing.assert_allclose(out[p], want, rtol=1e-6)
            np.testing.assert_allclose(kout[p], want, rtol=1e-6)

    def test_member_set_read(self):
        """RSS-set visibility: a newer non-member version is skipped."""
        store = init_store(1, 3, 4, jnp.float32)
        store = publish_page(store, 0, jnp.full((4,), 1.0), jnp.int32(10))
        store = publish_page(store, 0, jnp.full((4,), 2.0), jnp.int32(20))
        members = jnp.asarray([10], jnp.int32)     # 20 not in RSS
        out = snapshot_read_members(store, members)
        assert float(out[0][0]) == 1.0
        idx = visible_slots_members(store["ts"], members)
        assert int(store["ts"][0, idx[0]]) == 10

    def test_kernel_and_ref_agree_on_store(self):
        key = jax.random.PRNGKey(0)
        store = {"data": jax.random.normal(key, (16, 4, 256)),
                 "ts": jax.random.randint(key, (16, 4), 0, 30)}
        for wm in (0, 10, 29):
            np.testing.assert_allclose(
                snapshot_read(store, jnp.int32(wm)),
                snapshot_read_ref(store, jnp.int32(wm)), rtol=1e-6)
