"""Training infra: checkpoint fault tolerance, microbatching equivalence,
gradient compression, data pipeline determinism, HTAP train/serve flow."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.configs import get_config, smoke_variant
from repro.data import SyntheticPipeline
from repro.models import init_params
from repro.optim import AdamWConfig, adamw
from repro.serve import ServingEngine
from repro.tensorstore import VersionedParamStore
from repro.train import Trainer, init_state, make_train_step


CFG = smoke_variant(get_config("qwen1.5-0.5b"))


class TestCheckpoint:
    def test_roundtrip_bf16(self, tmp_path):
        state = {"a": jnp.ones((4, 4), jnp.bfloat16) * 1.5,
                 "b": {"c": jnp.arange(5, dtype=jnp.int32)}}
        ckpt.save(state, 7, str(tmp_path))
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        out = ckpt.restore(str(tmp_path), template)
        np.testing.assert_allclose(np.asarray(out["a"], np.float32), 1.5)
        np.testing.assert_array_equal(out["b"]["c"], np.arange(5))

    def test_atomic_latest_and_gc(self, tmp_path):
        s = {"x": jnp.zeros((2,))}
        for step in (1, 2, 3, 4, 5):
            ckpt.save(s, step, str(tmp_path), keep=2)
        assert ckpt.latest_step(str(tmp_path)) == 5
        kept = sorted(d for d in os.listdir(tmp_path)
                      if d.startswith("step_"))
        assert len(kept) == 2

    def test_crash_restore_resumes_identically(self, tmp_path):
        """Determinism: a run that crashes and restores must land on the
        same weights as an uninterrupted run."""
        t1 = Trainer(CFG, batch=2, seq_len=16, ckpt_dir=str(tmp_path / "a"),
                     ckpt_every=2)
        t1.run(6)
        t2 = Trainer(CFG, batch=2, seq_len=16, ckpt_dir=str(tmp_path / "b"),
                     ckpt_every=2)
        t2.run(6, inject_failure_at=4)        # crash at 4, resume from 4
        for a, b in zip(jax.tree.leaves(t1.state["params"]),
                        jax.tree.leaves(t2.state["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-5, atol=1e-6)


class TestMicrobatching:
    def test_grad_accum_equivalence(self):
        """A=2 microbatches == A=1 on the same global batch.  fp32 params so
        the check isolates accumulation logic from bf16 noise (Adam's first
        step normalizes tiny gradients to ±lr, amplifying any fwd jitter)."""
        cfg1 = CFG.with_overrides(microbatches=1, param_dtype="float32",
                                  compute_dtype="float32")
        cfg2 = CFG.with_overrides(microbatches=2, param_dtype="float32",
                                  compute_dtype="float32")
        opt = AdamWConfig(lr=1e-3, moment_dtype="float32")
        pipe = SyntheticPipeline(cfg1, batch=4, seq_len=16)
        batch = pipe.batch_at(0)
        s1 = init_state(jax.random.PRNGKey(0), cfg1, opt)
        s2 = {"params": s1["params"], "opt": adamw.init(s1["params"], opt),
              "step": s1["step"]}
        o1, m1 = jax.jit(make_train_step(cfg1, opt))(s1, batch)
        o2, m2 = jax.jit(make_train_step(cfg2, opt))(s2, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-2
        for a, b in zip(jax.tree.leaves(o1["params"]),
                        jax.tree.leaves(o2["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-2, atol=5e-4)


class TestGradCompression:
    def test_int8_error_feedback_trains(self):
        opt = AdamWConfig(lr=1e-3, compress=True)
        state = init_state(jax.random.PRNGKey(0), CFG, opt)
        assert "ef" in state["opt"]
        step = jax.jit(make_train_step(CFG, opt))
        pipe = SyntheticPipeline(CFG, batch=2, seq_len=16)
        batch = pipe.batch_at(0)
        losses = []
        for _ in range(4):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]

    def test_error_feedback_is_unbiased_over_steps(self):
        from repro.optim.adamw import _compress_int8
        g = jnp.asarray(np.random.default_rng(0).standard_normal(512),
                        jnp.float32) * 1e-3
        ef = jnp.zeros_like(g)
        total_sent = jnp.zeros_like(g)
        for _ in range(50):
            sent, ef = _compress_int8(g, ef)
            total_sent += sent
        np.testing.assert_allclose(total_sent / 50, g, atol=2e-5)


class TestDataPipeline:
    def test_deterministic_and_resumable(self):
        p1 = SyntheticPipeline(CFG, batch=2, seq_len=16, seed=5)
        b0, b1 = p1.next_batch(), p1.next_batch()
        p2 = SyntheticPipeline(CFG, batch=2, seq_len=16, seed=5)
        p2.restore_state({"step": 1, "seed": 5})
        b1b = p2.next_batch()
        np.testing.assert_array_equal(b1["tokens"], b1b["tokens"])
        assert not np.array_equal(b0["tokens"], b1["tokens"])


class TestStragglerMonitor:
    def test_flags_slow_steps(self):
        from repro.train import StragglerMonitor
        m = StragglerMonitor(alpha=0.5, factor=2.0)
        for _ in range(5):
            assert not m.observe(0, 1.0)
        assert m.observe(5, 10.0)
        assert m.flagged


class TestHTAPFlow:
    def test_trainer_publishes_server_reads_waitfree(self, tmp_path):
        store = VersionedParamStore(slots=2)
        tr = Trainer(CFG, batch=2, seq_len=16, store=store)
        tr.run(3)
        eng = ServingEngine(CFG, store, max_seq=32)
        eng.refresh()
        res = eng.generate({"tokens": jnp.ones((1, 4), jnp.int32)}, 3)
        assert res.tokens.shape == (1, 3)
        assert res.freshness_lag == 0
        # reader pinned while trainer advances: wait-free for both sides
        pin, _ = store.pin_snapshot()
        tr.run(2)
        assert store.stats["publishes"] >= 6
        store.release(pin)
