"""Unified VersionStore: codec round-trips, WAL->paged mirror parity with
the chain store, batched engine scans == per-key reads, and the end-to-end
scan path through run_single_node / run_multi_node (identical OLAP results
to the per-key oracle, asserted in-run by check_scans)."""

import random

import numpy as np
import pytest

from repro.core.replica import RssSnapshot
from repro.mvcc import (Engine, MultiNodeHTAP, SingleNodeHTAP,
                        run_multi_node, run_single_node)
from repro.mvcc.workload import Scale, load_initial, olap_query
from repro.tensorstore import (ChainVersionStore, PagedMirror,
                               PagedVersionStore, ScanPlan, decode_value,
                               encode_value)


class TestCodec:
    @pytest.mark.parametrize("value", [
        0, 1, -17, 5000, 2**31 - 1,
        {"next_o_id": 3, "ytd": 812},
        {"items": [], "total": 0},
        {"items": [4, 4, 11, 49], "total": 23},
    ])
    def test_round_trip(self, value):
        assert decode_value(encode_value(value, 32)) == value

    def test_initial_payload_decodes_to_zero(self):
        assert decode_value(np.zeros(32, np.int32)) == 0

    def test_unsupported_value_raises(self):
        with pytest.raises(TypeError):
            encode_value("a string", 32)


def _run_workload(eng, seed, n=300):
    """Random committed writes through the engine (workload-shaped values)."""
    rng = random.Random(seed)
    keys = [f"stock:0:{i}" for i in range(8)] + ["warehouse:0",
                                                 "district:0:0"]
    for _ in range(n):
        t = eng.begin()
        for key in rng.sample(keys, rng.randint(1, 3)):
            if key.startswith("district"):
                val = {"next_o_id": rng.randrange(50), "ytd": rng.randrange(99)}
            else:
                val = rng.randrange(200)
            eng.write(t, key, val)
        try:
            eng.commit(t)
        except Exception:
            pass
    return keys


class TestMirrorParity:
    def test_mirror_matches_chain_store_at_watermarks(self):
        eng = Engine("ssi")
        keys = _run_workload(eng, seed=5)
        mirror = PagedMirror(slots=4)
        mirror.catch_up(eng.wal)
        chain = ChainVersionStore(eng.store)
        paged = PagedVersionStore(mirror)
        # the mirror holds K=4 slots: the newest watermark is always exact
        wm = eng.seq
        assert paged.scan_at(keys, wm) == chain.scan_at(keys, wm)
        assert paged.scan_at(["missing:key"], wm) == [0]

    def test_mirror_member_scan_matches_chain(self):
        eng = Engine("ssi")
        keys = _run_workload(eng, seed=9, n=40)
        mirror = PagedMirror(slots=64)          # retain everything
        mirror.catch_up(eng.wal)
        chain = ChainVersionStore(eng.store)
        paged = PagedVersionStore(mirror)
        committed = [r.txn for r in eng.wal.records if r.type == "commit"]
        rng = random.Random(0)
        for _ in range(10):
            members = frozenset(rng.sample(committed,
                                           rng.randint(0, len(committed))))
            snap = RssSnapshot(lsn=eng.wal.head_lsn, txns=members)
            assert paged.scan_members(keys, snap) == \
                chain.scan_members(keys, snap)

    def test_rss_manager_member_seqs_matches_mirror(self):
        """The commit-seq -> member-ts mapping exported by RSSManager equals
        the mirror's own bookkeeping (both stamped from WAL commit seqs)."""
        from repro.core.replica import RSSManager
        eng = Engine("ssi")
        _run_workload(eng, seed=13, n=50)
        rss = RSSManager()
        rss.catch_up(eng.wal)
        snap = rss.construct()
        mirror = PagedMirror()
        mirror.catch_up(eng.wal)
        assert list(mirror.member_seqs_for(snap)) == rss.member_seqs(snap)

    def test_mirror_jnp_store_kernel_parity(self):
        """The exported device store serves the same member scan through the
        rss_gather Pallas kernel (interpret mode)."""
        from repro.kernels.rss_gather.ops import snapshot_read_members
        from repro.tensorstore.mirror import decode_value as dec
        eng = Engine("ssi")
        keys = _run_workload(eng, seed=2, n=30)
        mirror = PagedMirror(slots=64)
        mirror.catch_up(eng.wal)
        committed = [r.txn for r in eng.wal.records if r.type == "commit"]
        snap = RssSnapshot(lsn=eng.wal.head_lsn,
                           txns=frozenset(committed[::2]))
        store = mirror.jnp_store()
        member_ts = mirror.member_seqs_for(snap)
        out = np.asarray(snapshot_read_members(
            store, np.asarray(member_ts, np.int32)))
        want = mirror.scan_members(mirror.keys, snap)
        got = [dec(row) for row in out[:mirror.n_pages]]
        assert got == want


class TestEngineScan:
    def test_scan_equals_per_key_reads_si(self):
        eng = Engine("si")
        keys = _run_workload(eng, seed=3)
        t = eng.begin(read_only=True)
        assert eng.execute(t, ScanPlan(tuple(keys))) == \
            [eng.read(t, k) for k in keys]

    def test_scan_sees_own_writes(self):
        eng = Engine("si")
        t = eng.begin()
        eng.write(t, "k1", 42)
        assert eng.execute(t, ScanPlan(("k0", "k1"))) == [0, 42]

    def test_ssi_scan_falls_back_to_tracked_reads(self):
        """SSI-tracked transactions must take the per-key path so SIRead
        registration still observes every key."""
        eng = Engine("ssi")
        t = eng.begin(read_only=True)
        eng.execute(t, ScanPlan(("a", "b")))
        assert t.tid in eng.siread.get("a", set())
        assert t.tid in eng.siread.get("b", set())

    def test_rss_scan_has_no_siread_side_effects(self):
        eng = Engine("ssi")
        snap = RssSnapshot(lsn=0, txns=frozenset())
        t = eng.begin(read_only=True, rss=snap)
        eng.execute(t, ScanPlan(("a", "b")))
        assert "a" not in eng.siread and "b" not in eng.siread


SMALL = dict(oltp_clients=4, olap_clients=2, rounds=1200, seed=17)


class TestDriverScanPath:
    @pytest.mark.parametrize("mode", ["ssi", "ssi+safesnapshots", "ssi+rss"])
    def test_single_node_scan_matches_per_key_oracle(self, mode):
        m = run_single_node(olap_mode=mode, olap_scan=True, check_scans=True,
                            **SMALL)
        assert m.olap_scan_steps > 0
        assert m.olap_agg_steps > 0     # fused aggregates, parity-checked

    @pytest.mark.parametrize("mode", ["ssi+si", "ssi+rss"])
    def test_multi_node_scan_matches_per_key_oracle(self, mode):
        m = run_multi_node(olap_mode=mode, olap_scan=True, check_scans=True,
                           **SMALL)
        assert m.olap_scan_steps > 0
        assert m.olap_agg_steps > 0

    def test_single_node_paged_scan_matches_oracle_and_chain_run(self):
        m_paged = run_single_node(olap_mode="ssi+rss", olap_scan=True,
                                  paged_olap=True, check_scans=True, **SMALL)
        m_chain = run_single_node(olap_mode="ssi+rss", olap_scan=True,
                                  **SMALL)
        assert m_paged.olap_scan_steps > 0
        # the device-backed surface changes nothing observable
        assert m_paged.olap_outputs == m_chain.olap_outputs
        assert m_paged.olap_commits == m_chain.olap_commits

    def test_multi_node_paged_scan_matches_oracle_and_chain_run(self):
        m_paged = run_multi_node(olap_mode="ssi+rss", olap_scan=True,
                                 paged_olap=True, check_scans=True, **SMALL)
        m_chain = run_multi_node(olap_mode="ssi+rss", olap_scan=True,
                                 **SMALL)
        assert m_paged.olap_scan_steps > 0
        assert m_paged.olap_outputs == m_chain.olap_outputs

    def test_rss_scan_path_stays_wait_and_abort_free(self):
        m = run_single_node(olap_mode="ssi+rss", olap_scan=True, **SMALL)
        assert m.olap_aborts == 0 and m.olap_wait_rounds == 0
        assert m.olap_commits > 0

    def test_scan_path_multiplies_olap_throughput(self):
        m_scan = run_single_node(olap_mode="ssi+rss", olap_scan=True, **SMALL)
        m_key = run_single_node(olap_mode="ssi+rss", olap_scan=False, **SMALL)
        assert m_scan.olap_commits > 5 * max(m_key.olap_commits, 1)


class TestBatchedQueryShape:
    def test_batched_generators_yield_olap_plan_steps(self):
        from repro.tensorstore import (AggPlan, MultiAggPlan, Plan, ScanPlan,
                                       plan_keys)
        rng = random.Random(0)
        sc = Scale()
        seen = set()
        for _ in range(30):
            gen, name = olap_query(rng, sc, batched=True)
            step = gen.send(None)
            assert step[0] == "olap", name
            plan = step[1]
            assert isinstance(plan, Plan.__args__), name
            assert plan_keys(plan), name    # first step always reads keys
            seen.add(type(plan))
        # pure aggregates, compound aggregates, AND value scans (the
        # district passes that derive order key ranges) all appear in the
        # batched mix (GroupByPlan comes second in its query — after the
        # district scan — so it is not in the first-step set)
        assert {ScanPlan, AggPlan, MultiAggPlan} <= seen
