"""GC under pinned readers, over random publish/pin/release interleavings.

Three layers of the same hot_standby_feedback contract:
  1. `publish_page` (device store) never recycles the slot that is the
     newest visible at `gc_floor` — a pinned reader at that floor always
     resolves to its version,
  2. the WAL->mirror `_publish` twin keeps the identical guarantee,
  3. `PRoTManager.gc_floor_seq()` + `Engine.prune_versions` preserve every
     version any pinned `RssSnapshot` can still read (the prefix-safe floor
     of Algorithm 1 snapshots).

Seeded randomness (no hypothesis dependence) so the properties execute on
minimal containers; each seed is an independent interleaving.
"""

import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.wal import WalRecord
from repro.mvcc import SingleNodeHTAP
from repro.tensorstore import (PagedMirror, init_store, publish_page,
                               snapshot_read_ref, visible_slots)


def _floor_version(ts_row, floor):
    """(slot, ts) of the newest version at-or-below floor in a [K] ts row."""
    vis = [(t, k) for k, t in enumerate(ts_row) if t <= floor]
    t, k = max(vis, key=lambda tk: (tk[0], -tk[1]))
    return k, t


@pytest.mark.parametrize("seed", range(8))
def test_publish_page_never_recycles_floor_slot(seed):
    rng = random.Random(seed)
    P, K, E = 4, 3, 8
    store = init_store(P, K, E, jnp.float32)
    ts = 0
    # a pinned reader at a floor frozen partway through the interleaving
    floor, expected = 0, {p: 0.0 for p in range(P)}
    for step in range(40):
        ts += rng.randint(1, 3)
        p = rng.randrange(P)
        store = publish_page(store, p, jnp.full((E,), float(ts)),
                             jnp.int32(ts), gc_floor=floor)
        if step == 10:                      # pin: freeze the floor here
            floor = ts
            out = snapshot_read_ref(store, jnp.int32(floor))
            expected = {q: float(out[q][0]) for q in range(P)}
        if step >= 10:
            # the pinned reader still resolves every page to its version
            out = snapshot_read_ref(store, jnp.int32(floor))
            for q in range(P):
                assert float(out[q][0]) == expected[q], (seed, step, q)


@pytest.mark.parametrize("seed", range(8))
def test_mirror_publish_never_recycles_floor_slot(seed):
    rng = random.Random(seed)
    mirror = PagedMirror(slots=3, page_elems=8)
    keys = [f"k{i}" for i in range(4)]
    lsn = 0
    seq = 0
    floor, expected = 0, {}

    def commit(key, value, gc_floor):
        nonlocal lsn, seq
        lsn += 1
        seq += 1
        mirror.apply(WalRecord(lsn, "commit", seq, writes=((key, value),),
                               seq=seq), gc_floor=gc_floor)

    for step in range(40):
        commit(rng.choice(keys), rng.randrange(1000), gc_floor=floor)
        if step == 10:
            floor = seq
            expected = dict(zip(keys, mirror.scan_at(keys, floor)))
        if step >= 10:
            assert dict(zip(keys, mirror.scan_at(keys, floor))) == expected


@pytest.mark.parametrize("seed", range(10))
def test_prune_preserves_pinned_rss_reads(seed):
    """Random commit/refresh/pin/release/prune interleavings on the
    single-node HTAP system: after every prune at gc_floor_seq(), every
    still-pinned snapshot reads exactly the values recorded at pin time."""
    rng = random.Random(seed)
    htap = SingleNodeHTAP("ssi+rss")
    eng = htap.engine
    keys = [f"k{i}" for i in range(6)]
    pins = {}                    # rid -> (snap, expected values at pin time)

    def chain_read(snap, key):
        ch = eng.store.chains.get(key)
        return ch.visible_in(snap.visible).value if ch else 0

    for step in range(300):
        act = rng.random()
        if act < 0.5:                                   # writer commits
            t = eng.begin()
            for key in rng.sample(keys, rng.randint(1, 2)):
                eng.write(t, key, rng.randrange(1000))
            try:
                eng.commit(t)
            except Exception:
                pass
        elif act < 0.65:                                # RSS refresh
            htap.refresh_rss()
        elif act < 0.8:                                 # pin a reader
            rid, snap = htap.prot.acquire()
            pins[rid] = (snap, {k: chain_read(snap, k) for k in keys})
        elif act < 0.9 and pins:                        # release a reader
            rid = rng.choice(list(pins))
            htap.prot.release(rid)
            del pins[rid]
        else:                                           # version GC
            htap.gc_versions()
        # invariant: every pinned snapshot still reads its pin-time values
        for rid, (snap, expected) in pins.items():
            got = {k: chain_read(snap, k) for k in keys}
            assert got == expected, (seed, step, rid)
    # final prune with everything released must not crash reads
    for rid in list(pins):
        htap.prot.release(rid)
    htap.gc_versions()
    assert htap.engine.store.version_count() >= len(eng.store.chains)


def test_gc_floor_seq_tracks_minimum_pin():
    htap = SingleNodeHTAP("ssi+rss")
    eng = htap.engine
    for i in range(3):
        t = eng.begin()
        eng.write(t, "a", i)
        eng.commit(t)
    htap.refresh_rss()
    rid1, snap1 = htap.prot.acquire()
    floor1 = htap.prot.gc_floor_seq()
    assert floor1 == snap1.floor_seq > 0
    for i in range(3):
        t = eng.begin()
        eng.write(t, "a", 10 + i)
        eng.commit(t)
    htap.refresh_rss()
    rid2, snap2 = htap.prot.acquire()
    assert snap2.floor_seq > snap1.floor_seq
    assert htap.prot.gc_floor_seq() == snap1.floor_seq   # min over pins
    htap.prot.release(rid1)
    assert htap.prot.gc_floor_seq() == snap2.floor_seq
    htap.prot.release(rid2)


@pytest.mark.parametrize("seed", range(4))
def test_sustained_load_state_bounded_with_pins(seed):
    """Acceptance: under a sustained workload with refresh_rss (state GC +
    WAL truncation) every round, RSSManager per-txn state, engine.txns and
    the primary WAL stay bounded by the active/pinned window — and no
    pinned reader's reads change."""
    rng = random.Random(seed)
    htap = SingleNodeHTAP("ssi+rss")
    eng = htap.engine
    keys = [f"k{i}" for i in range(6)]
    pins = {}
    peaks = {"rss": 0, "txns": 0, "wal": 0}
    for step in range(1200):
        t = eng.begin()
        for key in rng.sample(keys, rng.randint(1, 2)):
            eng.write(t, key, rng.randrange(1000))
        try:
            eng.commit(t)
        except Exception:
            pass
        if step % 7 == 0:
            htap.refresh_rss()
        if rng.random() < 0.1 and len(pins) < 3:
            rid, snap = htap.prot.acquire()
            pins[rid] = (step, snap,
                         {k: eng.version_store.read_members(k, snap)
                          for k in keys})
        for rid in [r for r, (born, _, _) in pins.items()
                    if step - born > 25 or rng.random() < 0.05]:
            htap.prot.release(rid)
            del pins[rid]
        peaks["rss"] = max(peaks["rss"], htap.rss_manager.tracked_txns())
        peaks["txns"] = max(peaks["txns"], len(eng.txns))
        peaks["wal"] = max(peaks["wal"], len(eng.wal.records))
        for rid, (_, snap, expected) in pins.items():
            got = {k: eng.version_store.read_members(k, snap) for k in keys}
            assert got == expected, (seed, step, rid)
    # bounded by the pinned/active window, not the 1200-commit history
    assert peaks["rss"] < 120, peaks
    assert peaks["txns"] < 120, peaks
    assert peaks["wal"] < 120, peaks


def test_prune_versions_respects_floor_visibility():
    """Direct contract: prune at a snapshot's floor keeps the version the
    snapshot resolves to on every key (prefix-safety of floor_seq)."""
    htap = SingleNodeHTAP("ssi+rss")
    eng = htap.engine
    for i in range(5):
        t = eng.begin()
        eng.write(t, "x", i)
        eng.commit(t)
    htap.refresh_rss()
    rid, snap = htap.prot.acquire()
    want = eng.store.chains["x"].visible_in(snap.visible).value
    eng.prune_versions(htap.prot.gc_floor_seq())
    assert eng.store.chains["x"].visible_in(snap.visible).value == want
    htap.prot.release(rid)
