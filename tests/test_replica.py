"""WAL / replica / versioned-store behaviour."""

import pytest

from repro.core import RSSManager, PRoTManager, Wal, WalRecord, replicate
from repro.tensorstore import VersionedParamStore


class TestWal:
    def test_roundtrip(self, tmp_path):
        wal = Wal()
        wal.log_begin(1)
        wal.log_commit(1, [("k", 5)])
        wal.log_deps(2, [1, 3])
        p = str(tmp_path / "wal.jsonl")
        wal.dump(p)
        wal2 = Wal.load(p)
        assert wal2.records == wal.records

    def test_tail_streams_increments(self):
        wal = Wal()
        wal.log_begin(1)
        assert len(list(wal.tail(0))) == 1
        assert len(list(wal.tail(1))) == 0
        wal.log_commit(1)
        assert len(list(wal.tail(1))) == 1

    def test_truncate_recycles_prefix(self):
        wal = Wal()
        for i in range(1, 5):
            wal.log_begin(i)
        assert wal.truncate(2) == 2
        assert wal.base_lsn == 2 and wal.head_lsn == 4
        assert [r.lsn for r in wal.tail(2)] == [3, 4]
        wal.log_begin(9)
        assert wal.records[-1].lsn == 5          # LSNs keep counting
        with pytest.raises(LookupError):
            list(wal.tail(1))                    # prefix is gone
        assert wal.truncate(99) == 3             # clamps at head

    def test_truncated_dump_load_roundtrip(self, tmp_path):
        wal = Wal()
        wal.log_begin(1); wal.log_commit(1, [("k", 5)]); wal.log_begin(2)
        wal.truncate(1)
        p = str(tmp_path / "wal.jsonl")
        wal.dump(p)
        wal2 = Wal.load(p)
        assert wal2.base_lsn == 1
        assert wal2.records == wal.records
        assert [r.lsn for r in wal2.tail(1)] == [2, 3]

    def test_fully_truncated_dump_load_keeps_lsn_clock(self, tmp_path):
        """A WAL truncated down to zero records must reload with its LSN
        clock intact — otherwise fresh appends reuse old LSNs and resumed
        consumers silently drop them via the idempotent-replay guard."""
        wal = Wal()
        wal.log_begin(1); wal.log_commit(1); wal.log_begin(2)
        wal.truncate(3)
        assert not wal.records and wal.head_lsn == 3
        p = str(tmp_path / "wal.jsonl")
        wal.dump(p)
        wal2 = Wal.load(p)
        assert wal2.base_lsn == 3 and wal2.head_lsn == 3
        assert wal2.log_begin(9).lsn == 4              # clock continues


class TestRSSManager:
    def test_idempotent_replay(self):
        wal = Wal()
        wal.log_begin(1); wal.log_commit(1)
        m = RSSManager()
        m.catch_up(wal)
        lsn = m.applied_lsn
        m.catch_up(wal)              # no-op
        assert m.applied_lsn == lsn
        for rec in wal.records:      # direct re-apply is also idempotent
            m.apply(rec)
        assert m.applied_lsn == lsn

    def test_batched_lag(self):
        wal = Wal()
        for i in range(1, 6):
            wal.log_begin(i); wal.log_commit(i)
        m = RSSManager()
        snap = replicate(wal, m, batch=3)
        assert m.applied_lsn == 3
        snap = replicate(wal, m)
        assert m.applied_lsn == 10
        # all five commits are Clear members, folded into the floor
        assert all(m.is_member(t, snap) for t in range(1, 6))
        assert snap.floor_seq == m.commit_seq[5]
        assert snap.txns == frozenset()      # nothing above the floor

    def test_active_txn_blocks_clear(self):
        wal = Wal()
        wal.log_begin(1)             # stays active
        wal.log_begin(2); wal.log_commit(2)
        m = RSSManager()
        m.catch_up(wal)
        assert m.clear() == set()    # T2 concurrent with active T1
        snap = m.construct()
        assert snap.txns == frozenset() and snap.floor_seq == 0
        assert not m.is_member(2, snap)

    def test_deps_pull_obscure_txn_into_rss(self):
        wal = Wal()
        wal.log_begin(1); wal.log_commit(1)           # T1 clear
        wal.log_begin(2)
        wal.log_begin(3)                              # active
        wal.log_commit(2)
        wal.log_deps(2, [1])                          # T2 -rw-> T1 (clear)
        m = RSSManager()
        m.catch_up(wal)
        assert m.clear() == {1}
        snap = m.construct()
        assert m.is_member(1, snap) and m.is_member(2, snap)
        # T2 is commit-seq contiguous with T1, so both fold into the floor
        assert snap.floor_seq == m.commit_seq[2]
        assert snap.txns == frozenset()

    def test_pulled_member_above_floor_stays_explicit(self):
        """A pulled member separated from the floor by a non-member keeps
        its id/seq in the compressed snapshot's above-floor set."""
        wal = Wal()
        wal.log_begin(1); wal.log_commit(1)           # T1 clear
        wal.log_begin(5)                              # active forever
        wal.log_begin(2); wal.log_commit(2)           # obscure, not pulled
        wal.log_begin(4); wal.log_commit(4)           # obscure...
        wal.log_deps(4, [1])                          # ...pulled via T1
        m = RSSManager()
        m.catch_up(wal)
        snap = m.construct()
        assert m.is_member(1, snap)
        assert not m.is_member(2, snap)               # gap non-member
        assert m.is_member(4, snap)
        assert snap.floor_seq == m.commit_seq[1]      # blocked by T2
        assert set(snap.txns) == {4}
        assert snap.member_seqs == (m.commit_seq[4],)

    def test_legacy_seq_fallback_never_regresses(self):
        """Mixing seq-stamped and legacy commit records must not mint a
        fallback seq that collides with or regresses below shipped seqs
        (a dense local clock corrupted floor_seq)."""
        wal = Wal()
        wal.log_begin(1); wal.log_commit(1, seq=7)    # shipped seq
        wal.log_begin(2); wal.log_commit(2)           # legacy record
        wal.log_begin(3); wal.log_commit(3, seq=12)
        wal.log_begin(4); wal.log_commit(4)           # legacy again
        m = RSSManager()
        m.catch_up(wal)
        assert m.commit_seq[2] == 8                   # max(seen) + 1, not 2
        assert m.commit_seq[4] == 13
        seqs = [m.commit_seq[t] for t in (1, 2, 3, 4)]
        assert seqs == sorted(seqs) and len(set(seqs)) == 4
        snap = m.construct()
        assert snap.floor_seq == 13                   # all Clear, monotone

    def test_stamped_seq_colliding_with_minted_fallback_is_bumped(self):
        """The converse collision: a legacy record mints max(seen)+1, then
        the primary ships that very seq for a later commit.  The shared
        clock (effective_commit_seq) re-stamps it strictly above everything
        seen, so an obscure non-member can never become floor-covered and
        all WAL consumers stay bit-identical."""
        from repro.tensorstore import PagedMirror
        wal = Wal()
        wal.log_begin(1); wal.log_commit(1, seq=7, writes=[("k", 1)])
        wal.log_begin(2); wal.log_commit(2, writes=[("k", 2)])  # minted 8
        wal.log_begin(9)                               # stays active
        wal.log_begin(3)
        wal.log_commit(3, seq=8, writes=[("k", 3)])    # primary's own 8!
        m = RSSManager()
        m.catch_up(wal)
        assert m.commit_seq[2] == 8
        assert m.commit_seq[3] == 9                    # bumped, no collision
        snap = m.construct()
        assert snap.floor_seq == 8                     # T1, T2 Clear
        # T3 is obscure (concurrent with active T9): must NOT be a member,
        # and in particular must not be floor-covered via the collision
        assert not m.is_member(3, snap)
        mirror = PagedMirror()
        mirror.catch_up(wal)
        assert mirror.commit_seq == m.commit_seq       # consumers agree
        assert mirror.read_members("k", snap) == 2     # T2's write, not T3's


class TestPRoTManager:
    def test_pin_release_gc_floor(self):
        wal = Wal()
        wal.log_begin(1); wal.log_commit(1)
        m = RSSManager(); m.catch_up(wal); m.construct()
        prot = PRoTManager(m)
        rid, snap = prot.acquire()
        assert snap.visible(1, m.commit_seq[1])   # floor-covered member
        assert m.is_member(1, snap)
        assert prot.gc_floor() == snap.lsn
        prot.release(rid)
        assert prot.pinned == 0


class TestRSSManagerGC:
    def test_state_pruned_below_pins_and_horizon(self):
        wal = Wal()
        for i in range(1, 51):
            wal.log_begin(i); wal.log_commit(i, seq=i)
        m = RSSManager(); m.catch_up(wal); m.construct()
        prot = PRoTManager(m)
        rid, pinned = prot.acquire()
        assert m.tracked_txns() == 50
        m.gc(keep_lsn=prot.gc_floor(), keep_seq=prot.gc_floor_seq())
        assert m.tracked_txns() == 0            # everything Clear + folded
        # pruned ids still answer membership via the floor
        assert all(m.is_member(t, pinned) for t in range(1, 51))
        prot.release(rid)

    def test_gc_preserves_pinned_visibility_and_future_construction(self):
        wal = Wal()
        wal.log_begin(1); wal.log_commit(1, seq=1)
        wal.log_begin(2)                              # long-running active
        wal.log_begin(3); wal.log_commit(3, seq=2)    # obscure (conc. w/ T2)
        m = RSSManager(); m.catch_up(wal)
        snap = m.construct()
        prot = PRoTManager(m)
        rid, _ = prot.acquire()
        m.gc(keep_lsn=prot.gc_floor(), keep_seq=prot.gc_floor_seq())
        assert 2 in m.begun and 3 in m.begun          # active+obscure kept
        assert 1 not in m.begun                       # clear member pruned
        # T3's deps edge into pruned-Clear T1 still pulls T3 in, even with
        # T2 active (T3 stays obscure: membership comes from the pull alone)
        wal.log_deps(3, [1])
        m.catch_up(wal)
        snap2 = m.construct()
        assert m.is_member(3, snap2)
        assert m.stats["edges_pruned_pull"] == 1
        assert snap2.floor_seq >= snap.floor_seq      # floor is monotone


class TestVersionedParamStore:
    def test_wait_free_publish_under_pin(self):
        store = VersionedParamStore(slots=2)
        store.publish({"w": 1}); store.refresh()
        pin, params = store.pin_snapshot()
        assert params == {"w": 1}
        # publisher keeps going; never blocks, ring may grow
        for i in range(2, 6):
            store.publish({"w": i})
        _, params2 = store.pin_snapshot()
        assert params2 == {"w": 1}            # watermark not refreshed yet
        store.refresh()
        _, params3 = store.pin_snapshot()
        assert params3 == {"w": 5}
        # the original pin still reads its version (no abort, no invalidation)
        assert store.slots[store._pins[pin]].params == {"w": 1}

    def test_freshness_lag_metric(self):
        store = VersionedParamStore(slots=2)
        store.publish({"w": 0}); store.refresh()
        for i in range(3):
            store.publish({"w": i})
        assert store.freshness_lag() > 0
        store.refresh()
        assert store.freshness_lag() == 0
