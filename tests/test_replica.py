"""WAL / replica / versioned-store behaviour."""

import pytest

from repro.core import RSSManager, PRoTManager, Wal, WalRecord, replicate
from repro.tensorstore import VersionedParamStore


class TestWal:
    def test_roundtrip(self, tmp_path):
        wal = Wal()
        wal.log_begin(1)
        wal.log_commit(1, [("k", 5)])
        wal.log_deps(2, [1, 3])
        p = str(tmp_path / "wal.jsonl")
        wal.dump(p)
        wal2 = Wal.load(p)
        assert wal2.records == wal.records

    def test_tail_streams_increments(self):
        wal = Wal()
        wal.log_begin(1)
        assert len(list(wal.tail(0))) == 1
        assert len(list(wal.tail(1))) == 0
        wal.log_commit(1)
        assert len(list(wal.tail(1))) == 1


class TestRSSManager:
    def test_idempotent_replay(self):
        wal = Wal()
        wal.log_begin(1); wal.log_commit(1)
        m = RSSManager()
        m.catch_up(wal)
        lsn = m.applied_lsn
        m.catch_up(wal)              # no-op
        assert m.applied_lsn == lsn
        for rec in wal.records:      # direct re-apply is also idempotent
            m.apply(rec)
        assert m.applied_lsn == lsn

    def test_batched_lag(self):
        wal = Wal()
        for i in range(1, 6):
            wal.log_begin(i); wal.log_commit(i)
        m = RSSManager()
        snap = replicate(wal, m, batch=3)
        assert m.applied_lsn == 3
        snap = replicate(wal, m)
        assert m.applied_lsn == 10
        assert set(snap.txns) == {1, 2, 3, 4, 5}

    def test_active_txn_blocks_clear(self):
        wal = Wal()
        wal.log_begin(1)             # stays active
        wal.log_begin(2); wal.log_commit(2)
        m = RSSManager()
        m.catch_up(wal)
        assert m.clear() == set()    # T2 concurrent with active T1
        assert m.construct().txns == frozenset()

    def test_deps_pull_obscure_txn_into_rss(self):
        wal = Wal()
        wal.log_begin(1); wal.log_commit(1)           # T1 clear
        wal.log_begin(2)
        wal.log_begin(3)                              # active
        wal.log_commit(2)
        wal.log_deps(2, [1])                          # T2 -rw-> T1 (clear)
        m = RSSManager()
        m.catch_up(wal)
        assert m.clear() == {1}
        assert set(m.construct().txns) == {1, 2}


class TestPRoTManager:
    def test_pin_release_gc_floor(self):
        wal = Wal()
        wal.log_begin(1); wal.log_commit(1)
        m = RSSManager(); m.catch_up(wal); m.construct()
        prot = PRoTManager(m)
        rid, snap = prot.acquire()
        assert snap.visible(1)
        assert prot.gc_floor() == snap.lsn
        prot.release(rid)
        assert prot.pinned == 0


class TestVersionedParamStore:
    def test_wait_free_publish_under_pin(self):
        store = VersionedParamStore(slots=2)
        store.publish({"w": 1}); store.refresh()
        pin, params = store.pin_snapshot()
        assert params == {"w": 1}
        # publisher keeps going; never blocks, ring may grow
        for i in range(2, 6):
            store.publish({"w": i})
        _, params2 = store.pin_snapshot()
        assert params2 == {"w": 1}            # watermark not refreshed yet
        store.refresh()
        _, params3 = store.pin_snapshot()
        assert params3 == {"w": 5}
        # the original pin still reads its version (no abort, no invalidation)
        assert store.slots[store._pins[pin]].params == {"w": 1}

    def test_freshness_lag_metric(self):
        store = VersionedParamStore(slots=2)
        store.publish({"w": 0}); store.refresh()
        for i in range(3):
            store.publish({"w": i})
        assert store.freshness_lag() > 0
        store.refresh()
        assert store.freshness_lag() == 0
