"""rss_gather kernel parity: Pallas (interpret) == jnp oracle == per-page
python scan, over randomized (P, K, E, M) shapes INCLUDING the empty member
set — plus the paged.py empty-member-set regression.  (Seeded numpy
randomness: runs even without hypothesis installed.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rss_gather.kernel import rss_gather
from repro.kernels.rss_gather.ops import snapshot_read_members as op_members
from repro.kernels.rss_gather.ref import rss_gather_ref
from repro.tensorstore import (init_store, publish_page,
                               snapshot_read_members, visible_slots_members)


def _python_oracle(data, ts, members, floor=0):
    """Independent per-page scan: newest slot with ts<=floor or ts in
    members, ties toward the lowest slot index; all-invisible pages ->
    slot 0."""
    P, K, _ = data.shape
    mset = set(int(m) for m in members)
    out = np.empty((P, data.shape[2]), data.dtype)
    for p in range(P):
        best, best_ts = 0, -1
        for k in range(K):
            t = int(ts[p, k])
            if (t <= floor or t in mset) and t > best_ts:
                best, best_ts = k, t
        out[p] = data[p, best]
    return out


SHAPES = [(8, 2, 128), (16, 4, 256), (32, 3, 128), (8, 8, 512)]


@pytest.mark.parametrize("P,K,E", SHAPES)
@pytest.mark.parametrize("M", [0, 1, 7, 150])
def test_kernel_matches_oracles(P, K, E, M):
    rng = np.random.default_rng(P * K + M)
    data = rng.standard_normal((P, K, E)).astype(np.float32)
    ts = rng.integers(0, 60, (P, K)).astype(np.int32)
    members = np.sort(rng.choice(np.arange(1, 60), size=min(M, 59),
                                 replace=False)).astype(np.int32)
    out = np.asarray(rss_gather(jnp.asarray(data), jnp.asarray(ts),
                                jnp.asarray(members)))
    ref = np.asarray(rss_gather_ref(jnp.asarray(data), jnp.asarray(ts),
                                    jnp.asarray(members)))
    py = _python_oracle(data, ts, members)
    np.testing.assert_array_equal(out, ref)      # kernel == jnp oracle
    np.testing.assert_array_equal(out, py)       # kernel == python scan


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_kernel_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    data = (jax.random.normal(key, (16, 4, 256)) * 10).astype(dtype)
    ts = jax.random.randint(jax.random.fold_in(key, 1), (16, 4), 0, 30)
    members = jnp.asarray([3, 11, 19, 27], jnp.int32)
    out = rss_gather(data, ts, members)
    ref = rss_gather_ref(data, ts, members)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(ref, np.float32))


def test_empty_member_set_resolves_initial_slots():
    """Regression: the searchsorted formulation indexed garbage for M == 0;
    an empty RSS must read every page's initial (ts=0) version."""
    store = init_store(4, 3, 8, jnp.float32,
                       initial=jnp.arange(32.0).reshape(4, 8))
    store = publish_page(store, 1, jnp.full((8,), 9.0), jnp.int32(10))
    store = publish_page(store, 2, jnp.full((8,), 7.0), jnp.int32(20))
    empty = jnp.zeros((0,), jnp.int32)
    # jnp fallback in tensorstore.paged
    idx = visible_slots_members(store["ts"], empty)
    np.testing.assert_array_equal(np.asarray(idx), np.zeros(4, np.int32))
    out = snapshot_read_members(store, empty)
    np.testing.assert_allclose(np.asarray(out),
                               np.arange(32.0).reshape(4, 8))
    # Pallas kernel path agrees
    kout = op_members(store, empty)
    np.testing.assert_allclose(np.asarray(kout), np.asarray(out))


def test_member_read_skips_non_member_version():
    store = init_store(1, 3, 8, jnp.float32)
    store = publish_page(store, 0, jnp.full((8,), 1.0), jnp.int32(10))
    store = publish_page(store, 0, jnp.full((8,), 2.0), jnp.int32(20))
    members = jnp.asarray([10], jnp.int32)           # ts=20 not a member
    out = op_members(store, members)
    assert float(out[0, 0]) == 1.0
    ref = snapshot_read_members(store, members)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("P,K,E", SHAPES[:2])
@pytest.mark.parametrize("M", [0, 5])
@pytest.mark.parametrize("floor", [0, 13, 59])
def test_floor_compressed_membership(P, K, E, M, floor):
    """Compressed-snapshot visibility: ts <= floor is always a member's
    version, with the explicit member array only covering the above-floor
    window — kernel == jnp oracle == python scan."""
    rng = np.random.default_rng(P + M + floor)
    data = rng.standard_normal((P, K, E)).astype(np.float32)
    ts = rng.integers(0, 60, (P, K)).astype(np.int32)
    members = np.sort(rng.choice(np.arange(floor + 1, floor + 60),
                                 size=M, replace=False)).astype(np.int32)
    out = np.asarray(rss_gather(jnp.asarray(data), jnp.asarray(ts),
                                jnp.asarray(members), floor))
    ref = np.asarray(rss_gather_ref(jnp.asarray(data), jnp.asarray(ts),
                                    jnp.asarray(members), floor))
    py = _python_oracle(data, ts, members, floor)
    np.testing.assert_array_equal(out, ref)
    np.testing.assert_array_equal(out, py)
    # paged.py host path agrees
    idx = visible_slots_members(jnp.asarray(ts), jnp.asarray(members), floor)
    np.testing.assert_array_equal(
        np.take_along_axis(data, np.asarray(idx)[:, None, None], 1)[:, 0],
        py)


def test_floor_equivalence_to_explicit_members():
    """A floor is exactly equivalent to enumerating every committed seq at
    or below it in the member array (the uncompressed representation)."""
    rng = np.random.default_rng(3)
    data = rng.standard_normal((16, 4, 64)).astype(np.float32)
    ts = rng.integers(0, 40, (16, 4)).astype(np.int32)
    above = np.asarray([25, 31, 39], np.int32)
    floor = 20
    explicit = np.asarray(sorted(set(range(1, floor + 1)) | set(above)),
                          np.int32)
    a = np.asarray(rss_gather(jnp.asarray(data), jnp.asarray(ts),
                              jnp.asarray(above), floor))
    b = np.asarray(rss_gather(jnp.asarray(data), jnp.asarray(ts),
                              jnp.asarray(explicit), 0))
    np.testing.assert_array_equal(a, b)
